"""Paper Fig. 13: bit-width sweep — model size (exponential shrink) and
quantization error (the UInt3 cliff). Also measures integer-QNet inference
wall time on this host for one design point."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_us
from repro.core import cu, qnet as Q
from repro.core.calibrate import calibrate
from repro.core.quant import QuantConfig
from repro.models import layers, mobilenet_v2 as mnv2


def run():
    # model size vs BW (Fig 13b)
    for bw in (3, 4, 5, 6, 8, 32):
        net = mnv2.build(alpha=0.75, input_hw=160, bits=min(bw, 32))
        mib = (net.n_params(False) * bw) / 8 / 2**20
        row(f"fig13_size_bw{bw}", 0.0, f"{mib:.2f}MiB ratio={32/bw:.1f}x")

    # weight quantization error vs BW (Fig 13a proxy: SQNR)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(3, 3, 32, 64)) * 0.1, jnp.float32)
    for bw in (3, 4, 5, 6, 8):
        from repro.core.quant import fake_quant_minmax
        wq = fake_quant_minmax(w, QuantConfig(bw, symmetric=True, channel_axis=-1))
        err = float(jnp.mean((w - wq) ** 2))
        sqnr = 10 * np.log10(float(jnp.mean(w**2)) / max(err, 1e-12))
        row(f"fig13_sqnr_bw{bw}", 0.0, f"{sqnr:.1f}dB")

    # integer inference wall time (this host, CPU) for one design point
    net = mnv2.build(alpha=0.35, input_hw=32, num_classes=10)
    params = layers.init_params(jax.random.PRNGKey(0), net)

    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]

    batches = [jax.random.uniform(jax.random.PRNGKey(i), (1, 32, 32, 3),
                                  minval=-1, maxval=1) for i in range(2)]
    obs = calibrate(apply_fn, params, batches, QuantConfig(4, False, None))
    qn = Q.quantize_net(params, net, obs)
    run_q = jax.jit(lambda x: cu.run_qnet(qn, x))
    run_f = jax.jit(lambda x: layers.forward(params, x, net)[0])
    us_q = time_us(run_q, batches[0])
    us_f = time_us(run_f, batches[0])
    row("qnet_int_inference", us_q, f"float={us_f:.0f}us host-cpu")


if __name__ == "__main__":
    run()
