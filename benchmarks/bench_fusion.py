"""Paper Sec. 5.1.2 claim (2)(3): fused Body-CU execution removes the
shared-memory round trips of the expanded intermediate tensors.

For every IRB of MobileNet-V2 (alpha=0.75, H=224) we account HBM traffic:
  unfused: in + expand_out + expand_in + dw_out + dw_in + project_out
  fused  : in + project_out            (+ weights, both cases)
and report the per-block and whole-network traffic reduction. This is the
quantity the Pallas fused_irb kernel realizes on TPU (intermediates live in
VMEM only) — verified bit-exact vs the unfused path in
tests/test_kernels_fused_irb.py.
"""
from __future__ import annotations

from benchmarks.common import row
from repro.models import mobilenet_v2 as mnv2


def run(alpha=0.75, hw=224, act_bits=4):
    net = mnv2.build(alpha=alpha, input_hw=hw, bits=4)
    h = net.input_hw
    tot_unfused = tot_fused = 0
    for blk in net.blocks:
        sizes = []
        for op in blk.ops:
            if op.kind == "dense":
                continue
            h_out = -(-h // op.stride)
            sizes.append((h * h * op.in_ch, h_out * h_out * op.out_ch))
            h = h_out
        if len(blk.ops) == 3 and blk.name.startswith("irb"):
            s_in = sizes[0][0]
            s_out = sizes[-1][1]
            inter = sizes[0][1] + sizes[1][1]  # expand out + dw out
            unfused = (s_in + 2 * inter + s_out) * act_bits // 8
            fused = (s_in + s_out) * act_bits // 8
            tot_unfused += unfused
            tot_fused += fused
            row(f"fusion_{blk.name}", 0.0,
                f"unfused={unfused/1e3:.0f}KB fused={fused/1e3:.0f}KB "
                f"reduction={unfused/max(fused,1):.2f}x")
        else:
            for (si, so) in sizes:
                b = (si + so) * act_bits // 8
                tot_unfused += b
                tot_fused += b
    row("fusion_total", 0.0,
        f"unfused={tot_unfused/1e6:.2f}MB fused={tot_fused/1e6:.2f}MB "
        f"net_reduction={tot_unfused/tot_fused:.2f}x")


if __name__ == "__main__":
    run()
