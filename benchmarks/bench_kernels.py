"""Kernel-level microbenchmarks: the Pallas kernels against their XLA
oracles on this host. Pallas interpret mode is a correctness vehicle (Python
execution), so wall time is reported for the COMPILED path on this backend —
off-TPU that is the integer fast-path formulation each kernel mirrors
(`int_depthwise_shifts`, exactness-gated f32 matmul) vs the reference XLA
integer op it replaces; the derived column carries the kernel's analytic
VMEM/HBM accounting for the TPU target."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_us
from repro.core import integer_ops as IO
from repro.kernels import ref


def run():
    results = {}
    rng = np.random.default_rng(0)
    # depthwise: paper Eq. 8 geometry (K=3, widest MobileNet-V2 dw layer)
    c = 192
    x = jnp.asarray(rng.integers(0, 16, (1, 56, 56, c)), jnp.int32)
    w = jnp.asarray(rng.integers(-7, 8, (3, 3, c)), jnp.int32)
    mult = jnp.ones(c, jnp.float32) * 0.01
    zc = jnp.zeros(c, jnp.float32)
    b = jnp.zeros(c, jnp.int32)
    f = jax.jit(lambda *a: ref.depthwise_conv_q_ref(*a))
    us_ref = time_us(f, x, w, mult, zc, b)
    # the compiled fast-path formulation the row-tiled kernel mirrors off-TPU
    g = jax.jit(lambda x, w: IO.int_depthwise_shifts(x, w))
    us_fast = time_us(g, x, w)
    # row-tiled kernel HBM accounting: raw input + output + weights; the old
    # jnp.pad path additionally materialized the padded copy in HBM
    hbm_raw = (x.size + 56 * 56 * c) * 1 + w.size
    hbm_padded_copy = 58 * 58 * c
    results["dw_ref_us"] = us_ref
    results["dw_fast_us"] = us_fast
    results["dw_speedup"] = us_ref / us_fast if us_fast else 0.0
    results["dw_hbm_bytes"] = hbm_raw
    results["dw_hbm_bytes_saved_vs_padded"] = hbm_padded_copy
    row("kernel_depthwise_56x56x192", us_ref,
        f"hbm_bytes={hbm_raw/1e3:.0f}KB parallel_ops={9*c}")
    row("kernel_depthwise_shifts_fastpath", us_fast,
        f"speedup_vs_int_conv={us_ref/us_fast:.1f}x "
        f"row_tiled_hbm_saves={hbm_padded_copy/1e3:.0f}KB_pad_copy")

    # pointwise CU: the MACs-dominant op class (MobileNet-V2 expand/project)
    m, k, n = 28 * 28, 96, 576
    xq = jnp.asarray(rng.integers(0, 16, (m, k)), jnp.int32)
    wq = jnp.asarray(rng.integers(-7, 8, (k, n)), jnp.int32)
    multp = jnp.ones(n, jnp.float32) * 0.01
    bp = jnp.zeros(n, jnp.int32)
    pw_ref = jax.jit(lambda x, w: IO.quantized_op_epilogue(
        IO.int_pointwise(x, w), z_x=jnp.int32(0), wsum=w.sum(0),
        bias_q=bp, mult=multp, qmax=15))
    us_ref = time_us(pw_ref, xq, wq)
    pw_fast = jax.jit(lambda x, w: IO.quantized_op_epilogue(
        IO.int_pointwise_f32(x, w), z_x=jnp.int32(0), wsum=w.sum(0),
        bias_q=bp, mult=multp, qmax=15))
    us_fast = time_us(pw_fast, xq, wq)
    results["pw_ref_us"] = us_ref
    results["pw_fast_us"] = us_fast
    results["pw_speedup"] = us_ref / us_fast if us_fast else 0.0
    results["pw_hbm_bytes"] = m * (k + n) + k * n
    row("kernel_pointwise_784x96x576", us_ref,
        f"hbm_bytes={(m*(k+n)+k*n)/1e3:.0f}KB mxu_tiles={-(-m//128)*-(-n//128)}")
    row("kernel_pointwise_f32exact_fastpath", us_fast,
        f"speedup_vs_int_dot={us_ref/us_fast:.1f}x epilogue=fused")

    # fused IRB vs unfused traffic (the Body CU)
    cc, e, co = 32, 192, 32
    x = jnp.asarray(rng.integers(0, 16, (1, 28, 28, cc)), jnp.int32)
    w1 = jnp.asarray(rng.integers(-7, 8, (cc, e)), jnp.int32)
    w2 = jnp.asarray(rng.integers(-7, 8, (3, 3, e)), jnp.int32)
    w3 = jnp.asarray(rng.integers(-7, 8, (e, co)), jnp.int32)
    def mk(n):
        return (jnp.ones(n, jnp.float32) * 0.01, jnp.zeros(n, jnp.float32),
                jnp.zeros(n, jnp.int32))
    m1, c1, b1 = mk(e)
    m2, c2, b2 = mk(e)
    m3, c3, b3 = mk(co)
    g = jax.jit(lambda *a: ref.fused_irb_q_ref(*a))
    us = time_us(g, x, w1, m1, c1, b1, w2, m2, c2, b2, w3, m3, c3, b3)
    s_io = (28 * 28 * (cc + co))
    s_int = 2 * (28 * 28 * e)
    results["irb_us"] = us
    results["irb_bytes_saved_frac"] = s_int / (s_io + s_int)
    row("kernel_fused_irb_28x28", us,
        f"fused_saves={s_int/(s_io+s_int)*100:.0f}%_of_traffic "
        f"vmem_resident={28*30*e*4/1e3:.0f}KB_strip")

    # quantized matmul (LM linear, d=2048 -> 8192)
    xf = jnp.asarray(rng.normal(size=(256, 2048)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 128, (2048, 1024)), jnp.int8)
    sc = jnp.ones((1, 1024), jnp.float32) * 0.01
    h = jax.jit(lambda a, b, s: ref.quant_matmul_ref(a, b, s[0]))
    us = time_us(h, xf, wq, sc)
    results["qmm_us"] = us
    row("kernel_quant_matmul_256x2048x1024", us,
        f"w_bytes_int8={wq.size/1e6:.1f}MB vs_f32={wq.size*4/1e6:.1f}MB")
    return results


if __name__ == "__main__":
    run()
