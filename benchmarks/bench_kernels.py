"""Kernel-level microbenchmarks: the three Pallas kernels against their
XLA-compiled oracles on this host. Pallas interpret mode is a correctness
vehicle (Python execution), so wall time is reported for the ORACLE (XLA)
path; the derived column carries the kernel's analytic VMEM/HBM accounting
for the TPU target."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_us
from repro.kernels import ref


def run():
    rng = np.random.default_rng(0)
    # depthwise: paper Eq. 8 geometry (K=3, widest MobileNet-V2 dw layer)
    c = 192
    x = jnp.asarray(rng.integers(0, 16, (1, 56, 56, c)), jnp.int32)
    w = jnp.asarray(rng.integers(-7, 8, (3, 3, c)), jnp.int32)
    mult = jnp.ones(c, jnp.float32) * 0.01
    zc = jnp.zeros(c, jnp.float32)
    b = jnp.zeros(c, jnp.int32)
    f = jax.jit(lambda *a: ref.depthwise_conv_q_ref(*a))
    us = time_us(f, x, w, mult, zc, b)
    hbm = (x.size + 56 * 56 * c) * 1 + w.size
    row("kernel_depthwise_56x56x192", us,
        f"hbm_bytes={hbm/1e3:.0f}KB parallel_ops={9*c}")

    # fused IRB vs unfused traffic (the Body CU)
    cc, e, co = 32, 192, 32
    x = jnp.asarray(rng.integers(0, 16, (1, 28, 28, cc)), jnp.int32)
    w1 = jnp.asarray(rng.integers(-7, 8, (cc, e)), jnp.int32)
    w2 = jnp.asarray(rng.integers(-7, 8, (3, 3, e)), jnp.int32)
    w3 = jnp.asarray(rng.integers(-7, 8, (e, co)), jnp.int32)
    mk = lambda n: (jnp.ones(n, jnp.float32) * 0.01, jnp.zeros(n, jnp.float32),
                    jnp.zeros(n, jnp.int32))
    m1, c1, b1 = mk(e)
    m2, c2, b2 = mk(e)
    m3, c3, b3 = mk(co)
    g = jax.jit(lambda *a: ref.fused_irb_q_ref(*a))
    us = time_us(g, x, w1, m1, c1, b1, w2, m2, c2, b2, w3, m3, c3, b3)
    s_io = (28 * 28 * (cc + co))
    s_int = 2 * (28 * 28 * e)
    row("kernel_fused_irb_28x28", us,
        f"fused_saves={s_int/(s_io+s_int)*100:.0f}%_of_traffic "
        f"vmem_resident={28*30*e*4/1e3:.0f}KB_strip")

    # quantized matmul (LM linear, d=2048 -> 8192)
    xf = jnp.asarray(rng.normal(size=(256, 2048)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 128, (2048, 1024)), jnp.int8)
    sc = jnp.ones((1, 1024), jnp.float32) * 0.01
    h = jax.jit(lambda a, b, s: ref.quant_matmul_ref(a, b, s[0]))
    us = time_us(h, xf, wq, sc)
    row("kernel_quant_matmul_256x2048x1024", us,
        f"w_bytes_int8={wq.size/1e6:.1f}MB vs_f32={wq.size*4/1e6:.1f}MB")


if __name__ == "__main__":
    run()
