"""Beyond-paper: the paper's quantization applied to LM serving — measures
the weight-memory roofline win (bytes moved per decode step) for BW in
{bf16, int8, int4} and the host-CPU wall time of the dequant-matmul path."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_us
from repro.configs import reduced_config
from repro.models.lm import model as M

HBM = 819e9


def run():
    base = reduced_config("llama3.2-1b")
    sizes = {}
    for bits, name in ((None, "bf16"), (8, "int8"), (4, "int4")):
        cfg = dataclasses.replace(base, quant_bits=bits)
        params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
        nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
        sizes[name] = nbytes
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
        logits, cache = M.prefill(params, cfg, tokens, max_len=16)
        dec = jax.jit(lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos))
        us = time_us(dec, params, tokens[:, :1], cache, jnp.int32(8))
        # decode is weight-bound: per-step HBM time ~ param bytes / BW
        t_w = nbytes / HBM
        row(f"quant_serve_{name}", us,
            f"param_bytes={nbytes/1e6:.2f}MB roofline_decode_us={t_w*1e6:.2f}")
    row("quant_serve_compression", 0.0,
        f"int8={sizes['bf16']/sizes['int8']:.2f}x "
        f"int4={sizes['bf16']/sizes['int4']:.2f}x vs bf16")


if __name__ == "__main__":
    run()
