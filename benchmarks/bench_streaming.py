"""Streaming vs full-window serving: the O(hop) per-window claim, measured.

The workload is the edge-sensor deployment shape: one long sensor stream,
windows of W frames with hop H = W/8 (8x overlap — each frame is seen by
8 windows). Two ways to serve every window of the same calibrated 1-D
DSCNN:

  * full-window — `jax.jit(cu.run_qnet)` over the whole window, every hop:
    what a stateless deployment does; per-window cost O(W).
  * streaming   — `serve.stream.StreamEngine`: per-session integer ring
    buffers, recompute only the H new frames + per-layer SAME-pad halo;
    per-window cost O(H + halo).

Both routes are proven bit-exact on the measured stream before any timing
is reported (a fast-but-wrong stream would be worthless). Reports:

  * fps (windows/sec) for both routes and the speedup ratio — the
    headline gate (same machine, same trace, so the ratio is robust to
    host speed),
  * frames-computed-per-inference for both routes — the *deterministic*
    accounting of the claim (a pure function of the plan, no clocks),
  * per-session ring-buffer bytes and the session-table total at
    `n_sessions` concurrent streams.

Writes experiments/streaming.json and prints the usual CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.core import cu
from repro.models import dscnn1d, layers
from repro.serve import stream as ST

OUT_JSON = "experiments/streaming.json"


def _build_qnet(input_t: int, channels: int, n_blocks: int, kernel: int,
                input_ch: int, bits: int):
    net = dscnn1d.build_kws(
        input_t=input_t, input_ch=input_ch, channels=channels,
        n_blocks=n_blocks, kernel=kernel, bits=bits, num_classes=12)
    return layers.make_calibrated_qnet(net, seed=0)


def run(input_t: int = 2048, channels: int = 256, n_blocks: int = 5,
        kernel: int = 5, input_ch: int = 10, bits: int = 8,
        hop: int = 0, windows: int = 16, n_sessions: int = 8,
        repeats: int = 3, out: str = OUT_JSON) -> dict:
    """Measure streaming vs full-window FPS on one long stream.

    Both routes are warmed (XLA compilation paid) before any timer starts;
    the timed region is the steady state either deployment would sit in."""
    hop = hop or input_t // 8  # the 8x-overlap deployment shape
    qnet = _build_qnet(input_t, channels, n_blocks, kernel, input_ch, bits)
    plan = ST.plan_stream(qnet, hop)
    rng = np.random.default_rng(0)
    frames = rng.uniform(-1, 1, (ST.frames_for_windows(
        windows, input_t, hop), input_ch)).astype(np.float32)

    # -- full-window route: jitted monolithic inference per window --------
    pq = cu.prepare_qnet(qnet)
    full = jax.jit(lambda x: cu.run_qnet(pq, x))
    win = [frames[i * hop:i * hop + input_t][None]
           for i in range(windows)]
    ref = np.concatenate([np.asarray(jax.block_until_ready(full(w)))
                          for w in win])  # warm: pays compilation
    t_full = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for w in win[1:]:
            jax.block_until_ready(full(w))
        t_full = min(t_full, time.perf_counter() - t0)
    fps_full = (windows - 1) / t_full

    # -- streaming route: prime once, then one step per hop --------------
    eng = ST.StreamEngine(qnet, hop)
    eng.warm()  # pays both compilations outside the timed region
    got = None
    t_stream = float("inf")
    for r in range(repeats):
        sid = eng.open_session(f"bench{r}")
        res = eng.push(sid, frames[:input_t])  # prime
        t0 = time.perf_counter()
        for i in range(1, windows):
            res += eng.push(
                sid, frames[input_t + (i - 1) * hop:input_t + i * hop])
        t_stream = min(t_stream, time.perf_counter() - t0)
        eng.close_session(sid)
        got = np.stack([r_.logits for r_ in res])
    fps_stream = (windows - 1) / t_stream

    bit_exact = bool(got.shape == ref.shape and np.array_equal(got, ref))
    speedup = fps_stream / fps_full

    # session-table footprint at n_sessions concurrent primed streams
    eng_n = ST.StreamEngine(qnet, hop, max_sessions=n_sessions)
    for i in range(n_sessions):
        eng_n.push(eng_n.open_session(), frames[:input_t])
    table_bytes = eng_n.session_table_bytes()

    report = {
        "net": qnet.spec.name,
        "backend": jax.default_backend(),
        "window": input_t,
        "hop": hop,
        "overlap_x": input_t // hop,
        "channels": channels,
        "n_blocks": n_blocks,
        "kernel": kernel,
        "act_bits": bits,
        "windows_measured": windows - 1,
        "bit_exact_with_run_qnet": bit_exact,
        "fps_full_window": fps_full,
        "fps_streaming": fps_stream,
        "speedup_vs_full_window": speedup,
        "frames_computed_per_inference": plan.frames_step,
        "frames_full_window": plan.frames_full,
        "frames_ratio": plan.frames_full / plan.frames_step,
        "reuse_fraction": plan.reuse_fraction,
        "macs_per_window_full": plan.macs_full,
        "macs_per_window_step": plan.macs_step,
        "macs_ratio": plan.macs_full / plan.macs_step,
        "session_buffer_bytes": plan.buffer_bytes,
        "n_sessions": n_sessions,
        "session_table_bytes": table_bytes,
    }
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    row("stream_full_window_fps", 1e6 / fps_full, f"{fps_full:.1f}fps")
    row("stream_streaming_fps", 1e6 / fps_stream, f"{fps_stream:.1f}fps")
    row("stream_speedup", 0.0, f"{speedup:.2f}x")
    row("stream_frames_per_inference", 0.0,
        f"{plan.frames_step}/{plan.frames_full}")
    row("stream_bit_exact", 0.0, bit_exact)
    row("stream_session_table_bytes", 0.0, f"{table_bytes}B@{n_sessions}")
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--input-t", type=int, default=2048)
    ap.add_argument("--hop", type=int, default=0,
                    help="0 = window/8 (the 8x-overlap deployment shape)")
    ap.add_argument("--channels", type=int, default=256)
    ap.add_argument("--n-blocks", type=int, default=5)
    ap.add_argument("--kernel", type=int, default=5)
    ap.add_argument("--windows", type=int, default=16)
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=OUT_JSON)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(input_t=args.input_t, hop=args.hop, channels=args.channels,
        n_blocks=args.n_blocks, kernel=args.kernel, windows=args.windows,
        n_sessions=args.sessions, repeats=args.repeats, out=args.out)


if __name__ == "__main__":
    main()
