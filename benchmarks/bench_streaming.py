"""Streaming vs full-window serving: the O(hop) per-window claim, measured.

The workload is the edge-sensor deployment shape: one long sensor stream,
windows of W frames with hop H = W/8 (8x overlap — each frame is seen by
8 windows). Two ways to serve every window of the same calibrated 1-D
DSCNN:

  * full-window — `jax.jit(cu.run_qnet)` over the whole window, every hop:
    what a stateless deployment does; per-window cost O(W).
  * streaming   — `serve.stream.StreamEngine`: per-session integer ring
    buffers, recompute only the H new frames + per-layer SAME-pad halo;
    per-window cost O(H + halo).

Both routes are proven bit-exact on the measured stream before any timing
is reported (a fast-but-wrong stream would be worthless). Reports:

  * fps (windows/sec) for both routes and the speedup ratio — the
    headline gate (same machine, same trace, so the ratio is robust to
    host speed),
  * frames-computed-per-inference for both routes — the *deterministic*
    accounting of the claim (a pure function of the plan, no clocks),
  * per-session ring-buffer bytes and the session-table total at
    `n_sessions` concurrent streams.

Writes experiments/streaming.json and prints the usual CSV rows.

`run_batched` measures the FLEET shape on a smaller (realistic KWS-sized)
net: N concurrent sessions all advancing every hop. serial = the PR-7
path, one jitted step dispatch per session per hop; batched = `drain()`
grouping every ready session into one bucketed jitted step that stacks
the session buffers on the batch axis. Every session's every window is
proven bit-exact against `cu.run_qnet` (and thereby against the serial
path, which the test suite pins to the same oracle) BEFORE any number is
reported — the sweep raises on any mismatch rather than print a timing.
The headline `speedup_vs_serial_step` is a same-machine same-process
ratio, so it gates in CI across heterogeneous hosts. Writes
experiments/streaming_batched.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Tuple

import jax
import numpy as np

from benchmarks.common import row
from repro.core import cu
from repro.models import dscnn1d, layers
from repro.serve import stream as ST

OUT_JSON = "experiments/streaming.json"
BATCHED_OUT_JSON = "experiments/streaming_batched.json"


def _build_qnet(input_t: int, channels: int, n_blocks: int, kernel: int,
                input_ch: int, bits: int):
    net = dscnn1d.build_kws(
        input_t=input_t, input_ch=input_ch, channels=channels,
        n_blocks=n_blocks, kernel=kernel, bits=bits, num_classes=12)
    return layers.make_calibrated_qnet(net, seed=0)


def run(input_t: int = 2048, channels: int = 256, n_blocks: int = 5,
        kernel: int = 5, input_ch: int = 10, bits: int = 8,
        hop: int = 0, windows: int = 16, n_sessions: int = 8,
        repeats: int = 3, out: str = OUT_JSON) -> dict:
    """Measure streaming vs full-window FPS on one long stream.

    Both routes are warmed (XLA compilation paid) before any timer starts;
    the timed region is the steady state either deployment would sit in."""
    hop = hop or input_t // 8  # the 8x-overlap deployment shape
    qnet = _build_qnet(input_t, channels, n_blocks, kernel, input_ch, bits)
    plan = ST.plan_stream(qnet, hop)
    rng = np.random.default_rng(0)
    frames = rng.uniform(-1, 1, (ST.frames_for_windows(
        windows, input_t, hop), input_ch)).astype(np.float32)

    # -- full-window route: jitted monolithic inference per window --------
    pq = cu.prepare_qnet(qnet)
    full = jax.jit(lambda x: cu.run_qnet(pq, x))
    win = [frames[i * hop:i * hop + input_t][None]
           for i in range(windows)]
    ref = np.concatenate([np.asarray(jax.block_until_ready(full(w)))
                          for w in win])  # warm: pays compilation
    t_full = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for w in win[1:]:
            jax.block_until_ready(full(w))
        t_full = min(t_full, time.perf_counter() - t0)
    fps_full = (windows - 1) / t_full

    # -- streaming route: prime once, then one step per hop --------------
    eng = ST.StreamEngine(qnet, hop)
    eng.warm()  # pays both compilations outside the timed region
    got = None
    t_stream = float("inf")
    for r in range(repeats):
        sid = eng.open_session(f"bench{r}")
        res = eng.push(sid, frames[:input_t])  # prime
        t0 = time.perf_counter()
        for i in range(1, windows):
            res += eng.push(
                sid, frames[input_t + (i - 1) * hop:input_t + i * hop])
        t_stream = min(t_stream, time.perf_counter() - t0)
        eng.close_session(sid)
        got = np.stack([r_.logits for r_ in res])
    fps_stream = (windows - 1) / t_stream

    bit_exact = bool(got.shape == ref.shape and np.array_equal(got, ref))
    speedup = fps_stream / fps_full

    # session-table footprint at n_sessions concurrent primed streams
    eng_n = ST.StreamEngine(qnet, hop, max_sessions=n_sessions)
    for i in range(n_sessions):
        eng_n.push(eng_n.open_session(), frames[:input_t])
    table_bytes = eng_n.session_table_bytes()

    # modeled energy (docs/energy.md): busy power x measured step time +
    # analytic ring-buffer traffic; fps/W at the measured streaming rate
    energy_j = eng.energy_j_per_window()
    watts = eng.power.idle_w + energy_j * fps_stream
    fps_per_watt = fps_stream / watts if watts > 0 else 0.0

    report = {
        "net": qnet.spec.name,
        "backend": jax.default_backend(),
        "window": input_t,
        "hop": hop,
        "overlap_x": input_t // hop,
        "channels": channels,
        "n_blocks": n_blocks,
        "kernel": kernel,
        "act_bits": bits,
        "windows_measured": windows - 1,
        "bit_exact_with_run_qnet": bit_exact,
        "fps_full_window": fps_full,
        "fps_streaming": fps_stream,
        "speedup_vs_full_window": speedup,
        "frames_computed_per_inference": plan.frames_step,
        "frames_full_window": plan.frames_full,
        "frames_ratio": plan.frames_full / plan.frames_step,
        "reuse_fraction": plan.reuse_fraction,
        "macs_per_window_full": plan.macs_full,
        "macs_per_window_step": plan.macs_step,
        "macs_ratio": plan.macs_full / plan.macs_step,
        "session_buffer_bytes": plan.buffer_bytes,
        "n_sessions": n_sessions,
        "session_table_bytes": table_bytes,
        "bytes_per_window_full": plan.bytes_full,
        "bytes_per_window_step": plan.bytes_step,
        "energy_j_per_window_step": energy_j,
        "watts": watts,
        "fps_per_watt": fps_per_watt,
        "power_source": eng.power.source,
    }
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    row("stream_full_window_fps", 1e6 / fps_full, f"{fps_full:.1f}fps")
    row("stream_streaming_fps", 1e6 / fps_stream, f"{fps_stream:.1f}fps")
    row("stream_speedup", 0.0, f"{speedup:.2f}x")
    row("stream_frames_per_inference", 0.0,
        f"{plan.frames_step}/{plan.frames_full}")
    row("stream_bit_exact", 0.0, bit_exact)
    row("stream_session_table_bytes", 0.0, f"{table_bytes}B@{n_sessions}")
    row("stream_fps_per_watt", 0.0,
        f"{fps_per_watt:.1f}fps/W ({energy_j * 1e6:.1f}uJ/window)")
    return report


def run_batched(input_t: int = 256, channels: int = 32, n_blocks: int = 3,
                kernel: int = 5, input_ch: int = 10, bits: int = 8,
                hop: int = 0, windows: int = 12,
                sessions: Tuple[int, ...] = (1, 2, 4, 8),
                repeats: int = 3, out: str = BATCHED_OUT_JSON) -> dict:
    """Sessions x batch sweep: serial per-session stepping vs `drain()`.

    The net is fleet-sized (a realistic always-on KWS footprint): per-hop
    compute is small enough that one-dispatch-per-session overhead is the
    dominant serial cost, which is exactly the regime a million-stream
    deployment lives in. Throughput counts windows (inferences) per
    second summed across the fleet."""
    hop = hop or input_t // 8
    qnet = _build_qnet(input_t, channels, n_blocks, kernel, input_ch, bits)
    plan = ST.plan_stream(qnet, hop)
    max_n = max(sessions)
    rng = np.random.default_rng(0)
    n_frames = ST.frames_for_windows(windows, input_t, hop)
    streams = rng.uniform(-1, 1, (max_n, n_frames, input_ch)
                          ).astype(np.float32)
    refs = [ST.reference_windows(qnet, streams[i], input_t, hop)
            for i in range(max_n)]

    buckets = tuple(b for b in (2, 4, 8, 16) if b <= max_n)
    eng = ST.StreamEngine(qnet, hop, max_sessions=max_n,
                          batch_buckets=buckets)
    eng.warm(batches=buckets)  # all traces paid before any timed region

    def check(sid_frames, got):
        for (i, sid) in sid_frames:
            logits = np.stack([r.logits for r in got[sid]])
            if not np.array_equal(logits, refs[i][1:]):
                raise RuntimeError(
                    f"streamed logits diverged from cu.run_qnet for {sid} "
                    f"— refusing to report a timing for a wrong result")
            eng.close_session(sid)

    per = {}
    for n in sessions:
        t_serial = float("inf")
        for r in range(repeats):
            sids = [(i, eng.open_session(f"serial{n}_{r}_{i}"))
                    for i in range(n)]
            for i, sid in sids:
                eng.push(sid, streams[i][:input_t])  # prime (untimed)
            got = {sid: [] for _, sid in sids}
            t0 = time.perf_counter()
            for w in range(1, windows):
                lo = input_t + (w - 1) * hop
                for i, sid in sids:
                    got[sid] += eng.push(sid, streams[i][lo:lo + hop])
            t_serial = min(t_serial, time.perf_counter() - t0)
            check(sids, got)

        t_batched = float("inf")
        for r in range(repeats):
            sids = [(i, eng.open_session(f"batched{n}_{r}_{i}"))
                    for i in range(n)]
            for i, sid in sids:
                eng.push(sid, streams[i][:input_t], defer=True)
            eng.drain()  # batched prime (untimed, like the serial prime)
            got = {sid: [] for _, sid in sids}
            t0 = time.perf_counter()
            for w in range(1, windows):
                lo = input_t + (w - 1) * hop
                for i, sid in sids:
                    eng.push(sid, streams[i][lo:lo + hop], defer=True)
                for res in eng.drain():
                    got[res.sid].append(res)
            t_batched = min(t_batched, time.perf_counter() - t0)
            check(sids, got)

        steps = (windows - 1) * n
        per[str(n)] = {
            "fps_serial": steps / t_serial,
            "fps_batched": steps / t_batched,
            "speedup": t_serial / t_batched,
        }

    head = per[str(max_n)]
    stats = eng.stats()
    report = {
        "net": qnet.spec.name,
        "backend": jax.default_backend(),
        "window": input_t,
        "hop": hop,
        "overlap_x": input_t // hop,
        "channels": channels,
        "n_blocks": n_blocks,
        "kernel": kernel,
        "act_bits": bits,
        "windows_per_session": windows - 1,
        "sessions_sweep": [int(n) for n in sessions],
        "sessions_max": max_n,
        "batch_buckets": list(buckets),
        "bit_exact_with_run_qnet": True,  # check() raised otherwise
        "per_sessions": per,
        "fps_serial_step": head["fps_serial"],
        "fps_batched_step": head["fps_batched"],
        "speedup_vs_serial_step": head["speedup"],
        "frames_computed_per_inference": plan.frames_step,
        "frames_ratio": plan.frames_full / plan.frames_step,
        "session_buffer_bytes": plan.buffer_bytes,
        "pad_rows": stats["pad_rows"],
        "batched_traces": stats["batched_traces"],
    }
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    for n in sessions:
        p = per[str(n)]
        row(f"stream_batched_x{n}", 1e6 / p["fps_batched"],
            f"{p['fps_batched']:.0f}fps serial={p['fps_serial']:.0f}fps "
            f"{p['speedup']:.2f}x")
    row("stream_speedup_vs_serial_step", 0.0,
        f"{head['speedup']:.2f}x@{max_n}sessions")
    row("stream_batched_bit_exact", 0.0, True)
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--input-t", type=int, default=2048)
    ap.add_argument("--hop", type=int, default=0,
                    help="0 = window/8 (the 8x-overlap deployment shape)")
    ap.add_argument("--channels", type=int, default=256)
    ap.add_argument("--n-blocks", type=int, default=5)
    ap.add_argument("--kernel", type=int, default=5)
    ap.add_argument("--windows", type=int, default=16)
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default=OUT_JSON)
    ap.add_argument("--batched", action="store_true",
                    help="also run the sessions x batch fleet sweep")
    ap.add_argument("--batched-out", default=BATCHED_OUT_JSON)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(input_t=args.input_t, hop=args.hop, channels=args.channels,
        n_blocks=args.n_blocks, kernel=args.kernel, windows=args.windows,
        n_sessions=args.sessions, repeats=args.repeats, out=args.out)
    if args.batched:
        run_batched(out=args.batched_out)


if __name__ == "__main__":
    main()
