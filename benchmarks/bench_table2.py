"""Paper Table 2: MobileNet-V2 alpha x H sweep — Params(Mib) and #Ops(M).

Pure-arithmetic reproduction from the NetSpec; `derived` compares against the
paper's published numbers (relative error). The paper's #Ops includes the
(pre-fusing) BN elementwise ops — see tests/test_bn_fuse.py.
"""
from __future__ import annotations

from benchmarks.common import row
from repro.models import mobilenet_v2 as mnv2

# paper Table 2 values: {alpha: (params_Mb, {H: ops_M})}
PAPER = {
    1.0: (13.31, {224: 313.621, 192: 230.755, 160: 160.638, 128: 103.269, 96: 58.649}),
    0.75: (10.01, {224: 220.326, 192: 162.212, 160: 113.038, 128: 72.805, 96: 41.513}),
    0.5: (7.48, {224: 104.164, 192: 76.868, 160: 53.772, 128: 34.875, 96: 20.177}),
    0.35: (6.37, {224: 64.835, 192: 47.973, 160: 33.706, 128: 22.033, 96: 12.953}),
}


def run():
    worst_p = worst_o = 0.0
    for alpha, (p_mb, ops) in PAPER.items():
        net = mnv2.build(alpha=alpha, input_hw=224, bits=4)
        ours_mb = net.model_bits(with_bias=False) / 2**20  # Mib
        err_p = abs(ours_mb - p_mb) / p_mb
        worst_p = max(worst_p, err_p)
        row(f"table2_params_a{alpha}", 0.0,
            f"ours={ours_mb:.2f}Mib paper={p_mb} err={err_p*100:.1f}%")
        for h, paper_ops in ops.items():
            net_h = mnv2.build(alpha=alpha, input_hw=h, bits=4)
            ours_ops = (net_h.count_macs() + net_h.count_bn_ops()) / 1e6
            err = abs(ours_ops - paper_ops) / paper_ops
            worst_o = max(worst_o, err)
            row(f"table2_ops_a{alpha}_h{h}", 0.0,
                f"ours={ours_ops:.1f}M paper={paper_ops} err={err*100:.1f}%")
    row("table2_worst_err", 0.0,
        f"params={worst_p*100:.1f}% ops={worst_o*100:.1f}%")


if __name__ == "__main__":
    run()
