"""Paper Table 3/4/5: FPS per design point — TPU-v5e roofline projection.

The paper measures FPS on a ZCU102 at 200 MHz. This container has no TPU, so
we project per-image latency from the roofline model (int8 MXU path at the
paper's BW=4 datapath): t = max(compute, memory) with

    compute = MACs * 2 / (197e12 * int8_speedup)
    memory  = (weights at BW bits + activation traffic) / 819e9

and report FPS = 1/t for one chip, preserving the paper's design-space TREND
(FPS grows as alpha/H shrink). The derived column carries the paper's
measured FPS for reference.
"""
from __future__ import annotations

from benchmarks.common import row
from repro.models import mobilenet_v2 as mnv2

PEAK = 197e12  # bf16; int8 ~2x on v5e MXU
HBM = 819e9

# paper Table 3 FPS at 200MHz ZCU102
PAPER_FPS = {
    (0.75, 224): 11, (0.75, 192): 14, (0.75, 160): 18, (0.75, 128): 22,
    (0.75, 96): 28,
    (0.5, 224): 16, (0.5, 192): 19, (0.5, 160): 25, (0.5, 128): 30,
    (0.5, 96): 37,
    (0.35, 224): 20, (0.35, 192): 25, (0.35, 160): 31, (0.35, 128): 40,
    (0.35, 96): 51,
}


def activation_bytes(net, bits=4):
    h = net.input_hw
    total = 0
    for b in net.blocks:
        for op in b.ops:
            if op.kind == "dense":
                total += (op.in_ch + op.out_ch) * bits // 8
                continue
            h_out = -(-h // op.stride)
            total += (h * h * op.in_ch + h_out * h_out * op.out_ch) * bits // 8
            h = h_out
    return total


def run():
    for (alpha, hh), paper in sorted(PAPER_FPS.items()):
        net = mnv2.build(alpha=alpha, input_hw=hh, bits=4)
        macs = net.count_macs()
        wbytes = net.model_bits(with_bias=False) / 8
        abytes = activation_bytes(net)
        t_c = macs * 2 / (PEAK * 2)  # int8 path
        t_m = (wbytes + abytes) / HBM
        fps = 1.0 / max(t_c, t_m)
        row(f"table3_fps_a{alpha}_h{hh}", 0.0,
            f"tpu_roofline_fps={fps:.0f} paper_zcu102_fps={paper} "
            f"bound={'mem' if t_m > t_c else 'compute'}")
    # trend check: FPS must increase monotonically as H decreases per alpha
    for alpha in (0.75, 0.5, 0.35):
        fps = []
        for hh in (224, 192, 160, 128, 96):
            net = mnv2.build(alpha=alpha, input_hw=hh, bits=4)
            macs = net.count_macs()
            t_c = macs * 2 / (PEAK * 2)
            t_m = (net.model_bits(False) / 8 + activation_bytes(net)) / HBM
            fps.append(1.0 / max(t_c, t_m))
        mono = all(fps[i] < fps[i + 1] for i in range(len(fps) - 1))
        row(f"table3_trend_a{alpha}", 0.0, f"fps_monotone_in_H={mono}")


if __name__ == "__main__":
    run()
