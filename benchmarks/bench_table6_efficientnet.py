"""Paper Table 6/7 + Fig 19: compact EfficientNet — algorithmic specs, CU
mapping (Body invoked 9x, 1.78x fewer than MobileNet-V2), roofline FPS."""
from __future__ import annotations

from benchmarks.common import row
from repro.core import compiler as CC
from repro.models import efficientnet as effnet, mobilenet_v2 as mnv2

PEAK, HBM = 197e12, 819e9


def run():
    net = effnet.build_compact(input_hw=128, bits=4)
    plan = CC.compile_net(net)
    mib = net.model_bits(with_bias=False) / 2**20
    ops = (net.count_macs() + net.count_bn_ops()) / 1e6
    row("table6_params", 0.0, f"ours={mib:.2f}Mib paper=7.81Mb")
    row("table6_ops", 0.0, f"ours={ops:.1f}M (paper reports 4.914M ops*)")
    row("table6_body_invocations", 0.0,
        f"ours={plan.body_invocations} paper=9")
    m_inv = CC.compile_net(mnv2.build(alpha=0.75, input_hw=224)).body_invocations
    row("table6_body_ratio_vs_mnv2", 0.0,
        f"{m_inv / plan.body_invocations:.2f}x paper=1.78x")
    macs = net.count_macs()
    t_c = macs * 2 / (PEAK * 2)
    t_m = (net.model_bits(False) / 8) / HBM
    row("table6_roofline_fps", 0.0, f"{1.0/max(t_c, t_m):.0f} (one v5e chip)")


if __name__ == "__main__":
    run()
