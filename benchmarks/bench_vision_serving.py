"""Vision serving throughput: pipelined CU-stage engine vs naive `run_qnet`.

Four ways to serve the same calibrated integer MobileNet-V2:

  * naive      — one batch at a time through the monolithic `cu.run_qnet`
                 (op-by-op dispatch, block between batches): what a
                 straight-line port of the reference runner does.
  * monolith   — `jax.jit(run_qnet)` as one XLA program, still one batch at
                 a time: removes dispatch overhead but keeps the device
                 idle between batches.
  * pipelined  — the PR-1 serve.vision engine (per-CU jitted stage
                 executors, micro-batches streamed) with reference op
                 bodies and per-trace host constants (prepare=False).
  * fast       — the PR-2 engine defaults: `PreparedQNet` device-cached
                 constants + the compiled integer fast path (shifted-slice
                 depthwise, exactness-gated f32 matmuls; per-op Pallas
                 kernels when on TPU).

Reports images/sec (the paper's Table 3/6 FPS view) and the engine's
energy-proxy FPS/W. Writes a JSON report (default
experiments/vision_serving.json) and prints the usual CSV rows. The
previously saved report (the PR-1 baseline) is read *before* overwriting so
`speedup_vs_saved_baseline` tracks the perf trajectory across PRs.

`run_scaling` (or `--scaling`) measures the multi-replica curve instead:
the same engine with micro-batches sharded over a `dist.sharding.data_mesh`
of 1..N replicas (N = visible devices; on CPU force them with
`XLA_FLAGS=--xla_force_host_platform_device_count=N`). Every point is
checked bit-exact against both the live `run_qnet` reference and the frozen
golden fixture of `tests/golden/` — replication must never move a logit.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import cu, qnet as Q
from repro.dist.sharding import data_mesh
from repro.models import layers, mobilenet_v2 as mnv2
from repro.serve.vision import VisionEngine


def _run_engine(qnet, imgs, batch, repeats, obs=False, **engine_kwargs):
    """Best-of-N serving drains; returns (stats, results) — plus the best
    round's (tracer, metrics) when `obs=True` (fresh per round, so the
    exported trace/snapshot describe exactly the drain that won)."""
    stats = results = best_obs = None
    for _ in range(repeats):
        kw = dict(engine_kwargs)
        if obs:
            from repro.obs import MetricsRegistry, Tracer
            kw.update(tracer=Tracer(), metrics=MetricsRegistry())
        eng = VisionEngine(qnet, buckets=(batch,), **kw)
        eng.warmup()
        for img in imgs:
            eng.submit(img)
        res = eng.run()
        st = eng.stats()
        if stats is None or st.fps > stats.fps:
            stats, results = st, res
            if obs:
                best_obs = (kw["tracer"], kw["metrics"])
    if obs:
        return stats, results, best_obs
    return stats, results


def _load_tuned(path):
    """Committed tuning cache, or None when absent (recorded as
    `tuned_cache: null` in the report — `benchmarks/run.py` turns that
    into a hard failure when a cache was requested, so CI can never go
    green without exercising the tuned path). A cache that EXISTS but
    fails to parse raises immediately."""
    if not path or not os.path.exists(path):
        return None
    from repro.tune import load_tuned
    return load_tuned(path)


def run(alpha: float = 0.35, hw: int = 48, batch: int = 8, n_images: int = 64,
        repeats: int = 2, out: str = "experiments/vision_serving.json",
        tuned_cache: str = None):
    net = mnv2.build(alpha=alpha, input_hw=hw, num_classes=1000)
    qnet = layers.make_calibrated_qnet(net)
    imgs = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(7), (n_images, hw, hw, 3), minval=-1, maxval=1),
        np.float32)
    batches = [jnp.asarray(imgs[i:i + batch])
               for i in range(0, n_images, batch)]

    # perf trajectory: what did the last PR's engine do on this config?
    saved_baseline = None
    if os.path.exists(out):
        try:
            with open(out) as f:
                saved = json.load(f)
            if (saved.get("input_hw"), saved.get("batch")) == (hw, batch):
                saved_baseline = saved.get(
                    "fps_pipelined_fast", saved.get("fps_pipelined"))
        except (json.JSONDecodeError, OSError):
            pass

    # best-of-N for each serving mode: the box this runs on is shared, so a
    # single pass is hostage to scheduler noise
    # --- naive: monolithic runner, one batch at a time -------------------
    ref0 = jax.block_until_ready(cu.run_qnet(qnet, batches[0]))  # warm caches
    if batches[-1].shape != batches[0].shape:  # ragged tail: warm it too
        jax.block_until_ready(cu.run_qnet(qnet, batches[-1]))
    t_naive = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for x in batches:
            jax.block_until_ready(cu.run_qnet(qnet, x))
        t_naive = min(t_naive, time.perf_counter() - t0)
    fps_naive = n_images / t_naive

    # --- monolith jit: one XLA program, one batch at a time --------------
    mono = jax.jit(lambda x: cu.run_qnet(qnet, x))
    jax.block_until_ready(mono(batches[0]))
    if batches[-1].shape != batches[0].shape:
        jax.block_until_ready(mono(batches[-1]))
    t_mono = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for x in batches:
            jax.block_until_ready(mono(x))
        t_mono = min(t_mono, time.perf_counter() - t0)
    fps_mono = n_images / t_mono

    # --- PR-1 pipelined CU-stage engine (reference op bodies) ------------
    stats_pr1, _ = _run_engine(
        qnet, imgs, batch, repeats,
        prepare=False, op_kernels="off", body_fast_path="off")

    # --- PR-2 fast path: PreparedQNet + compiled integer formulations ----
    stats, results = _run_engine(qnet, imgs, batch, repeats)

    # sanity: serving path is bit-exact with the reference
    got0 = np.stack([results[r].logits for r in sorted(results)[:batch]])
    exact = bool(np.array_equal(got0, np.asarray(ref0)))

    # --- observability overhead: same fast engine, tracing + metrics on --
    # (the <5% budget the obs layer owes the hot path; the winning round's
    # snapshot rides the report as the serving profile). A smoke-geometry
    # drain is ~10ms and shared-box scheduler noise swamps a back-to-back
    # best-of comparison, so the overhead is the MEDIAN of paired
    # (obs-off, obs-on) round ratios — drift inside a pair hits both
    # configurations, and the median discards the outlier pairs.
    obs_rounds = max(repeats, 5)
    stats_obs = results_obs = best_obs = None
    ratios = []
    for _ in range(obs_rounds):
        st_f, _ = _run_engine(qnet, imgs, batch, 1)
        st_o, res_o, pair = _run_engine(qnet, imgs, batch, 1, obs=True)
        if stats_obs is None or st_o.fps > stats_obs.fps:
            stats_obs, results_obs, best_obs = st_o, res_o, pair
        if st_f.fps > 0:
            ratios.append(st_o.fps / st_f.fps)
    tracer, metrics = best_obs
    got_obs = np.stack(
        [results_obs[r].logits for r in sorted(results_obs)[:batch]])
    exact_obs = bool(np.array_equal(got_obs, np.asarray(ref0)))
    ratios.sort()
    obs_overhead = (1.0 - ratios[len(ratios) // 2]) if ratios else None

    # --- PR-4 tuned path: measured per-op routes from the committed cache -
    tuned_plan = _load_tuned(tuned_cache)
    stats_tuned = exact_tuned = coverage = None
    if tuned_plan is not None:
        coverage = tuned_plan.coverage(qnet)
        stats_tuned, results_tuned = _run_engine(
            qnet, imgs, batch, repeats, tuned=tuned_plan)
        got_t = np.stack(
            [results_tuned[r].logits for r in sorted(results_tuned)[:batch]])
        exact_tuned = bool(np.array_equal(got_t, np.asarray(ref0)))

    report = {
        "net": qnet.spec.name,
        "alpha": alpha,
        "input_hw": hw,
        "batch": batch,
        "n_images": n_images,
        "repeats": repeats,
        "fps_naive": fps_naive,
        "fps_monolith_jit": fps_mono,
        "fps_pipelined": stats_pr1.fps,
        "fps_pipelined_fast": stats.fps,
        "speedup_vs_naive": stats.fps / fps_naive,
        "speedup_vs_monolith_jit": stats.fps / fps_mono,
        "speedup_fast_vs_pipelined": stats.fps / stats_pr1.fps,
        "speedup_vs_saved_baseline": (
            stats.fps / saved_baseline if saved_baseline else None),
        "saved_baseline_fps": saved_baseline,
        "bit_exact_with_run_qnet": exact,
        "tuned_cache": tuned_cache if tuned_plan is not None else None,
        "tuned_route_coverage": coverage,
        "fps_pipelined_tuned": (
            stats_tuned.fps if stats_tuned is not None else None),
        "speedup_tuned_vs_default": (
            stats_tuned.fps / stats.fps if stats_tuned is not None else None),
        "tuned_bit_exact_with_run_qnet": exact_tuned,
        "fps_pipelined_obs": stats_obs.fps,
        "obs_overhead_frac": obs_overhead,
        "obs_bit_exact_with_run_qnet": exact_obs,
        "obs_trace_events": len(tracer),
        "obs_metrics_snapshot": metrics.snapshot(),
        "latency_p50_s": stats.latency_p50_s,
        "latency_p95_s": stats.latency_p95_s,
        "latency_p50_s_pipelined_pr1": stats_pr1.latency_p50_s,
        "micro_batches": stats.micro_batches,
        "pad_fraction": stats.pad_fraction,
        "harvest_wait_s": stats.harvest_wait_s,
        "macs_per_image": stats.macs_per_image,
        "energy_j_per_image": stats.energy_j_per_image,
        "watts": stats.watts,
        "fps_per_watt": stats.fps_per_watt,
        "power_source": stats.power_source,
        "energy_tuned_fraction": stats.energy_tuned_fraction,
        "backend": jax.default_backend(),
    }
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    row("vision_serve_naive", t_naive / len(batches) * 1e6,
        f"fps={fps_naive:.1f}")
    row("vision_serve_monolith_jit", t_mono / len(batches) * 1e6,
        f"fps={fps_mono:.1f}")
    row("vision_serve_pipelined_pr1",
        stats_pr1.wall_s / stats_pr1.micro_batches * 1e6,
        f"fps={stats_pr1.fps:.1f}")
    row("vision_serve_pipelined_fast",
        stats.wall_s / stats.micro_batches * 1e6,
        f"fps={stats.fps:.1f} "
        f"speedup_vs_pr1_pipelined={report['speedup_fast_vs_pipelined']:.2f}x "
        f"exact={exact}")
    if stats_tuned is not None:
        row("vision_serve_pipelined_tuned",
            stats_tuned.wall_s / stats_tuned.micro_batches * 1e6,
            f"fps={stats_tuned.fps:.1f} "
            f"vs_default={report['speedup_tuned_vs_default']:.2f}x "
            f"coverage={coverage:.2f} exact={exact_tuned}")
    row("vision_serve_pipelined_obs",
        stats_obs.wall_s / stats_obs.micro_batches * 1e6,
        f"fps={stats_obs.fps:.1f} overhead={obs_overhead:+.1%} "
        f"trace_events={len(tracer)} exact={exact_obs}")
    return report


def _golden_bit_exact(replicas: int):
    """Serve the frozen golden fixture net sharded over `replicas` and
    compare logits against the checked-in golden vectors — the conformance
    gate the scaling curve must clear at every point. Returns None when the
    fixtures are unavailable (run outside the repo root): the report must
    say 'not checked', never a fabricated pass."""
    try:
        from tests.regen_golden import build_net, fixture_paths
    except ImportError:
        return None
    qnet_path, npz_path = fixture_paths("mobilenet_v2", 4)
    if not (os.path.exists(npz_path) and os.path.exists(qnet_path)):
        return None
    qnet = Q.load_qnet(qnet_path, build_net("mobilenet_v2", 4))
    fix = np.load(npz_path)
    mesh = data_mesh(replicas) if replicas > 1 else None
    # bucket 2 == the fixture batch; the engine rounds it up to a replica
    # multiple itself when sharded
    eng = VisionEngine(qnet, buckets=(2,), mesh=mesh)
    rids = [eng.submit(img) for img in fix["input"]]
    results = eng.run()
    got = np.stack([results[r].logits for r in rids])
    return bool(np.array_equal(got, fix["logits"]))


def run_scaling(alpha: float = 0.35, hw: int = 48, batch: int = 8,
                n_images: int = 64, repeats: int = 2,
                replica_counts=None,
                out: str = "experiments/vision_serving_scaling.json"):
    n_dev = len(jax.devices())
    if replica_counts is None:
        replica_counts = [r for r in (1, 2, 4, 8)
                          if r <= n_dev and batch % r == 0]
    net = mnv2.build(alpha=alpha, input_hw=hw, num_classes=1000)
    qnet = layers.make_calibrated_qnet(net)
    imgs = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(7), (n_images, hw, hw, 3), minval=-1, maxval=1),
        np.float32)
    ref = np.asarray(cu.run_qnet(qnet, jnp.asarray(imgs[:batch])))

    # one pre-warmed engine per replica count; measurement rounds interleave
    # the counts (instead of best-of-N per count back to back) so scheduler
    # noise and cache warmth hit every point symmetrically
    engines = {}
    for r in replica_counts:
        mesh = data_mesh(r) if r > 1 else None
        engines[r] = VisionEngine(qnet, buckets=(batch,), mesh=mesh)
        engines[r].warmup()
    best_fps = dict.fromkeys(replica_counts, 0.0)
    last = {}
    for _ in range(max(repeats, 1)):
        for r in replica_counts:
            eng = engines[r]
            before = eng.stats()
            for img in imgs:
                eng.submit(img)
            results = eng.run()
            after = eng.stats()
            dt = after.wall_s - before.wall_s
            fps = (after.n_ok - before.n_ok) / dt if dt > 0 else 0.0
            best_fps[r] = max(best_fps[r], fps)
            last[r] = results
    curve = {}
    for r in replica_counts:
        stats = engines[r].stats()
        results = last[r]
        got = np.stack([results[i].logits for i in sorted(results)[:batch]])
        point = {
            "fps": best_fps[r],
            "latency_p50_s": stats.latency_p50_s,
            "latency_p95_s": stats.latency_p95_s,
            "harvest_wait_s": stats.harvest_wait_s,
            "bit_exact_with_run_qnet": bool(np.array_equal(got, ref)),
            "bit_exact_with_golden": _golden_bit_exact(r),
        }
        curve[str(r)] = point
        row(f"vision_serve_sharded_x{r}",
            (batch / best_fps[r] * 1e6) if best_fps[r] > 0 else 0.0,
            f"fps={best_fps[r]:.1f} exact={point['bit_exact_with_run_qnet']} "
            f"golden={point['bit_exact_with_golden']}")

    base_fps = curve[str(replica_counts[0])]["fps"]
    report = {
        "net": qnet.spec.name,
        "alpha": alpha,
        "input_hw": hw,
        "batch": batch,
        "n_images": n_images,
        "device_count": n_dev,
        "backend": jax.default_backend(),
        "replica_counts": list(replica_counts),
        "curve": curve,
        "speedup_max_replicas_vs_1": (
            curve[str(replica_counts[-1])]["fps"] / base_fps
            if base_fps else None),
        # golden None == fixtures unavailable (reported as such above);
        # only an actually-failed comparison breaks conformance here
        "all_bit_exact": all(
            p["bit_exact_with_run_qnet"]
            and p["bit_exact_with_golden"] is not False
            for p in curve.values()),
        "golden_checked": all(
            p["bit_exact_with_golden"] is not None for p in curve.values()),
    }
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=float, default=0.35)
    ap.add_argument("--hw", type=int, default=48)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-images", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--scaling", action="store_true",
                    help="measure the multi-replica scaling curve instead")
    ap.add_argument("--tuned-cache", default="experiments/tuned/bench_cpu.json",
                    help="tuning cache for the tuned-vs-default measurement "
                         "(skipped when the file is absent)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.scaling:
        run_scaling(alpha=args.alpha, hw=args.hw, batch=args.batch,
                    n_images=args.n_images, repeats=args.repeats,
                    out=args.out or "experiments/vision_serving_scaling.json")
        return
    run(alpha=args.alpha, hw=args.hw, batch=args.batch,
        n_images=args.n_images, repeats=args.repeats,
        out=args.out or "experiments/vision_serving.json",
        tuned_cache=args.tuned_cache)


if __name__ == "__main__":
    main()
