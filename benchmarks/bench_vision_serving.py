"""Vision serving throughput: pipelined CU-stage engine vs naive `run_qnet`.

Four ways to serve the same calibrated integer MobileNet-V2:

  * naive      — one batch at a time through the monolithic `cu.run_qnet`
                 (op-by-op dispatch, block between batches): what a
                 straight-line port of the reference runner does.
  * monolith   — `jax.jit(run_qnet)` as one XLA program, still one batch at
                 a time: removes dispatch overhead but keeps the device
                 idle between batches.
  * pipelined  — the PR-1 serve.vision engine (per-CU jitted stage
                 executors, micro-batches streamed) with reference op
                 bodies and per-trace host constants (prepare=False).
  * fast       — the PR-2 engine defaults: `PreparedQNet` device-cached
                 constants + the compiled integer fast path (shifted-slice
                 depthwise, exactness-gated f32 matmuls; per-op Pallas
                 kernels when on TPU).

Reports images/sec (the paper's Table 3/6 FPS view) and the engine's
energy-proxy FPS/W. Writes a JSON report (default
experiments/vision_serving.json) and prints the usual CSV rows. The
previously saved report (the PR-1 baseline) is read *before* overwriting so
`speedup_vs_saved_baseline` tracks the perf trajectory across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import cu, qnet as Q
from repro.core.calibrate import calibrate
from repro.core.quant import QuantConfig
from repro.models import layers, mobilenet_v2 as mnv2
from repro.serve.vision import VisionEngine


def _make_qnet(net, hw: int):
    params = layers.init_params(jax.random.PRNGKey(0), net)

    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]

    cal = [jax.random.uniform(jax.random.PRNGKey(i), (2, hw, hw, 3),
                              minval=-1, maxval=1) for i in range(2)]
    obs = calibrate(apply_fn, params, cal, QuantConfig(4, False, None))
    return Q.quantize_net(params, net, obs)


def _run_engine(qnet, imgs, batch, repeats, **engine_kwargs):
    """Best-of-N serving drains; returns (stats, results)."""
    stats = results = None
    for _ in range(repeats):
        eng = VisionEngine(qnet, buckets=(batch,), **engine_kwargs)
        eng.warmup()
        for img in imgs:
            eng.submit(img)
        res = eng.run()
        st = eng.stats()
        if stats is None or st.fps > stats.fps:
            stats, results = st, res
    return stats, results


def run(alpha: float = 0.35, hw: int = 48, batch: int = 8, n_images: int = 64,
        repeats: int = 2, out: str = "experiments/vision_serving.json"):
    net = mnv2.build(alpha=alpha, input_hw=hw, num_classes=1000)
    qnet = _make_qnet(net, hw)
    imgs = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(7), (n_images, hw, hw, 3), minval=-1, maxval=1),
        np.float32)
    batches = [jnp.asarray(imgs[i:i + batch])
               for i in range(0, n_images, batch)]

    # perf trajectory: what did the last PR's engine do on this config?
    saved_baseline = None
    if os.path.exists(out):
        try:
            with open(out) as f:
                saved = json.load(f)
            if (saved.get("input_hw"), saved.get("batch")) == (hw, batch):
                saved_baseline = saved.get(
                    "fps_pipelined_fast", saved.get("fps_pipelined"))
        except (json.JSONDecodeError, OSError):
            pass

    # best-of-N for each serving mode: the box this runs on is shared, so a
    # single pass is hostage to scheduler noise
    # --- naive: monolithic runner, one batch at a time -------------------
    ref0 = jax.block_until_ready(cu.run_qnet(qnet, batches[0]))  # warm caches
    if batches[-1].shape != batches[0].shape:  # ragged tail: warm it too
        jax.block_until_ready(cu.run_qnet(qnet, batches[-1]))
    t_naive = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for x in batches:
            jax.block_until_ready(cu.run_qnet(qnet, x))
        t_naive = min(t_naive, time.perf_counter() - t0)
    fps_naive = n_images / t_naive

    # --- monolith jit: one XLA program, one batch at a time --------------
    mono = jax.jit(lambda x: cu.run_qnet(qnet, x))
    jax.block_until_ready(mono(batches[0]))
    if batches[-1].shape != batches[0].shape:
        jax.block_until_ready(mono(batches[-1]))
    t_mono = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for x in batches:
            jax.block_until_ready(mono(x))
        t_mono = min(t_mono, time.perf_counter() - t0)
    fps_mono = n_images / t_mono

    # --- PR-1 pipelined CU-stage engine (reference op bodies) ------------
    stats_pr1, _ = _run_engine(
        qnet, imgs, batch, repeats,
        prepare=False, op_kernels="off", body_fast_path="off")

    # --- PR-2 fast path: PreparedQNet + compiled integer formulations ----
    stats, results = _run_engine(qnet, imgs, batch, repeats)

    # sanity: serving path is bit-exact with the reference
    got0 = np.stack([results[r].logits for r in sorted(results)[:batch]])
    exact = bool(np.array_equal(got0, np.asarray(ref0)))

    report = {
        "net": qnet.spec.name,
        "alpha": alpha,
        "input_hw": hw,
        "batch": batch,
        "n_images": n_images,
        "repeats": repeats,
        "fps_naive": fps_naive,
        "fps_monolith_jit": fps_mono,
        "fps_pipelined": stats_pr1.fps,
        "fps_pipelined_fast": stats.fps,
        "speedup_vs_naive": stats.fps / fps_naive,
        "speedup_vs_monolith_jit": stats.fps / fps_mono,
        "speedup_fast_vs_pipelined": stats.fps / stats_pr1.fps,
        "speedup_vs_saved_baseline": (
            stats.fps / saved_baseline if saved_baseline else None),
        "saved_baseline_fps": saved_baseline,
        "bit_exact_with_run_qnet": exact,
        "latency_p50_s": stats.latency_p50_s,
        "latency_p95_s": stats.latency_p95_s,
        "latency_p50_s_pipelined_pr1": stats_pr1.latency_p50_s,
        "micro_batches": stats.micro_batches,
        "pad_fraction": stats.pad_fraction,
        "harvest_wait_s": stats.harvest_wait_s,
        "macs_per_image": stats.macs_per_image,
        "energy_j_per_image_proxy": stats.energy_j_per_image_proxy,
        "fps_per_watt_proxy": stats.fps_per_watt_proxy,
        "backend": jax.default_backend(),
    }
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1)

    row("vision_serve_naive", t_naive / len(batches) * 1e6,
        f"fps={fps_naive:.1f}")
    row("vision_serve_monolith_jit", t_mono / len(batches) * 1e6,
        f"fps={fps_mono:.1f}")
    row("vision_serve_pipelined_pr1",
        stats_pr1.wall_s / stats_pr1.micro_batches * 1e6,
        f"fps={stats_pr1.fps:.1f}")
    row("vision_serve_pipelined_fast",
        stats.wall_s / stats.micro_batches * 1e6,
        f"fps={stats.fps:.1f} "
        f"speedup_vs_pr1_pipelined={report['speedup_fast_vs_pipelined']:.2f}x "
        f"exact={exact}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--alpha", type=float, default=0.35)
    ap.add_argument("--hw", type=int, default=48)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-images", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--out", default="experiments/vision_serving.json")
    args = ap.parse_args()
    run(alpha=args.alpha, hw=args.hw, batch=args.batch,
        n_images=args.n_images, repeats=args.repeats, out=args.out)


if __name__ == "__main__":
    main()
