"""Shared benchmark utilities. Every bench prints `name,us_per_call,derived`
CSV rows (us_per_call = measured wall time on this host where meaningful,
else 0; derived = the paper-table quantity being reproduced)."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_us(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def row(name: str, us: float, derived) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
