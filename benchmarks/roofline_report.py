"""Aggregate experiments/dryrun/*.json into the §Roofline markdown table.

    PYTHONPATH=src:. python -m benchmarks.roofline_report [--dir experiments/dryrun]

Emits, per (arch x shape x mesh): the three roofline terms (seconds), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio), the
roofline fraction (useful model FLOPs time / bound time), memory fit, and a
one-line recommendation for the dominant term.
"""
from __future__ import annotations

import argparse
import json
import os

PEAK = 197e12
HBM_GB = 16e9  # v5e per-chip HBM


def reco(r: dict) -> str:
    b = r["roofline"]["bottleneck"]
    if b == "memory":
        return ("cut HBM traffic: lower-precision weights/acts, better "
                "fusion, larger per-step batch re-use")
    if b == "collective":
        return ("cut collective payload: 2D-sharded activations, "
                "grad compression, overlap via latency hiding")
    return "raise MXU utilization: larger tiles, fewer remat recomputes"


def load(dir_):
    rows = []
    for f in sorted(os.listdir(dir_)):
        if f.endswith(".json"):
            with open(os.path.join(dir_, f)) as fh:
                rows.append(json.load(fh))
    return rows


def fmt(rows, mesh_filter=None):
    print("| arch | shape | mesh | t_comp(s) | t_mem(s) | t_coll(s) | "
          "bound | MF/HLO | roofline-frac | peak/chip | fits |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_err = 0
    for r in rows:
        if r["status"] == "skipped":
            n_skip += 1
            arch, shape, mesh = r["cell"].split("__")[:3]
            if mesh_filter and mesh != mesh_filter:
                continue
            print(f"| {arch} | {shape} | {mesh} | — | — | — | skipped "
                  f"(quadratic@512k) | — | — | — | — |")
            continue
        if r["status"] != "ok":
            n_err += 1
            print(f"| {r['cell']} | ERROR: {r.get('error','')[:60]} |")
            continue
        n_ok += 1
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        rl = r["roofline"]
        t_bound = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        useful_t = r["model_flops_per_device"] / PEAK
        frac = useful_t / t_bound if t_bound else 0.0
        mem = r["memory"]
        peak = max(mem.get("peak_bytes", 0),
                   mem.get("temp_bytes", 0) + mem.get("argument_bytes", 0))
        fits = "Y" if peak <= HBM_GB else f"N({peak/1e9:.0f}GB)"
        ratio = r.get("useful_flops_ratio") or 0.0
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {rl['t_compute_s']:.2e} | {rl['t_memory_s']:.2e} "
              f"| {rl['t_collective_s']:.2e} | {rl['bottleneck']} "
              f"| {ratio:.2f} | {frac*100:.1f}% | {peak/1e9:.2f}GB | {fits} |")
    print(f"\ncells: {n_ok} ok, {n_skip} skipped, {n_err} error")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--reco", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    fmt(rows, args.mesh)
    if args.reco:
        print("\nrecommendations (dominant-term):")
        for r in rows:
            if r["status"] == "ok":
                print(f"  {r['cell']}: {reco(r)}")


if __name__ == "__main__":
    main()
