# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_table2              Table 2   (alpha x H: params / #ops)
  bench_bw_sweep            Fig. 13   (bit-width: size / SQNR / int inference)
  bench_table3              Table 3/4 (FPS per design point, roofline-projected)
  bench_fusion              Sec 5.1.2 (fused Body CU traffic reduction)
  bench_table6_efficientnet Table 6/7 (compact EfficientNet + CU mapping)
  bench_quant_serving       beyond-paper: LM weight-quantized serving
  bench_vision_serving      beyond-paper: pipelined CU-stage vision serving
                            (+ the multi-replica sharded scaling curve)
  bench_streaming           beyond-paper: ring-buffer streaming vs
                            full-window recompute on a 1-D DSCNN
                            (+ the batched multi-session fleet sweep)
  bench_kernels             kernel-level microbenchmarks

`--smoke` runs the fast subset (kernels + a reduced vision-serving pass +
the replica-scaling sweep + the streaming pass in an isolated
single-device subprocess) and asserts the JSON reports still parse — the
CI gate. A full (or smoke) run aggregates the per-benchmark results into a
perf-trajectory report at the repo root, BENCH_PR10.json: throughput /
latency / analytic bytes-moved, the calibrated energy model's J/image /
watts / FPS-per-Watt view of serving and streaming (docs/energy.md),
tuned-vs-default serving FPS (measured
per-op routes from the committed `experiments/tuned/` cache), the
obs-enabled serving FPS + metrics-snapshot profile (the observability
layer's <5% hot-path overhead budget, recorded as `obs_overhead_frac`),
the per-replica-count scaling curve (each point conformance-checked
against the frozen golden fixtures), the mixed-precision Pareto summary
(the committed `experiments/precision/` artifact the per-layer act-bit
search produced — front size, headline domination pair, per-point
objectives; see docs/tuning.md), plus deltas against the previous
PR's `experiments/vision_serving.json` baseline captured before this run
overwrote it. Force N CPU devices with
`XLA_FLAGS=--xla_force_host_platform_device_count=N` to exercise the
sharded points.

`--check-regression <baseline.json>` is the CI perf gate: after the run it
compares this report's throughput metrics against a committed baseline
report (e.g. BENCH_PR3.json) and FAILS on a >25% FPS regression
(`--regression-threshold` to tune), printing a full delta table. Only
same-config metrics can fail the gate — a smoke run compared against a
full-geometry baseline reports the deltas as informational — and latency /
kernel-microseconds rows are always informational (the gate is a
*throughput* gate; absolute wall times across heterogeneous CI machines
are too noisy to fail on).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

BENCH_REPORT = "BENCH_PR10.json"
PRECISION_PARETO = "experiments/precision/mobilenet_v2_cpu_pareto.json"
VISION_REPORT = "experiments/vision_serving.json"
SCALING_REPORT = "experiments/vision_serving_scaling.json"
STREAMING_REPORT = "experiments/streaming.json"
STREAMING_BATCHED_REPORT = "experiments/streaming_batched.json"
TUNED_CACHE = "experiments/tuned/bench_cpu.json"


def _load_baseline(path: str):
    """The previous PR's vision-serving numbers (read before overwriting)."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        return None


def _run_streaming_isolated(out: str, batched_out: str,
                            n_sessions: int = 8) -> tuple:
    """Run bench_streaming in its own single-device subprocess.

    The streaming step is a single-session latency path: its deployment
    configuration is one device, and its ~3ms steps are sensitive both to
    the virtual-device thread-pool split the scaling sweep forces
    (``--xla_force_host_platform_device_count``) and to allocator/cache
    state left behind by the serving benches earlier in this process. A
    fresh subprocess with the device-count flag stripped measures the
    configuration streaming actually serves in; the full-window reference
    runs in the SAME subprocess, so the gated speedup remains a
    same-process ratio. The batched fleet sweep (`run_batched`) rides in
    the same subprocess for the same reason: its gated
    `speedup_vs_serial_step` is a serial-vs-drain() ratio measured on one
    host in one process. Returns (streaming, streaming_batched) dicts."""
    env = dict(os.environ)
    flags = [t for t in env.get("XLA_FLAGS", "").split()
             if not t.startswith("--xla_force_host_platform_device_count")]
    if flags:
        env["XLA_FLAGS"] = " ".join(flags)
    else:
        env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_streaming",
         "--sessions", str(n_sessions), "--out", out,
         "--batched", "--batched-out", batched_out],
        env=env, capture_output=True, text=True)
    sys.stderr.write(res.stderr)
    for line in res.stdout.splitlines():
        if line and line != "name,us_per_call,derived":
            print(line)
    if res.returncode:
        raise RuntimeError(
            f"bench_streaming subprocess exited {res.returncode}")
    with open(out) as f:
        streaming = json.load(f)
    with open(batched_out) as f:
        batched = json.load(f)
    return streaming, batched


def _precision_summary(path: str = PRECISION_PARETO):
    """The committed mixed-precision Pareto artifact, trajectory-shaped:
    front size, the headline mixed-dominates-uniform pair, and each
    point's four objectives. None when no artifact is committed (the
    trajectory row is absent, not null-filled, pre-PR-10)."""
    if not os.path.exists(path):
        return None
    try:
        from repro.tune import precision as P
        doc = P.check_pareto_artifact(path)
        points = {p["name"]: {
            "accuracy": p["accuracy"],
            "fps": p["fps"],
            "us_per_image": p["us_per_image"],
            "model_bytes": p["model_bytes"],
            "j_per_image": p["j_per_image"],
            "uniform": p["uniform"],
        } for p in doc["points"]}
        dom = P.find_domination([P.PrecisionPoint(
            name=p["name"], block_bits=p["block_bits"], alloc=p["alloc"],
            uniform=p["uniform"], accuracy=p["accuracy"],
            us_per_image=p["us_per_image"], model_bytes=p["model_bytes"],
            j_per_image=p["j_per_image"], edp=p["edp"],
            tuned_fraction=p["tuned_fraction"]) for p in doc["points"]])
        return {
            "artifact": path,
            "model": doc["model"],
            "backend": doc["backend"],
            "choices": doc["choices"],
            "n_points": len(doc["points"]),
            "front": doc["pareto"],
            "domination": ({"mixed": dom[0], "uniform": dom[1]}
                           if dom else None),
            "points": points,
        }
    except (ValueError, KeyError, ImportError) as e:
        print(f"# precision artifact {path} unreadable: {e}",
              file=sys.stderr)
        return None


def _write_trajectory(vision, kernels, baseline, smoke: bool,
                      scaling=None, streaming=None,
                      streaming_batched=None) -> None:
    # deltas are only meaningful against a same-config baseline (smoke runs
    # a reduced geometry, so its trajectory carries absolute numbers only)
    if baseline and vision and (
            (baseline.get("input_hw"), baseline.get("batch"))
            != (vision["input_hw"], vision["batch"])):
        baseline = None
    pr1_fps = None
    if baseline:
        pr1_fps = baseline.get("fps_pipelined_fast",
                               baseline.get("fps_pipelined"))
    report = {
        "pr": 10,
        "smoke": smoke,
        "baseline_source": VISION_REPORT if baseline else None,
        "serving": None,
        "tuned": None,
        "observability": None,
        "scaling": None,
        "streaming": None,
        "streaming_batched": None,
        "precision": _precision_summary(),
        "kernels": kernels,
    }
    if vision:
        fast = vision["fps_pipelined_fast"]
        report["serving"] = {
            "net": vision["net"],
            "input_hw": vision["input_hw"],
            "batch": vision["batch"],
            "backend": vision["backend"],
            "fps_naive": vision["fps_naive"],
            "fps_monolith_jit": vision["fps_monolith_jit"],
            "fps_pipelined_pr1": vision["fps_pipelined"],
            "fps_pipelined_fast": fast,
            "fps_pipelined_tuned": vision.get("fps_pipelined_tuned"),
            "fps_pipelined_obs": vision.get("fps_pipelined_obs"),
            "latency_p50_s": vision["latency_p50_s"],
            "latency_p95_s": vision["latency_p95_s"],
            "bit_exact_with_run_qnet": vision["bit_exact_with_run_qnet"],
            "speedup_fast_vs_pr1_pipelined":
                vision["speedup_fast_vs_pipelined"],
            "pr1_baseline_fps": pr1_fps,
            "speedup_vs_pr1_baseline_file": (
                fast / pr1_fps if pr1_fps else None),
            "latency_p50_delta_vs_pr1_s": (
                vision["latency_p50_s"] - baseline["latency_p50_s"]
                if baseline and "latency_p50_s" in baseline else None),
            # calibrated energy model (docs/energy.md); absent from
            # pre-PR-9 baseline files, so every read tolerates None
            "energy_j_per_image": vision.get("energy_j_per_image"),
            "watts": vision.get("watts"),
            "fps_per_watt": vision.get("fps_per_watt"),
            "power_source": vision.get("power_source"),
            "energy_tuned_fraction": vision.get("energy_tuned_fraction"),
        }
        if vision.get("fps_pipelined_obs") is not None:
            # the serving profile as the obs layer saw it: headline FPS
            # with tracing+metrics on (the <5% overhead budget), plus the
            # registry snapshot's latency percentiles / FPS-per-Watt proxy
            snap = vision.get("obs_metrics_snapshot") or {}
            lat = (snap.get("histograms") or {}).get(
                'serve_request_latency_seconds{model="default"}') or {}
            report["observability"] = {
                "fps_obs_on": vision["fps_pipelined_obs"],
                "obs_overhead_frac": vision.get("obs_overhead_frac"),
                "bit_exact_with_obs_on":
                    vision.get("obs_bit_exact_with_run_qnet"),
                "trace_events": vision.get("obs_trace_events"),
                "latency_p50_s": lat.get("p50"),
                "latency_p95_s": lat.get("p95"),
                "latency_p99_s": lat.get("p99"),
                "fps_per_watt": (snap.get("gauges") or {}).get(
                    'serve_fps_per_watt{model="default"}'),
                "metrics_snapshot": snap,
            }
        if vision.get("tuned_cache"):
            report["tuned"] = {
                "cache": vision["tuned_cache"],
                "route_coverage": vision.get("tuned_route_coverage"),
                "fps_default": fast,
                "fps_tuned": vision.get("fps_pipelined_tuned"),
                "speedup_tuned_vs_default":
                    vision.get("speedup_tuned_vs_default"),
                "bit_exact_with_run_qnet":
                    vision.get("tuned_bit_exact_with_run_qnet"),
            }
    if scaling:
        report["scaling"] = {
            "device_count": scaling["device_count"],
            "input_hw": scaling["input_hw"],
            "batch": scaling["batch"],
            "replica_counts": scaling["replica_counts"],
            "fps_per_replica_count": {
                r: p["fps"] for r, p in scaling["curve"].items()},
            "speedup_max_replicas_vs_1":
                scaling["speedup_max_replicas_vs_1"],
            "all_bit_exact_incl_golden": scaling["all_bit_exact"],
            "golden_checked": scaling.get("golden_checked"),
        }
    if streaming:
        report["streaming"] = {
            "net": streaming["net"],
            "backend": streaming["backend"],
            "window": streaming["window"],
            "hop": streaming["hop"],
            "overlap_x": streaming["overlap_x"],
            "channels": streaming["channels"],
            "n_blocks": streaming["n_blocks"],
            "kernel": streaming["kernel"],
            "bit_exact_with_run_qnet":
                streaming["bit_exact_with_run_qnet"],
            "fps_full_window": streaming["fps_full_window"],
            "fps_streaming": streaming["fps_streaming"],
            "speedup_vs_full_window":
                streaming["speedup_vs_full_window"],
            "frames_computed_per_inference":
                streaming["frames_computed_per_inference"],
            "frames_full_window": streaming["frames_full_window"],
            "frames_ratio": streaming["frames_ratio"],
            "reuse_fraction": streaming["reuse_fraction"],
            "macs_ratio": streaming["macs_ratio"],
            "session_buffer_bytes": streaming["session_buffer_bytes"],
            "n_sessions": streaming["n_sessions"],
            "session_table_bytes": streaming["session_table_bytes"],
            "bytes_per_window_step": streaming.get("bytes_per_window_step"),
            "energy_j_per_window_step":
                streaming.get("energy_j_per_window_step"),
            "watts": streaming.get("watts"),
            "fps_per_watt": streaming.get("fps_per_watt"),
            "power_source": streaming.get("power_source"),
        }
    if streaming_batched:
        sb = streaming_batched
        report["streaming_batched"] = {
            "net": sb["net"],
            "backend": sb["backend"],
            "window": sb["window"],
            "hop": sb["hop"],
            "channels": sb["channels"],
            "n_blocks": sb["n_blocks"],
            "kernel": sb["kernel"],
            "sessions_sweep": sb["sessions_sweep"],
            "sessions_max": sb["sessions_max"],
            "batch_buckets": sb["batch_buckets"],
            "bit_exact_with_run_qnet": sb["bit_exact_with_run_qnet"],
            "per_sessions": sb["per_sessions"],
            "fps_serial_step": sb["fps_serial_step"],
            "fps_batched_step": sb["fps_batched_step"],
            "speedup_vs_serial_step": sb["speedup_vs_serial_step"],
            "pad_rows": sb["pad_rows"],
            "batched_traces": sb["batched_traces"],
        }
    if kernels:
        report["bytes_moved"] = {
            "dw_hbm_bytes": kernels.get("dw_hbm_bytes"),
            "dw_hbm_bytes_saved_vs_padded_copy":
                kernels.get("dw_hbm_bytes_saved_vs_padded"),
            "irb_fused_traffic_saved_frac":
                kernels.get("irb_bytes_saved_frac"),
            "pw_hbm_bytes": kernels.get("pw_hbm_bytes"),
        }
    with open(BENCH_REPORT, "w") as f:
        json.dump(report, f, indent=1)
    print(f"# wrote {BENCH_REPORT}", file=sys.stderr)


def _assert_reports_parse(*paths: str) -> None:
    for path in (BENCH_REPORT, *paths):
        with open(path) as f:
            json.load(f)  # raises on corruption — the CI smoke assertion


def _serving_config(report):
    s = (report or {}).get("serving") or {}
    return (s.get("input_hw"), s.get("batch"), s.get("backend"))


def _collect_throughput_rows(base, cur):
    """(name, base, cur, gated) rows for the regression table.

    `gated` == the row may FAIL the gate. Only the headline serving
    throughput (the pipelined fast/tuned FPS — the metrics this repo's
    perf work owns, measured over a full drain) gates, and only when the
    measurement config matches between baseline and current. Everything
    else is informational: naive/monolith/PR-1 FPS are tiny-sample eager
    baselines, the replica-scaling curve is flat at the machine ceiling
    on small hosts (spread ~1.2x — pure machine variance), and latency /
    kernel-microsecond rows are absolute wall times."""
    rows = []
    same_serving = (_serving_config(base) == _serving_config(cur)
                    and None not in _serving_config(cur))
    bs, cs = base.get("serving") or {}, cur.get("serving") or {}
    for key in ("fps_pipelined_fast", "fps_pipelined_tuned",
                "fps_per_watt"):
        # fps_per_watt is modeled-energy throughput (docs/energy.md);
        # pre-PR-9 baselines lack the key, so the row simply doesn't form
        if bs.get(key) is not None and cs.get(key) is not None:
            rows.append((f"serving.{key}", bs[key], cs[key], same_serving))
    for key in ("fps_pipelined_obs", "fps_pipelined_pr1",
                "fps_monolith_jit", "fps_naive",
                "latency_p50_s", "latency_p95_s"):
        if bs.get(key) is not None and cs.get(key) is not None:
            rows.append((f"serving.{key}", bs[key], cs[key], False))
    bst, cst = base.get("streaming") or {}, cur.get("streaming") or {}
    st_cfg = ("window", "hop", "channels", "n_blocks", "kernel", "backend")
    same_stream = (bst and cst
                   and all(bst.get(k) == cst.get(k) for k in st_cfg))
    # the speedup ratio is same-machine by construction (both routes run
    # on the same host in one process), so it gates even across
    # heterogeneous CI machines; frames_ratio is a pure function of the
    # plan — any drop means the halo math got worse, so it gates too
    for key in ("speedup_vs_full_window", "frames_ratio",
                "fps_per_watt"):
        if bst.get(key) is not None and cst.get(key) is not None:
            rows.append((f"streaming.{key}", bst[key], cst[key],
                         bool(same_stream)))
    for key in ("fps_streaming", "fps_full_window",
                "frames_computed_per_inference"):
        if bst.get(key) is not None and cst.get(key) is not None:
            rows.append((f"streaming.{key}", bst[key], cst[key], False))
    bsb = base.get("streaming_batched") or {}
    csb = cur.get("streaming_batched") or {}
    sb_cfg = ("window", "hop", "channels", "n_blocks", "kernel",
              "backend", "sessions_max", "batch_buckets")
    same_batched = (bsb and csb
                    and all(bsb.get(k) == csb.get(k) for k in sb_cfg))
    # serial-vs-drain() on the same host in one process: a same-machine
    # ratio, so it gates across heterogeneous CI machines like the
    # streaming speedup above
    if bsb.get("speedup_vs_serial_step") is not None \
            and csb.get("speedup_vs_serial_step") is not None:
        rows.append(("streaming_batched.speedup_vs_serial_step",
                     bsb["speedup_vs_serial_step"],
                     csb["speedup_vs_serial_step"], bool(same_batched)))
    for key in ("fps_batched_step", "fps_serial_step"):
        if bsb.get(key) is not None and csb.get(key) is not None:
            rows.append((f"streaming_batched.{key}",
                         bsb[key], csb[key], False))
    bsc, csc = base.get("scaling") or {}, cur.get("scaling") or {}
    bfps = bsc.get("fps_per_replica_count") or {}
    cfps = csc.get("fps_per_replica_count") or {}
    for r in sorted(set(bfps) & set(cfps), key=lambda v: int(v)):
        rows.append((f"scaling.fps_x{r}", bfps[r], cfps[r], False))
    bk, ck = base.get("kernels") or {}, cur.get("kernels") or {}
    for key in sorted(set(bk) & set(ck)):
        if key.endswith("_us") and isinstance(bk[key], (int, float)):
            rows.append((f"kernels.{key}", bk[key], ck[key], False))
    return rows


def check_regression(report, baseline, threshold: float = 0.25,
                     baseline_path: str = "") -> int:
    """Compare `report` against a committed baseline report; return the
    number of gated throughput metrics that regressed beyond `threshold`.

    `baseline` is the already-loaded baseline dict (callers snapshot it
    BEFORE the benchmark run — this run overwrites the report file the
    baseline may live in) or a path. Prints the full delta table either
    way — regressions, improvements, and informational
    (config-mismatched / latency) rows alike."""
    if isinstance(baseline, str):
        baseline_path = baseline_path or baseline
        try:
            with open(baseline) as f:
                baseline = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[perf-gate] cannot read baseline {baseline}: {e}",
                  file=sys.stderr)
            return 1
    base = baseline
    rows = _collect_throughput_rows(base, report)
    if not rows:
        print(f"[perf-gate] no shared metrics with {baseline_path} — "
              f"nothing to gate", file=sys.stderr)
        return 0
    failures = 0
    name_w = max(len(r[0]) for r in rows)
    print(f"\n[perf-gate] vs {baseline_path} "
          f"(fail: gated fps metric down >{threshold:.0%})")
    print(f"{'metric':<{name_w}}  {'baseline':>12}  {'current':>12}  "
          f"{'delta':>8}  verdict")
    for name, b, c, gated in rows:
        higher_better = not (name.endswith("_s") or name.endswith("_us"))
        delta = (c - b) / b if b else float("inf")
        regressed = (delta < -threshold) if higher_better \
            else (delta > threshold)
        gateable = name in ("serving.fps_pipelined_fast",
                            "serving.fps_pipelined_tuned",
                            "serving.fps_per_watt",
                            "streaming.speedup_vs_full_window",
                            "streaming.frames_ratio",
                            "streaming.fps_per_watt",
                            "streaming_batched.speedup_vs_serial_step")
        if gated and regressed:
            verdict = "FAIL"
            failures += 1
        elif not gated:
            verdict = "info" + (" (config differs)" if gateable else "")
        else:
            verdict = "ok"
        print(f"{name:<{name_w}}  {b:>12.4g}  {c:>12.4g}  "
              f"{delta:>+7.1%}  {verdict}")
    if failures:
        print(f"[perf-gate] FAILED: {failures} throughput metric(s) "
              f"regressed >{threshold:.0%}", file=sys.stderr)
    else:
        print("[perf-gate] ok")
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset + JSON-report parse assertion (CI)")
    ap.add_argument("--tuned-cache", default=TUNED_CACHE,
                    help="tuning cache for the tuned-vs-default serving "
                         "measurement (skipped when absent)")
    ap.add_argument("--check-regression", metavar="BASELINE[:THRESHOLD]",
                    action="append", default=None,
                    help="after the run, gate this report's throughput "
                         "against a committed baseline report; repeatable; "
                         "an optional per-baseline :THRESHOLD overrides "
                         "--regression-threshold (e.g. BENCH_PR4.json:0.5 "
                         "for a cross-machine guard-rail)")
    ap.add_argument("--regression-threshold", type=float, default=0.25,
                    help="relative FPS drop that fails the gate")
    args = ap.parse_args(argv)

    # snapshot gate baselines BEFORE running: this run overwrites
    # BENCH_PR4.json, which is itself a valid (committed) baseline
    gate_baselines = []
    for spec in args.check_regression or ():
        path, sep, thr = spec.rpartition(":")
        try:
            threshold = float(thr) if sep else None
        except ValueError:
            threshold = None
        if threshold is None:
            path, threshold = spec, args.regression_threshold
        base = _load_baseline(path)
        gate_baselines.append((path, threshold, base))

    from benchmarks import (
        bench_bw_sweep,
        bench_fusion,
        bench_kernels,
        bench_quant_serving,
        bench_streaming,
        bench_table2,
        bench_table3,
        bench_table6_efficientnet,
        bench_vision_serving,
    )

    baseline = _load_baseline(VISION_REPORT)
    print("name,us_per_call,derived")
    failures = 0
    vision = kernels = scaling = streaming = streaming_batched = None

    # smoke must not clobber the committed perf-trajectory baseline with
    # reduced-size numbers
    vision_out = ("experiments/vision_serving_smoke.json" if args.smoke
                  else VISION_REPORT)
    scaling_out = ("experiments/vision_serving_scaling_smoke.json"
                   if args.smoke else SCALING_REPORT)
    streaming_out = ("experiments/streaming_smoke.json" if args.smoke
                     else STREAMING_REPORT)
    batched_out = ("experiments/streaming_batched_smoke.json" if args.smoke
                   else STREAMING_BATCHED_REPORT)
    if args.smoke:
        plan = [
            (bench_kernels, "kernels", lambda: bench_kernels.run()),
            (bench_vision_serving, "vision",
             lambda: bench_vision_serving.run(hw=32, n_images=16, repeats=1,
                                              out=vision_out,
                                              tuned_cache=args.tuned_cache)),
            (bench_vision_serving, "scaling",
             lambda: bench_vision_serving.run_scaling(
                 hw=32, n_images=16, repeats=1, out=scaling_out)),
            # same geometry AND windows/repeats as the committed baseline
            # (the speedup / frames_ratio gates compare like against
            # like; fewer timed windows makes the ~3ms streaming steps
            # noise-dominated and under-reports the speedup). Only the
            # session-table sizing is trimmed — it is untimed. Runs in an
            # isolated single-device subprocess (see
            # _run_streaming_isolated). The batched fleet sweep keeps its
            # full default config too — its gated speedup_vs_serial_step
            # compares like against like with the committed baseline.
            (bench_streaming, "streaming",
             lambda: _run_streaming_isolated(streaming_out, batched_out,
                                             n_sessions=2)),
        ]
    else:
        plan = [
            (m, None, m.run) for m in (
                bench_table2, bench_bw_sweep, bench_table3, bench_fusion,
                bench_table6_efficientnet, bench_quant_serving)
        ] + [
            (bench_kernels, "kernels", lambda: bench_kernels.run()),
            (bench_vision_serving, "vision",
             lambda: bench_vision_serving.run(
                 tuned_cache=args.tuned_cache)),
            (bench_vision_serving, "scaling",
             lambda: bench_vision_serving.run_scaling(out=scaling_out)),
            (bench_streaming, "streaming",
             lambda: _run_streaming_isolated(streaming_out, batched_out)),
        ]

    for mod, slot, fn in plan:
        try:
            out = fn()
            if slot == "kernels":
                kernels = out
            elif slot == "vision":
                vision = out
            elif slot == "scaling":
                scaling = out
            elif slot == "streaming":
                streaming, streaming_batched = out
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},0.0,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)

    if args.tuned_cache and vision is not None \
            and not vision.get("tuned_cache"):
        # the tuned path was requested (CI passes the committed cache
        # explicitly) but the cache file was absent: failing loudly here
        # is what keeps the tuned fps gate row from silently vanishing
        # from the regression table. Opt out with --tuned-cache "".
        failures += 1
        print(f"benchmarks.run,0.0,ERROR:tuned cache {args.tuned_cache} "
              f"missing — tuned serving path was not exercised",
              file=sys.stderr)
    _write_trajectory(vision, kernels, baseline, args.smoke, scaling,
                      streaming, streaming_batched)
    if failures:
        # exit on the recorded benchmark errors before asserting report
        # files that a failed benchmark never wrote (a FileNotFoundError
        # here would bury the real cause)
        sys.exit(1)
    if args.smoke:
        _assert_reports_parse(vision_out, scaling_out, streaming_out,
                              batched_out)
    if gate_baselines:
        with open(BENCH_REPORT) as f:
            report = json.load(f)
        gate_failures = 0
        for path, threshold, base in gate_baselines:
            if base is None:
                print(f"[perf-gate] cannot read baseline {path}",
                      file=sys.stderr)
                gate_failures += 1
                continue
            gate_failures += check_regression(report, base, threshold,
                                              baseline_path=path)
        if gate_failures:
            sys.exit(2)


if __name__ == "__main__":
    main()
