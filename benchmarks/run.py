# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_table2              Table 2   (alpha x H: params / #ops)
  bench_bw_sweep            Fig. 13   (bit-width: size / SQNR / int inference)
  bench_table3              Table 3/4 (FPS per design point, roofline-projected)
  bench_fusion              Sec 5.1.2 (fused Body CU traffic reduction)
  bench_table6_efficientnet Table 6/7 (compact EfficientNet + CU mapping)
  bench_quant_serving       beyond-paper: LM weight-quantized serving
  bench_vision_serving      beyond-paper: pipelined CU-stage vision serving
  bench_kernels             kernel-level microbenchmarks
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        bench_bw_sweep,
        bench_fusion,
        bench_kernels,
        bench_quant_serving,
        bench_table2,
        bench_table3,
        bench_table6_efficientnet,
        bench_vision_serving,
    )

    print("name,us_per_call,derived")
    mods = [
        bench_table2, bench_bw_sweep, bench_table3, bench_fusion,
        bench_table6_efficientnet, bench_quant_serving,
        bench_vision_serving, bench_kernels,
    ]
    failures = 0
    for m in mods:
        try:
            m.run()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{m.__name__},0.0,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
