# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_table2              Table 2   (alpha x H: params / #ops)
  bench_bw_sweep            Fig. 13   (bit-width: size / SQNR / int inference)
  bench_table3              Table 3/4 (FPS per design point, roofline-projected)
  bench_fusion              Sec 5.1.2 (fused Body CU traffic reduction)
  bench_table6_efficientnet Table 6/7 (compact EfficientNet + CU mapping)
  bench_quant_serving       beyond-paper: LM weight-quantized serving
  bench_vision_serving      beyond-paper: pipelined CU-stage vision serving
                            (+ the multi-replica sharded scaling curve)
  bench_kernels             kernel-level microbenchmarks

`--smoke` runs the fast subset (kernels + a reduced vision-serving pass +
the replica-scaling sweep) and asserts the JSON reports still parse — the
CI gate. A full (or smoke) run aggregates the per-benchmark results into a
perf-trajectory report at the repo root, BENCH_PR3.json: throughput /
latency / analytic bytes-moved, the per-replica-count scaling curve (each
point conformance-checked against the frozen golden fixtures), plus deltas
against the previous PR's `experiments/vision_serving.json` baseline
captured before this run overwrote it. Force N CPU devices with
`XLA_FLAGS=--xla_force_host_platform_device_count=N` to exercise the
sharded points.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BENCH_REPORT = "BENCH_PR3.json"
VISION_REPORT = "experiments/vision_serving.json"
SCALING_REPORT = "experiments/vision_serving_scaling.json"


def _load_baseline(path: str):
    """The previous PR's vision-serving numbers (read before overwriting)."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        return None


def _write_trajectory(vision, kernels, baseline, smoke: bool,
                      scaling=None) -> None:
    # deltas are only meaningful against a same-config baseline (smoke runs
    # a reduced geometry, so its trajectory carries absolute numbers only)
    if baseline and vision and (
            (baseline.get("input_hw"), baseline.get("batch"))
            != (vision["input_hw"], vision["batch"])):
        baseline = None
    pr1_fps = None
    if baseline:
        pr1_fps = baseline.get("fps_pipelined_fast",
                               baseline.get("fps_pipelined"))
    report = {
        "pr": 3,
        "smoke": smoke,
        "baseline_source": VISION_REPORT if baseline else None,
        "serving": None,
        "scaling": None,
        "kernels": kernels,
    }
    if vision:
        fast = vision["fps_pipelined_fast"]
        report["serving"] = {
            "net": vision["net"],
            "input_hw": vision["input_hw"],
            "batch": vision["batch"],
            "backend": vision["backend"],
            "fps_naive": vision["fps_naive"],
            "fps_monolith_jit": vision["fps_monolith_jit"],
            "fps_pipelined_pr1": vision["fps_pipelined"],
            "fps_pipelined_fast": fast,
            "latency_p50_s": vision["latency_p50_s"],
            "latency_p95_s": vision["latency_p95_s"],
            "bit_exact_with_run_qnet": vision["bit_exact_with_run_qnet"],
            "speedup_fast_vs_pr1_pipelined":
                vision["speedup_fast_vs_pipelined"],
            "pr1_baseline_fps": pr1_fps,
            "speedup_vs_pr1_baseline_file": (
                fast / pr1_fps if pr1_fps else None),
            "latency_p50_delta_vs_pr1_s": (
                vision["latency_p50_s"] - baseline["latency_p50_s"]
                if baseline and "latency_p50_s" in baseline else None),
        }
    if scaling:
        report["scaling"] = {
            "device_count": scaling["device_count"],
            "input_hw": scaling["input_hw"],
            "batch": scaling["batch"],
            "replica_counts": scaling["replica_counts"],
            "fps_per_replica_count": {
                r: p["fps"] for r, p in scaling["curve"].items()},
            "speedup_max_replicas_vs_1":
                scaling["speedup_max_replicas_vs_1"],
            "all_bit_exact_incl_golden": scaling["all_bit_exact"],
            "golden_checked": scaling.get("golden_checked"),
        }
    if kernels:
        report["bytes_moved"] = {
            "dw_hbm_bytes": kernels.get("dw_hbm_bytes"),
            "dw_hbm_bytes_saved_vs_padded_copy":
                kernels.get("dw_hbm_bytes_saved_vs_padded"),
            "irb_fused_traffic_saved_frac":
                kernels.get("irb_bytes_saved_frac"),
            "pw_hbm_bytes": kernels.get("pw_hbm_bytes"),
        }
    with open(BENCH_REPORT, "w") as f:
        json.dump(report, f, indent=1)
    print(f"# wrote {BENCH_REPORT}", file=sys.stderr)


def _assert_reports_parse(*paths: str) -> None:
    for path in (BENCH_REPORT, *paths):
        with open(path) as f:
            json.load(f)  # raises on corruption — the CI smoke assertion


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset + JSON-report parse assertion (CI)")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_bw_sweep,
        bench_fusion,
        bench_kernels,
        bench_quant_serving,
        bench_table2,
        bench_table3,
        bench_table6_efficientnet,
        bench_vision_serving,
    )

    baseline = _load_baseline(VISION_REPORT)
    print("name,us_per_call,derived")
    failures = 0
    vision = kernels = scaling = None

    # smoke must not clobber the committed perf-trajectory baseline with
    # reduced-size numbers
    vision_out = ("experiments/vision_serving_smoke.json" if args.smoke
                  else VISION_REPORT)
    scaling_out = ("experiments/vision_serving_scaling_smoke.json"
                   if args.smoke else SCALING_REPORT)
    if args.smoke:
        plan = [
            (bench_kernels, "kernels", lambda: bench_kernels.run()),
            (bench_vision_serving, "vision",
             lambda: bench_vision_serving.run(hw=32, n_images=16, repeats=1,
                                              out=vision_out)),
            (bench_vision_serving, "scaling",
             lambda: bench_vision_serving.run_scaling(
                 hw=32, n_images=16, repeats=1, out=scaling_out)),
        ]
    else:
        plan = [
            (m, None, m.run) for m in (
                bench_table2, bench_bw_sweep, bench_table3, bench_fusion,
                bench_table6_efficientnet, bench_quant_serving)
        ] + [
            (bench_kernels, "kernels", lambda: bench_kernels.run()),
            (bench_vision_serving, "vision",
             lambda: bench_vision_serving.run()),
            (bench_vision_serving, "scaling",
             lambda: bench_vision_serving.run_scaling(out=scaling_out)),
        ]

    for mod, slot, fn in plan:
        try:
            out = fn()
            if slot == "kernels":
                kernels = out
            elif slot == "vision":
                vision = out
            elif slot == "scaling":
                scaling = out
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},0.0,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)

    _write_trajectory(vision, kernels, baseline, args.smoke, scaling)
    if failures:
        # exit on the recorded benchmark errors before asserting report
        # files that a failed benchmark never wrote (a FileNotFoundError
        # here would bury the real cause)
        sys.exit(1)
    if args.smoke:
        _assert_reports_parse(vision_out, scaling_out)


if __name__ == "__main__":
    main()
