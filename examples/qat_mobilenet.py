"""End-to-end driver (the paper's kind: power-efficient DSCNN *inference*).

Full DeepDive front-end flow (Fig. 1/4) on a synthetic learnable dataset:

    float pre-training -> online channel-wise 4-bit QAT -> calibration ->
    post-training quantization (ReLU6 fusion) -> QNet artifact on disk ->
    pure-integer inference accuracy report.

    PYTHONPATH=src python examples/qat_mobilenet.py [--steps 150]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cu, qnet as Q
from repro.core.calibrate import calibrate
from repro.core.quant import QuantConfig
from repro.data.pipeline import image_batch
from repro.models import layers, mobilenet_v2 as mnv2
from repro.train import optimizer as O

HW, CLASSES = 16, 4


def train(net, params, steps, qat, lr, seed=0, log_every=25):
    ocfg = O.AdamWConfig(lr=lr, warmup_steps=5, total_steps=steps,
                         weight_decay=0.0)
    opt = O.init_state(params)

    @jax.jit
    def step(params, opt, images, labels):
        def loss_fn(p):
            logits, _ = layers.forward(p, images, net, qat=qat)
            lp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(lp, labels[:, None], 1).mean()

        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = O.apply_updates(params, g, opt, ocfg)
        return params, opt, loss

    for s in range(steps):
        b = image_batch(seed, s, 32, HW, CLASSES)
        params, opt, loss = step(params, opt, jnp.asarray(b["images"]),
                                 jnp.asarray(b["labels"]))
        if (s + 1) % log_every == 0:
            print(f"  [{'qat' if qat else 'fp32'}] step {s+1} "
                  f"loss={float(loss):.4f}")
    return params


def accuracy(fn, seed=99, n=8):
    correct = total = 0
    for s in range(n):
        b = image_batch(seed, s, 32, HW, CLASSES)
        pred = fn(jnp.asarray(b["images"]))
        correct += int((np.asarray(pred) == b["labels"]).sum())
        total += len(b["labels"])
    return correct / total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--out", default="/tmp/qnet_mobilenet.bin")
    args = ap.parse_args()

    net = mnv2.build(alpha=0.35, input_hw=HW, num_classes=CLASSES)
    params = layers.init_params(jax.random.PRNGKey(0), net)
    print("stage 1: float pre-training")
    params = train(net, params, args.steps, qat=False, lr=2e-3)
    acc_fp = accuracy(lambda x: jnp.argmax(layers.forward(params, x, net)[0], -1))

    print("stage 2: online channel-wise 4-bit quantization (QAT)")
    params = train(net, params, args.steps // 2, qat=True, lr=5e-4)
    acc_qat = accuracy(
        lambda x: jnp.argmax(layers.forward(params, x, net, qat=True)[0], -1))

    print("stage 3: calibration + post-training quantization -> QNet")
    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]
    cal = [jnp.asarray(image_batch(1, s, 32, HW, CLASSES)["images"])
           for s in range(4)]
    obs = calibrate(apply_fn, params, cal, QuantConfig(4, False, None))
    qn = Q.quantize_net(params, net, obs)
    Q.save_qnet(qn, args.out)
    qn2 = Q.load_qnet(args.out, net)
    acc_int = accuracy(lambda x: jnp.argmax(cu.run_qnet(qn2, x), -1))

    fp32_kb = net.n_params(False) * 4 / 1e3
    print(f"\nresults:")
    print(f"  float accuracy      : {acc_fp:.3f}")
    print(f"  QAT (fake-quant)    : {acc_qat:.3f}")
    print(f"  integer QNet        : {acc_int:.3f}")
    print(f"  model size          : {qn2.model_bytes()/1e3:.1f} KB "
          f"(FP32: {fp32_kb:.1f} KB, {fp32_kb/(qn2.model_bytes()/1e3):.1f}x)")
    print(f"  QNet artifact       : {args.out}")


if __name__ == "__main__":
    main()
