"""Quickstart: the DeepDive flow end to end on a small MobileNet-V2.

    PYTHONPATH=src python examples/quickstart.py

1. build the network description (NetSpec) and inspect the paper's Table-2
   arithmetic,
2. compile it to heterogeneous CUs (Head/Body/Tail/Classifier),
3. quantize (calibration -> QNet) and run pure-integer inference,
4. run one Body-CU invocation through the fused Pallas kernel and check it
   against the unfused integer path bit-for-bit.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import compiler, cu, qnet as Q
from repro.core.calibrate import calibrate
from repro.core.quant import QuantConfig
from repro.kernels.ops import run_irb_block
from repro.models import layers, mobilenet_v2 as mnv2


def main():
    # 1. network description model -------------------------------------------------
    net = mnv2.build(alpha=0.35, input_hw=32, num_classes=10)
    print(f"net: {net.name}")
    print(f"  params        : {net.n_params(False)/1e6:.2f} M")
    print(f"  model size    : {net.model_bits(False)/2**20:.2f} Mib at BW=4 "
          f"({net.n_params(False)*4/2**20:.1f} MiB at FP32)")
    print(f"  MACs/image    : {net.count_macs()/1e6:.1f} M "
          f"(+{net.count_bn_ops()/1e6:.1f} M if BN unfused)")

    # 2. Network SoC Compiler: CU partition ---------------------------------------
    plan = compiler.compile_net(net)
    roles = [a.cu for a in plan.schedule]
    print(f"  CU schedule   : head x{roles.count('head')}, "
          f"body x{plan.body_invocations}, tail x{roles.count('tail')}, "
          f"classifier x{roles.count('classifier')}")
    print(f"  ParallelOps   : {plan.parallel_ops()}  (Eqs. 8-10)")

    # 3. quantize -> QNet -> integer inference ------------------------------------
    params = layers.init_params(jax.random.PRNGKey(0), net)

    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]

    batches = [jax.random.uniform(jax.random.PRNGKey(i), (4, 32, 32, 3),
                                  minval=-1, maxval=1) for i in range(3)]
    obs = calibrate(apply_fn, params, batches, QuantConfig(4, False, None))
    qn = Q.quantize_net(params, net, obs)
    print(f"  QNet size     : {qn.model_bytes()/1e3:.1f} KB "
          f"(vs {net.n_params(False)*4/1e3:.1f} KB FP32)")
    x = batches[0]
    logits_int = cu.run_qnet(qn, x)
    logits_fp, _ = layers.forward(params, x, net)
    agree = float((jnp.argmax(logits_int, -1) == jnp.argmax(logits_fp, -1)).mean())
    print(f"  int-vs-float top-1 agreement on random net: {agree:.2f}")

    # 4. fused Body CU through the Pallas kernel ----------------------------------
    first = qn.ops[net.blocks[0].ops[0].name]
    y = cu.quantize_input(x, first.in_scale, first.in_zp, 8)
    s, z = first.in_scale, first.in_zp
    for block in net.blocks:
        if len(block.ops) == 3 and block.se is None:
            y_fused, _, _ = run_irb_block(y, block, qn, s, z, interpret=True)
            y_ref, _, _ = cu.run_block(y, block, qn, s, z)
            exact = bool((y_fused == y_ref).all())
            print(f"  fused Pallas Body CU ({block.name}): bit-exact={exact}")
            break
        y, s, z = cu.run_block(y, block, qn, s, z)
    print("quickstart OK")


if __name__ == "__main__":
    main()
