"""Serve a small LM with batched requests (+ the paper's weight quantization).

    PYTHONPATH=src python examples/serve_lm.py [--quant-bits 8]

Uses the production Engine (prefill + lockstep batched decode) on the
reduced llama3.2-1b config; --quant-bits applies DeepDive's range-based
symmetric per-channel quantization to every linear operator.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "llama3.2-1b", "--reduced", "--requests", "6",
                "--slots", "3", "--max-new", "12"] + argv
    main(argv)
