"""Quickstart: serve a quantized MobileNet-V2 through the pipelined
CU-stage vision engine.

    PYTHONPATH=src python examples/serve_vision.py

Multi-replica sharded serving (split micro-batches across N CPU devices):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python examples/serve_vision.py

Walks the full deployment path from the paper: build the NetSpec, calibrate
activations, quantize to an integer QNet, compile the CU schedule into
stage executors, then serve a stream of requests with continuous batching —
and shows the engine output is bit-exact with the reference integer runner.
When more than one device is visible, the engine shards every micro-batch
data-parallel across a `dist.sharding.data_mesh`; the logits stay
bit-identical to the single-device run. A second model (the compact
EfficientNet) is served concurrently through the EDF `MultiModelEngine`.
The multi-model run records a request-lifecycle trace + metrics
(`repro.obs`), dumps the trace as Perfetto-loadable Chrome JSON, and prints
the pipeline-profile summary.
"""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler as CC, cu
from repro.dist.sharding import data_mesh
from repro.models import efficientnet as effn, mobilenet_v2 as mnv2
from repro.models.layers import make_calibrated_qnet
from repro.obs import MetricsRegistry, Tracer, render_report, summarize_trace
from repro.serve.vision import MultiModelEngine, VisionEngine


def main():
    hw = 64
    net = mnv2.build(alpha=0.35, input_hw=hw, num_classes=1000)
    # front-end: float model -> calibrated integer QNet (BW=4)
    qnet = make_calibrated_qnet(net, n_cal=4)

    # back-end: CU schedule -> pipelined serving engine, replicated over
    # every visible device (a single device degenerates to the plain engine)
    plan = CC.compile_net(net)
    print("CU schedule:", [(s.cu, s.invocations)
                           for s in plan.stage_signatures()])
    n_dev = len(jax.devices())
    mesh = data_mesh(n_dev) if n_dev > 1 else None
    print(f"serving over {n_dev} device(s)"
          + (f" (mesh {dict(mesh.shape)})" if mesh else ""))
    engine = VisionEngine(qnet, plan, buckets=(1, 2, 4, 8), mesh=mesh)
    engine.warmup()

    # 3. serve a request stream (some with deadlines)
    images = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(7), (20, hw, hw, 3), minval=-1, maxval=1))
    now = time.perf_counter()
    rids = []
    for i, img in enumerate(images):
        deadline = now + 5.0 if i % 3 == 0 else None
        rids.append(engine.submit(img, deadline_s=deadline))
    results = engine.run()

    # 4. check against the monolithic integer reference + report stats
    ref = np.asarray(cu.run_qnet(qnet, jnp.asarray(images)))
    got = np.stack([results[r].logits for r in rids])
    print("bit-exact with cu.run_qnet:", bool(np.array_equal(got, ref)))
    stats = engine.stats()
    print(f"served {stats.n_ok} images in {stats.wall_s:.3f}s "
          f"({stats.fps:.1f} FPS, p95 latency {stats.latency_p95_s*1e3:.0f}ms)")
    print(f"micro-batches: {stats.micro_batches} "
          f"(pad fraction {stats.pad_fraction:.2f}), "
          f"stage invocations: {stats.stage_invocations}")
    print(f"modeled energy: {stats.energy_j_per_image*1e6:.2f} uJ/image "
          f"({stats.power_source}, {stats.energy_tuned_fraction:.0%} from "
          f"measured routes) -> {stats.watts:.1f} W, "
          f"{stats.fps_per_watt:.1f} FPS/W")

    # 5. multi-model routing: MobileNetV2 + compact EfficientNet share the
    # device(s); the router dispatches micro-batches EDF across models.
    # One shared tracer + registry puts both models on one observability
    # timeline (per-request lifecycle spans, per-stage dispatch tracks).
    tracer, metrics = Tracer(), MetricsRegistry()
    effq = make_calibrated_qnet(
        effn.build_compact(input_hw=hw, num_classes=1000), n_cal=4)
    router = MultiModelEngine({
        "mobilenet_v2": VisionEngine(
            qnet, buckets=(2, 4), mesh=mesh, tracer=tracer,
            metrics=metrics, name="mobilenet_v2"),
        "efficientnet_compact": VisionEngine(
            effq, buckets=(2, 4), mesh=mesh, tracer=tracer,
            metrics=metrics, name="efficientnet_compact"),
    })
    router.warmup()
    now = time.perf_counter()
    handles = [router.submit("mobilenet_v2" if i % 2 == 0
                             else "efficientnet_compact", img,
                             deadline_s=now + (1.0 if i % 4 == 1 else 10.0))
               for i, img in enumerate(images[:8])]
    res = router.run()
    router.stats()  # refresh the fps / fps-per-watt gauges
    print(f"multi-model: {sum(res[h].status == 'ok' for h in handles)}/8 ok, "
          f"dispatch order {[m for m, _ in router.dispatch_log]}")

    # 6. export the trace (drop into https://ui.perfetto.dev) + summarize
    trace_path = os.path.join(tempfile.gettempdir(), "serve_vision_trace.json")
    tracer.save(trace_path)
    print(f"trace ({len(tracer)} events) -> {trace_path}")
    print(render_report(summarize_trace(tracer.to_chrome()),
                        metrics.snapshot()))


if __name__ == "__main__":
    main()
