"""Quickstart: serve a quantized MobileNet-V2 through the pipelined
CU-stage vision engine.

    PYTHONPATH=src python examples/serve_vision.py

Walks the full deployment path from the paper: build the NetSpec, calibrate
activations, quantize to an integer QNet, compile the CU schedule into
stage executors, then serve a stream of requests with continuous batching —
and shows the engine output is bit-exact with the reference integer runner.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler as CC, cu, qnet as Q
from repro.core.calibrate import calibrate
from repro.core.quant import QuantConfig
from repro.models import layers, mobilenet_v2 as mnv2
from repro.serve.vision import VisionEngine


def main():
    # 1. front-end: float model -> calibrated integer QNet (BW=4)
    hw = 64
    net = mnv2.build(alpha=0.35, input_hw=hw, num_classes=1000)
    params = layers.init_params(jax.random.PRNGKey(0), net)

    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]

    cal = [jax.random.uniform(jax.random.PRNGKey(i), (2, hw, hw, 3),
                              minval=-1, maxval=1) for i in range(4)]
    obs = calibrate(apply_fn, params, cal, QuantConfig(4, False, None))
    qnet = Q.quantize_net(params, net, obs)

    # 2. back-end: CU schedule -> pipelined serving engine
    plan = CC.compile_net(net)
    print("CU schedule:", [(s.cu, s.invocations)
                           for s in plan.stage_signatures()])
    engine = VisionEngine(qnet, plan, buckets=(1, 2, 4, 8))
    engine.warmup()

    # 3. serve a request stream (some with deadlines)
    images = np.asarray(jax.random.uniform(
        jax.random.PRNGKey(7), (20, hw, hw, 3), minval=-1, maxval=1))
    now = time.perf_counter()
    rids = []
    for i, img in enumerate(images):
        deadline = now + 5.0 if i % 3 == 0 else None
        rids.append(engine.submit(img, deadline_s=deadline))
    results = engine.run()

    # 4. check against the monolithic integer reference + report stats
    ref = np.asarray(cu.run_qnet(qnet, jnp.asarray(images)))
    got = np.stack([results[r].logits for r in rids])
    print("bit-exact with cu.run_qnet:", bool(np.array_equal(got, ref)))
    stats = engine.stats()
    print(f"served {stats.n_ok} images in {stats.wall_s:.3f}s "
          f"({stats.fps:.1f} FPS, p95 latency {stats.latency_p95_s*1e3:.0f}ms)")
    print(f"micro-batches: {stats.micro_batches} "
          f"(pad fraction {stats.pad_fraction:.2f}), "
          f"stage invocations: {stats.stage_invocations}")
    print(f"energy proxy: {stats.energy_j_per_image_proxy*1e6:.2f} uJ/image "
          f"-> {stats.fps_per_watt_proxy:.0f} FPS/W-proxy")


if __name__ == "__main__":
    main()
