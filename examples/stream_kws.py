"""Quickstart: stream a keyword-spotting DSCNN with ring-buffer serving.

    PYTHONPATH=src python examples/stream_kws.py

The deployment shape this demonstrates is always-on audio: windows of W
frames scored every H new frames (here H = W/8, i.e. 8x overlap). A
stateless deployment recomputes the whole W-frame window per score; the
`StreamEngine` keeps per-session integer ring buffers at every layer
boundary and recomputes only the H new frames plus each layer's SAME-pad
halo — per-window cost O(H + halo) instead of O(W), bit-exact with the
full-window reference.

The demo opens several concurrent sessions (think: microphones), feeds
them interleaved random-length chunks staged with `push(..., defer=True)`,
then advances the whole fleet with `drain()` — which groups every ready
session into one bucketed jitted step over the stacked batch axis instead
of dispatching per session — proves every session's logits are
bit-identical to `cu.run_qnet` over the corresponding full windows, and
prints the plan's reuse accounting, the engine stats (including the
batched-stepping counters), and the shared observability counters/trace.
"""
import os
import tempfile

import numpy as np

from repro.models import dscnn1d
from repro.models.layers import make_calibrated_qnet
from repro.obs import MetricsRegistry, Tracer
from repro.serve import stream as ST

WINDOW, HOP, N_SESSIONS, N_WINDOWS = 128, 16, 4, 12


def main():
    # front-end: float KWS net -> calibrated integer QNet
    net = dscnn1d.build_kws(input_t=WINDOW, input_ch=10, channels=32,
                            n_blocks=3, kernel=5, bits=8, num_classes=12)
    qnet = make_calibrated_qnet(net, seed=0)

    # the static plan is the whole story: per-layer halo + reuse accounting
    plan = ST.plan_stream(qnet, HOP)
    print(f"window={plan.window} hop={plan.hop} "
          f"({plan.window // plan.hop}x overlap)")
    print(f"frames computed per inference: {plan.frames_step} streaming "
          f"vs {plan.frames_full} full-window "
          f"({plan.reuse_fraction:.0%} of conv output frames reused)")
    print(f"ring buffers: {plan.buffer_bytes} bytes/session (uint8)")
    for bs in plan.blocks:
        for os_ in bs.ops:
            print(f"  {os_.name:<24} T={os_.tout:<4} recompute "
                  f"left={os_.lout:<3} right={os_.rout}")

    # one engine, shared jitted prime/step traces, N concurrent sessions;
    # batch buckets bound retraces: groups of 2 or 4 sessions advance in
    # one stacked dispatch, a straggler takes the single-session program
    tracer, metrics = Tracer(), MetricsRegistry()
    eng = ST.StreamEngine(qnet, HOP, tracer=tracer, metrics=metrics,
                          name="kws", batch_buckets=(2, 4))
    eng.warm(batches=(2, 4))  # pay every XLA compilation before live audio

    rng = np.random.default_rng(0)
    n_frames = ST.frames_for_windows(N_WINDOWS, WINDOW, HOP)
    mics = {eng.open_session(f"mic{i}"):
            rng.uniform(-1, 1, (n_frames, net.input_ch)).astype(np.float32)
            for i in range(N_SESSIONS)}

    # interleave random-length chunks across sessions, as live audio
    # would: stage each chunk without stepping (defer=True), then advance
    # every ready session at once — drain() batches the fleet
    results = {sid: [] for sid in mics}
    cursor = dict.fromkeys(mics, 0)
    while any(cursor[sid] < len(mics[sid]) for sid in mics):
        for sid in mics:
            lo = cursor[sid]
            if lo >= len(mics[sid]):
                continue
            hi = min(lo + int(rng.integers(1, 3 * HOP)), len(mics[sid]))
            eng.push(sid, mics[sid][lo:hi], defer=True)
            cursor[sid] = hi
        for r in eng.drain():
            results[r.sid].append(r)

    # every session's windows must match the full-window reference exactly
    for sid, frames in mics.items():
        got = np.stack([r.logits for r in results[sid]])
        ref = ST.reference_windows(qnet, frames, WINDOW, HOP)
        exact = bool(got.shape == ref.shape and np.array_equal(got, ref))
        print(f"{sid}: {len(results[sid])} windows, "
              f"bit-exact with cu.run_qnet: {exact}")
        assert exact

    stats = eng.stats()
    print(f"steady-state: {stats['fps_streamed']:.0f} windows/s "
          f"({stats['steps']:.0f} steps, {stats['primes']:.0f} primes, "
          f"{eng.sessions_active} sessions, "
          f"{eng.session_table_bytes()} bytes resident = "
          f"{eng.session_table_buffer_bytes()} ring + "
          f"{eng.session_table_pending_bytes()} pending)")
    print(f"batched: {stats['windows_batched']:.0f}/{stats['windows']:.0f} "
          f"windows in {stats['batched_calls']:.0f} stacked dispatches "
          f"({stats['batched_traces']:.0f} traces, "
          f"{stats['pad_rows']:.0f} pad rows)")
    snap = metrics.snapshot()
    for name, val in sorted(snap["counters"].items()):
        print(f"  {name} = {val:.0f}")

    trace_path = os.path.join(tempfile.gettempdir(), "stream_kws_trace.json")
    tracer.save(trace_path)
    print(f"trace ({len(tracer)} events) -> {trace_path}")


if __name__ == "__main__":
    main()
