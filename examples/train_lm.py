"""Train an LM on the deterministic synthetic stream with fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 100 \
        [--arch mamba2-1.3b] [--grad-compress] [--resume]

Reduced configs run on CPU; the same driver scales to the production mesh
(see launch/dryrun.py for the lowered 512-chip step). Checkpoints land in
--ckpt-dir and a restart with --resume continues the data stream exactly.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "llama3.2-1b"] + argv
    if "--reduced" not in argv:
        argv.append("--reduced")
    if "--ckpt-dir" not in argv:
        argv += ["--ckpt-dir", "/tmp/repro_lm_ckpt"]
    main(argv)
