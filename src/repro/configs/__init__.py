from repro.configs.registry import ARCHS, get_config, reduced_config

__all__ = ["ARCHS", "get_config", "reduced_config"]
