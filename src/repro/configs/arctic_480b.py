"""arctic-480b [moe]: 128 experts top-2 + dense residual MLP.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.models.lm.config import LMConfig


def get_config(**kw) -> LMConfig:
    return LMConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,  # the parallel dense-residual MLP
        vocab=32000,
        n_experts=128,
        top_k=2,
        moe_d_ff=4864,
        dense_residual=True,
        **kw,
    )
