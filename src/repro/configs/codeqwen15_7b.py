"""codeqwen1.5-7b [dense]: qwen1.5 architecture (MHA).

32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf]
"""
from repro.models.lm.config import LMConfig


def get_config(**kw) -> LMConfig:
    return LMConfig(
        name="codeqwen1.5-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,
        d_ff=13440,
        vocab=92416,
        **kw,
    )
