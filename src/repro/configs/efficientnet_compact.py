"""Compact EfficientNet (the paper's second case study, Sec. 5.2)."""
from repro.models import efficientnet as _e


def get_config(input_hw: int = 128, bits: int = 4, **kw):
    return _e.build_compact(input_hw=input_hw, bits=bits, **kw)
