"""mamba2-1.3b [ssm]: SSD (state-space duality), attention-free.

48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]
"""
from repro.models.lm.config import LMConfig


def get_config(**kw) -> LMConfig:
    return LMConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=32,  # d_inner / ssm_head_dim = 4096 / 128
        n_kv_heads=32,
        d_ff=0,
        vocab=50280,
        ssm_state=128,
        ssm_head_dim=128,
        ssm_expand=2,
        ssm_chunk=256,
        conv_width=4,
        tie_embeddings=True,
        **kw,
    )
