"""MobileNet-V2 design points (the paper's own case study, Sec. 5.1)."""
from repro.models import mobilenet_v2 as _m

# the paper's Table 2 design space
ALPHAS = (1.0, 0.75, 0.5, 0.35)
RESOLUTIONS = (224, 192, 160, 128, 96)


def get_config(alpha: float = 0.75, input_hw: int = 224, bits: int = 4, **kw):
    return _m.build(alpha=alpha, input_hw=input_hw, bits=bits, **kw)
