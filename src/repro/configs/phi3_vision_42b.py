"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend (STUB).

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The CLIP vision tower is a modality-frontend stub: input_specs() provides
precomputed patch embeddings [B, 576, d_model] (24x24 patches), projected by
a single learned matrix and prepended to the token sequence.
"""
from repro.models.lm.config import LMConfig


def get_config(**kw) -> LMConfig:
    return LMConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab=32064,
        frontend="vision",
        frontend_len=576,
        **kw,
    )
