"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts, top-4.

24L d_model=2048 16H (kv=16, MHA) d_ff=1408 vocab=151936, MoE 60e top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.models.lm.config import LMConfig


def get_config(**kw) -> LMConfig:
    return LMConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab=151936,
        n_experts=60,
        top_k=4,
        moe_d_ff=1408,
        n_shared_experts=4,
        shared_d_ff=4 * 1408,  # shared experts fused into one wide MLP
        **kw,
    )
