"""qwen3-32b [dense]: qk_norm, GQA.

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936
[hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.lm.config import LMConfig


def get_config(**kw) -> LMConfig:
    return LMConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=80,
        d_ff=25600,
        vocab=151936,
        qk_norm=True,
        **kw,
    )
