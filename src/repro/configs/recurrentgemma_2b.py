"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1 attn : 2 rec.

26L d_model=2560 10H (GQA kv=1 == MQA) d_ff=7680 vocab=256000
[arXiv:2402.19427; hf]
"""
from repro.models.lm.config import LMConfig


def get_config(**kw) -> LMConfig:
    return LMConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab=256000,
        block_pattern=("rec", "rec", "attn"),
        lru_width=2560,
        conv_width=4,
        local_window=2048,
        tie_embeddings=True,
        **kw,
    )
