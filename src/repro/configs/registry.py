"""Architecture registry: --arch <id> -> LMConfig / NetSpec.

`get_config(arch)` returns the FULL published configuration (exercised only
via the dry-run — ShapeDtypeStruct, no allocation). `reduced_config(arch)`
returns a structure-preserving shrunken version (same family, same flags,
same layer pattern, tiny dims) used by the per-arch CPU smoke tests and to
build the logical-sharding tree without materializing the full model.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.lm.config import LMConfig

# arch id -> module path (LM archs) — the paper's own DSCNNs are separate
ARCHS = {
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a27b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "llama3.2-1b": "repro.configs.llama32_1b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_42b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "mamba2-1.3b": "repro.configs.mamba2_13b",
}

CNN_ARCHS = ("mobilenet-v2", "efficientnet-compact")

# 1-D streaming DSCNN archs: arch id -> (build-record model family, builder
# defaults). The record round-trips through `qnet.build_netspec`, so a
# `.qnet` artifact saved with `build=netspec_build_record(arch)` is
# self-describing — `load_qnet(path)` alone rebuilds the graph.
DSCNN_ARCHS = {
    "dscnn_kws": ("dscnn_kws",
                  dict(input_t=49, input_ch=10, channels=64, n_blocks=4,
                       kernel=3, bits=8, num_classes=12)),
    "dscnn_har": ("dscnn_har",
                  dict(input_t=128, input_ch=3, stem_channels=48,
                       channels=[96, 128, 160], kernel=5, bits=8,
                       num_classes=12)),
}


def netspec_build_record(arch: str, **kw) -> dict:
    """Build record for a registered NetSpec arch (builder knob overrides
    in `kw`). Feed to `save_qnet(build=...)`; `build_netspec` inverts it."""
    if arch not in DSCNN_ARCHS:
        raise KeyError(
            f"unknown netspec arch {arch!r}; known: {sorted(DSCNN_ARCHS)}")
    model, defaults = DSCNN_ARCHS[arch]
    rec = {"model": model, **defaults}
    rec.update(kw)
    return rec


def get_netspec(arch: str, **kw):
    """Registered arch id -> built NetSpec (knob overrides in `kw`)."""
    from repro.core.qnet import build_netspec
    return build_netspec(netspec_build_record(arch, **kw))


def get_config(arch: str, **kw) -> LMConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)} + "
                       f"{CNN_ARCHS} + {tuple(sorted(DSCNN_ARCHS))}")
    mod = importlib.import_module(ARCHS[arch])
    return mod.get_config(**kw)


def reduced_config(arch: str, **kw) -> LMConfig:
    """Shrink dims, keep structure (family, pattern, flags, divisibility)."""
    cfg = get_config(arch, **kw)
    r = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
    )
    if cfg.family == "hybrid":
        r.update(n_layers=max(len(cfg.block_pattern),
                              len(cfg.block_pattern) + cfg.n_layers % len(cfg.block_pattern)),
                 lru_width=64, local_window=32)
    elif cfg.family in ("encdec", "audio"):
        r.update(n_layers=4, n_enc_layers=2, n_dec_layers=2, frontend_len=16)
    elif cfg.family == "ssm":
        r.update(n_layers=2, ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    else:
        r.update(n_layers=2)
    if cfg.family == "moe":
        # capacity_factor = n_experts makes routing lossless (cap == T) so the
        # smoke tests can assert prefill/decode == teacher-forced forward
        r.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                 moe_d_ff=64, capacity_factor=8.0,
                 shared_d_ff=64 if cfg.n_shared_experts else 0)
    if cfg.family == "vlm":
        r.update(frontend_len=8)
    return dataclasses.replace(cfg, **r)


__all__ = ["ARCHS", "CNN_ARCHS", "DSCNN_ARCHS", "get_config",
           "reduced_config", "get_netspec", "netspec_build_record"]
