"""Architecture registry: --arch <id> -> LMConfig / NetSpec.

`get_config(arch)` returns the FULL published configuration (exercised only
via the dry-run — ShapeDtypeStruct, no allocation). `reduced_config(arch)`
returns a structure-preserving shrunken version (same family, same flags,
same layer pattern, tiny dims) used by the per-arch CPU smoke tests and to
build the logical-sharding tree without materializing the full model.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.lm.config import LMConfig

# arch id -> module path (LM archs) — the paper's own DSCNNs are separate
ARCHS = {
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a27b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "llama3.2-1b": "repro.configs.llama32_1b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_42b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "mamba2-1.3b": "repro.configs.mamba2_13b",
}

CNN_ARCHS = ("mobilenet-v2", "efficientnet-compact")


def get_config(arch: str, **kw) -> LMConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)} + {CNN_ARCHS}")
    mod = importlib.import_module(ARCHS[arch])
    return mod.get_config(**kw)


def reduced_config(arch: str, **kw) -> LMConfig:
    """Shrink dims, keep structure (family, pattern, flags, divisibility)."""
    cfg = get_config(arch, **kw)
    r = dict(
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=256,
    )
    if cfg.family == "hybrid":
        r.update(n_layers=max(len(cfg.block_pattern),
                              len(cfg.block_pattern) + cfg.n_layers % len(cfg.block_pattern)),
                 lru_width=64, local_window=32)
    elif cfg.family in ("encdec", "audio"):
        r.update(n_layers=4, n_enc_layers=2, n_dec_layers=2, frontend_len=16)
    elif cfg.family == "ssm":
        r.update(n_layers=2, ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    else:
        r.update(n_layers=2)
    if cfg.family == "moe":
        # capacity_factor = n_experts makes routing lossless (cap == T) so the
        # smoke tests can assert prefill/decode == teacher-forced forward
        r.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                 moe_d_ff=64, capacity_factor=8.0,
                 shared_d_ff=64 if cfg.n_shared_experts else 0)
    if cfg.family == "vlm":
        r.update(frontend_len=8)
    return dataclasses.replace(cfg, **r)


__all__ = ["ARCHS", "CNN_ARCHS", "get_config", "reduced_config"]
