"""seamless-m4t-large-v2 [audio]: encoder-decoder, multimodal backbone.

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206, enc-dec
[arXiv:2308.11596; hf]

The speech frontend (w2v-BERT conformer) is a STUB: input_specs() provides
precomputed frame embeddings [B, S_enc, d_model]. We model the text/unit
backbone: 24 encoder + 24 decoder transformer layers.
"""
from repro.models.lm.config import LMConfig


def get_config(**kw) -> LMConfig:
    return LMConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        n_layers=48,  # 24 enc + 24 dec
        n_enc_layers=24,
        n_dec_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab=256206,
        frontend="audio",
        frontend_len=1024,
        **kw,
    )
