# The paper's primary contribution: DeepDive's front-end (quantization-aware
# training pipeline) and back-end (Network SoC Compiler + heterogeneous CU
# execution), adapted from edge-FPGA to TPU. See DESIGN.md.
from repro.core import bn_fuse, calibrate, compiler, cu, graph, integer_ops, qnet, quant

__all__ = [
    "bn_fuse",
    "calibrate",
    "compiler",
    "cu",
    "graph",
    "integer_ops",
    "qnet",
    "quant",
]
