"""Batch-Normalization fusing (DeepDive front-end, Sec. 3.1, Eqs. 3-6).

Folds a BN that follows a convolution / linear operator into the operator's
weights and bias so the deployed graph contains only (quantized) convolutions:

    v_hat = (sigma^2 + eps)^(-1/2)                      (Eq. 4)
    W_hat = W * diag(gamma * v_hat)    (per out-channel) (Eq. 5)
    B_hat = B + (xi - gamma * mu * v_hat)               (Eq. 6)

Weight layout convention in this repo:
  * conv2d weights:  [K, K, Cin, Cout]   (HWIO; out channel last)
  * depthwise conv:  [K, K, C, 1] or [K, K, C] (channel axis = 2)
  * linear weights:  [Din, Dout]         (out feature last)

`fuse_bn` takes the output-channel axis so all three share one code path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


# the one epsilon every BN formulation shares: batch-stats normalization
# (models/layers.py float pre-training), running-stat folding, and Eq. 4
# fusion must all divide by the same (sigma^2 + eps)^(1/2) or the float
# phase trains a subtly different network than fusion deploys
BN_EPS = 1e-5


@dataclasses.dataclass
class BNParams:
    gamma: jnp.ndarray  # BN weight
    beta: jnp.ndarray  # BN bias (xi in the paper)
    mean: jnp.ndarray  # running mu
    var: jnp.ndarray  # running sigma^2
    eps: float = BN_EPS

    @classmethod
    def from_tree(cls, tree, eps: float = BN_EPS) -> "BNParams":
        """Build from the {'gamma','beta','mean','var'} dict leaves a
        parameter pytree carries (the training-side storage format)."""
        return cls(gamma=tree["gamma"], beta=tree["beta"],
                   mean=tree["mean"], var=tree["var"], eps=eps)

    def as_tree(self):
        """Inverse of `from_tree` (eps is a constant, not a leaf)."""
        return {"gamma": self.gamma, "beta": self.beta,
                "mean": self.mean, "var": self.var}

    @staticmethod
    def init_tree(channels: int, dtype=jnp.float32):
        """Identity-BN leaves for a fresh op: gamma=1, beta=0, N(0,1) stats."""
        return {
            "gamma": jnp.ones((channels,), dtype),
            "beta": jnp.zeros((channels,), dtype),
            "mean": jnp.zeros((channels,), dtype),
            "var": jnp.ones((channels,), dtype),
        }


def fuse_bn(
    w: jnp.ndarray,
    b: Optional[jnp.ndarray],
    bn: BNParams,
    out_axis: int = -1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (W_hat, B_hat) per Eqs. 4-6."""
    v_hat = (bn.var + bn.eps) ** -0.5  # Eq. 4
    g = bn.gamma * v_hat
    shape = [1] * w.ndim
    shape[out_axis % w.ndim] = -1
    w_hat = w * g.reshape(shape)  # Eq. 5 (diag multiply)
    if b is None:
        b = jnp.zeros_like(bn.mean)
    b_hat = b * g + (bn.beta - bn.gamma * bn.mean * v_hat)  # Eq. 6 (with conv bias scaled too)
    return w_hat, b_hat


def bn_apply(x: jnp.ndarray, bn: BNParams, channel_axis: int = -1) -> jnp.ndarray:
    """Reference BN (inference mode), Eq. 3 — used to validate fusion exactness."""
    shape = [1] * x.ndim
    shape[channel_axis % x.ndim] = -1
    v_hat = (bn.var + bn.eps) ** -0.5
    return (x - bn.mean.reshape(shape)) * (bn.gamma * v_hat).reshape(shape) + bn.beta.reshape(shape)


def bn_op_count(num_channels: int, spatial: int) -> int:
    """Ops a standalone BN layer would cost at inference (mul+add per element).

    Used to reproduce the paper's "~4% computation reduction" claim: fusing
    removes 2 ops per output element of every BN layer.
    """
    return 2 * num_channels * spatial


__all__ = ["BN_EPS", "BNParams", "fuse_bn", "bn_apply", "bn_op_count"]
