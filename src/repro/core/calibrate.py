"""Calibration + post-training ReLU6 fusion (DeepDive front-end, Sec. 3 tail).

After BN-fused QAT, the paper runs the validation set once more to extract
per-layer activation min/max, then *re-derives* the activation quantizer
h^pq : [0, 6] -> [0, 2^BW - 1] so that the integer clip to [0, 2^BW - 1]
performed by the Approximator & Clip unit IS the ReLU6 — i.e. the activation
function is fused into the convolution's requantization for free.

This module provides:
  * `ActObserver`      — running min/max (and optional EMA) per tensor/channel
  * `calibrate`        — drive a model over batches collecting observers
  * `relu6_fused_qparams` — the h^pq quantizer: scale = 6 / (2^BW - 1), zp = 0
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax.numpy as jnp

from .quant import QuantConfig, compute_scale_zp, observe_range


@dataclasses.dataclass
class ActObserver:
    """Running range observer. Functional: `update` returns a new observer."""

    min_val: jnp.ndarray
    max_val: jnp.ndarray
    momentum: Optional[float] = None  # None = true min/max; else EMA

    @staticmethod
    def init(shape=(), momentum: Optional[float] = None) -> "ActObserver":
        return ActObserver(
            min_val=jnp.full(shape, jnp.inf), max_val=jnp.full(shape, -jnp.inf),
            momentum=momentum,
        )

    def update(self, x: jnp.ndarray, cfg: QuantConfig) -> "ActObserver":
        mn, mx = observe_range(x, cfg)
        if self.momentum is None:
            new_mn = jnp.minimum(self.min_val, mn)
            new_mx = jnp.maximum(self.max_val, mx)
        else:
            m = self.momentum
            init = jnp.isinf(self.min_val)
            new_mn = jnp.where(init, mn, m * self.min_val + (1 - m) * mn)
            new_mx = jnp.where(init, mx, m * self.max_val + (1 - m) * mx)
        return ActObserver(new_mn, new_mx, self.momentum)

    def qparams(self, cfg: QuantConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return compute_scale_zp(self.min_val, self.max_val, cfg)


def relu6_fused_qparams(cfg: QuantConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h^pq : [0, 6] -> [0, 2^BW - 1].

    With ReLU6 the post-activation range is exactly [0, 6]; the asymmetric
    quantizer then has S = 6 / (2^BW - 1), m_zp = 0, and the integer clip to
    [0, 2^BW - 1] realizes ReLU6 exactly (paper Sec. 3, 'QNet ... output set to
    the minimum and maximum quantized value automatically').
    """
    if cfg.symmetric:
        raise ValueError("ReLU6 fusion requires the asymmetric representation")
    scale = jnp.asarray(6.0 / cfg.qmax)
    zp = jnp.asarray(0.0)
    return scale, zp


def calibrate(
    apply_fn: Callable[..., Dict[str, jnp.ndarray]],
    params,
    batches: Iterable,
    act_cfg: QuantConfig,
    observers: Optional[Dict[str, ActObserver]] = None,
    momentum: Optional[float] = None,
) -> Dict[str, ActObserver]:
    """Run `apply_fn(params, batch)` over batches; it must return a dict of
    named intermediate activations. Returns per-name observers.

    `observers` continues a previous calibration round instead of starting
    fresh, and `momentum` seeds new observers as EMA trackers — together
    they are the *online* quantization mode: the QAT trainer re-drives
    calibration every epoch and the ranges follow the shifting activations
    rather than being pinned to the first epoch's extremes."""
    observers = dict(observers) if observers else {}
    for batch in batches:
        acts = apply_fn(params, batch)
        for name, x in acts.items():
            obs = observers.get(name)
            if obs is None:
                shape = () if act_cfg.channel_axis is None else (
                    x.shape[act_cfg.channel_axis],
                )
                obs = ActObserver.init(shape, momentum=momentum)
            observers[name] = obs.update(x, act_cfg)
    return observers


__all__ = ["ActObserver", "relu6_fused_qparams", "calibrate"]
