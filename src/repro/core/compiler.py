"""Network SoC Compiler analogue (Sec. 4.2).

Observes the network graph and partitions it into the four heterogeneous CU
classes based on operator recurrence — exactly the paper's rule:

  * Head       : the stem normal conv + the first (non-repeating) block
  * Body       : the repeated block pattern, invoked j times
  * Tail       : pointwise + global average pool feeding the classifier
  * Classifier : the dense mapping to k classes

It also derives the paper's architecture knobs: per-CU ParallelOps
(Eqs. 8-10: K_max^2 * N_max for dw/normal conv, N_max for pointwise), buffer
sizing from the maximum feature-map job, and the invocation schedule the host
would run. On TPU the 'hardware generation' step becomes: one jitted function
per CU signature (compile once, invoke j times — the AXI-Lite runtime
reconfiguration maps to shape-specialized retraces).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import graph as G

HEAD, BODY, TAIL, CLASSIFIER = "head", "body", "tail", "classifier"


@dataclasses.dataclass(frozen=True)
class CUAssignment:
    cu: str  # head | body | tail | classifier
    block: G.BlockSpec
    invocation: int  # order in the host schedule


@dataclasses.dataclass(frozen=True)
class StageSignature:
    """Shape contract of one CU stage (the 'AXI job descriptor' analogue).

    `in_hw`/`out_hw` are None once the tensor is spatially collapsed (after
    the Tail CU's global pool / in the Classifier CU)."""

    cu: str
    blocks: Tuple[G.BlockSpec, ...]
    in_hw: Optional[int]
    in_ch: int
    out_hw: Optional[int]
    out_ch: int

    @property
    def invocations(self) -> int:
        return len(self.blocks)


@dataclasses.dataclass
class CUPlan:
    net: G.NetSpec
    schedule: Tuple[CUAssignment, ...]
    # optional measured per-op route selection (repro.tune.TunedPlan); stage
    # compilers pick it up when the caller does not pass one explicitly
    tuned: Optional[object] = None

    @property
    def body_invocations(self) -> int:
        return sum(1 for a in self.schedule if a.cu == BODY)

    def blocks_for(self, cu: str) -> List[G.BlockSpec]:
        return [a.block for a in self.schedule if a.cu == cu]

    def stage_groups(self) -> Tuple[Tuple[str, Tuple[G.BlockSpec, ...]], ...]:
        """Contiguous same-CU runs of the schedule, in invocation order.

        This is the pipeline a serving engine executes: each group becomes
        one stage executor, invoked once per micro-batch. Raises if a CU
        role recurs non-contiguously (no such network exists under the
        recurrence partitioning rule, but a hand-built schedule could)."""
        groups: List[Tuple[str, List[G.BlockSpec]]] = []
        for a in self.schedule:
            if groups and groups[-1][0] == a.cu:
                groups[-1][1].append(a.block)
            else:
                groups.append((a.cu, [a.block]))
        seen = [cu for cu, _ in groups]
        if len(set(seen)) != len(seen):
            raise ValueError(f"non-contiguous CU schedule: {seen}")
        return tuple((cu, tuple(blocks)) for cu, blocks in groups)

    def op_descriptors(self) -> Tuple[Tuple[str, G.BlockSpec, G.OpSpec,
                                            Optional[int]], ...]:
        """Per-op job descriptors: (cu, block, op, in_hw) in schedule order.

        `in_hw` is the spatial size of the op's input tensor (None once the
        tensor is collapsed — DENSE after the Tail's global pool). This is
        the shape walk the route autotuner keys its per-op cache on: the
        same (kind, shape, act_bits) op in two nets resolves to the same
        tuning-cache entry. SE squeeze/excite ops are not enumerated — they
        run on the reference path."""
        descs: List[Tuple[str, G.BlockSpec, G.OpSpec, Optional[int]]] = []
        hw: Optional[int] = self.net.input_hw
        for a in self.schedule:
            for op in a.block.ops:
                descs.append((a.cu, a.block, op, hw))
                if op.kind == G.DENSE:
                    hw = None
                elif hw is not None:
                    hw = -(-hw // op.stride)
            if a.block.avgpool:
                hw = None
        return tuple(descs)

    def stage_signatures(self) -> Tuple[StageSignature, ...]:
        """Lower the schedule into per-stage shape signatures (what each
        jitted stage executor consumes/produces for batch size 1)."""
        sigs: List[StageSignature] = []
        hw: Optional[int] = self.net.input_hw
        ch = self.net.input_ch
        for cu, blocks in self.stage_groups():
            in_hw, in_ch = hw, ch
            for b in blocks:
                for op in b.ops:
                    if op.kind == G.DENSE:
                        hw = None
                    elif hw is not None:
                        hw = -(-hw // op.stride)
                    ch = op.out_ch
                if b.avgpool:
                    hw = None
            sigs.append(StageSignature(cu, blocks, in_hw, in_ch, hw, ch))
        return tuple(sigs)

    # ---- architecture knobs (paper Sec. 4.1) ----

    def parallel_ops(self) -> Dict[str, int]:
        """Eq. 8/9/10: ParallelOps per operator class across the network."""
        k_dw = n_dw = k_nc = n_nc = n_pw_exp = n_pw_proj = 0
        for _, op in self.net.all_ops():
            if op.kind == G.DW:
                k_dw = max(k_dw, op.kernel)
                n_dw = max(n_dw, op.in_ch)
            elif op.kind == G.CONV:
                k_nc = max(k_nc, op.kernel)
                n_nc = max(n_nc, op.in_ch)
            elif op.kind == G.PW:
                if op.out_ch >= op.in_ch:
                    n_pw_exp = max(n_pw_exp, op.in_ch)
                else:
                    n_pw_proj = max(n_pw_proj, op.in_ch)
        return {
            "dw": k_dw * k_dw * n_dw,  # Eq. 8
            "conv": k_nc * k_nc * n_nc,  # Eq. 9
            "pw_expansion": n_pw_exp,  # Eq. 10 (per pointwise type)
            "pw_projection": n_pw_proj,
        }

    def buffer_bytes(self) -> Dict[str, int]:
        """Max per-CU activation 'job' footprint (the paper sizes Body CU
        buffers for the most memory-bound IRB). Bytes at each op's act BW."""
        out: Dict[str, int] = {}
        h = self.net.input_hw
        rank = self.net.spatial_rank
        for a in self.schedule:
            peak = 0
            for op in a.block.ops:
                if op.kind == G.DENSE:
                    elems = op.in_ch + op.out_ch
                else:
                    h_out = -(-h // op.stride)
                    in_sp = h if rank == 1 else h * h
                    out_sp = h_out if rank == 1 else h_out * h_out
                    elems = in_sp * op.in_ch + out_sp * op.out_ch
                    h = h_out
                peak = max(peak, (elems * op.act_bits + 7) // 8)
            out[a.cu] = max(out.get(a.cu, 0), peak)
        return out


def compile_net(net: G.NetSpec, tuned: Optional[object] = None) -> CUPlan:
    """Partition blocks into CUs by recurrence (paper Sec. 4.2.1).

    Rule: the stem (normal conv) and the first instance of the repeating
    block pattern form the Head; the remaining repeats form the Body; the
    final pointwise+avgpool is the Tail; the dense layer the Classifier.

    `tuned` (a `repro.tune.TunedPlan`) rides on the plan: downstream stage
    compilers consult it for measured per-op route selection.
    """
    blocks = list(net.blocks)
    schedule: List[CUAssignment] = []
    inv = 0

    # classify structurally
    roles: List[str] = []
    seen_repeat = False
    for i, b in enumerate(blocks):
        is_dense_only = all(op.kind == G.DENSE for op in b.ops)
        if is_dense_only:
            roles.append(CLASSIFIER)
        elif b.avgpool:
            roles.append(TAIL)
        elif i == 0 or not seen_repeat:
            roles.append(HEAD)
            # the first IRB-like block (multi-op) after the stem completes the Head
            if len(b.ops) >= 2 or i > 0:
                seen_repeat = True
        else:
            roles.append(BODY)

    for b, role in zip(blocks, roles):
        schedule.append(CUAssignment(role, b, inv))
        inv += 1
    return CUPlan(net, tuple(schedule), tuned=tuned)


__all__ = ["CUPlan", "CUAssignment", "compile_net", "HEAD", "BODY", "TAIL", "CLASSIFIER"]
