"""Heterogeneous Compute Unit runners — integer QNet execution (Sec. 4).

The FPGA executes each CU as a fused pipeline: operators stream intermediate
feature maps through FIFOs; only CU inputs/outputs touch shared DDR. The TPU
analogue: each CU is ONE jitted function (one XLA program == one 'CU
invocation'), so all intra-CU intermediates stay on-chip; for the Body CU the
`kernels/fused_irb` Pallas kernel additionally pins the expanded intermediate
into VMEM explicitly.

All arithmetic inside a CU is integer: int MACs -> int32 accum -> requantize
-> clip (the Approximator & Clip unit == fused ReLU6), following
`core/integer_ops`. Zero floating point remains in the datapath except the
requant multiplier (which also has a faithful fixed-point mode; in that mode
the residual skip-add is integer too, via `int_residual_add`).

Two execution tiers share this module:

  * `QNet` (host numpy metadata) — the semantic reference. Every invocation
    re-uploads weights/requant constants, exactly what a cold host would do.
  * `PreparedQNet` (`prepare_qnet`) — the serving artifact: every constant a
    CU invocation needs is converted to a device-resident jnp array ONCE at
    plan-build time, and the operator bodies switch to the compiled integer
    fast-path formulations of `core/integer_ops` (shifted-slice depthwise,
    exactness-gated f32 matmul/conv). The accumulators are bit-identical to
    the reference, so `run_qnet(prepare_qnet(q), x) == run_qnet(q, x)`
    element-for-element — verified by tests/test_prepared_fastpath.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core.integer_ops import (
    f32_accum_exact,
    int_conv1d,
    int_conv1d_f32,
    int_conv2d,
    int_conv2d_f32,
    int_depthwise1d_shifts,
    int_depthwise_shifts,
    int_pointwise,
    int_pointwise_f32,
    int_residual_add,
    quantized_op_epilogue,
    residual_fixed_consts,
)
from repro.core.qnet import QNet, QOp


def quantize_input(x: jnp.ndarray, scale: float, zp: float, bits: int = 8):
    q = jnp.round(x / scale - zp)
    return jnp.clip(q, 0, 2**bits - 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# PreparedQNet: device-resident constants + compiled fast-path dispatch
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PreparedQOp:
    """One QOp with every kernel/epilogue constant already on device.

    Field names mirror `QOp` so the two are interchangeable wherever the
    runners only read metadata; the arrays are jnp (committed to the default
    device), so jitted stage traces close over device constants instead of
    re-uploading host numpy each invocation.
    """

    spec: G.OpSpec
    w_q: jnp.ndarray  # int32, original weight layout (conv HWIO / dw HW1C)
    w_kern: jnp.ndarray  # kernel layout: dw [K,K,C]; pw/dense [Cin,Cout]
    w_scale: jnp.ndarray  # [M] f32
    wsum: jnp.ndarray  # [M] i32
    bias_q: jnp.ndarray  # [M] i32
    mult: jnp.ndarray  # [M] f32
    zcorr: jnp.ndarray  # [M] f32 — in_zp * mult * wsum (float epilogue form)
    zpc: jnp.ndarray  # [M] i32 — int32(in_zp) * wsum (integer epilogue form)
    z_x: jnp.ndarray  # scalar i32 — int32(in_zp)
    mantissa: jnp.ndarray  # [M] i64/i32 fixed-point mantissa
    shift: jnp.ndarray  # [M] i32
    in_scale: float
    in_zp: float
    out_scale: float
    out_zp: float
    clip: bool
    in_qmax: int  # upper bound of the incoming activation tensor
    f32_exact: bool  # f32 accumulation provably bit-exact for this op

    @property
    def qmax(self) -> int:
        return 2**self.spec.act_bits - 1


@dataclasses.dataclass(frozen=True)
class PreparedQNet:
    """A QNet lowered for serving: per-op `PreparedQOp`s + per-residual
    integer skip-add constants. Drop-in for `QNet` in every runner here and
    in `kernels/ops.py` / `serve/vision/stages.py`.

    `routes` (op name -> (route, params)) carries a measured route
    selection resolved from a `repro.tune.TunedPlan` at prepare time: the
    runners execute a routed op through that route instead of the default
    formulation. Ops absent from the map fall back to the defaults, so a
    partial (or empty) map is always safe."""

    qnet: QNet
    ops: Dict[str, PreparedQOp]
    res_q: Dict[str, Tuple[float, float]]
    res_fixed: Dict[str, Tuple[int, int, int, int, int]]
    routes: Dict[str, Tuple[str, Dict[str, int]]] = dataclasses.field(
        default_factory=dict)

    @property
    def spec(self) -> G.NetSpec:
        return self.qnet.spec


def _prepare_qop(qop: QOp, in_qmax: int, put=jnp.asarray) -> PreparedQOp:
    w_np = np.asarray(qop.w_q)
    if qop.spec.kind == G.DW:
        w_kern = w_np.reshape(w_np.shape[0], w_np.shape[1], w_np.shape[-1])
    elif qop.spec.kind == G.DW1D:
        w_kern = w_np.reshape(w_np.shape[0], w_np.shape[-1])  # [K, C]
    elif qop.spec.kind in (G.PW, G.DENSE):
        w_kern = w_np[0, 0] if w_np.ndim == 4 else w_np
    else:
        w_kern = w_np
    zpc = np.int32(qop.in_zp) * np.asarray(qop.wsum, np.int32)
    return PreparedQOp(
        spec=qop.spec,
        w_q=put(jnp.asarray(w_np, jnp.int32)),
        w_kern=put(jnp.asarray(w_kern, jnp.int32)),
        w_scale=put(jnp.asarray(qop.w_scale, jnp.float32)),
        wsum=put(jnp.asarray(qop.wsum, jnp.int32)),
        bias_q=put(jnp.asarray(qop.bias_q, jnp.int32)),
        mult=put(jnp.asarray(qop.mult, jnp.float32)),
        zcorr=put(jnp.asarray(qop.in_zp * qop.mult * qop.wsum, jnp.float32)),
        zpc=put(jnp.asarray(zpc, jnp.int32)),
        z_x=put(jnp.asarray(qop.in_zp, jnp.int32)),
        mantissa=put(jnp.asarray(qop.mantissa)),
        shift=put(jnp.asarray(qop.shift, jnp.int32)),
        in_scale=qop.in_scale,
        in_zp=qop.in_zp,
        out_scale=qop.out_scale,
        out_zp=qop.out_zp,
        clip=qop.clip,
        in_qmax=in_qmax,
        f32_exact=f32_accum_exact(w_np, in_qmax),
    )


def _constant_put(mesh):
    """Constant placement for `prepare_qnet`: default device when mesh is
    None, else replicated across every replica of the 'data' mesh (so jitted
    sharded stage traces close over replica-local constants — the
    multi-replica analogue of DeepDive's per-CU weight buffers)."""
    if mesh is None:
        return lambda a: a
    from repro.dist.sharding import replicate
    return partial(replicate, mesh=mesh)


def replicate_prepared(pq: "PreparedQNet", mesh) -> "PreparedQNet":
    """Re-place an already-prepared net's constants replicated on `mesh`."""
    put = _constant_put(mesh)
    ops = {
        name: dataclasses.replace(
            pop, **{f: put(getattr(pop, f)) for f in (
                "w_q", "w_kern", "w_scale", "wsum", "bias_q", "mult",
                "zcorr", "zpc", "z_x", "mantissa", "shift")})
        for name, pop in pq.ops.items()
    }
    return dataclasses.replace(pq, ops=ops)


def _validate_routes(op_routes, ops: Dict[str, PreparedQOp]) -> Dict:
    """Attach-time validation of resolved routes against the *actual*
    prepared constants: an `int_f32` route whose op fails the 2^24
    exactness bound here (different weights than the tuned net) is
    dropped rather than run inexactly; unknown op names are ignored."""
    routes: Dict[str, Tuple[str, Dict[str, int]]] = {}
    for name, (route, params) in op_routes.items():
        pop = ops.get(name)
        if pop is None:
            continue
        if route == "int_f32" and not pop.f32_exact:
            continue
        routes[name] = (route, dict(params))
    return routes


def _resolve_tuned_routes(tuned, qnet,
                          ops: Dict[str, PreparedQOp]) -> Dict:
    """Project a `TunedPlan` onto prepared ops (op name -> (route, params))."""
    op_routes, _ = tuned.resolve(qnet)
    return _validate_routes(op_routes, ops)


def prepare_qnet(qnet: QNet, input_bits: int = 8, mesh=None,
                 tuned=None, routes=None) -> PreparedQNet:
    """Lower a QNet to its device-resident serving form (one-time cost).

    Walks the graph to bound each op's input activations (needed for the
    f32-exactness gate) and uploads every constant once. Idempotent on an
    already-prepared net (unless `mesh` is given, which re-places the
    constants replicated across the mesh's replicas).

    `tuned` (a `repro.tune.TunedPlan`) resolves the measured per-op route
    selection onto the prepared net: the runners then execute each routed
    op through its tuned route (see `PreparedQNet.routes`). Callers that
    already resolved a plan (the stage compiler) pass the op-name-keyed
    `routes` dict directly instead; both paths re-validate eligibility
    against the prepared constants.
    """
    if isinstance(qnet, PreparedQNet):
        pq = qnet if mesh is None else replicate_prepared(qnet, mesh)
        if routes is not None:
            pq = dataclasses.replace(
                pq, routes=_validate_routes(routes, pq.ops))
        elif tuned is not None:
            pq = dataclasses.replace(pq, routes=_resolve_tuned_routes(
                tuned, pq.qnet, pq.ops))
        return pq
    put = _constant_put(mesh)
    ops: Dict[str, PreparedQOp] = {}
    res_fixed: Dict[str, Tuple[int, int, int, int, int]] = {}
    cur_bits = input_bits
    for block in qnet.spec.blocks:
        for op in block.ops:
            qop = qnet.ops[op.name]
            ops[op.name] = _prepare_qop(qop, 2**cur_bits - 1, put)
            cur_bits = op.act_bits
            if block.se is not None and block.se_after == op.name:
                sq, ex = block.se.squeeze, block.se.excite
                # squeeze reads the (pooled) dw output; excite reads squeeze
                ops[sq.name] = _prepare_qop(
                    qnet.ops[sq.name], 2**cur_bits - 1, put)
                ops[ex.name] = _prepare_qop(
                    qnet.ops[ex.name], 2**sq.act_bits - 1, put)
        if block.residual:
            last = qnet.ops[block.ops[-1].name]
            first = qnet.ops[block.ops[0].name]
            y_s, y_z = qnet.res_q[block.name]
            res_fixed[block.name] = residual_fixed_consts(
                first.in_scale, first.in_zp,
                last.out_scale, last.out_zp, y_s, y_z)
    if routes is not None:
        attached = _validate_routes(routes, ops)
    elif tuned is not None:
        attached = _resolve_tuned_routes(tuned, qnet, ops)
    else:
        attached = {}
    return PreparedQNet(qnet=qnet, ops=ops, res_q=dict(qnet.res_q),
                        res_fixed=res_fixed, routes=attached)


def _accumulate(x_q: jnp.ndarray, qop, route: Optional[str] = None
                ) -> jnp.ndarray:
    """Int32 accumulator for one op.

    `QOp` (host metadata) takes the reference XLA integer ops; `PreparedQOp`
    takes the compiled fast-path formulations — shifted-slice depthwise and,
    when the per-op exactness bound holds, f32-unit matmul/conv — which
    produce the *same* int32 accumulator (see core/integer_ops docstrings).

    `route` (PreparedQOp only) forces one of the named tuned-cache
    accumulator routes instead of the heuristic default — every route is an
    alternate formulation of the identical accumulator, so the choice can
    never move a bit, only the wall clock.
    """
    op = qop.spec
    if route is not None:
        assert isinstance(qop, PreparedQOp), "routes bind to prepared ops"
        if route == "int_ref":
            if op.kind == G.CONV:
                return int_conv2d(x_q, qop.w_q, stride=op.stride)
            if op.kind == G.DW:
                return int_conv2d(x_q, qop.w_q, stride=op.stride,
                                  groups=op.in_ch)
            if op.kind == G.CONV1D:
                return int_conv1d(x_q, qop.w_q, stride=op.stride)
            if op.kind == G.DW1D:
                return int_conv1d(x_q, qop.w_q, stride=op.stride,
                                  groups=op.in_ch)
            return int_pointwise(x_q, qop.w_kern)
        if route == "dw_shifts":
            if op.kind == G.DW1D:
                return int_depthwise1d_shifts(x_q, qop.w_kern,
                                              stride=op.stride)
            return int_depthwise_shifts(x_q, qop.w_kern, stride=op.stride)
        if route == "int_f32":
            if op.kind == G.CONV:
                return int_conv2d_f32(x_q, qop.w_q, stride=op.stride)
            if op.kind == G.CONV1D:
                return int_conv1d_f32(x_q, qop.w_q, stride=op.stride)
            return int_pointwise_f32(x_q, qop.w_kern)
        raise ValueError(f"unknown tuned route {route!r} for {op.name}")
    if isinstance(qop, PreparedQOp):
        if op.kind == G.DW:
            return int_depthwise_shifts(x_q, qop.w_kern, stride=op.stride)
        if op.kind == G.DW1D:
            return int_depthwise1d_shifts(x_q, qop.w_kern, stride=op.stride)
        if op.kind in (G.PW, G.DENSE):
            if qop.f32_exact:
                return int_pointwise_f32(x_q, qop.w_kern)
            return int_pointwise(x_q, qop.w_kern)
        if op.kind == G.CONV:
            if qop.f32_exact:
                return int_conv2d_f32(x_q, qop.w_q, stride=op.stride)
            return int_conv2d(x_q, qop.w_q, stride=op.stride)
        if op.kind == G.CONV1D:
            if qop.f32_exact:
                return int_conv1d_f32(x_q, qop.w_q, stride=op.stride)
            return int_conv1d(x_q, qop.w_q, stride=op.stride)
        raise ValueError(op.kind)
    w_q = jnp.asarray(qop.w_q, jnp.int32)
    if op.kind == G.CONV:
        return int_conv2d(x_q, w_q, stride=op.stride)
    if op.kind == G.DW:
        return int_conv2d(x_q, w_q, stride=op.stride, groups=op.in_ch)
    if op.kind == G.CONV1D:
        return int_conv1d(x_q, w_q, stride=op.stride)
    if op.kind == G.DW1D:
        return int_conv1d(x_q, w_q, stride=op.stride, groups=op.in_ch)
    if op.kind == G.PW:
        return int_pointwise(x_q, w_q[0, 0] if w_q.ndim == 4 else w_q)
    if op.kind == G.DENSE:
        return int_pointwise(x_q, w_q)
    raise ValueError(op.kind)


def _run_qop(x_q: jnp.ndarray, qop, fixed_point: bool,
             route: Optional[Tuple[str, Dict[str, int]]] = None,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    op = qop.spec
    if route is not None and op.act != G.HSIGMOID and not fixed_point:
        name, params = route
        if name in ("pallas_pw", "pallas_dw"):
            # deferred import: kernels.ops imports this module at top level
            from repro.kernels import ops as K
            if name == "pallas_dw":
                return K.run_dw_qop(x_q, qop, interpret=interpret, **params)
            return K.run_pw_qop(x_q, qop, interpret=interpret, **params)
        acc = _accumulate(x_q, qop, route=name)
    else:
        acc = _accumulate(x_q, qop)

    if op.act == G.HSIGMOID:
        # gate: y = relu6(x + 3)/6 quantized to [0, qmax] with S=1/qmax.
        # dequant the accumulator (S_x*S_w), apply hsigmoid, requantize.
        y_fp = (
            acc.astype(jnp.float32)
            + qop.in_zp * jnp.asarray(qop.wsum, jnp.float32)
        ) * (qop.in_scale * jnp.asarray(qop.w_scale, jnp.float32))
        y_fp = y_fp + jnp.asarray(qop.bias_q, jnp.float32) * qop.out_scale
        # requantize with ONE constant multiply: chaining /6.0 with
        # /out_scale lets XLA reassociate the two divisions under jit
        # (reciprocal-multiply rewrites), flipping round() on boundary
        # values — jitted stage executors would drift off the eager
        # reference by 1 LSB. The f64-folded constant is order-free.
        requant = jnp.float32(1.0 / (6.0 * qop.out_scale))
        gate6 = jnp.clip(y_fp + 3.0, 0.0, 6.0)
        return jnp.round(gate6 * requant).astype(jnp.int32)

    if isinstance(qop, PreparedQOp):
        z_x, wsum = qop.z_x, qop.wsum
        bias, mult = qop.bias_q, qop.mult
        mantissa = qop.mantissa if fixed_point else None
        shift = qop.shift if fixed_point else None
    else:
        z_x = jnp.asarray(qop.in_zp, jnp.int32)
        wsum = jnp.asarray(qop.wsum, jnp.int32)
        bias = jnp.asarray(qop.bias_q, jnp.int32)
        mult = jnp.asarray(qop.mult, jnp.float32)
        mantissa = jnp.asarray(qop.mantissa, jnp.int64) if fixed_point else None
        shift = jnp.asarray(qop.shift, jnp.int32) if fixed_point else None
    return quantized_op_epilogue(
        acc,
        z_x=z_x,
        wsum=wsum,
        bias_q=bias,
        mult=mult,
        qmax=qop.qmax,
        z_y=jnp.asarray(0, jnp.int32),  # z_y folded into bias_q (qnet.py)
        fixed_point=fixed_point,
        mantissa=mantissa,
        shift=shift,
        clip_output=True,
    )


def _residual_add(
    a_q, a_s, a_z, b_q, b_s, b_z, y_s, y_z, qmax: int,
    fixed_consts=None,
) -> jnp.ndarray:
    """Skip-line add: rescale both operands into the output domain.

    Float-multiplier mode rescales in f32 (matching the requant multiplier's
    float mode). When `fixed_consts` is given (fixed_point mode), the add is
    pure integer: mantissa multiplies + one shared round-shift, the same
    'Approximator' arithmetic as the per-op fixed-point requant — no float
    remains anywhere in the fixed-point datapath.
    """
    if fixed_consts is not None:
        return int_residual_add(a_q, b_q, fixed_consts, qmax)
    a = (a_q.astype(jnp.float32) + a_z) * (a_s / y_s)
    b = (b_q.astype(jnp.float32) + b_z) * (b_s / y_s)
    return jnp.clip(jnp.round(a + b) - round(y_z), 0, qmax).astype(jnp.int32)


def _residual_consts_for(block, qnet, a_s, a_z, b_s, b_z, y_s, y_z):
    """Integer skip-add constants: cached on a PreparedQNet, else derived."""
    if isinstance(qnet, PreparedQNet):
        return qnet.res_fixed[block.name]
    return residual_fixed_consts(a_s, a_z, b_s, b_z, y_s, y_z)


def run_block(
    x_q: jnp.ndarray,
    block: G.BlockSpec,
    qnet: Union[QNet, PreparedQNet],
    in_s: float,
    in_z: float,
    fixed_point: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, float, float]:
    """Execute one block (one CU invocation) fully fused in integer math.

    A `PreparedQNet` carrying tuned `routes` (see `prepare_qnet(tuned=)`)
    dispatches each routed op through its measured route; everything else
    takes the default formulation. Tuned routes are float-requant only, so
    `fixed_point=True` ignores them (the reference fixed-point datapath is
    the bit-exactness contract there). `interpret` forwards to any routed
    Pallas kernel (None = auto by backend)."""
    routes = None
    if not fixed_point and isinstance(qnet, PreparedQNet) and qnet.routes:
        routes = qnet.routes
    y = x_q
    cur_s, cur_z = in_s, in_z
    for op in block.ops:
        qop = qnet.ops[op.name]
        y = _run_qop(y, qop, fixed_point,
                     route=routes.get(op.name) if routes else None,
                     interpret=interpret)
        cur_s, cur_z = qop.out_scale, qop.out_zp
        if block.se is not None and block.se_after == op.name:
            sq, ex = qnet.ops[block.se.squeeze.name], qnet.ops[block.se.excite.name]
            sp_axes = tuple(range(1, y.ndim - 1))  # (1, 2) NHWC / (1,) NTC
            pooled = jnp.round(jnp.mean(y.astype(jnp.float32), axis=sp_axes)).astype(jnp.int32)
            s = _run_qop(pooled, sq, fixed_point)
            gate_q = _run_qop(s, ex, fixed_point)  # [B, C] in [0, qmax], S=1/qmax
            # gated output keeps the dw quantizer: y' = y * gate
            # S_y (y'_q + z) = S_y (y_q + z) * S_g * g_q  with z == 0 (ReLU6 fused)
            gate_b = gate_q.reshape(
                gate_q.shape[0], *([1] * len(sp_axes)), gate_q.shape[-1])
            y = jnp.round(
                y.astype(jnp.float32)
                * gate_b.astype(jnp.float32)
                * ex.out_scale
            ).astype(jnp.int32)
    if block.residual:
        y_s, y_z = qnet.res_q[block.name]
        qmax = 2 ** block.ops[-1].act_bits - 1
        fixed_consts = None
        if fixed_point:
            fixed_consts = _residual_consts_for(
                block, qnet, in_s, in_z, cur_s, cur_z, y_s, y_z)
        y = _residual_add(x_q, in_s, in_z, y, cur_s, cur_z, y_s, y_z, qmax,
                          fixed_consts=fixed_consts)
        cur_s, cur_z = y_s, y_z
    if block.avgpool:
        sp_axes = tuple(range(1, y.ndim - 1))  # (1, 2) NHWC / (1,) NTC
        y = jnp.round(jnp.mean(y.astype(jnp.float32), axis=sp_axes)).astype(jnp.int32)
    return y, cur_s, cur_z


def run_blocks(
    x_q: jnp.ndarray,
    blocks,
    qnet: Union[QNet, PreparedQNet],
    in_s: float,
    in_z: float,
    fixed_point: bool = False,
) -> Tuple[jnp.ndarray, float, float]:
    """Execute a contiguous block sequence (e.g. one CU stage's blocks)."""
    y, cur_s, cur_z = x_q, in_s, in_z
    for block in blocks:
        y, cur_s, cur_z = run_block(y, block, qnet, cur_s, cur_z, fixed_point)
    return y, cur_s, cur_z


def propagate_qparams(blocks, qnet: QNet, in_s: float, in_z: float):
    """(scale, zp) of the tensor leaving `blocks`, computed from QNet
    metadata only — no data needed. Matches `run_blocks` exactly, which is
    what lets the stage compiler bake per-stage quantizers in as statics."""
    cur_s, cur_z = in_s, in_z
    for block in blocks:
        for op in block.ops:
            qop = qnet.ops[op.name]
            cur_s, cur_z = qop.out_scale, qop.out_zp
        if block.residual:
            cur_s, cur_z = qnet.res_q[block.name]
    return cur_s, cur_z


def input_qparams(qnet: QNet) -> Tuple[float, float]:
    """The network input quantizer (the first op's input activation)."""
    first = qnet.ops[qnet.spec.blocks[0].ops[0].name]
    return first.in_scale, first.in_zp


def run_qnet(
    qnet: Union[QNet, PreparedQNet],
    x: jnp.ndarray,
    fixed_point: bool = False,
    input_bits: int = 8,
) -> jnp.ndarray:
    """Full integer inference. Returns float logits (dequantized at the end,
    where the FPGA hands confidence computation back to the PS/softmax).

    Pass a `PreparedQNet` (see `prepare_qnet`) to run the compiled integer
    fast path with zero per-call host->device constant uploads; the logits
    are bit-identical either way."""
    in_s, in_z = input_qparams(qnet)
    y = quantize_input(x, in_s, in_z, input_bits)
    y, cur_s, cur_z = run_blocks(y, qnet.spec.blocks, qnet, in_s, in_z,
                                 fixed_point)
    return (y.astype(jnp.float32) + cur_z) * cur_s


__all__ = [
    "quantize_input",
    "PreparedQOp",
    "PreparedQNet",
    "prepare_qnet",
    "replicate_prepared",
    "run_block",
    "run_blocks",
    "propagate_qparams",
    "input_qparams",
    "run_qnet",
]
