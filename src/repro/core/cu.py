"""Heterogeneous Compute Unit runners — integer QNet execution (Sec. 4).

The FPGA executes each CU as a fused pipeline: operators stream intermediate
feature maps through FIFOs; only CU inputs/outputs touch shared DDR. The TPU
analogue: each CU is ONE jitted function (one XLA program == one 'CU
invocation'), so all intra-CU intermediates stay on-chip; for the Body CU the
`kernels/fused_irb` Pallas kernel additionally pins the expanded intermediate
into VMEM explicitly.

All arithmetic inside a CU is integer: int MACs -> int32 accum -> requantize
-> clip (the Approximator & Clip unit == fused ReLU6), following
`core/integer_ops`. Zero floating point remains in the datapath except the
requant multiplier (which also has a faithful fixed-point mode).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core.integer_ops import (
    clip_act,
    int_conv2d,
    int_pointwise,
    quantized_op_epilogue,
)
from repro.core.qnet import QNet, QOp


def quantize_input(x: jnp.ndarray, scale: float, zp: float, bits: int = 8):
    q = jnp.round(x / scale - zp)
    return jnp.clip(q, 0, 2**bits - 1).astype(jnp.int32)


def _run_qop(x_q: jnp.ndarray, qop: QOp, fixed_point: bool) -> jnp.ndarray:
    op = qop.spec
    w_q = jnp.asarray(qop.w_q, jnp.int32)
    if op.kind == G.CONV:
        acc = int_conv2d(x_q, w_q, stride=op.stride)
    elif op.kind == G.DW:
        acc = int_conv2d(x_q, w_q, stride=op.stride, groups=op.in_ch)
    elif op.kind == G.PW:
        acc = int_pointwise(x_q, w_q[0, 0] if w_q.ndim == 4 else w_q)
    elif op.kind == G.DENSE:
        acc = int_pointwise(x_q, w_q)
    else:
        raise ValueError(op.kind)

    if op.act == G.HSIGMOID:
        # gate: y = relu6(x + 3)/6 quantized to [0, qmax] with S=1/qmax.
        # dequant the accumulator (S_x*S_w), apply hsigmoid, requantize.
        y_fp = (
            acc.astype(jnp.float32)
            + qop.in_zp * jnp.asarray(qop.wsum, jnp.float32)
        ) * (qop.in_scale * jnp.asarray(qop.w_scale, jnp.float32))
        y_fp = y_fp + jnp.asarray(qop.bias_q, jnp.float32) * qop.out_scale
        gate = jnp.clip(y_fp + 3.0, 0.0, 6.0) / 6.0
        return jnp.round(gate / qop.out_scale).astype(jnp.int32)

    return quantized_op_epilogue(
        acc,
        z_x=jnp.asarray(qop.in_zp, jnp.int32),
        wsum=jnp.asarray(qop.wsum, jnp.int32),
        bias_q=jnp.asarray(qop.bias_q, jnp.int32),
        mult=jnp.asarray(qop.mult, jnp.float32),
        qmax=qop.qmax,
        z_y=jnp.asarray(0, jnp.int32),  # z_y folded into bias_q (qnet.py)
        fixed_point=fixed_point,
        mantissa=jnp.asarray(qop.mantissa, jnp.int64) if fixed_point else None,
        shift=jnp.asarray(qop.shift, jnp.int32) if fixed_point else None,
        clip_output=True,
    )


def _residual_add(
    a_q, a_s, a_z, b_q, b_s, b_z, y_s, y_z, qmax: int
) -> jnp.ndarray:
    """Integer skip-line add: rescale both operands into the output domain."""
    a = (a_q.astype(jnp.float32) + a_z) * (a_s / y_s)
    b = (b_q.astype(jnp.float32) + b_z) * (b_s / y_s)
    return jnp.clip(jnp.round(a + b) - round(y_z), 0, qmax).astype(jnp.int32)


def run_block(
    x_q: jnp.ndarray,
    block: G.BlockSpec,
    qnet: QNet,
    in_s: float,
    in_z: float,
    fixed_point: bool = False,
) -> Tuple[jnp.ndarray, float, float]:
    """Execute one block (one CU invocation) fully fused in integer math."""
    y = x_q
    cur_s, cur_z = in_s, in_z
    for op in block.ops:
        qop = qnet.ops[op.name]
        y = _run_qop(y, qop, fixed_point)
        cur_s, cur_z = qop.out_scale, qop.out_zp
        if block.se is not None and block.se_after == op.name:
            sq, ex = qnet.ops[block.se.squeeze.name], qnet.ops[block.se.excite.name]
            pooled = jnp.round(jnp.mean(y.astype(jnp.float32), axis=(1, 2))).astype(jnp.int32)
            s = _run_qop(pooled, sq, fixed_point)
            gate_q = _run_qop(s, ex, fixed_point)  # [B, C] in [0, qmax], S=1/qmax
            # gated output keeps the dw quantizer: y' = y * gate
            # S_y (y'_q + z) = S_y (y_q + z) * S_g * g_q  with z == 0 (ReLU6 fused)
            y = jnp.round(
                y.astype(jnp.float32)
                * gate_q[:, None, None, :].astype(jnp.float32)
                * ex.out_scale
            ).astype(jnp.int32)
    if block.residual:
        y_s, y_z = qnet.res_q[block.name]
        qmax = 2 ** block.ops[-1].act_bits - 1
        y = _residual_add(x_q, in_s, in_z, y, cur_s, cur_z, y_s, y_z, qmax)
        cur_s, cur_z = y_s, y_z
    if block.avgpool:
        y = jnp.round(jnp.mean(y.astype(jnp.float32), axis=(1, 2))).astype(jnp.int32)
    return y, cur_s, cur_z


def run_blocks(
    x_q: jnp.ndarray,
    blocks,
    qnet: QNet,
    in_s: float,
    in_z: float,
    fixed_point: bool = False,
) -> Tuple[jnp.ndarray, float, float]:
    """Execute a contiguous block sequence (e.g. one CU stage's blocks)."""
    y, cur_s, cur_z = x_q, in_s, in_z
    for block in blocks:
        y, cur_s, cur_z = run_block(y, block, qnet, cur_s, cur_z, fixed_point)
    return y, cur_s, cur_z


def propagate_qparams(blocks, qnet: QNet, in_s: float, in_z: float):
    """(scale, zp) of the tensor leaving `blocks`, computed from QNet
    metadata only — no data needed. Matches `run_blocks` exactly, which is
    what lets the stage compiler bake per-stage quantizers in as statics."""
    cur_s, cur_z = in_s, in_z
    for block in blocks:
        for op in block.ops:
            qop = qnet.ops[op.name]
            cur_s, cur_z = qop.out_scale, qop.out_zp
        if block.residual:
            cur_s, cur_z = qnet.res_q[block.name]
    return cur_s, cur_z


def input_qparams(qnet: QNet) -> Tuple[float, float]:
    """The network input quantizer (the first op's input activation)."""
    first = qnet.ops[qnet.spec.blocks[0].ops[0].name]
    return first.in_scale, first.in_zp


def run_qnet(
    qnet: QNet,
    x: jnp.ndarray,
    fixed_point: bool = False,
    input_bits: int = 8,
) -> jnp.ndarray:
    """Full integer inference. Returns float logits (dequantized at the end,
    where the FPGA hands confidence computation back to the PS/softmax)."""
    in_s, in_z = input_qparams(qnet)
    y = quantize_input(x, in_s, in_z, input_bits)
    y, cur_s, cur_z = run_blocks(y, qnet.spec.blocks, qnet, in_s, in_z,
                                 fixed_point)
    return (y.astype(jnp.float32) + cur_z) * cur_s


__all__ = [
    "quantize_input",
    "run_block",
    "run_blocks",
    "propagate_qparams",
    "input_qparams",
    "run_qnet",
]
