"""Network graph IR consumed by the Network Compiler (Sec. 4.2).

The front-end emits, and the back-end consumes, a tiny layer IR: a network is
a sequence of blocks; each block is a sequence of convolutional operators plus
optional residual / squeeze-excitation / pooling structure. The compiler
(`core/compiler.py`) partitions blocks into Head / Body / Tail / Classifier
CUs based on their recurrence pattern, exactly like the paper's Network SoC
Compiler ("Depending on the recurrence of the convolutional operators, they
are mapped to the Head, Body, Tail, and Classifier CU").

The same IR drives:
  * float inference & QAT        (models/layers.py interpreter)
  * op/param counting            (Table 2 reproduction)
  * quantization to QNet         (core/qnet.py)
  * fused integer CU execution   (core/cu.py)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# Operator kinds
CONV = "conv"  # normal convolution (spatial + channel reduction)
DW = "dw"  # depthwise convolution (spatial only, groups == channels)
PW = "pw"  # pointwise convolution (1x1, channel only)
DENSE = "dense"  # classifier matmul
# 1-D (temporal) variants for streaming DSCNNs ([B, T, C] activations).
# PW and DENSE are rank-agnostic (channel-only mixing), so only the ops
# with a spatial/temporal window get dedicated kinds.
CONV1D = "conv1d"  # normal temporal convolution (stem of a 1-D DSCNN)
DW1D = "dw1d"  # depthwise temporal convolution

# op kinds that fix the activation rank to 1 (a net containing any of these
# runs on [B, T, C] tensors; see `spatial_rank`)
RANK1_KINDS = (CONV1D, DW1D)

# Activations
RELU6 = "relu6"
NONE = "none"  # linear (projection convs, classifier)
HSIGMOID = "hsigmoid"  # hard sigmoid, Eq. 1 (EfficientNet SE gate)


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One convolutional operator (paper Sec. 4.1)."""

    name: str
    kind: str  # CONV | DW | PW | DENSE
    in_ch: int
    out_ch: int
    kernel: int = 1
    stride: int = 1
    act: str = RELU6
    bits: int = 4  # BW of this operator's datapath
    act_bits: int = 4  # BW of its output activation tensor

    def weight_shape(self) -> Tuple[int, ...]:
        if self.kind == DW:
            # HWIO with feature_group_count == C: [K, K, 1, C]; out channel last,
            # matching the per-channel quantization axis of every other op.
            return (self.kernel, self.kernel, 1, self.in_ch)
        if self.kind == DW1D:
            # WIO with feature_group_count == C: [K, 1, C]; out channel last.
            return (self.kernel, 1, self.in_ch)
        if self.kind == CONV1D:
            return (self.kernel, self.in_ch, self.out_ch)
        if self.kind == DENSE:
            return (self.in_ch, self.out_ch)
        return (self.kernel, self.kernel, self.in_ch, self.out_ch)

    def n_params(self, with_bias: bool = True) -> int:
        n = 1
        for d in self.weight_shape():
            n *= d
        return n + (self.out_ch if with_bias else 0)

    def macs(self, h: int, w: int) -> int:
        """Multiply-accumulates to produce an (h, w) output map.

        1-D ops take (t, 1): h * w is the number of output positions either
        way, and the temporal window contributes `kernel` taps, not K^2."""
        if self.kind == DW:
            return h * w * self.kernel * self.kernel * self.in_ch
        if self.kind == DW1D:
            return h * w * self.kernel * self.in_ch
        if self.kind == CONV1D:
            return h * w * self.kernel * self.in_ch * self.out_ch
        if self.kind == DENSE:
            return self.in_ch * self.out_ch
        return h * w * self.kernel * self.kernel * self.in_ch * self.out_ch


@dataclasses.dataclass(frozen=True)
class SESpec:
    """Squeeze-and-Excitation (EfficientNet IRB, Fig. 3b): global-avg ->
    PW-SQ (reduce) -> PW-EX (expand) -> hard-sigmoid gate."""

    channels: int
    reduced: int
    bits: int = 4
    prefix: str = "se"

    @property
    def squeeze(self) -> OpSpec:
        return OpSpec(
            f"{self.prefix}/pw_sq", PW, self.channels, self.reduced,
            act=RELU6, bits=self.bits, act_bits=self.bits,
        )

    @property
    def excite(self) -> OpSpec:
        return OpSpec(
            f"{self.prefix}/pw_ex", PW, self.reduced, self.channels,
            act=HSIGMOID, bits=self.bits, act_bits=self.bits,
        )


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """A fusable group of operators — the unit the compiler maps to one CU
    invocation. `residual` adds the skip-line (Fig. 3) when shapes permit."""

    name: str
    ops: Tuple[OpSpec, ...]
    residual: bool = False
    se: Optional[SESpec] = None  # SE applied after the depthwise op
    se_after: Optional[str] = None  # op name the SE gate follows
    avgpool: bool = False  # global average pool after the ops (Tail CU)

    @property
    def stride(self) -> int:
        s = 1
        for op in self.ops:
            s *= op.stride
        return s

    @property
    def in_ch(self) -> int:
        return self.ops[0].in_ch

    @property
    def out_ch(self) -> int:
        return self.ops[-1].out_ch


@dataclasses.dataclass(frozen=True)
class NetSpec:
    """Whole-network description (the front-end's 'network description model')."""

    name: str
    blocks: Tuple[BlockSpec, ...]
    input_hw: int
    input_ch: int = 3
    num_classes: int = 1000

    def all_ops(self):
        for b in self.blocks:
            for op in b.ops:
                yield b, op
            if b.se is not None:
                yield b, b.se.squeeze
                yield b, b.se.excite

    def n_params(self, with_bias: bool = True) -> int:
        return sum(op.n_params(with_bias) for _, op in self.all_ops())

    def model_bits(self, with_bias: bool = True, bias_bits: int = 32) -> int:
        """Model size in bits with per-op BW — reproduces Table 2 Params(Mb)."""
        total = 0
        for _, op in self.all_ops():
            n = op.n_params(with_bias=False)
            total += n * op.bits
            if with_bias:
                total += op.out_ch * bias_bits
        return total

    @property
    def spatial_rank(self) -> int:
        """1 for temporal ([B, T, C]) nets, 2 for image ([B, H, W, C]) nets.

        Derived from the op kinds rather than stored, so `.qnet`
        serialization and every existing 2-D build record are untouched."""
        return 1 if any(op.kind in RANK1_KINDS
                        for _, op in self.all_ops()) else 2

    def input_shape(self) -> Tuple[int, ...]:
        """Per-example input tensor shape (no batch dim)."""
        if self.spatial_rank == 1:
            return (self.input_hw, self.input_ch)
        return (self.input_hw, self.input_hw, self.input_ch)

    def count_macs(self) -> int:
        """Total MACs for one input image (Table 2 '#Ops')."""
        h = self.input_hw
        w_of = (lambda h_out: 1) if self.spatial_rank == 1 else (lambda h_out: h_out)
        total = 0
        for b in self.blocks:
            for op in b.ops:
                if op.kind == DENSE:
                    total += op.macs(1, 1)
                    continue
                h_out = -(-h // op.stride)  # ceil div, SAME padding
                total += op.macs(h_out, w_of(h_out))
                h = h_out
            if b.se is not None:
                # SE convs act on 1x1 pooled features
                total += b.se.squeeze.macs(1, 1) + b.se.excite.macs(1, 1)
        return total

    def count_bn_ops(self) -> int:
        """Elementwise ops the (unfused) BN layers would add — the ~4% claim."""
        h = self.input_hw
        total = 0
        for b in self.blocks:
            for op in b.ops:
                if op.kind == DENSE:
                    continue
                h_out = -(-h // op.stride)
                elems = h_out if self.spatial_rank == 1 else h_out * h_out
                total += 2 * elems * op.out_ch  # scale + shift per element
                h = h_out
        return total


# name suffix appended by the act-bit rewrites below; stripped before
# re-appending so re-quantization is idempotent on the name
_ACT_SUFFIX_RE = re.compile(r"(_act(?:\d+|mix[0-9a-f]+))+$")


def _base_name(name: str) -> str:
    """Net name with any `_act{n}` / `_actmix{hash}` suffix removed."""
    return _ACT_SUFFIX_RE.sub("", name)


def with_act_bits(net: NetSpec, act_bits: int) -> NetSpec:
    """The same network at a different activation bit-width.

    Rewrites `act_bits` on every plain convolutional operator — the knob the
    QAT anneal schedule turns (train at 8-bit activations first, then step
    down to the deployment BW, per the paper's UInt4 recipe). Weight
    bit-widths and SE gates are left untouched: the gate output range is
    exactly [0, 1] regardless of BW, and `SESpec` derives both widths from
    one field. Op names (and therefore param trees) are unchanged, so one
    set of float params serves every anneal stage.

    The name gains one `_act{n}` suffix; any existing act suffix is
    stripped first, so re-quantizing an already-suffixed net yields
    `mnv2_act4`, never `mnv2_act8_act4` (artifact / tuned-cache / golden
    naming stays in sync across repeated anneal steps).
    """
    blocks = tuple(
        dataclasses.replace(
            b, ops=tuple(dataclasses.replace(op, act_bits=act_bits)
                         for op in b.ops))
        for b in net.blocks
    )
    return dataclasses.replace(
        net, name=f"{_base_name(net.name)}_act{act_bits}", blocks=blocks)


def with_op_act_bits(net: NetSpec, alloc: Dict[str, int]) -> NetSpec:
    """Per-op generalization of `with_act_bits`: heterogeneous precision.

    `alloc` maps op names to activation bit-widths; ops absent from the
    map keep their current `act_bits`. Unknown names raise — a typo'd
    allocation silently keeping the old width is exactly the bug class
    the mixed-precision tooling must not have. SE gate ops are derived
    from `SESpec` and are not individually addressable (the gate range is
    [0, 1] at any BW), so their names are rejected too.

    The returned net's name carries a deterministic `_actmix{hash}`
    suffix (stripping any existing act suffix first), so two different
    allocations never alias in tuned-cache `nets` lists or artifact
    filenames, while the same allocation always produces the same name.
    """
    if not alloc:
        return net
    known = {op.name for b in net.blocks for op in b.ops}
    unknown = sorted(set(alloc) - known)
    if unknown:
        raise KeyError(
            f"with_op_act_bits: unknown op name(s) {unknown!r} — "
            f"allocation keys must name plain ops of {net.name!r}")
    blocks = tuple(
        dataclasses.replace(
            b, ops=tuple(
                dataclasses.replace(op, act_bits=int(alloc[op.name]))
                if op.name in alloc else op
                for op in b.ops))
        for b in net.blocks
    )
    new = dataclasses.replace(net, blocks=blocks)
    widths = sorted({op.act_bits for b in new.blocks for op in b.ops})
    if len(widths) == 1:
        # degenerate map: every op ends at one width — same spelling as
        # the uniform rewrite so names stay canonical
        name = f"{_base_name(net.name)}_act{widths[0]}"
    else:
        sig = "-".join(f"{op.name}={op.act_bits}"
                       for b in new.blocks for op in b.ops)
        import hashlib

        digest = hashlib.sha1(sig.encode()).hexdigest()[:8]
        name = f"{_base_name(net.name)}_actmix{digest}"
    return dataclasses.replace(new, name=name)


def op_act_bits(net: NetSpec) -> Dict[str, int]:
    """The net's current per-op activation widths, `{op_name: bits}` —
    the inverse view `with_op_act_bits` consumes (plain ops only; SE gate
    widths are derived from `SESpec.bits`)."""
    return {op.name: op.act_bits for b in net.blocks for op in b.ops}


__all__ = [
    "OpSpec",
    "SESpec",
    "BlockSpec",
    "NetSpec",
    "with_act_bits",
    "with_op_act_bits",
    "op_act_bits",
    "CONV",
    "DW",
    "PW",
    "DENSE",
    "CONV1D",
    "DW1D",
    "RANK1_KINDS",
    "RELU6",
    "NONE",
    "HSIGMOID",
]
