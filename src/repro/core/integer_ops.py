"""Integer inference arithmetic — the Approximator & Clip unit (Sec. 4.1).

The FPGA datapath performs:  int MACs -> int32 accumulator -> requantize
(truncate/round by a per-channel multiplier) -> clip to [0, 2^BW - 1]
(which doubles as the fused ReLU6).

Number system (weights symmetric per out channel, activations asymmetric):

    x  = S_x * (x_q + z_x)            x_q in [0, 2^BW-1]
    w  = S_w[c] * w_q                  w_q in [-(2^{BW-1}-1), 2^{BW-1}-1]
    y  = conv(x, w) + b
    y_q = clip( round( M[c] * (acc[c] + z_x * wsum[c]) + b_q[c] ), 0, qmax )

with    acc  = sum x_q * w_q          (pure integer MACs)
        wsum = sum w_q                (folded at compile time)
        M[c] = S_x * S_w[c] / S_y     (the requant multiplier)
        b_q  = b / S_y                (bias pre-scaled into output units)

Two requantization modes are provided:
  * float multiplier (what XLA would do on TPU with an f32 epilogue), and
  * fixed-point: M ~= m * 2^-shift with m an int32 mantissa — the faithful
    model of the FPGA's integer 'Approximator' (round-half-up truncation).

Both are validated against each other and against the dequantized float path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def quantize_multiplier(m: np.ndarray, bits: int = 31) -> Tuple[np.ndarray, np.ndarray]:
    """Decompose positive float multiplier(s) M into (mantissa, shift) with
    M ~= mantissa * 2^-shift, mantissa in [2^(bits-1), 2^bits)."""
    m = np.asarray(m, np.float64)
    if np.any(m <= 0):
        raise ValueError("requant multiplier must be positive")
    exp = np.ceil(np.log2(m))
    mant = m / np.exp2(exp)  # in (0.5, 1]
    mantissa = np.round(mant * (1 << bits)).astype(np.int64)
    # handle mant == 1.0 rounding up to 2^bits
    overflow = mantissa == (1 << bits)
    mantissa = np.where(overflow, mantissa >> 1, mantissa)
    exp = np.where(overflow, exp + 1, exp)
    shift = (bits - exp).astype(np.int32)
    return mantissa, shift


def requantize_fixedpoint(
    acc: jnp.ndarray, mantissa: jnp.ndarray, shift: jnp.ndarray
) -> jnp.ndarray:
    """y = round(acc * mantissa * 2^-shift) using only integer ops (int64 wide)."""
    wide = acc.astype(jnp.int64) * mantissa.astype(jnp.int64)
    # round half away from zero, like the FPGA 'Approximator' rounding mode
    sh = shift.astype(jnp.int64)
    bias = jnp.where(wide >= 0, jnp.int64(1), jnp.int64(-1)) << jnp.maximum(sh - 1, 0)
    bias = jnp.where(sh > 0, bias, 0)
    return ((wide + bias) >> sh).astype(jnp.int32)


def requantize_float(acc: jnp.ndarray, mult: jnp.ndarray) -> jnp.ndarray:
    return jnp.round(acc.astype(jnp.float32) * mult).astype(jnp.int32)


def clip_act(y_q: jnp.ndarray, qmax: int) -> jnp.ndarray:
    """The Clip unit == fused ReLU6 (Sec. 3: h^pq maps [0,6] onto [0, qmax])."""
    return jnp.clip(y_q, 0, qmax)


# ---------------------------------------------------------------------------
# Integer operator bodies (used by the CU runners and as kernel oracles).
# Layouts: activations NHWC, conv weights HWIO, depthwise HWC1, linear [Din,Dout].
# ---------------------------------------------------------------------------


def int_conv2d(
    x_q: jnp.ndarray,
    w_q: jnp.ndarray,
    stride: int = 1,
    padding: str = "SAME",
    groups: int = 1,
) -> jnp.ndarray:
    """Integer convolution with int32 accumulation (normal / group / depthwise)."""
    return jax.lax.conv_general_dilated(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=jnp.int32,
    )


def _conv1d_padding(padding):
    """Normalize a 1-D conv padding spec for `conv_general_dilated`:
    'SAME'/'VALID' pass through; an explicit (lo, hi) pair wraps into the
    per-spatial-dim tuple form. Explicit pads are what the streaming engine
    uses to compute ring-buffer edge segments with VALID-style convs."""
    if isinstance(padding, str):
        return padding
    lo, hi = padding
    return ((int(lo), int(hi)),)


def int_conv1d(
    x_q: jnp.ndarray,
    w_q: jnp.ndarray,
    stride: int = 1,
    padding="SAME",
    groups: int = 1,
) -> jnp.ndarray:
    """Integer temporal convolution, int32 accumulation. x: [B, T, C];
    w: [K, Cin/groups, Cout]. `padding` is 'SAME'/'VALID' or an explicit
    (lo, hi) pair."""
    return jax.lax.conv_general_dilated(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        window_strides=(stride,),
        padding=_conv1d_padding(padding),
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=groups,
        preferred_element_type=jnp.int32,
    )


def int_conv1d_f32(
    x_q: jnp.ndarray, w_q: jnp.ndarray, stride: int = 1, padding="SAME"
) -> jnp.ndarray:
    """`int_conv1d` through the f32 conv path (only under `f32_accum_exact`;
    Precision HIGHEST for true f32 multiplies — see `int_pointwise_f32`)."""
    acc = jax.lax.conv_general_dilated(
        x_q.astype(jnp.float32),
        w_q.astype(jnp.float32),
        window_strides=(stride,),
        padding=_conv1d_padding(padding),
        dimension_numbers=("NWC", "WIO", "NWC"),
        precision=jax.lax.Precision.HIGHEST,
    )
    return acc.astype(jnp.int32)


def int_depthwise1d_shifts(
    x_q: jnp.ndarray, w_q: jnp.ndarray, stride: int = 1, padding="SAME"
) -> jnp.ndarray:
    """Depthwise temporal conv as K unrolled shifted multiplies.

    x_q: [B, T, C]; w_q: [K, C]. Bit-identical to `int_conv1d(...,
    groups=C)` (integer adds in a different order), but lowers to
    vectorized elementwise ops — the 1-D analogue of
    `int_depthwise_shifts`. `padding` is 'SAME' or an explicit (lo, hi)
    pair (the streaming engine's edge segments)."""
    from repro.kernels.common import same_pad_amount

    b, t, c = x_q.shape
    kernel = w_q.shape[0]
    if isinstance(padding, str):
        if padding == "SAME":
            p_lo, p_hi, t_out = same_pad_amount(t, kernel, stride)
        elif padding == "VALID":
            p_lo, p_hi, t_out = 0, 0, (t - kernel) // stride + 1
        else:
            raise ValueError(padding)
    else:
        p_lo, p_hi = int(padding[0]), int(padding[1])
        t_out = (t + p_lo + p_hi - kernel) // stride + 1
    xp = jnp.pad(x_q.astype(jnp.int32), ((0, 0), (p_lo, p_hi), (0, 0)))
    w2 = w_q.astype(jnp.int32)
    acc = jnp.zeros((b, t_out, c), jnp.int32)
    for ki in range(kernel):
        patch = jax.lax.slice(
            xp,
            (0, ki, 0),
            (b, ki + (t_out - 1) * stride + 1, c),
            (1, stride, 1),
        )
        acc = acc + patch * w2[ki][None, None, :]
    return acc


def int_pointwise(x_q: jnp.ndarray, w_q: jnp.ndarray) -> jnp.ndarray:
    """Pointwise conv == matmul over the channel axis (the paper's systolic fit)."""
    return jax.lax.dot_general(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


# ---------------------------------------------------------------------------
# Compiled integer fast-path formulations (bit-identical accumulators).
#
# XLA's CPU backend lowers integer convolutions to a naive scalar loop (no
# Eigen/oneDNN path exists for s32 convs), which makes `int_conv2d` the
# serving hot-spot off-TPU. The formulations below compute the *same int32
# accumulator* through operations XLA does vectorize:
#   * depthwise  -> K x K shifted elementwise multiply-adds (always exact),
#   * matmul/conv-> f32 arithmetic, which is exact as long as every partial
#     sum stays below 2^24 (f32 integers are exact up to 2^24); the bound is
#     checked per-op against the actual quantized weights by
#     `f32_accum_exact`.
# ---------------------------------------------------------------------------


def int_depthwise_shifts(
    x_q: jnp.ndarray, w_q: jnp.ndarray, stride: int = 1
) -> jnp.ndarray:
    """Depthwise conv as unrolled shifted multiplies (SAME padding).

    x_q: [B, H, W, C]; w_q: [K, K, C]. Bit-identical to `int_conv2d(...,
    groups=C)` — integer adds/multiplies in a different order — but lowers to
    vectorized elementwise ops instead of XLA-CPU's naive int conv loop.
    """
    from repro.kernels.common import same_pad_amount

    b, h, w, c = x_q.shape
    kernel = w_q.shape[0]
    ph_lo, ph_hi, h_out = same_pad_amount(h, kernel, stride)
    pw_lo, pw_hi, w_out = same_pad_amount(w, kernel, stride)
    xp = jnp.pad(
        x_q.astype(jnp.int32), ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0))
    )
    w3 = w_q.astype(jnp.int32)
    acc = jnp.zeros((b, h_out, w_out, c), jnp.int32)
    for ki in range(kernel):
        for kj in range(kernel):
            patch = jax.lax.slice(
                xp,
                (0, ki, kj, 0),
                (b, ki + (h_out - 1) * stride + 1,
                 kj + (w_out - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            acc = acc + patch * w3[ki, kj][None, None, None, :]
    return acc


def f32_accum_exact(w_q: np.ndarray, in_qmax: int) -> bool:
    """True when an f32 accumulation over `w_q`'s reduction axes is exact.

    Bound: activations lie in [0, in_qmax], so |acc| and every partial sum
    are at most in_qmax * max_n(sum_k |w_q[..., n]|). Integers below 2^24 are
    exactly representable in f32 (and any summation order stays below the
    bound), so the f32 result equals the int32 accumulator bit-for-bit.
    """
    w = np.abs(np.asarray(w_q, np.int64))
    red = tuple(range(w.ndim - 1))
    colsum = w.sum(axis=red).max() if w.size else 0
    return int(in_qmax) * int(colsum) < 2**24


def int_pointwise_f32(x_q: jnp.ndarray, w_q: jnp.ndarray) -> jnp.ndarray:
    """`int_pointwise` computed through the f32 units (use only when
    `f32_accum_exact` holds for the operands). Precision HIGHEST forbids
    bf16/tf32 shortcuts on accelerators — the exactness proof needs true
    f32 multiplies."""
    acc = jax.lax.dot_general(
        x_q.astype(jnp.float32),
        w_q.astype(jnp.float32),
        (((x_q.ndim - 1,), (0,)), ((), ())),
        precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )
    return acc.astype(jnp.int32)


def int_conv2d_f32(
    x_q: jnp.ndarray, w_q: jnp.ndarray, stride: int = 1, padding: str = "SAME"
) -> jnp.ndarray:
    """`int_conv2d` computed through the f32 conv path (use only when
    `f32_accum_exact` holds for the operands). Precision HIGHEST as above."""
    acc = jax.lax.conv_general_dilated(
        x_q.astype(jnp.float32),
        w_q.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        precision=jax.lax.Precision.HIGHEST,
    )
    return acc.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Integer residual skip-add (the fixed-point 'Approximator' applied to the
# skip-line, Sec. 4.1): both operands are rescaled into the output domain
# with integer mantissa multiplies + one shared round-shift.
# ---------------------------------------------------------------------------

# 14-bit mantissas keep every term below 2^24, so the path is exact in int32
# even without jax x64 (255 * 2^14 * 2 + |c| < 2^31 with huge margin).
RESIDUAL_MANT_BITS = 14


def residual_fixed_consts(
    a_s: float, a_z: float, b_s: float, b_z: float, y_s: float, y_z: float
):
    """Fold the skip-add rescale into integer constants (host-side, once).

    Returns (m_a, m_b, c, shift, zy): y_q = round_shift(a_q*m_a + b_q*m_b
    + c, shift) - zy, matching `_residual_add`'s float math to within the
    14-bit mantissa quantization.
    """
    r_a, r_b = a_s / y_s, b_s / y_s
    _, shift = quantize_multiplier(max(r_a, r_b), bits=RESIDUAL_MANT_BITS)
    shift = int(shift)
    m_a = int(round(r_a * 2.0**shift))
    m_b = int(round(r_b * 2.0**shift))
    c = int(round((a_z * r_a + b_z * r_b) * 2.0**shift))
    return m_a, m_b, c, shift, int(round(y_z))


def int_residual_add(
    a_q: jnp.ndarray,
    b_q: jnp.ndarray,
    consts,
    qmax: int,
) -> jnp.ndarray:
    """Integer skip-line add: y = clip(round_shift(a*m_a + b*m_b + c) - zy).

    Round-half-away-from-zero, like `requantize_fixedpoint` (the FPGA
    'Approximator' rounding mode). Pure int32 arithmetic.
    """
    m_a, m_b, c, shift, zy = consts
    wide = (
        a_q.astype(jnp.int32) * jnp.int32(m_a)
        + b_q.astype(jnp.int32) * jnp.int32(m_b)
        + jnp.int32(c)
    )
    if shift > 0:
        half = jnp.where(wide >= 0, jnp.int32(1), jnp.int32(-1)) << (shift - 1)
        wide = wide + half
    y = (wide >> shift) - jnp.int32(zy)
    return jnp.clip(y, 0, qmax).astype(jnp.int32)


def quantized_op_epilogue(
    acc: jnp.ndarray,
    z_x: jnp.ndarray,
    wsum: jnp.ndarray,
    bias_q: jnp.ndarray,
    mult: jnp.ndarray,
    qmax: int,
    z_y: jnp.ndarray = 0,
    fixed_point: bool = False,
    mantissa: Optional[jnp.ndarray] = None,
    shift: Optional[jnp.ndarray] = None,
    clip_output: bool = True,
) -> jnp.ndarray:
    """acc -> requant -> (+bias) -> clip. Matches Fig. 8's Approximator & Clip.

    bias_q is expressed in output-quant units (b / S_y), already rounded.
    z_y is the output zero point (0 when ReLU6 is fused, Sec. 3).
    """
    corrected = acc + z_x.astype(jnp.int32) * wsum.astype(jnp.int32)
    if fixed_point:
        y = requantize_fixedpoint(corrected, mantissa, shift)
    else:
        y = requantize_float(corrected, mult)
    y = y + bias_q.astype(jnp.int32) - jnp.asarray(z_y, jnp.int32)
    if clip_output:
        y = clip_act(y, qmax)
    return y


__all__ = [
    "quantize_multiplier",
    "requantize_fixedpoint",
    "requantize_float",
    "clip_act",
    "int_conv2d",
    "int_conv1d",
    "int_conv1d_f32",
    "int_pointwise",
    "int_depthwise_shifts",
    "int_depthwise1d_shifts",
    "int_pointwise_f32",
    "int_conv2d_f32",
    "f32_accum_exact",
    "residual_fixed_consts",
    "int_residual_add",
    "RESIDUAL_MANT_BITS",
    "quantized_op_epilogue",
]
