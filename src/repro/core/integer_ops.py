"""Integer inference arithmetic — the Approximator & Clip unit (Sec. 4.1).

The FPGA datapath performs:  int MACs -> int32 accumulator -> requantize
(truncate/round by a per-channel multiplier) -> clip to [0, 2^BW - 1]
(which doubles as the fused ReLU6).

Number system (weights symmetric per out channel, activations asymmetric):

    x  = S_x * (x_q + z_x)            x_q in [0, 2^BW-1]
    w  = S_w[c] * w_q                  w_q in [-(2^{BW-1}-1), 2^{BW-1}-1]
    y  = conv(x, w) + b
    y_q = clip( round( M[c] * (acc[c] + z_x * wsum[c]) + b_q[c] ), 0, qmax )

with    acc  = sum x_q * w_q          (pure integer MACs)
        wsum = sum w_q                (folded at compile time)
        M[c] = S_x * S_w[c] / S_y     (the requant multiplier)
        b_q  = b / S_y                (bias pre-scaled into output units)

Two requantization modes are provided:
  * float multiplier (what XLA would do on TPU with an f32 epilogue), and
  * fixed-point: M ~= m * 2^-shift with m an int32 mantissa — the faithful
    model of the FPGA's integer 'Approximator' (round-half-up truncation).

Both are validated against each other and against the dequantized float path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def quantize_multiplier(m: np.ndarray, bits: int = 31) -> Tuple[np.ndarray, np.ndarray]:
    """Decompose positive float multiplier(s) M into (mantissa, shift) with
    M ~= mantissa * 2^-shift, mantissa in [2^(bits-1), 2^bits)."""
    m = np.asarray(m, np.float64)
    if np.any(m <= 0):
        raise ValueError("requant multiplier must be positive")
    exp = np.ceil(np.log2(m))
    mant = m / np.exp2(exp)  # in (0.5, 1]
    mantissa = np.round(mant * (1 << bits)).astype(np.int64)
    # handle mant == 1.0 rounding up to 2^bits
    overflow = mantissa == (1 << bits)
    mantissa = np.where(overflow, mantissa >> 1, mantissa)
    exp = np.where(overflow, exp + 1, exp)
    shift = (bits - exp).astype(np.int32)
    return mantissa, shift


def requantize_fixedpoint(
    acc: jnp.ndarray, mantissa: jnp.ndarray, shift: jnp.ndarray
) -> jnp.ndarray:
    """y = round(acc * mantissa * 2^-shift) using only integer ops (int64 wide)."""
    wide = acc.astype(jnp.int64) * mantissa.astype(jnp.int64)
    # round half away from zero, like the FPGA 'Approximator' rounding mode
    sh = shift.astype(jnp.int64)
    bias = jnp.where(wide >= 0, jnp.int64(1), jnp.int64(-1)) << jnp.maximum(sh - 1, 0)
    bias = jnp.where(sh > 0, bias, 0)
    return ((wide + bias) >> sh).astype(jnp.int32)


def requantize_float(acc: jnp.ndarray, mult: jnp.ndarray) -> jnp.ndarray:
    return jnp.round(acc.astype(jnp.float32) * mult).astype(jnp.int32)


def clip_act(y_q: jnp.ndarray, qmax: int) -> jnp.ndarray:
    """The Clip unit == fused ReLU6 (Sec. 3: h^pq maps [0,6] onto [0, qmax])."""
    return jnp.clip(y_q, 0, qmax)


# ---------------------------------------------------------------------------
# Integer operator bodies (used by the CU runners and as kernel oracles).
# Layouts: activations NHWC, conv weights HWIO, depthwise HWC1, linear [Din,Dout].
# ---------------------------------------------------------------------------


def int_conv2d(
    x_q: jnp.ndarray,
    w_q: jnp.ndarray,
    stride: int = 1,
    padding: str = "SAME",
    groups: int = 1,
) -> jnp.ndarray:
    """Integer convolution with int32 accumulation (normal / group / depthwise)."""
    return jax.lax.conv_general_dilated(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=jnp.int32,
    )


def int_pointwise(x_q: jnp.ndarray, w_q: jnp.ndarray) -> jnp.ndarray:
    """Pointwise conv == matmul over the channel axis (the paper's systolic fit)."""
    return jax.lax.dot_general(
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        (((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def quantized_op_epilogue(
    acc: jnp.ndarray,
    z_x: jnp.ndarray,
    wsum: jnp.ndarray,
    bias_q: jnp.ndarray,
    mult: jnp.ndarray,
    qmax: int,
    z_y: jnp.ndarray = 0,
    fixed_point: bool = False,
    mantissa: Optional[jnp.ndarray] = None,
    shift: Optional[jnp.ndarray] = None,
    clip_output: bool = True,
) -> jnp.ndarray:
    """acc -> requant -> (+bias) -> clip. Matches Fig. 8's Approximator & Clip.

    bias_q is expressed in output-quant units (b / S_y), already rounded.
    z_y is the output zero point (0 when ReLU6 is fused, Sec. 3).
    """
    corrected = acc + z_x.astype(jnp.int32) * wsum.astype(jnp.int32)
    if fixed_point:
        y = requantize_fixedpoint(corrected, mantissa, shift)
    else:
        y = requantize_float(corrected, mult)
    y = y + bias_q.astype(jnp.int32) - jnp.asarray(z_y, jnp.int32)
    if clip_output:
        y = clip_act(y, qmax)
    return y


__all__ = [
    "quantize_multiplier",
    "requantize_fixedpoint",
    "requantize_float",
    "clip_act",
    "int_conv2d",
    "int_pointwise",
    "quantized_op_epilogue",
]
