"""QNet — the front-end's output artifact (Fig. 1, Fig. 4).

A QNet holds, for every convolutional operator of the network:
  * integer weights (symmetric per-output-channel, int8 storage; packed int4
    for BW<=4 available via `quant.pack_int4`),
  * the per-channel requantization multipliers M = S_x * S_w / S_y (both as
    float and as fixed-point mantissa/shift pairs for the faithful FPGA
    'Approximator' model),
  * folded constants: wsum (zero-point correction) and bias_q (bias in output
    units), and
  * the activation quantizers — with ReLU6 *fused*: ReLU6-activated ops use
    h^pq: [0,6] -> [0, 2^BW - 1] so the integer clip is the activation.

`quantize_net` converts (float params [+ observers from calibration]) into a
QNet; `core/cu.py` executes it with pure integer arithmetic.
"""
from __future__ import annotations

import dataclasses
import io
import json
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import graph as G
from repro.core.calibrate import ActObserver, relu6_fused_qparams
from repro.core.integer_ops import quantize_multiplier
from repro.core.quant import QuantConfig, compute_scale_zp, observe_range, quantize


@dataclasses.dataclass
class QOp:
    """One quantized operator + all folded metadata (per-channel)."""

    spec: G.OpSpec
    w_q: np.ndarray  # int8, original weight shape
    w_scale: np.ndarray  # [M]
    wsum: np.ndarray  # [M] int32 — sum of w_q over reduction axes
    bias_q: np.ndarray  # [M] int32 — round(b / S_y)
    in_scale: float
    in_zp: float
    out_scale: float
    out_zp: float
    mult: np.ndarray  # [M] float — S_x * S_w / S_y
    mantissa: np.ndarray  # [M] int64 fixed-point mantissa
    shift: np.ndarray  # [M] int32 fixed-point shift
    clip: bool  # True when ReLU6 is fused (clip == activation)

    @property
    def qmax(self) -> int:
        return 2**self.spec.act_bits - 1


@dataclasses.dataclass
class QNet:
    spec: G.NetSpec
    ops: Dict[str, QOp]
    # per residual block: (out_scale, out_zp) of the post-add tensor
    res_q: Dict[str, Tuple[float, float]] = dataclasses.field(default_factory=dict)

    def model_bytes(self) -> int:
        """Packed model size in bytes (weights at their BW + int32 bias/meta)."""
        total = 0
        for name, qop in self.ops.items():
            n = int(np.prod(qop.w_q.shape))
            total += (n * qop.spec.bits + 7) // 8
            total += qop.bias_q.size * 4
        return total


def _weight_qparams(w: np.ndarray, op: G.OpSpec) -> Tuple[np.ndarray, np.ndarray]:
    cfg = QuantConfig(op.bits, symmetric=True, channel_axis=-1)
    mn, mx = observe_range(jnp.asarray(w), cfg)
    scale, zp = compute_scale_zp(mn, mx, cfg)
    q = quantize(jnp.asarray(w), scale, zp, cfg)
    return np.asarray(q, np.int8), np.asarray(scale)


def _act_qparams(
    op: G.OpSpec, observer: Optional[ActObserver]
) -> Tuple[float, float]:
    """Output activation quantizer: ReLU6-fused for relu6 ops (h^pq), or
    calibration-derived asymmetric for linear / hsigmoid outputs."""
    acfg = QuantConfig(op.act_bits, symmetric=False, channel_axis=None)
    if op.act == G.RELU6:
        s, z = relu6_fused_qparams(acfg)
        return float(s), float(z)
    if op.act == G.HSIGMOID:
        return 1.0 / acfg.qmax, 0.0  # gate output range is exactly [0, 1]
    if observer is None:
        raise ValueError(f"calibration observer required for linear op {op.name}")
    s, z = observer.qparams(acfg)
    return float(s), float(z)


def quantize_net(
    params,
    net: G.NetSpec,
    observers: Dict[str, ActObserver],
    input_range: Tuple[float, float] = (-1.0, 1.0),
    input_bits: int = 8,
) -> QNet:
    """Post-training model quantization: float params + calibration -> QNet."""
    qops: Dict[str, QOp] = {}
    res_q: Dict[str, Tuple[float, float]] = {}
    in_cfg = QuantConfig(input_bits, symmetric=False, channel_axis=None)
    in_scale, in_zp = compute_scale_zp(
        jnp.asarray(input_range[0]), jnp.asarray(input_range[1]), in_cfg
    )
    cur_scale, cur_zp = float(in_scale), float(in_zp)

    for block in net.blocks:
        for op in block.ops:
            cur_scale, cur_zp = _quantize_op(
                qops, params, op, cur_scale, cur_zp, observers
            )
            if block.se is not None and block.se_after == op.name:
                # SE branch: squeeze reads the dw output quantizer; excite
                # reads squeeze's; the hsigmoid gate output is [0,1] and the
                # gated tensor keeps the dw quantizer (gating only shrinks).
                s1, z1 = _quantize_op(
                    qops, params, block.se.squeeze, cur_scale, cur_zp, observers
                )
                _quantize_op(qops, params, block.se.excite, s1, z1, observers)
        if block.residual:
            obs = observers.get(block.name + "/residual")
            if obs is None:
                raise ValueError(
                    f"residual block {block.name} needs a '/residual' observer"
                )
            acfg = QuantConfig(block.ops[-1].act_bits, symmetric=False, channel_axis=None)
            s, z = obs.qparams(acfg)
            res_q[block.name] = (float(s), float(z))
            cur_scale, cur_zp = float(s), float(z)
    return QNet(net, qops, res_q)


def _quantize_op(qops, params, op: G.OpSpec, in_scale, in_zp, observers):
    w = np.asarray(params[op.name]["w"], np.float64)
    b = np.asarray(params[op.name]["b"], np.float64)
    w_q, w_scale = _weight_qparams(w, op)
    out_scale, out_zp = _act_qparams(op, observers.get(op.name))
    red_axes = tuple(range(w_q.ndim - 1))
    wsum = w_q.astype(np.int64).sum(axis=red_axes).astype(np.int32)
    # fold the output zero-point into the bias (one rounding fewer:
    # y_q = round(M*acc) + round(b/S_y - z_y) keeps error <= 1 LSB)
    bias_q = np.round(b / out_scale - out_zp).astype(np.int32)
    mult = np.asarray(in_scale * w_scale.astype(np.float64) / out_scale)
    mantissa, shift = quantize_multiplier(mult)
    qops[op.name] = QOp(
        spec=op,
        w_q=w_q,
        w_scale=w_scale,
        wsum=wsum,
        bias_q=bias_q,
        in_scale=float(in_scale),
        in_zp=float(in_zp),
        out_scale=float(out_scale),
        out_zp=float(out_zp),
        mult=mult,
        mantissa=mantissa,
        shift=shift,
        clip=op.act in (G.RELU6, G.HSIGMOID),
    )
    return float(out_scale), float(out_zp)


# ---------------------------------------------------------------------------
# serialization — QNet is the deployment artifact, so it must round-trip
# ---------------------------------------------------------------------------


def build_netspec(build: Dict) -> G.NetSpec:
    """Rebuild a NetSpec from a `.qnet` build record (see `save_qnet`).

    The record names the model family plus its builder knobs, so a frozen
    artifact is self-describing: `load_qnet(path)` with no NetSpec in hand
    reconstructs the graph the weights were quantized against. An
    `act_bits` entry differing from the weight BW is applied through
    `graph.with_act_bits` after the family builder runs (the builders
    derive both widths from one `bits` knob). A heterogeneous artifact
    instead carries `op_act_bits` — a `{op_name: bits}` allocation map
    applied through `graph.with_op_act_bits` on top of any uniform
    `act_bits` base, so a mixed-precision `.qnet` self-describes its full
    per-layer assignment."""
    kind = build.get("model")
    kw = {k: v for k, v in build.items()
          if k not in ("model", "act_bits", "op_act_bits")}
    if kind == "mobilenet_v2":
        from repro.models import mobilenet_v2 as mnv2
        net = mnv2.build(**kw)
    elif kind == "efficientnet_compact":
        from repro.models import efficientnet as effn
        net = effn.build_compact(**kw)
    elif kind == "dscnn_kws":
        from repro.models import dscnn1d
        net = dscnn1d.build_kws(**kw)
    elif kind == "dscnn_har":
        from repro.models import dscnn1d
        net = dscnn1d.build_har(**kw)
    else:
        raise ValueError(f"unknown model family in build record: {kind!r}")
    act_bits = build.get("act_bits")
    if act_bits is not None and act_bits != build.get("bits"):
        net = G.with_act_bits(net, act_bits)
    alloc = build.get("op_act_bits")
    if alloc:
        net = G.with_op_act_bits(net, {str(k): int(v)
                                       for k, v in alloc.items()})
    return net


def read_qnet_meta(path: str) -> Dict:
    """The artifact's JSON header (ops/res_q/build/provenance) without the
    weight payload — what CI's artifact-schema gate inspects."""
    with open(path, "rb") as f:
        n = int.from_bytes(f.read(8), "little")
        return json.loads(f.read(n).decode())


def save_qnet(qnet: QNet, path: str, build: Optional[Dict] = None,
              provenance: Optional[Dict] = None) -> None:
    """Serialize the deployment artifact.

    `build` (model family + builder kwargs, see `build_netspec`) makes the
    artifact loadable with `load_qnet(path)` alone; `provenance` is free-form
    training metadata (steps, seeds, calibration recipe) carried verbatim."""
    arrays = {}
    meta = {"net": qnet.spec.name, "ops": {}}
    if build is not None:
        meta["build"] = dict(build)
    if provenance is not None:
        meta["provenance"] = dict(provenance)
    for name, q in qnet.ops.items():
        key = name.replace("/", "__")
        arrays[f"{key}.w_q"] = q.w_q
        arrays[f"{key}.w_scale"] = np.asarray(q.w_scale)
        arrays[f"{key}.wsum"] = q.wsum
        arrays[f"{key}.bias_q"] = q.bias_q
        arrays[f"{key}.mult"] = np.asarray(q.mult)
        arrays[f"{key}.mantissa"] = q.mantissa
        arrays[f"{key}.shift"] = q.shift
        meta["ops"][name] = {
            "in_scale": q.in_scale,
            "in_zp": q.in_zp,
            "out_scale": q.out_scale,
            "out_zp": q.out_zp,
            "clip": q.clip,
            "bits": q.spec.bits,
        }
    meta["res_q"] = qnet.res_q
    buf = io.BytesIO()
    np.savez_compressed(buf, **arrays)
    with open(path, "wb") as f:
        f.write(len(json.dumps(meta)).to_bytes(8, "little"))
        f.write(json.dumps(meta).encode())
        f.write(buf.getvalue())


def load_qnet(path: str, net: Optional[G.NetSpec] = None) -> QNet:
    """Load a serialized QNet. `net=None` rebuilds the NetSpec from the
    artifact's own build record (artifacts written by the export pipeline);
    passing a NetSpec keeps working for record-less fixtures."""
    with open(path, "rb") as f:
        n = int.from_bytes(f.read(8), "little")
        meta = json.loads(f.read(n).decode())
        arrays = np.load(io.BytesIO(f.read()))
    if net is None:
        if "build" not in meta:
            raise ValueError(
                f"{path} carries no build record; pass the NetSpec explicitly")
        net = build_netspec(meta["build"])
    qops = {}
    specs = {op.name: op for _, op in net.all_ops()}
    for name, m in meta["ops"].items():
        key = name.replace("/", "__")
        qops[name] = QOp(
            spec=specs[name],
            w_q=arrays[f"{key}.w_q"],
            w_scale=arrays[f"{key}.w_scale"],
            wsum=arrays[f"{key}.wsum"],
            bias_q=arrays[f"{key}.bias_q"],
            in_scale=m["in_scale"],
            in_zp=m["in_zp"],
            out_scale=m["out_scale"],
            out_zp=m["out_zp"],
            mult=arrays[f"{key}.mult"],
            mantissa=arrays[f"{key}.mantissa"],
            shift=arrays[f"{key}.shift"],
            clip=m["clip"],
        )
    res_q = {k: tuple(v) for k, v in meta.get("res_q", {}).items()}
    return QNet(net, qops, res_q)


__all__ = ["QOp", "QNet", "quantize_net", "save_qnet", "load_qnet",
           "build_netspec", "read_qnet_meta"]
