"""Range-based linear quantization (DeepDive front-end, Sec. 3.2).

Implements the paper's quantizer family:

  * asymmetric:  [min_x, max_x] -> [0, 2^BW - 1]           (Eq. 7 mapping)
  * symmetric :  [-max|x|, max|x|] -> [-(2^BW-1), 2^BW-1 - 1]

with either per-tensor or per-output-channel granularity, plus the
fake-quantization (quantize->dequantize) operator used for online
quantization-aware training with a straight-through estimator (STE).

The convention follows Eq. 7 of the paper:  x = S * (x_q + m_zp),
i.e. the stored integer is x_q and the zero point m_zp satisfies
S * (q(0) + m_zp) == 0  =>  m_zp = -round(-min_x / S)  (asymmetric).

All functions are pure and jit/vmap/grad-safe.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static configuration of one quantizer (hashable; safe as a jit static arg)."""

    bits: int = 4
    symmetric: bool = False
    # axis over which separate (scale, zp) pairs are kept; None = per-tensor.
    # For conv weights [K,K,N,M] the paper's per-output-channel mode is axis=-1.
    channel_axis: Optional[int] = None
    # Narrow-range symmetric uses [-(2^{BW-1}-1), 2^{BW-1}-1] keeping 0 exact.
    narrow_range: bool = True

    @property
    def qmin(self) -> int:
        if self.symmetric:
            return -(2 ** (self.bits - 1)) + (1 if self.narrow_range else 0)
        return 0

    @property
    def qmax(self) -> int:
        if self.symmetric:
            return 2 ** (self.bits - 1) - 1
        return 2**self.bits - 1

    @property
    def levels(self) -> int:
        return self.qmax - self.qmin


def _reduce_axes(x: jnp.ndarray, channel_axis: Optional[int]) -> Tuple[int, ...]:
    if channel_axis is None:
        return tuple(range(x.ndim))
    axis = channel_axis % x.ndim
    return tuple(a for a in range(x.ndim) if a != axis)


def compute_scale_zp(
    min_x: jnp.ndarray, max_x: jnp.ndarray, cfg: QuantConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Derive (S, m_zp) from observed ranges.

    Returns float scale S and *integer-valued* (but float-dtype) zero point such
    that  dequant(q) = S * (q + m_zp)  reproduces 0.0 exactly.
    """
    min_x = jnp.minimum(min_x, 0.0)  # range must include 0 so zp is representable
    max_x = jnp.maximum(max_x, 0.0)
    if cfg.symmetric:
        amax = jnp.maximum(jnp.abs(min_x), jnp.abs(max_x))
        scale = amax / cfg.qmax
        scale = jnp.where(scale <= 0, 1.0, scale)
        zp = jnp.zeros_like(scale)
        return scale, zp
    scale = (max_x - min_x) / cfg.levels
    scale = jnp.where(scale <= 0, 1.0, scale)
    # x = S*(x_q + m_zp); x=min at x_q=qmin=0  =>  m_zp = min_x / S
    zp = jnp.round(min_x / scale)
    return scale, zp


def observe_range(
    x: jnp.ndarray, cfg: QuantConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Min/max over everything but the channel axis (calibration observer)."""
    axes = _reduce_axes(x, cfg.channel_axis)
    return jnp.min(x, axis=axes), jnp.max(x, axis=axes)


def _broadcast_qparams(
    x: jnp.ndarray, scale: jnp.ndarray, zp: jnp.ndarray, cfg: QuantConfig
):
    if cfg.channel_axis is None:
        return scale, zp
    axis = cfg.channel_axis % x.ndim
    shape = [1] * x.ndim
    shape[axis] = -1
    return scale.reshape(shape), zp.reshape(shape)


def quantize(
    x: jnp.ndarray, scale: jnp.ndarray, zp: jnp.ndarray, cfg: QuantConfig
) -> jnp.ndarray:
    """h: T -> Q. Returns integers stored in int32 (packing handled elsewhere)."""
    s, z = _broadcast_qparams(x, scale, zp, cfg)
    q = jnp.round(x / s - z)
    return jnp.clip(q, cfg.qmin, cfg.qmax).astype(jnp.int32)


def dequantize(
    q: jnp.ndarray, scale: jnp.ndarray, zp: jnp.ndarray, cfg: QuantConfig
) -> jnp.ndarray:
    s, z = _broadcast_qparams(q, scale, zp, cfg)
    return (q.astype(s.dtype) + z) * s


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def fake_quant(x, scale, zp, cfg: QuantConfig):
    """Quantize->dequantize with STE gradient (the 'online quantization' op).

    Forward emulates the integer datapath exactly; backward passes gradients
    straight through inside the representable range and zeroes them outside
    (standard clipped-STE, matching QAT practice the paper builds on [11]).
    """
    q = quantize(x, scale, zp, cfg)
    return dequantize(q, scale, zp, cfg)


def _fake_quant_fwd(x, scale, zp, cfg):
    s, z = _broadcast_qparams(x, scale, zp, cfg)
    lo = (cfg.qmin + z) * s
    hi = (cfg.qmax + z) * s
    mask = jnp.logical_and(x >= lo, x <= hi)
    return fake_quant(x, scale, zp, cfg), mask


def _fake_quant_bwd(cfg, mask, g):
    return (jnp.where(mask, g, 0.0), None, None)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def fake_quant_minmax(x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Fake-quant using the tensor's own dynamic range (weight QAT path)."""
    mn, mx = observe_range(x, cfg)
    mn, mx = jax.lax.stop_gradient(mn), jax.lax.stop_gradient(mx)
    scale, zp = compute_scale_zp(mn, mx, cfg)
    return fake_quant(x, scale, zp, cfg)


# ---------------------------------------------------------------------------
# Sub-byte packing: the FPGA synthesizes true BW-bit datapaths; on TPU we keep
# BW-bit *storage* by packing into int8 words (2x for 4-bit, 8/3 for ~3-bit is
# not byte-aligned, so 3/5/6-bit packs into the next dense power layout).
# ---------------------------------------------------------------------------


def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int32 values in [0,15] (or [-8,7]) pairwise into uint8, last axis.

    Last axis must be even. Low nibble = even index, high nibble = odd index.
    """
    if q.shape[-1] % 2:
        raise ValueError(f"last axis must be even for int4 packing: {q.shape}")
    u = jnp.asarray(q, jnp.uint8) & 0xF
    lo = u[..., 0::2]
    hi = u[..., 1::2]
    return lo | (hi << 4)


def unpack_int4(p: jnp.ndarray, signed: bool = False) -> jnp.ndarray:
    lo = (p & 0xF).astype(jnp.int32)
    hi = ((p >> 4) & 0xF).astype(jnp.int32)
    q = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)
    if signed:
        q = jnp.where(q >= 8, q - 16, q)
    return q


def packed_nbytes(shape: Tuple[int, ...], bits: int) -> int:
    """Model-size accounting used by the paper (Params are reported in Mbit)."""
    n = int(np.prod(shape))
    return (n * bits + 7) // 8


__all__ = [
    "QuantConfig",
    "compute_scale_zp",
    "observe_range",
    "quantize",
    "dequantize",
    "fake_quant",
    "fake_quant_minmax",
    "pack_int4",
    "unpack_int4",
    "packed_nbytes",
]
