"""Deterministic sharded data pipeline with restart/elastic semantics.

Synthetic corpora (token LM + labeled-image) generated counter-based from
(seed, global_step, host_shard), so:

  * restart-from-checkpoint resumes the exact stream (no repeated batches) —
    the data-skip half of fault tolerance;
  * changing the data-parallel world size re-partitions the stream
    deterministically (elastic scaling);
  * no host I/O — every worker synthesizes its shard (the pattern a real
    deployment swaps for its tokenized corpus reader).

Also provides the CNN-side loader used by the QAT examples: a mixture-of-
Gaussians "imagenet-lite" whose labels are learnable (for convergence tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 32
    n_hosts: int = 1
    host_id: int = 0


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    # counter-based: a fresh generator per (seed, step, host) triple
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def lm_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Host-local shard of the global batch for `step`."""
    assert cfg.global_batch % cfg.n_hosts == 0
    local = cfg.global_batch // cfg.n_hosts
    rng = _rng_for(cfg, step)
    # structured synthetic LM: next token depends on the previous one, so a
    # model can actually reduce loss (used by convergence tests)
    tokens = np.zeros((local, cfg.seq_len), np.int32)
    tokens[:, 0] = rng.integers(0, cfg.vocab, local)
    jumps = rng.integers(1, 17, (local, cfg.seq_len))
    for t in range(1, cfg.seq_len):
        tokens[:, t] = (tokens[:, t - 1] + jumps[:, t]) % cfg.vocab
    return {"tokens": tokens}


def lm_stream(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1


def image_batch(seed: int, step: int, batch: int, hw: int, classes: int,
                channels: int = 3) -> Dict[str, np.ndarray]:
    """Learnable synthetic image classification (class-conditional blobs)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    labels = rng.integers(0, classes, batch)
    # class-dependent spatial frequency pattern + noise
    xx, yy = np.meshgrid(np.linspace(0, 1, hw), np.linspace(0, 1, hw))
    imgs = np.empty((batch, hw, hw, channels), np.float32)
    for i, c in enumerate(labels):
        freq = 1 + (c % 5)
        phase = (c // 5) * 0.7
        base = np.sin(2 * np.pi * freq * xx + phase) * np.cos(
            2 * np.pi * freq * yy - phase)
        imgs[i] = base[..., None] + 0.3 * rng.standard_normal((hw, hw, channels))
    return {"images": imgs.astype(np.float32), "labels": labels.astype(np.int32)}


__all__ = ["DataConfig", "lm_batch", "lm_stream", "image_batch"]
