"""Distribution utilities: logical-axis sharding rules and pipeline
parallelism. Everything degrades gracefully to a single-device no-op so the
same model code runs on a laptop CPU and a multi-pod mesh."""
