"""Pipeline parallelism over the 'pod' mesh axis (GPipe-style, minimal).

The repeated-layer stack is split into `n_stages` contiguous stages; each pod
holds one stage's layer parameters (leading stage axis, sharded P('pod')
under `shard_map`). The loss streams `n_micro` microbatches through the
stages: at tick t, stage s runs microbatch t-s while stage s+1 runs t-s-1 —
the same double-buffered invocation schedule the DeepDive host uses for its
Body CU, applied across devices. Stage-to-stage activation handoff is
`jax.lax.ppermute` (a collective-permute in the compiled HLO), which is
differentiable, so one `jax.grad` trains all stages.

Only uniform-layer families (a single repeating block kind, no unrolled
tail) are supported — that covers every dense/moe/ssm/rec config here.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.lm import model as M
from repro.models.lm.config import LMConfig

F32 = jnp.float32


def split_stage_params(layers, n_stages: int):
    """[L, ...]-stacked layer params -> [S, L/S, ...] (stage-major)."""

    def split(x):
        n_layers = x.shape[0]
        if n_layers % n_stages:
            raise ValueError(
                f"{n_layers} layers not divisible into {n_stages} stages")
        return x.reshape(n_stages, n_layers // n_stages, *x.shape[1:])

    return jax.tree.map(split, layers)


def make_pp_loss(cfg: LMConfig, n_stages: int, n_micro: int,
                 axis_name: str = "pod"):
    """Build loss(params, tokens) for use inside shard_map.

    Expects params["layers"] stage-split (see `split_stage_params`) and
    sharded P(axis_name); every other param replicated. tokens: [B, S] with
    B divisible by n_micro. Returns the scalar next-token loss (no aux)."""
    kinds = M.layer_kinds(cfg)
    pat, _, tail = M._kind_groups(kinds)
    if len(pat) != 1 or tail:
        raise NotImplementedError(
            "pipeline parallelism requires a uniform layer stack")
    kind = pat[0]

    def stage_apply(layers_p, x, positions):
        def body(xx, layer_p):
            xx, _, _ = M._apply_layer(layer_p, xx, cfg, kind, positions)
            return xx, None

        x, _ = jax.lax.scan(body, x, layers_p, unroll=cfg.scan_unroll)
        return x

    def loss(params: Dict[str, Any], tokens: jax.Array):
        stage = jax.lax.axis_index(axis_name)
        # this device's stage chunk: [1, L/S, ...] -> [L/S, ...]
        layers_p = jax.tree.map(lambda x: x[0], params["layers"])

        x = M.embed_tokens(params, cfg, tokens)
        b = x.shape[0]
        if b % n_micro:
            raise ValueError(f"batch {b} not divisible by n_micro={n_micro}")
        micro = x.reshape(n_micro, b // n_micro, *x.shape[1:])
        positions = jnp.arange(tokens.shape[1])

        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        buf = jnp.zeros_like(micro[0])
        outs = jnp.zeros_like(micro)
        n_ticks = n_micro + n_stages - 1
        for t in range(n_ticks):
            if t < n_micro:  # stage 0 injects microbatch t
                buf = jnp.where(stage == 0, micro[t], buf)
            buf = stage_apply(layers_p, buf, positions)
            m = t - (n_stages - 1)  # microbatch leaving the last stage
            if m >= 0:
                outs = outs.at[m].set(
                    jnp.where(stage == n_stages - 1, buf, outs[m]))
            if t < n_ticks - 1:  # hand activations to the next stage
                buf = jax.lax.ppermute(buf, axis_name, perm)

        # next-token cross-entropy on the last stage's outputs; other stages
        # contribute zero and receive the value via the psum.
        hidden = outs.reshape(b, *x.shape[1:])
        logits = M.logits_from_hidden(params, cfg, hidden)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(F32), axis=-1)
        onehot = jax.nn.one_hot(tokens[:, 1:], lp.shape[-1], dtype=lp.dtype)
        local = -(lp * onehot).sum(-1).mean()
        return jax.lax.psum(
            jnp.where(stage == n_stages - 1, local, 0.0), axis_name)

    return loss


__all__ = ["split_stage_params", "make_pp_loss"]
