"""Logical-axis sharding: model code names axes ('batch', 'heads', 'ffn',
'vocab', 'embed', ...); this module maps them onto whatever mesh is active.

Design rules (single pod (data, model); multi-pod adds a leading 'pod' axis):
  * 'batch'   -> every data-parallel mesh axis present (('pod', 'data') on the
                 multi-pod mesh, ('data',) on a single pod)
  * 'heads' / 'ffn' / 'vocab' / 'experts' -> 'model' (tensor parallelism)
  * 'embed'   -> 'data' under FSDP (ZeRO-3-style param sharding), else None
  * 'seq'     -> None (no sequence parallelism by default)
  * a name that IS a mesh axis passes through verbatim

With no active mesh every helper is a no-op (`shard` returns its input,
`axis_size` is 1), so model code never branches on distribution.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# mesh axes that carry data parallelism, outermost first
_DATA_AXES = ("pod", "data")
# logical axes that map onto the tensor-parallel mesh axis
_MODEL_AXES = frozenset({"heads", "ffn", "vocab", "experts"})


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.fsdp: bool = False


_STATE = _State()


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


@contextlib.contextmanager
def use_mesh(mesh: Mesh, fsdp: bool = False):
    """Activate `mesh` for `shard` / `axis_size` within the context."""
    prev = (_STATE.mesh, _STATE.fsdp)
    _STATE.mesh, _STATE.fsdp = mesh, fsdp
    try:
        yield mesh
    finally:
        _STATE.mesh, _STATE.fsdp = prev


def axis_size(name: str) -> int:
    """Size of a mesh axis under the active mesh (1 when absent / no mesh)."""
    mesh = _STATE.mesh
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(name, 1))


def logical_to_spec(axes: Sequence[Optional[str]], mesh: Mesh,
                    fsdp: bool = False) -> P:
    """Logical axis names -> PartitionSpec for `mesh` (see module rules)."""
    present = set(mesh.axis_names)
    out = []
    for ax in axes:
        if ax is None:
            out.append(None)
        elif ax == "batch":
            out.append(tuple(a for a in _DATA_AXES if a in present))
        elif ax == "embed":
            out.append("data" if (fsdp and "data" in present) else None)
        elif ax in _MODEL_AXES:
            out.append("model" if "model" in present else None)
        elif ax in present:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def _fit_spec_to_shape(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharded axes whose mesh extent does not divide the dim size.

    Keeps `jax.jit(in_shardings=...)` legal for ragged dims (e.g. a vocab
    that is not a multiple of the TP degree) instead of erroring."""
    sizes = dict(mesh.shape)
    out = []
    for i, dim in enumerate(shape):
        entry = spec[i] if i < len(spec) else None
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        extent = 1
        for name in names:
            extent *= int(sizes[name])
        out.append(entry if extent > 0 and dim % extent == 0 else None)
    return P(*out)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain `x` to its logical sharding under the active mesh (no-op
    without one). Safe inside and outside jit."""
    mesh = _STATE.mesh
    if mesh is None:
        return x
    spec = logical_to_spec(axes, mesh, _STATE.fsdp)
    spec = _fit_spec_to_shape(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, axes: Sequence[Optional[str]],
                   fsdp: bool = False) -> NamedSharding:
    """NamedSharding from logical axes (`()` -> fully replicated)."""
    return NamedSharding(mesh, logical_to_spec(axes, mesh, fsdp))


# ---------------------------------------------------------------------------
# serving replication: a 1-D 'data' mesh + placement helpers
# ---------------------------------------------------------------------------


def data_mesh(replicas: Optional[int] = None, devices=None) -> Mesh:
    """1-D data-parallel mesh over the first `replicas` devices ('data' axis).

    The serving analogue of DeepDive's CU replication: every replica holds
    the full integer datapath (constants replicated), micro-batches split
    along 'data'. Defaults to every visible device; on CPU,
    `XLA_FLAGS=--xla_force_host_platform_device_count=N` overrides the
    device count before jax initialises."""
    devs = list(jax.devices()) if devices is None else list(devices)
    n = len(devs) if replicas is None else int(replicas)
    if n <= 0 or n > len(devs):
        raise ValueError(f"replicas={n} with {len(devs)} visible devices")
    return Mesh(np.asarray(devs[:n]), ("data",))


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully-replicated placement on `mesh` (the constant/weight sharding)."""
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh) -> NamedSharding:
    """Leading-dim 'data' split (the activation/micro-batch sharding)."""
    return NamedSharding(mesh, P("data"))


def replicate(x, mesh: Optional[Mesh]):
    """Place one array (or pytree leaf) replicated across `mesh`; identity
    when `mesh` is None, so callers never branch on distribution. Host
    arrays go straight to `device_put` — no default-device stopover, so
    each constant pays exactly one placement."""
    if mesh is None:
        return jnp.asarray(x)
    return jax.device_put(x, replicated(mesh))


def _is_axes(x: Any) -> bool:
    """A logical-axes leaf: a (possibly empty) tuple of names / Nones."""
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def tree_shardings(logical, mesh: Mesh, fsdp: bool = False, shapes=None):
    """Map a pytree of logical-axes tuples to NamedShardings.

    `shapes` (an aligned pytree of ShapeDtypeStructs/arrays) enables
    shape-fitting: any axis that does not divide its dim is dropped."""

    def one(axes, leaf):
        spec = logical_to_spec(axes, mesh, fsdp)
        if leaf is not None:
            spec = _fit_spec_to_shape(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    if shapes is None:
        return jax.tree_util.tree_map(
            lambda a: one(a, None), logical, is_leaf=_is_axes)
    return jax.tree_util.tree_map(one, logical, shapes, is_leaf=_is_axes)


__all__ = [
    "shard",
    "axis_size",
    "use_mesh",
    "current_mesh",
    "logical_to_spec",
    "named_sharding",
    "tree_shardings",
    "data_mesh",
    "replicated",
    "batch_sharding",
    "replicate",
]
