"""Energy modeling: power curves, per-op energy, power-capped dispatch.

The package behind the paper's FPS/Watt headline (Sec. 6, Table 6):

  * `power`    — `PowerModel` device curves; RAPL-calibrated on Linux
                 CPUs where `/sys/class/powercap` is readable, per-
                 backend constants otherwise.
  * `model`    — `estimate_energy`: autotuner route timings × analytic
                 bytes-moved × the power curve → modeled J/image, plus
                 the `edp_score` the tuner's energy-delay objective
                 shares.
  * `governor` — `PowerGovernor`: the deterministic rolling-window watt
                 estimate behind `VisionEngine(power_budget_w=...)`.

See docs/energy.md.
"""
from .governor import PowerGovernor
from .model import (
    PJ_PER_BYTE,
    PJ_PER_MAC,
    PJ_PER_MAC_DEFAULT,
    EnergyReport,
    OpEnergy,
    analytic_energy_j,
    edp_score,
    estimate_energy,
    op_bytes_moved,
    op_macs,
    op_pj_per_mac,
)
from .power import (
    BACKEND_WATTS,
    DEFAULT_RAPL_ROOT,
    PowerModel,
    RaplEnergyReader,
    RaplUnavailable,
    calibrate_power,
    default_power_model,
    measure_power,
    reset_default_power_model,
)

__all__ = [
    "BACKEND_WATTS",
    "DEFAULT_RAPL_ROOT",
    "PJ_PER_BYTE",
    "PJ_PER_MAC",
    "PJ_PER_MAC_DEFAULT",
    "EnergyReport",
    "OpEnergy",
    "PowerGovernor",
    "PowerModel",
    "RaplEnergyReader",
    "RaplUnavailable",
    "analytic_energy_j",
    "calibrate_power",
    "default_power_model",
    "edp_score",
    "estimate_energy",
    "measure_power",
    "op_bytes_moved",
    "op_macs",
    "op_pj_per_mac",
    "reset_default_power_model",
]
