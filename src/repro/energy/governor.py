"""Rolling-window modeled power accounting for power-capped dispatch.

`PowerGovernor` is the bookkeeping behind `VisionEngine(power_budget_w=)`:
every dispatched batch records its modeled joules at the engine clock's
"now"; the modeled draw is then

    watts(now) = idle_w + (joules recorded in [now - window, now]) / window

The EDF dispatcher asks `would_exceed(batch_j, now)` *before* yielding a
batch and defers or sheds instead of dispatching when the answer is yes —
so the estimate never crosses the budget at any dispatch point.

Determinism: the governor never reads a wall clock. All times are passed
in from the engine's injected clock, so fake-clock tests replay dispatch
decisions bit-identically. One instance may be shared by every engine
under a `MultiModelEngine` to enforce a fleet-wide budget.

See docs/energy.md for the scheduling policy this feeds.
"""
from __future__ import annotations

from typing import List, Tuple


class PowerGovernor:
    """Tracks modeled dispatch energy over a sliding window vs a watt cap."""

    def __init__(self, budget_w: float, *, window_s: float = 1.0,
                 idle_w: float = 0.0):
        if budget_w <= idle_w:
            raise ValueError(
                f"power budget {budget_w} W must exceed idle draw "
                f"{idle_w} W — nothing could ever dispatch")
        if window_s <= 0:
            raise ValueError(f"window_s must be positive, got {window_s}")
        self.budget_w = float(budget_w)
        self.window_s = float(window_s)
        self.idle_w = float(idle_w)
        self._events: List[Tuple[float, float]] = []  # (t, joules)
        self.total_j = 0.0  # lifetime dispatched joules (not pruned)

    def _prune(self, now: float) -> None:
        cut = now - self.window_s
        i = 0
        for i, (t, _) in enumerate(self._events):
            if t > cut:
                break
        else:
            i = len(self._events)
        if i:
            del self._events[:i]

    def record(self, joules: float, now: float) -> None:
        """Account `joules` of modeled work dispatched at time `now`."""
        if joules < 0:
            raise ValueError(f"negative energy {joules}")
        self._events.append((now, joules))
        self.total_j += joules
        self._prune(now)

    def window_j(self, now: float) -> float:
        self._prune(now)
        return sum(j for _, j in self._events)

    def watts(self, now: float) -> float:
        """Modeled average draw over the trailing window ending at `now`."""
        return self.idle_w + self.window_j(now) / self.window_s

    def headroom_j(self, now: float) -> float:
        """Joules that can still be dispatched at `now` without crossing
        the budget."""
        return ((self.budget_w - self.idle_w) * self.window_s
                - self.window_j(now))

    def would_exceed(self, joules: float, now: float) -> bool:
        """True if dispatching `joules` at `now` would push the windowed
        estimate over the budget."""
        return joules > self.headroom_j(now) * (1 + 1e-12)


__all__ = ["PowerGovernor"]
