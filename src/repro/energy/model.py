"""Calibrated per-op energy model: route timings × bytes × power curve.

The paper's headline metric is FPS/Watt (47.4 for MobileNetV2, 233.3 for
compact EfficientNet on ZCU102). This module reproduces that accounting
in software from data the system already measures:

    op energy = compute term            + memory term
              = busy_w × route_time     + bytes_moved × PJ_PER_BYTE

  * `route_time` comes from the autotuner's committed caches
    (`experiments/tuned/*.json` — the best measured wall time of the
    bit-exact winning route, divided by the batch it was timed at).
    Ops with no cache entry fall back to an analytic MAC count priced
    at per-bit pJ/MAC constants (Horowitz, ISSCC'14 ballpark) — so the
    model degrades gracefully on untuned nets, and `tuned_fraction`
    reports how much of the estimate is measurement-backed.
  * `bytes_moved` is the analytic DDR traffic of the op — input and
    output activations at 1 byte/element (the integer datapath stores
    ≤8-bit activations) plus a single weight stream. This is the term
    the old `_energy_j_per_image` MAC proxy dropped: a DW and a PW op
    with identical MACs differ ~10x in bytes, and now score
    differently.
  * the power curve is a `repro.energy.power.PowerModel` — RAPL-
    calibrated on Linux CPUs where available, per-backend constants
    otherwise.

Consumers: `VisionEngine`/`StreamEngine` stats (J/image, watts,
FPS/Watt gauges), the autotuner's `objective="edp"` route scoring, and
the `PowerGovernor` behind `VisionEngine(power_budget_w=...)`.

See docs/energy.md for assumptions and recalibration.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from ..core import compiler as CC
from ..core import graph as G
from ..tune import cache as TC
from .power import PowerModel, default_power_model

# Energy per multiply-accumulate at the op's datapath bit width, in pJ.
# Horowitz ISSCC'14 45nm ballpark, interpolated for the intermediate
# anneal widths. (Moved here from serve/vision/engine.py, where it was
# the whole model; it is now only the fallback compute term for ops
# without a measured route timing.)
PJ_PER_MAC: Dict[int, float] = {8: 0.23, 6: 0.18, 5: 0.15, 4: 0.12, 3: 0.10}
PJ_PER_MAC_DEFAULT = 0.2

# DRAM access energy per byte (LPDDR4-class, ~20 pJ/B). The dominant
# term for memory-bound ops — exactly why DW and PW ops with equal MACs
# must not score equally.
PJ_PER_BYTE = 20.0


def op_pj_per_mac(op: G.OpSpec) -> float:
    """pJ per MAC at the op's *effective* datapath width.

    A MAC multiplies an `op.bits` weight by an activation; pricing by
    weight width alone let a w4/a8 op bill 4-bit MACs while moving and
    multiplying 8-bit activations. The effective width is the wider of
    the two — for uniform w4/a4 nets this reduces to the old `op.bits`
    pricing bit-for-bit."""
    eff = max(op.bits, op.act_bits)
    return PJ_PER_MAC.get(eff, PJ_PER_MAC_DEFAULT)

_TUNED = "tuned"
_ANALYTIC = "analytic"


def op_bytes_moved(op: G.OpSpec, in_hw: Optional[int], rank: int = 2,
                   *, in_bits: Optional[int] = None) -> int:
    """Analytic DDR bytes for one op at batch 1.

    Input activations read + output activations written, packed at their
    activation bit-widths (`in_bits` for the incoming tensor — the
    upstream op's `act_bits`, defaulting to this op's own width when the
    caller doesn't thread the chain — and `op.act_bits` for the output:
    a 4-bit tensor moves half the DDR bytes of an 8-bit one, which is
    exactly the axis the mixed-precision search trades on) plus the
    weight tensor streamed once (1 byte per weight, int32 bias).
    Intermediate SRAM/cache reuse is deliberately not modeled: this is
    the off-chip traffic bound the paper's co-design minimizes."""
    if op.kind == G.DENSE or in_hw is None:
        n_in, n_out = op.in_ch, op.out_ch
    else:
        out_hw = -(-in_hw // op.stride)
        if rank == 1:
            n_in = in_hw * op.in_ch
            n_out = out_hw * op.out_ch
        else:
            n_in = in_hw * in_hw * op.in_ch
            n_out = out_hw * out_hw * op.out_ch
    in_bits = op.act_bits if in_bits is None else int(in_bits)
    act_bytes = (n_in * in_bits + n_out * op.act_bits) / 8.0
    w_bytes = op.n_params(with_bias=False) + 4 * op.out_ch
    return int(math.ceil(act_bytes)) + w_bytes


def op_macs(op: G.OpSpec, in_hw: Optional[int], rank: int = 2) -> int:
    """MACs for one op at batch 1 (the `NetSpec.count_macs` shape walk)."""
    if op.kind == G.DENSE or in_hw is None:
        return op.macs(1, 1)
    out_hw = -(-in_hw // op.stride)
    return op.macs(out_hw, 1 if rank == 1 else out_hw)


@dataclasses.dataclass(frozen=True)
class OpEnergy:
    """One op's modeled cost: where its time came from and both J terms."""

    name: str
    cu: str
    kind: str
    key: str
    us: float  # modeled per-image execution time, microseconds
    source: str  # "tuned" (measured route timing) | "analytic" (pJ/MAC)
    macs: int
    bytes_moved: int
    compute_j: float
    memory_j: float

    @property
    def j(self) -> float:
        return self.compute_j + self.memory_j


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """Modeled energy of one net on one device power curve."""

    net: str
    backend: str
    power: PowerModel
    ops: Tuple[OpEnergy, ...]

    @property
    def j_per_image(self) -> float:
        return sum(o.j for o in self.ops)

    @property
    def us_per_image(self) -> float:
        return sum(o.us for o in self.ops)

    @property
    def tuned_fraction(self) -> float:
        """Fraction of ops priced from measured route timings."""
        if not self.ops:
            return 0.0
        return sum(1 for o in self.ops if o.source == _TUNED) / len(self.ops)

    def watts(self, fps: float) -> float:
        """Average device watts while serving `fps` images/s."""
        return self.power.idle_w + self.j_per_image * max(fps, 0.0)

    def fps_per_watt(self, fps: float) -> float:
        w = self.watts(fps)
        return fps / w if w > 0 else 0.0

    def per_cu(self) -> Dict[str, float]:
        """Joules per image broken down by CU."""
        out: Dict[str, float] = {}
        for o in self.ops:
            out[o.cu] = out.get(o.cu, 0.0) + o.j
        return out

    def as_dict(self) -> Dict[str, object]:
        return {
            "net": self.net,
            "backend": self.backend,
            "power": self.power.as_dict(),
            "j_per_image": self.j_per_image,
            "us_per_image": self.us_per_image,
            "tuned_fraction": self.tuned_fraction,
            "per_cu_j": self.per_cu(),
            "n_ops": len(self.ops),
        }


def _se_ops(block: G.BlockSpec) -> Tuple[G.OpSpec, ...]:
    if block.se is None:
        return ()
    return (block.se.squeeze, block.se.excite)


def estimate_energy(
    qnet,
    plan: Optional[CC.CUPlan] = None,
    *,
    tuned: Optional[TC.TunedPlan] = None,
    power: Optional[PowerModel] = None,
    backend: Optional[str] = None,
) -> EnergyReport:
    """Model per-image energy for `qnet` (anything with a `.spec` NetSpec).

    Walks the compiled plan's op descriptors in schedule order. Each op's
    execution time comes from the tuned cache when a shape-keyed entry
    exists (`us / tuned_batch` — the route actually served), otherwise
    from the analytic pJ/MAC table; either way the analytic bytes-moved
    term is added on top. SE squeeze/excite ops (not enumerated by the
    autotuner — they ride inside the Body CU invocation) are priced
    analytically at their pooled 1x1 spatial size."""
    spec: G.NetSpec = getattr(qnet, "spec", qnet)
    plan = plan if plan is not None else CC.compile_net(spec)
    if backend is None:
        if tuned is not None:
            backend = tuned.backend
        else:
            import jax
            backend = jax.default_backend()
    power = power if power is not None else default_power_model(backend)
    rank = spec.spatial_rank
    per_image = max(tuned.tuned_batch, 1) if tuned is not None else 1

    ops = []
    seen_se = set()
    # incoming activation width, threaded op to op in schedule order (the
    # same `cur_bits = op.act_bits` chain `cu.prepare_qnet` walks); the
    # input image is quantized at 8 bits
    cur_bits = 8
    for cu, block, op, in_hw in plan.op_descriptors():
        key = TC.op_key(op, in_hw, backend, rank)
        macs = op_macs(op, in_hw, rank)
        nbytes = op_bytes_moved(op, in_hw, rank, in_bits=cur_bits)
        cur_bits = op.act_bits
        entry = tuned.entries.get(key) if tuned is not None else None
        if entry is not None and entry.us > 0:
            us = entry.us / per_image
            compute_j = power.busy_w * us * 1e-6
            source = _TUNED
        else:
            compute_j = macs * op_pj_per_mac(op) * 1e-12
            us = compute_j / power.busy_w * 1e6
            source = _ANALYTIC
        memory_j = nbytes * PJ_PER_BYTE * 1e-12
        ops.append(OpEnergy(
            name=op.name, cu=cu, kind=op.kind, key=key, us=us, source=source,
            macs=macs, bytes_moved=nbytes, compute_j=compute_j,
            memory_j=memory_j,
        ))
        if block.se is not None and block.name not in seen_se:
            seen_se.add(block.name)
            for se_op in _se_ops(block):
                se_macs = op_macs(se_op, 1, rank)
                se_bytes = op_bytes_moved(se_op, 1, rank)
                se_cj = se_macs * op_pj_per_mac(se_op) * 1e-12
                ops.append(OpEnergy(
                    name=f"{block.name}/{se_op.name}", cu=cu, kind=se_op.kind,
                    key="", us=se_cj / power.busy_w * 1e6, source=_ANALYTIC,
                    macs=se_macs, bytes_moved=se_bytes, compute_j=se_cj,
                    memory_j=se_bytes * PJ_PER_BYTE * 1e-12,
                ))
    return EnergyReport(net=spec.name, backend=backend, power=power,
                        ops=tuple(ops))


def analytic_energy_j(spec: G.NetSpec) -> float:
    """Pure-analytic J/image (MAC + bytes terms, no timings, no power).

    The corrected successor of the deleted `_energy_j_per_image` MAC
    proxy: same pJ/MAC table, but DDR traffic is now priced too, so ops
    with equal MACs and different bytes-moved no longer tie."""
    total = 0.0
    rank = spec.spatial_rank
    plan = CC.compile_net(spec)
    cur_bits = 8
    for _, block, op, in_hw in plan.op_descriptors():
        total += op_macs(op, in_hw, rank) * op_pj_per_mac(op) * 1e-12
        total += (op_bytes_moved(op, in_hw, rank, in_bits=cur_bits)
                  * PJ_PER_BYTE * 1e-12)
        cur_bits = op.act_bits
    return total


def edp_score(time_s: float, bytes_moved: int, power: PowerModel) -> float:
    """Energy-delay product for route selection: (P·t + bytes·pJ/B) · t.

    Shared by `tune.autotune` in `objective="edp"` mode so the tuner and
    the serving-side model price candidates identically. With equal
    bytes (per-op candidates of one op) the score is monotone in t and
    EDP selection degenerates to latency selection; the term that can
    flip a winner is block-level traffic (fused IRB keeps intermediates
    on-chip, per-op spills them)."""
    if time_s <= 0 or not math.isfinite(time_s):
        return math.inf
    energy_j = power.busy_w * time_s + bytes_moved * PJ_PER_BYTE * 1e-12
    return energy_j * time_s


__all__ = [
    "PJ_PER_BYTE",
    "PJ_PER_MAC",
    "PJ_PER_MAC_DEFAULT",
    "EnergyReport",
    "OpEnergy",
    "analytic_energy_j",
    "edp_score",
    "estimate_energy",
    "op_bytes_moved",
    "op_macs",
    "op_pj_per_mac",
]
