"""Device power curves: RAPL-measured on Linux CPUs, constants elsewhere.

The energy model (`repro.energy.model`) converts per-op route *times* into
joules through a `PowerModel` — two numbers and a provenance string:

  * `busy_w`  — average package power while the integer datapath is
    executing (compute + cache dynamic power; DRAM traffic is priced
    separately, per byte, by the model),
  * `idle_w`  — static draw the device pays whether or not it is serving
    (what makes FPS/Watt rate-dependent, exactly as on real silicon).

On Linux CPUs the kernel exposes RAPL package energy counters under
`/sys/class/powercap/intel-rapl:<pkg>/energy_uj` — microjoule counters
that wrap at `max_energy_range_uj`. `RaplEnergyReader` turns them into a
monotone cumulative joule count (wraparound handled per domain), and
`calibrate_power` derives a measured `PowerModel` from two sampling
windows (idle, then under a busy spin). Everywhere RAPL is absent,
unreadable (non-root), or not a CPU, `default_power_model` falls back to
per-backend constants — including the paper's ZCU102 board power, the
basis of its 47.4 / 233.3 FPS/Watt headline.

See docs/energy.md for the calibration procedure and model assumptions.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional, Tuple

DEFAULT_RAPL_ROOT = "/sys/class/powercap"

# Per-backend (busy_w, idle_w) constant fallbacks. Ballpark package powers:
# a laptop/desktop-class CPU package under vectorized integer load, a TPU
# board, a discrete GPU — plus the paper's ZCU102 (Table 6 reports ~7.2 W
# board power for the MobileNetV2 design point, the FPS/Watt denominator).
BACKEND_WATTS: Dict[str, Tuple[float, float]] = {
    "cpu": (18.0, 4.0),
    "tpu": (200.0, 75.0),
    "gpu": (250.0, 40.0),
    "zcu102": (7.2, 0.7),
}
_FALLBACK_WATTS = (18.0, 4.0)


class RaplUnavailable(RuntimeError):
    """No readable RAPL domain (missing tree, no permission, non-Linux)."""


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Device power curve: busy/idle watts plus where they came from."""

    busy_w: float
    idle_w: float = 0.0
    source: str = "constant"

    def __post_init__(self):
        if self.busy_w <= 0:
            raise ValueError(f"busy_w must be positive, got {self.busy_w}")
        if self.idle_w < 0 or self.idle_w > self.busy_w:
            raise ValueError(
                f"idle_w {self.idle_w} outside [0, busy_w={self.busy_w}]")

    def as_dict(self) -> Dict[str, object]:
        return {"busy_w": self.busy_w, "idle_w": self.idle_w,
                "source": self.source}


def _read_uj(path: str) -> int:
    """One sysfs microjoule counter read (split out so tests can fault it
    with PermissionError/OSError without touching real sysfs)."""
    with open(path) as f:
        return int(f.read().strip())


@dataclasses.dataclass
class _RaplDomain:
    path: str  # .../energy_uj
    range_uj: int
    last_uj: int
    acc_uj: int = 0


class RaplEnergyReader:
    """Cumulative joules since construction from a RAPL powercap tree.

    Scans `root` for package-level domains (directories holding an
    `energy_uj` counter whose name is not a `:N:M` subdomain — core/dram
    subdomains are *included* in the package counter and would double
    count). Each `read_j()` advances a per-domain accumulator; a raw
    counter that moved backwards is a wraparound and contributes
    `range - last + raw` (the `max_energy_range_uj` the kernel
    advertises, defaulting to the 32-bit microjoule range when the file
    is absent). Raises `RaplUnavailable` when no domain is readable —
    the signal `default_power_model` uses to fall back to constants."""

    def __init__(self, root: str = DEFAULT_RAPL_ROOT):
        self.root = root
        self._domains: List[_RaplDomain] = []
        if not os.path.isdir(root):
            raise RaplUnavailable(f"no powercap tree at {root}")
        for entry in sorted(os.listdir(root)):
            if entry.count(":") >= 2:
                continue  # :N:M subdomain — already inside the package
            energy = os.path.join(root, entry, "energy_uj")
            if not os.path.isfile(energy):
                continue
            try:
                last = _read_uj(energy)
                rng_path = os.path.join(root, entry, "max_energy_range_uj")
                rng = (_read_uj(rng_path) if os.path.isfile(rng_path)
                       else 2 ** 32 - 1)
            except OSError:
                continue  # unreadable domain (permissions): skip it
            self._domains.append(_RaplDomain(energy, rng, last))
        if not self._domains:
            raise RaplUnavailable(
                f"no readable RAPL energy_uj counters under {root}")

    @property
    def n_domains(self) -> int:
        return len(self._domains)

    def read_j(self) -> float:
        """Total joules consumed across all domains since construction."""
        for d in self._domains:
            try:
                raw = _read_uj(d.path)
            except OSError as e:
                raise RaplUnavailable(f"RAPL counter vanished: {e}") from e
            if raw >= d.last_uj:
                d.acc_uj += raw - d.last_uj
            else:  # counter wrapped at max_energy_range_uj
                d.acc_uj += d.range_uj - d.last_uj + raw
            d.last_uj = raw
        return sum(d.acc_uj for d in self._domains) * 1e-6


def measure_power(fn: Callable[[], None], reader: RaplEnergyReader,
                  clock: Callable[[], float] = time.perf_counter) -> float:
    """Average package watts while `fn` runs: RAPL energy delta / wall."""
    e0 = reader.read_j()
    t0 = clock()
    fn()
    dt = clock() - t0
    de = reader.read_j() - e0
    if dt <= 0:
        raise ValueError("zero-duration measurement window")
    return de / dt


def _busy_spin(duration_s: float, clock: Callable[[], float]) -> None:
    """Compute-bound calibration load (integer matmul spin)."""
    import numpy as np

    a = np.random.default_rng(0).integers(
        0, 127, (256, 256), dtype=np.int32)
    t_end = clock() + duration_s
    while clock() < t_end:
        a = (a @ a) & 0x7F


def calibrate_power(
    *,
    reader: Optional[RaplEnergyReader] = None,
    root: str = DEFAULT_RAPL_ROOT,
    clock: Callable[[], float] = time.perf_counter,
    duration_s: float = 0.2,
    idle_fn: Optional[Callable[[], None]] = None,
    busy_fn: Optional[Callable[[], None]] = None,
) -> PowerModel:
    """Measure a `PowerModel` off the live RAPL counters.

    Two sampling windows: `idle_fn` (default: sleep `duration_s`) pins the
    static package floor, `busy_fn` (default: an integer matmul spin for
    `duration_s`) the loaded draw. Both are injectable so tests drive the
    whole path against a fixture tree and a fake clock. Raises
    `RaplUnavailable` when no counters are readable."""
    reader = reader if reader is not None else RaplEnergyReader(root)
    idle_fn = idle_fn or (lambda: time.sleep(duration_s))
    busy_fn = busy_fn or (lambda: _busy_spin(duration_s, clock))
    idle_w = measure_power(idle_fn, reader, clock)
    busy_w = measure_power(busy_fn, reader, clock)
    # a busy window slower than idle is measurement noise on a loaded box;
    # clamp so the model stays valid (busy >= idle > 0)
    idle_w = max(idle_w, 0.0)
    busy_w = max(busy_w, idle_w, 1e-3)
    return PowerModel(busy_w=busy_w, idle_w=idle_w,
                      source=f"rapl:{reader.root}")


_DEFAULT_MEMO: Dict[Tuple[str, str], PowerModel] = {}


def default_power_model(backend: Optional[str] = None,
                        root: str = DEFAULT_RAPL_ROOT,
                        calibrate_s: float = 0.04) -> PowerModel:
    """The power curve the engines use when none is injected.

    CPU backend with a readable RAPL tree: a short (`2 * calibrate_s`)
    live calibration, memoized per (backend, root) so a process pays it
    once. Everything else — RAPL absent/unreadable, accelerator backends
    — falls back to the `BACKEND_WATTS` constants. Deterministic tests
    inject an explicit `PowerModel` instead and never touch this path."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    key = (backend, root)
    memo = _DEFAULT_MEMO.get(key)
    if memo is not None:
        return memo
    model: Optional[PowerModel] = None
    if backend == "cpu" and calibrate_s > 0:
        try:
            model = calibrate_power(root=root, duration_s=calibrate_s)
        except (RaplUnavailable, ValueError):
            model = None
    if model is None:
        busy, idle = BACKEND_WATTS.get(backend, _FALLBACK_WATTS)
        model = PowerModel(busy_w=busy, idle_w=idle,
                           source=f"constant:{backend}")
    _DEFAULT_MEMO[key] = model
    return model


def reset_default_power_model() -> None:
    """Drop the process memo (tests that re-point `root` call this)."""
    _DEFAULT_MEMO.clear()


__all__ = [
    "BACKEND_WATTS",
    "DEFAULT_RAPL_ROOT",
    "PowerModel",
    "RaplEnergyReader",
    "RaplUnavailable",
    "calibrate_power",
    "default_power_model",
    "measure_power",
    "reset_default_power_model",
]
