# Pallas TPU kernels for the paper's compute hot-spots (see README.md
# 'Performance' for the CU-role -> kernel fast-path matrix):
#   pointwise_conv  - the pointwise/matmul CU (PW + DENSE ops, fused epilogue)
#   depthwise_conv  - the depthwise CU (Eq. 8 parallelism, row-tiled grid)
#   fused_irb       - the fused Body CU (expanded intermediates stay in VMEM)
#   quant_matmul    - W4/W8 pointwise/linear GEMM with in-register dequant
#   decode_attention- flash-decode w/ grouped GQA + int8-KV (beyond paper)
# Each has ops.py wrappers and ref.py oracles; tests assert bit-exactness
# (integer kernels) or allclose (float GEMM/attention).
