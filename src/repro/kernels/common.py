"""Shared helpers for the quantized Pallas kernels.

The epilogue implements the paper's Approximator & Clip unit (Fig. 8):
int32 accumulator -> per-channel requant multiply -> round -> +bias -> clip
to [0, 2^BW - 1] (== fused ReLU6 when the op is ReLU6-activated).

`zcorr` is the folded zero-point correction M * z_x * wsum (a per-channel
constant computed at QNet build time), so the kernel itself never sees the
input zero point.
"""
from __future__ import annotations

import jax.numpy as jnp


def requant_clip(acc, mult, zcorr, bias_q, qmax: int, clip: bool = True):
    """acc:int32[..., C]; mult/zcorr:f32[C]; bias_q:i32[C] -> int8-range int32."""
    y = jnp.round(acc.astype(jnp.float32) * mult + zcorr).astype(jnp.int32)
    y = y + bias_q.astype(jnp.int32)
    if clip:
        y = jnp.clip(y, 0, qmax)
    return y


def same_pad_amount(size: int, kernel: int, stride: int):
    """SAME padding (lo, hi) for one spatial dim."""
    out = -(-size // stride)
    total = max((out - 1) * stride + kernel - size, 0)
    lo = total // 2
    return lo, total - lo, out


__all__ = ["requant_clip", "same_pad_amount"]
