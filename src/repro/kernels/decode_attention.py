"""Flash-decode attention Pallas kernel (grouped GQA + fused int8-KV dequant).

The §Perf cell-A analysis showed memory-bound decode is dominated by KV-cache
streaming plus the materialized f32 score pipeline. This kernel is the
TPU-native fix: one `pallas_call` whose grid walks KV blocks with an
online-softmax accumulator held in VMEM scratch, so per step it

  * streams each cache byte from HBM exactly once (int8 or bf16 storage),
  * dequantizes int8 KV *in-register* next to the MXU dot (the paper's
    Approximator placement, applied to attention),
  * evaluates all `rep` grouped query heads against each KV head block
    without materializing repeats,
  * never writes scores/probabilities back to HBM (block-local VMEM only).

Grid: (batch, kv_head, s_blocks) — s innermost so the (m, l, acc) scratch
carries across cache blocks; the output block is written on the last step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_s: int, n_blocks: int,
            quant: bool, scale: float):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(F32)  # [rep, dh]
    k = k_ref[0, :, 0]  # [bs, dh] int8|bf16
    v = v_ref[0, :, 0]
    if quant:
        k = k.astype(F32) * ks_ref[0, :, 0].astype(F32)[:, None]
        v = v.astype(F32) * vs_ref[0, :, 0].astype(F32)[:, None]
    else:
        k = k.astype(F32)
        v = v.astype(F32)

    s = jnp.dot(q, k.T, preferred_element_type=F32) * scale  # [rep, bs]
    pos = sb * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, NEG)

    m_prev = m_ref[...]  # [rep]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=F32)
    m_ref[...] = m_new

    @pl.when(sb == n_blocks - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(
    q: jnp.ndarray,  # [B, KV, rep, dh] (current step's grouped queries)
    k_cache: jnp.ndarray,  # [B, S, KV, dh] bf16 or int8
    v_cache: jnp.ndarray,  # [B, S, KV, dh]
    kv_len: jnp.ndarray,  # [] int32 — valid cache length (mask beyond)
    k_scale: jnp.ndarray = None,  # [B, S, KV] when int8
    v_scale: jnp.ndarray = None,
    *,
    block_s: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    b, kv, rep, dh = q.shape
    s = k_cache.shape[1]
    quant = k_cache.dtype == jnp.int8
    bs = min(block_s, s)
    pad = (-s) % bs
    if pad:  # masked by kv_len anyway
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if quant:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nb = sp // bs
    if not quant:  # dummy scale operands keep one kernel signature
        k_scale = jnp.zeros((b, sp, kv), jnp.bfloat16)
        v_scale = jnp.zeros((b, sp, kv), jnp.bfloat16)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (1,))

    grid = (b, kv, nb)
    out = pl.pallas_call(
        functools.partial(_kernel, block_s=bs, n_blocks=nb, quant=quant,
                          scale=dh**-0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, g, sb: (0,)),
            pl.BlockSpec((1, 1, rep, dh), lambda bi, g, sb: (bi, g, 0, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bi, g, sb: (bi, sb, g, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bi, g, sb: (bi, sb, g, 0)),
            pl.BlockSpec((1, bs, 1), lambda bi, g, sb: (bi, sb, g)),
            pl.BlockSpec((1, bs, 1), lambda bi, g, sb: (bi, sb, g)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, dh), lambda bi, g, sb: (bi, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, rep, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep,), F32),
            pltpu.VMEM((rep,), F32),
            pltpu.VMEM((rep, dh), F32),
        ],
        interpret=interpret,
    )(lens, q, k_cache, v_cache, k_scale, v_scale)
    return out


__all__ = ["decode_attention"]
