"""Quantized depthwise convolution Pallas kernel (paper Sec. 4.1.1).

FPGA original: a 3D line buffer streams input rows; a K x K sliding window
with parallel read ports feeds K*K*N parallel MACs (Eq. 8); results pass the
Approximator & Clip unit.

TPU adaptation: depthwise conv has *no channel reduction*, so the natural TPU
mapping is (row-strip, channel)-tiled VMEM blocks with the K x K accumulation
fully unrolled as shifted vector multiplies over the (rows, cols) plane — the
VPU analogue of K*K*N parallel MACs; there is nothing for the MXU to do (that
is the paper's point: systolic arrays waste FMAs on depthwise).

Grid: (batch, channel_tiles, row_tiles), row tiles innermost — the input
block's index map does not depend on the row-tile coordinate, so the
[H, W, block_c] slab is fetched HBM->VMEM once per (batch, channel tile) and
stays resident while every row strip of it is processed. HBM holds only the
RAW activations — SAME padding happens in-kernel (VMEM-local zero pad + halo
slice per row strip), so no jnp.pad-ed copy of the feature map is ever
materialized in HBM; this mirrors the line buffer, which also pads at the
window, not in DDR. Each grid step slices its strip (with K-1 halo rows) out
of the slab, runs the unrolled K x K accumulation for `block_h` output rows,
applies the per-channel requant epilogue and writes
[block_h, W_out, block_c].

Depthwise inputs are ReLU6-fused quantized (zero-point 0), so the in-kernel
zero padding is exact.

CU mapping (see README 'Performance'): this kernel is the DW op's compiled
path on TPU, and the Body CU's dw stage when the fused-IRB kernel does not
apply; off-TPU the same math runs as `integer_ops.int_depthwise_shifts`
(identical shifted-multiply accumulation, XLA-compiled).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import requant_clip


def _dw_kernel(x_ref, w_ref, mult_ref, zcorr_ref, bias_ref, o_ref,
               *, kernel: int, stride: int, th: int, w_out: int,
               pad_top: int, pad_left: int, hp: int, wp: int, qmax: int,
               clip: bool):
    x = x_ref[0].astype(jnp.int32)  # [H, W, bc] — raw, unpadded
    bc = x.shape[-1]
    # VMEM-local SAME padding (zp == 0 for ReLU6-fused dw inputs)
    xp = jnp.pad(
        x,
        ((pad_top, hp - pad_top - x.shape[0]),
         (pad_left, wp - pad_left - x.shape[1]),
         (0, 0)),
    )
    # this strip's rows (including the K-1 halo); grid dim 2 is the row tile
    nrows = (th - 1) * stride + kernel
    row0 = pl.program_id(2) * th * stride
    strip = jax.lax.dynamic_slice(xp, (row0, 0, 0), (nrows, wp, bc))
    w = w_ref[...].astype(jnp.int32)  # [K, K, bc]
    acc = jnp.zeros((th, w_out, bc), jnp.int32)
    # K x K unrolled shifted multiply-accumulate == the sliding window
    for ki in range(kernel):
        for kj in range(kernel):
            patch = jax.lax.slice(
                strip,
                (ki, kj, 0),
                (ki + (th - 1) * stride + 1,
                 kj + (w_out - 1) * stride + 1, bc),
                (stride, stride, 1),
            )
            acc = acc + patch * w[ki, kj][None, None, :]
    y = requant_clip(acc, mult_ref[...], zcorr_ref[...], bias_ref[...], qmax,
                     clip)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kernel", "stride", "qmax", "clip", "block_c", "block_h",
                     "interpret"),
)
def depthwise_conv_q(
    x_q: jnp.ndarray,  # [B, H, W, C] int8/int32 quantized activations (zp folded)
    w_q: jnp.ndarray,  # [K, K, C] int8 symmetric per-channel weights
    mult: jnp.ndarray,  # [C] f32 requant multiplier S_x*S_w/S_y
    zcorr: jnp.ndarray,  # [C] f32 folded zero-point correction M*z_x*wsum
    bias_q: jnp.ndarray,  # [C] i32 bias in output units
    *,
    kernel: int = 3,
    stride: int = 1,
    qmax: int = 15,
    clip: bool = True,
    block_c: int = 128,
    block_h: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas depthwise conv, SAME padding, grid (B, C_tiles, row_tiles).

    `block_h` output rows per grid step (shrunk to the largest divisor of
    H_out); padding is applied in-kernel and the input slab is re-used
    across the innermost row-tile steps, so HBM traffic is the raw input +
    output + weights. Returns int32 in [0, qmax].
    """
    b, h, w, c = x_q.shape
    from repro.kernels.common import same_pad_amount

    ph_lo, ph_hi, h_out = same_pad_amount(h, kernel, stride)
    pw_lo, pw_hi, w_out = same_pad_amount(w, kernel, stride)
    bc = min(block_c, c)
    if c % bc:
        raise ValueError(f"channels {c} must be divisible by block_c {bc}")
    th = min(block_h, h_out)
    while h_out % th:
        th -= 1
    # in-kernel pad must cover the last strip's halo rows
    nrows = (th - 1) * stride + kernel
    max_row = (h_out // th - 1) * th * stride + nrows
    hp = max(ph_lo + h + ph_hi, max_row)
    wp = pw_lo + w + pw_hi

    # row tiles innermost: the x/w/const block indices ignore the row-tile
    # coordinate, so those blocks stay VMEM-resident across consecutive steps
    grid = (b, c // bc, h_out // th)
    out = pl.pallas_call(
        functools.partial(
            _dw_kernel,
            kernel=kernel,
            stride=stride,
            th=th,
            w_out=w_out,
            pad_top=ph_lo,
            pad_left=pw_lo,
            hp=hp,
            wp=wp,
            qmax=qmax,
            clip=clip,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, w, bc), lambda i, k, j: (i, 0, 0, k)),
            pl.BlockSpec((kernel, kernel, bc), lambda i, k, j: (0, 0, k)),
            pl.BlockSpec((bc,), lambda i, k, j: (k,)),
            pl.BlockSpec((bc,), lambda i, k, j: (k,)),
            pl.BlockSpec((bc,), lambda i, k, j: (k,)),
        ],
        out_specs=pl.BlockSpec((1, th, w_out, bc), lambda i, k, j: (i, j, 0, k)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, c), jnp.int32),
        interpret=interpret,
    )(x_q, w_q, mult, zcorr, bias_q)
    return out


__all__ = ["depthwise_conv_q"]
