"""Quantized depthwise convolution Pallas kernel (paper Sec. 4.1.1).

FPGA original: a 3D line buffer streams input rows; a K x K sliding window
with parallel read ports feeds K*K*N parallel MACs (Eq. 8); results pass the
Approximator & Clip unit.

TPU adaptation: depthwise conv has *no channel reduction*, so the natural TPU
mapping is channel-tiled VMEM blocks with the K x K accumulation fully
unrolled as shifted vector multiplies over the (rows, cols) plane — the VPU
analogue of K*K*N parallel MACs; there is nothing for the MXU to do (that is
the paper's point: systolic arrays waste FMAs on depthwise).

Grid: (batch, channel_tiles). Each grid step holds one zero-padded image
slab [Hp, Wp, bc] in VMEM, computes all H_out rows (the 'line buffer' is the
VMEM slab; Pallas double-buffers the HBM->VMEM stream across grid steps),
applies the per-channel requant epilogue and writes [H_out, W_out, bc].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import requant_clip


def _dw_kernel(x_ref, w_ref, mult_ref, zcorr_ref, bias_ref, o_ref,
               *, kernel: int, stride: int, h_out: int, w_out: int, qmax: int,
               clip: bool):
    x = x_ref[0].astype(jnp.int32)  # [Hp, Wp, bc]
    w = w_ref[...].astype(jnp.int32)  # [K, K, bc]
    acc = jnp.zeros((h_out, w_out, x.shape[-1]), jnp.int32)
    # K x K unrolled shifted multiply-accumulate == the sliding window
    for ki in range(kernel):
        for kj in range(kernel):
            patch = jax.lax.slice(
                x,
                (ki, kj, 0),
                (ki + (h_out - 1) * stride + 1, kj + (w_out - 1) * stride + 1, x.shape[-1]),
                (stride, stride, 1),
            )
            acc = acc + patch * w[ki, kj][None, None, :]
    y = requant_clip(acc, mult_ref[...], zcorr_ref[...], bias_ref[...], qmax, clip)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kernel", "stride", "qmax", "clip", "block_c", "interpret"),
)
def depthwise_conv_q(
    x_q: jnp.ndarray,  # [B, H, W, C] int8/int32 quantized activations (zp folded)
    w_q: jnp.ndarray,  # [K, K, C] int8 symmetric per-channel weights
    mult: jnp.ndarray,  # [C] f32 requant multiplier S_x*S_w/S_y
    zcorr: jnp.ndarray,  # [C] f32 folded zero-point correction M*z_x*wsum
    bias_q: jnp.ndarray,  # [C] i32 bias in output units
    *,
    kernel: int = 3,
    stride: int = 1,
    qmax: int = 15,
    clip: bool = True,
    block_c: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas depthwise conv with SAME padding. Returns int32 in [0, qmax]."""
    b, h, w, c = x_q.shape
    from repro.kernels.common import same_pad_amount

    ph_lo, ph_hi, h_out = same_pad_amount(h, kernel, stride)
    pw_lo, pw_hi, w_out = same_pad_amount(w, kernel, stride)
    xp = jnp.pad(
        x_q, ((0, 0), (ph_lo, ph_hi), (pw_lo, pw_hi), (0, 0))
    )  # dw input is ReLU6-fused quantized: zp == 0, so zero padding is exact
    hp, wp = xp.shape[1], xp.shape[2]
    bc = min(block_c, c)
    if c % bc:
        raise ValueError(f"channels {c} must be divisible by block_c {bc}")

    grid = (b, c // bc)
    out = pl.pallas_call(
        functools.partial(
            _dw_kernel,
            kernel=kernel,
            stride=stride,
            h_out=h_out,
            w_out=w_out,
            qmax=qmax,
            clip=clip,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, bc), lambda i, j: (i, 0, 0, j)),
            pl.BlockSpec((kernel, kernel, bc), lambda i, j: (0, 0, j)),
            pl.BlockSpec((bc,), lambda i, j: (j,)),
            pl.BlockSpec((bc,), lambda i, j: (j,)),
            pl.BlockSpec((bc,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, h_out, w_out, bc), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, c), jnp.int32),
        interpret=interpret,
    )(xp, w_q, mult, zcorr, bias_q)
    return out


__all__ = ["depthwise_conv_q"]
