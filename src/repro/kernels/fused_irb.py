"""Fused Inverted-Residual-Block Pallas kernel — the Body CU (Sec. 4.2.3).

FPGA original: the Body CU executes pointwise(expand) -> depthwise ->
pointwise(project) *concurrently in a fused fashion*, streaming intermediate
feature maps through FIFOs so the t*C-expanded tensor never reaches DDR.

TPU adaptation: one `pallas_call` whose grid walks (batch, output-row strips).
Per grid step it:
  1. loads an input strip (with dw halo rows) from the VMEM-resident image,
  2. expands it on the MXU (int8 matmul, int32 accum) + requant/clip (ReLU6),
  3. zero-masks halo positions (== the dw's SAME zero padding, exact because
     ReLU6-fused quantization has zero-point 0),
  4. runs the K x K depthwise accumulation on the strip (VPU),
  5. projects back down on the MXU + requant,
  6. optionally adds the skip-line in integer arithmetic.

The expanded intermediate exists ONLY as kernel-local values (VMEM/VREG) —
the exact analogue of the paper's stream FIFOs. HBM traffic per block is
input + output + weights instead of input + output + 2 x t-times-expanded
intermediates; see benchmarks/bench_fusion.py for the traffic accounting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import requant_clip, same_pad_amount


def _irb_kernel(
    x_ref,
    w1_ref, m1_ref, c1_ref, b1_ref,
    w2_ref, m2_ref, c2_ref, b2_ref,
    w3_ref, m3_ref, c3_ref, b3_ref,
    o_ref,
    *,
    kernel: int,
    stride: int,
    th: int,
    h: int,
    w: int,
    pad_top: int,
    pad_left: int,
    qmax: int,
    residual: bool,
    res_consts,
):
    si = pl.program_id(1)
    nrows = (th - 1) * stride + kernel
    wp = x_ref.shape[2]
    w_out = -(-w // stride)  # SAME

    # ---- 1. input strip (includes dw halo; x is HBM-padded with dead rows) ----
    row0 = si * th * stride
    x = x_ref[0, pl.dslice(row0, nrows), :, :].astype(jnp.int32)  # [nrows, Wp, C]

    # ---- 2. pointwise expansion on the strip (MXU) ----
    c_in = x.shape[-1]
    e_ch = w1_ref.shape[-1]
    acc1 = jnp.dot(
        x.reshape(-1, c_in), w1_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    ).reshape(nrows, wp, e_ch)
    e = requant_clip(acc1, m1_ref[...], c1_ref[...], b1_ref[...], qmax, clip=True)

    # ---- 3. zero-mask halo rows/cols (the dw SAME padding; zp == 0) ----
    grow = row0 + jax.lax.broadcasted_iota(jnp.int32, (nrows, wp), 0)
    gcol = jax.lax.broadcasted_iota(jnp.int32, (nrows, wp), 1)
    valid = (
        (grow >= pad_top) & (grow < pad_top + h)
        & (gcol >= pad_left) & (gcol < pad_left + w)
    )
    e = jnp.where(valid[:, :, None], e, 0)

    # ---- 4. depthwise K x K on the expanded strip (VPU) ----
    w2 = w2_ref[...].astype(jnp.int32)  # [K, K, E]
    acc2 = jnp.zeros((th, w_out, e_ch), jnp.int32)
    for ki in range(kernel):
        for kj in range(kernel):
            patch = jax.lax.slice(
                e,
                (ki, kj, 0),
                (ki + (th - 1) * stride + 1, kj + (w_out - 1) * stride + 1, e_ch),
                (stride, stride, 1),
            )
            acc2 = acc2 + patch * w2[ki, kj][None, None, :]
    d = requant_clip(acc2, m2_ref[...], c2_ref[...], b2_ref[...], qmax, clip=True)

    # ---- 5. pointwise projection (MXU) ----
    c_out = w3_ref.shape[-1]
    acc3 = jnp.dot(
        d.reshape(-1, e_ch), w3_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    ).reshape(th, w_out, c_out)
    y = requant_clip(acc3, m3_ref[...], c3_ref[...], b3_ref[...], qmax, clip=True)

    # ---- 6. skip-line (residual path, Fig. 3) ----
    if residual:
        a_mult, a_off, b_mult, b_off = res_consts
        a = x_ref[0, pl.dslice(pad_top + si * th, th), pad_left : pad_left + w, :]
        a = a.astype(jnp.float32) * a_mult + a_off
        yb = y.astype(jnp.float32) * b_mult + b_off
        y = jnp.clip(jnp.round(a + yb), 0, qmax).astype(jnp.int32)

    o_ref[0] = y


@functools.partial(
    jax.jit,
    static_argnames=(
        "kernel", "stride", "qmax", "residual", "res_consts", "block_h", "interpret",
    ),
)
def fused_irb_q(
    x_q: jnp.ndarray,  # [B, H, W, C] quantized activations
    w1_q: jnp.ndarray,  # [C, E]   expand
    mult1, zcorr1, bias1,  # [E]
    w2_q: jnp.ndarray,  # [K, K, E] depthwise
    mult2, zcorr2, bias2,  # [E]
    w3_q: jnp.ndarray,  # [E, Co]  project
    mult3, zcorr3, bias3,  # [Co]
    *,
    kernel: int = 3,
    stride: int = 1,
    qmax: int = 15,
    residual: bool = False,
    res_consts=None,  # (a_mult, a_off, b_mult, b_off) static floats
    block_h: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, w, c = x_q.shape
    e_ch = w1_q.shape[-1]
    c_out = w3_q.shape[-1]
    ph_lo, ph_hi, h_out = same_pad_amount(h, kernel, stride)
    pw_lo, pw_hi, w_out = same_pad_amount(w, kernel, stride)
    # pad so every strip's halo load is in range (values are masked, not read)
    th = min(block_h, h_out)
    while h_out % th:
        th -= 1
    max_row = (h_out // th - 1) * th * stride + (th - 1) * stride + kernel
    extra = max(max_row - (ph_lo + h + ph_hi), 0)
    xp = jnp.pad(x_q, ((0, 0), (ph_lo, ph_hi + extra), (pw_lo, pw_hi), (0, 0)))
    hp, wp = xp.shape[1], xp.shape[2]

    grid = (b, h_out // th)
    kern = functools.partial(
        _irb_kernel,
        kernel=kernel,
        stride=stride,
        th=th,
        h=h,
        w=w,
        pad_top=ph_lo,
        pad_left=pw_lo,
        qmax=qmax,
        residual=residual,
        res_consts=res_consts,
    )
    vec = lambda n: pl.BlockSpec((n,), lambda i, j: (0,))  # noqa: E731
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hp, wp, c), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((c, e_ch), lambda i, j: (0, 0)),
            vec(e_ch), vec(e_ch), vec(e_ch),
            pl.BlockSpec((kernel, kernel, e_ch), lambda i, j: (0, 0, 0)),
            vec(e_ch), vec(e_ch), vec(e_ch),
            pl.BlockSpec((e_ch, c_out), lambda i, j: (0, 0)),
            vec(c_out), vec(c_out), vec(c_out),
        ],
        out_specs=pl.BlockSpec((1, th, w_out, c_out), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, c_out), jnp.int32),
        interpret=interpret,
    )(
        xp,
        w1_q, mult1, zcorr1, bias1,
        w2_q, mult2, zcorr2, bias2,
        w3_q, mult3, zcorr3, bias3,
    )
    return out


__all__ = ["fused_irb_q"]
