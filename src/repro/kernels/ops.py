"""Public jit'd wrappers around the Pallas kernels.

These adapt QNet metadata (per-channel scales, zero-point corrections) into
the raw kernel signatures, pick interpret mode automatically (CPU container
-> interpret=True; real TPU -> compiled), and expose a float `quantized_linear`
for the LM architectures (weight-only quantization, the paper's Sec. 3.2 math).

Every wrapper accepts either a host `QOp` or a device-resident
`cu.PreparedQOp` — prepared ops reuse their cached jnp constants, so a jitted
stage trace built over a `PreparedQNet` closes over device arrays and never
re-uploads per invocation (the PR-2 'device-cached epilogue constants' path).

Fast-path matrix (which CU op hits which kernel — see README 'Performance'):

    op kind   on TPU (compiled Pallas)         off TPU (compiled XLA)
    -------   ------------------------------   ---------------------------
    PW/DENSE  pointwise_conv.pointwise_conv_q  int_pointwise(_f32) + epilogue
    DW        depthwise_conv.depthwise_conv_q  int_depthwise_shifts + epilogue
    IRB       fused_irb.fused_irb_q (Body CU)  per-op path above
    CONV      (stem only) XLA conv             int_conv2d(_f32) + epilogue
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qnet import QNet
from repro.core import cu as _cu
from repro.core import graph as G
from repro.core.quant import QuantConfig
from repro.kernels import depthwise_conv as _dw
from repro.kernels import fused_irb as _irb
from repro.kernels import pointwise_conv as _pw
from repro.kernels import quant_matmul as _qmm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _epilogue_consts(qop) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(mult, zcorr, bias') for the kernel epilogue.

    kernel computes round(acc * mult + zcorr) + bias; z_y is already folded
    into bias_q at QNet build time (see qnet._quantize_op). PreparedQOps
    return their device-cached constants directly.
    """
    if isinstance(qop, _cu.PreparedQOp):
        return qop.mult, qop.zcorr, qop.bias_q
    mult = jnp.asarray(qop.mult, jnp.float32)
    zcorr = jnp.asarray(qop.in_zp * qop.mult * qop.wsum, jnp.float32)
    bias = jnp.asarray(qop.bias_q, jnp.int32)
    return mult, zcorr, bias


def _dw_weight(qop) -> jnp.ndarray:
    if isinstance(qop, _cu.PreparedQOp):
        return qop.w_kern
    w = jnp.asarray(qop.w_q)  # [K, K, 1, C] -> [K, K, C]
    return w.reshape(w.shape[0], w.shape[1], w.shape[-1])


def _mat_weight(qop) -> jnp.ndarray:
    if isinstance(qop, _cu.PreparedQOp):
        return qop.w_kern
    w = jnp.asarray(qop.w_q)
    return w[0, 0] if w.ndim == 4 else w


def _pick_block_c(c: int) -> int:
    for cand in (128, 64, 32, 16, 8):
        if c % cand == 0 and c >= cand:
            return cand
    return c


def run_dw_qop(x_q: jnp.ndarray, qop, interpret: Optional[bool] = None,
               block_h: int = 8):
    """Depthwise QNet op via the row-tiled Pallas kernel."""
    interp = (not on_tpu()) if interpret is None else interpret
    mult, zcorr, bias = _epilogue_consts(qop)
    return _dw.depthwise_conv_q(
        x_q, _dw_weight(qop), mult, zcorr, bias,
        kernel=qop.spec.kernel, stride=qop.spec.stride, qmax=qop.qmax,
        clip=qop.clip, block_c=_pick_block_c(x_q.shape[-1]),
        block_h=block_h, interpret=interp,
    )


def _pw_zpc(qop) -> jnp.ndarray:
    if isinstance(qop, _cu.PreparedQOp):
        return qop.zpc
    return jnp.int32(qop.in_zp) * jnp.asarray(qop.wsum, jnp.int32)


def run_pw_qop(x_q: jnp.ndarray, qop, interpret: Optional[bool] = None,
               block_m: int = 128, block_n: int = 128, block_k: int = 128):
    """Pointwise / dense QNet op via the Pallas matmul-CU kernel.

    Bit-exact with `int_pointwise` + `quantized_op_epilogue` (the kernel
    applies the identical integer zero-point correction and f32 requant
    sequence). Clips to [0, qmax] like the reference epilogue — linear ops
    included, since the output quantizer's codomain is [0, qmax] either way.

    `block_m/n/k` expose the kernel's tile sizes (the route autotuner
    sweeps them; tiling only reorders identical integer accumulations, so
    any tile choice stays bit-exact).
    """
    interp = (not on_tpu()) if interpret is None else interpret
    mult = qop.mult if isinstance(qop, _cu.PreparedQOp) else jnp.asarray(
        qop.mult, jnp.float32)
    bias = qop.bias_q if isinstance(qop, _cu.PreparedQOp) else jnp.asarray(
        qop.bias_q, jnp.int32)
    return _pw.pointwise_conv_q(
        x_q, _mat_weight(qop), mult, _pw_zpc(qop), bias,
        qmax=qop.qmax, clip=True, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=interp,
    )


def fusable_irb(block: G.BlockSpec) -> bool:
    """True when `block` fits the fused Body-CU kernel: the canonical
    expand -> dw -> project shape with no squeeze-excitation branch and one
    activation bit-width (the kernel clips all three stages with a single
    qmax, so mixed act_bits would requantize wrongly)."""
    return (
        len(block.ops) == 3
        and block.se is None
        and block.ops[0].kind == G.PW
        and block.ops[1].kind == G.DW
        and block.ops[2].kind == G.PW
        and not block.avgpool
        and len({op.act_bits for op in block.ops}) == 1
    )


def run_irb_block(
    x_q: jnp.ndarray,
    block: G.BlockSpec,
    qnet: QNet,
    in_s: float,
    in_z: float,
    interpret: Optional[bool] = None,
):
    """Body-CU invocation: a full IRB through the fused Pallas kernel.

    Only for expand->dw->project blocks (no SE). Returns (y_q, out_s, out_z).
    """
    interp = (not on_tpu()) if interpret is None else interpret
    assert len(block.ops) == 3 and block.se is None
    q1, q2, q3 = (qnet.ops[op.name] for op in block.ops)
    m1, c1, b1 = _epilogue_consts(q1)
    m2, c2, b2 = _epilogue_consts(q2)
    m3, c3, b3 = _epilogue_consts(q3)
    res_consts = None
    out_s, out_z = q3.out_scale, q3.out_zp
    if block.residual:
        y_s, y_z = qnet.res_q[block.name]
        res_consts = (
            in_s / y_s,
            in_s / y_s * in_z - round(y_z),
            q3.out_scale / y_s,
            q3.out_scale / y_s * q3.out_zp,
        )
        out_s, out_z = y_s, y_z
    y = _irb.fused_irb_q(
        x_q,
        _mat_weight(q1),
        m1, c1, b1,
        _dw_weight(q2), m2, c2, b2,
        _mat_weight(q3),
        m3, c3, b3,
        kernel=q2.spec.kernel,
        stride=q2.spec.stride,
        qmax=q3.qmax,
        residual=block.residual,
        res_consts=res_consts,
        interpret=interp,
    )
    return y, out_s, out_z


def run_block_kernels(
    x_q: jnp.ndarray,
    block: G.BlockSpec,
    qnet,
    in_s: float,
    in_z: float,
    interpret: Optional[bool] = None,
):
    """One block through the per-op Pallas kernels (no IRB fusion).

    Mirrors `cu.run_block` exactly, but routes DW ops through the row-tiled
    depthwise kernel and PW/DENSE ops through the pointwise-CU kernel — the
    compiled path for Head/Tail/Classifier stages and for Body blocks the
    fused-IRB kernel cannot take (SE branches, mixed act_bits). CONV (the
    stem) and the SE gate stay on the XLA path inside `cu.run_block`'s
    reference op body. Returns (y_q, out_s, out_z).
    """
    y = x_q
    cur_s, cur_z = in_s, in_z
    for op in block.ops:
        qop = qnet.ops[op.name]
        if op.kind == G.DW:
            y = run_dw_qop(y, qop, interpret=interpret)
        elif op.kind in (G.PW, G.DENSE) and op.act != G.HSIGMOID:
            y = run_pw_qop(y, qop, interpret=interpret)
        else:
            y = _cu._run_qop(y, qop, fixed_point=False)
        cur_s, cur_z = qop.out_scale, qop.out_zp
        if block.se is not None and block.se_after == op.name:
            sq = qnet.ops[block.se.squeeze.name]
            ex = qnet.ops[block.se.excite.name]
            pooled = jnp.round(
                jnp.mean(y.astype(jnp.float32), axis=(1, 2))).astype(jnp.int32)
            s = run_pw_qop(pooled, sq, interpret=interpret)
            gate_q = _cu._run_qop(s, ex, fixed_point=False)  # hsigmoid gate
            y = jnp.round(
                y.astype(jnp.float32)
                * gate_q[:, None, None, :].astype(jnp.float32)
                * ex.out_scale
            ).astype(jnp.int32)
    if block.residual:
        y_s, y_z = qnet.res_q[block.name]
        qmax = 2 ** block.ops[-1].act_bits - 1
        y = _cu._residual_add(x_q, in_s, in_z, y, cur_s, cur_z, y_s, y_z, qmax)
        cur_s, cur_z = y_s, y_z
    if block.avgpool:
        y = jnp.round(jnp.mean(y.astype(jnp.float32), axis=(1, 2))).astype(jnp.int32)
    return y, cur_s, cur_z


# ---------------------------------------------------------------------------
# LM-side weight-only quantized linear (per-channel / grouped, BW in {4, 8})
# ---------------------------------------------------------------------------


def quantize_weight_for_matmul(
    w: jnp.ndarray, bits: int = 4, group_size: Optional[int] = None
):
    """[K, N] float -> (w_q packed, scales [G, N]) symmetric per-(group, out)."""
    k, n = w.shape
    if group_size is None:
        group_size = k
    g = k // group_size
    wg = w.reshape(g, group_size, n)
    cfg = QuantConfig(bits, symmetric=True, channel_axis=None)
    amax = jnp.max(jnp.abs(wg), axis=1)  # [G, N]
    scale = jnp.where(amax > 0, amax / cfg.qmax, 1.0)
    q = jnp.clip(jnp.round(wg / scale[:, None, :]), cfg.qmin, cfg.qmax)
    q = q.reshape(k, n).astype(jnp.int32)
    if bits == 4:
        packed = _qmm.pack_int4(jnp.where(q < 0, q + 16, q).astype(jnp.int32))
        return packed, scale
    return q.astype(jnp.int8), scale


def quantized_linear(
    x: jnp.ndarray,
    w_q: jnp.ndarray,
    w_scale: jnp.ndarray,
    bits: int = 4,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = x @ dequant(w_q). x: [..., K]. Uses the Pallas quant_matmul."""
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    # pad M to a block multiple
    bm = 128 if m >= 128 else m
    pad = (-m) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = w_q.shape[1] * (2 if bits == 4 else 1)
    # largest divisor of N at most 128 (one giant N block would blow VMEM
    # for non-multiple-of-128 N; any divisor tiles exactly)
    bn = _pw._largest_divisor(n, 128)
    group = k // w_scale.shape[0]
    bk = min(512, group) if group < 512 or group % 512 else 512
    # bk must divide K and align with the scale-group size; halving can
    # bottom out (e.g. group == 0 when there are more scale rows than K, or
    # no shared power-of-two factor) — fall back to gcd(k, group), floor 1
    while bk > 1 and (k % bk or (group % bk and bk % group)):
        bk //= 2
    if bk < 1 or k % bk or (group % bk and bk % group):
        bk = max(math.gcd(k, group), 1)
    y = _qmm.quant_matmul(
        x2, w_q, w_scale, bits=bits, block_m=bm, block_n=bn, block_k=bk,
        interpret=interp,
    )
    if pad:
        y = y[:m]
    return y.reshape(*lead, n).astype(x.dtype)


def decode_attend(q, kv_cache, kv_len, interpret: Optional[bool] = None):
    """Flash-decode attention over a model KV cache dict.

    q: [B, 1, H, dh] (one new token); kv_cache: {"k","v"[,"k_scale","v_scale"]}
    with k/v [B, S, KV, dh]. Returns [B, 1, H, dh].
    """
    from repro.kernels.decode_attention import decode_attention

    interp = (not on_tpu()) if interpret is None else interpret
    b, one, h, dh = q.shape
    kv = kv_cache["k"].shape[2]
    qg = q.reshape(b, kv, h // kv, dh)
    out = decode_attention(
        qg, kv_cache["k"], kv_cache["v"], kv_len,
        kv_cache.get("k_scale"), kv_cache.get("v_scale"), interpret=interp)
    return out.reshape(b, 1, h, dh)


__all__ = [
    "run_dw_qop",
    "run_pw_qop",
    "run_block_kernels",
    "fusable_irb",
    "run_irb_block",
    "quantize_weight_for_matmul",
    "quantized_linear",
    "decode_attend",
    "on_tpu",
]
