"""Public jit'd wrappers around the Pallas kernels.

These adapt QNet metadata (per-channel scales, zero-point corrections) into
the raw kernel signatures, pick interpret mode automatically (CPU container
-> interpret=True; real TPU -> compiled), and expose a float `quantized_linear`
for the LM architectures (weight-only quantization, the paper's Sec. 3.2 math).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qnet import QOp, QNet
from repro.core import graph as G
from repro.core.quant import QuantConfig, compute_scale_zp, observe_range, quantize
from repro.kernels import depthwise_conv as _dw
from repro.kernels import fused_irb as _irb
from repro.kernels import quant_matmul as _qmm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _epilogue_consts(qop: QOp) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(mult, zcorr, bias') for the kernel epilogue.

    kernel computes round(acc * mult + zcorr) + bias; z_y is already folded
    into bias_q at QNet build time (see qnet._quantize_op).
    """
    mult = jnp.asarray(qop.mult, jnp.float32)
    zcorr = jnp.asarray(qop.in_zp * qop.mult * qop.wsum, jnp.float32)
    bias = jnp.asarray(qop.bias_q, jnp.int32)
    return mult, zcorr, bias


def run_dw_qop(x_q: jnp.ndarray, qop: QOp, interpret: Optional[bool] = None):
    """Depthwise QNet op via the Pallas kernel."""
    interp = (not on_tpu()) if interpret is None else interpret
    mult, zcorr, bias = _epilogue_consts(qop)
    w = jnp.asarray(qop.w_q)  # [K, K, 1, C] -> [K, K, C]
    w = w.reshape(w.shape[0], w.shape[1], w.shape[-1])
    c = x_q.shape[-1]
    bc = c
    for cand in (128, 64, 32, 16, 8):
        if c % cand == 0 and c >= cand:
            bc = cand
            break
    return _dw.depthwise_conv_q(
        x_q, w, mult, zcorr, bias,
        kernel=qop.spec.kernel, stride=qop.spec.stride, qmax=qop.qmax,
        clip=qop.clip, block_c=bc, interpret=interp,
    )


def fusable_irb(block: G.BlockSpec) -> bool:
    """True when `block` fits the fused Body-CU kernel: the canonical
    expand -> dw -> project shape with no squeeze-excitation branch and one
    activation bit-width (the kernel clips all three stages with a single
    qmax, so mixed act_bits would requantize wrongly)."""
    return (
        len(block.ops) == 3
        and block.se is None
        and block.ops[0].kind == G.PW
        and block.ops[1].kind == G.DW
        and block.ops[2].kind == G.PW
        and not block.avgpool
        and len({op.act_bits for op in block.ops}) == 1
    )


def run_irb_block(
    x_q: jnp.ndarray,
    block: G.BlockSpec,
    qnet: QNet,
    in_s: float,
    in_z: float,
    interpret: Optional[bool] = None,
):
    """Body-CU invocation: a full IRB through the fused Pallas kernel.

    Only for expand->dw->project blocks (no SE). Returns (y_q, out_s, out_z).
    """
    interp = (not on_tpu()) if interpret is None else interpret
    assert len(block.ops) == 3 and block.se is None
    q1, q2, q3 = (qnet.ops[op.name] for op in block.ops)
    m1, c1, b1 = _epilogue_consts(q1)
    m2, c2, b2 = _epilogue_consts(q2)
    m3, c3, b3 = _epilogue_consts(q3)
    res_consts = None
    out_s, out_z = q3.out_scale, q3.out_zp
    if block.residual:
        y_s, y_z = qnet.res_q[block.name]
        res_consts = (
            in_s / y_s,
            in_s / y_s * in_z - round(y_z),
            q3.out_scale / y_s,
            q3.out_scale / y_s * q3.out_zp,
        )
        out_s, out_z = y_s, y_z
    w2 = jnp.asarray(q2.w_q)
    w2 = w2.reshape(w2.shape[0], w2.shape[1], w2.shape[-1])
    y = _irb.fused_irb_q(
        x_q,
        jnp.asarray(q1.w_q)[0, 0] if q1.w_q.ndim == 4 else jnp.asarray(q1.w_q),
        m1, c1, b1,
        w2, m2, c2, b2,
        jnp.asarray(q3.w_q)[0, 0] if q3.w_q.ndim == 4 else jnp.asarray(q3.w_q),
        m3, c3, b3,
        kernel=q2.spec.kernel,
        stride=q2.spec.stride,
        qmax=q3.qmax,
        residual=block.residual,
        res_consts=res_consts,
        interpret=interp,
    )
    return y, out_s, out_z


# ---------------------------------------------------------------------------
# LM-side weight-only quantized linear (per-channel / grouped, BW in {4, 8})
# ---------------------------------------------------------------------------


def quantize_weight_for_matmul(
    w: jnp.ndarray, bits: int = 4, group_size: Optional[int] = None
):
    """[K, N] float -> (w_q packed, scales [G, N]) symmetric per-(group, out)."""
    k, n = w.shape
    if group_size is None:
        group_size = k
    g = k // group_size
    wg = w.reshape(g, group_size, n)
    cfg = QuantConfig(bits, symmetric=True, channel_axis=None)
    amax = jnp.max(jnp.abs(wg), axis=1)  # [G, N]
    scale = jnp.where(amax > 0, amax / cfg.qmax, 1.0)
    q = jnp.clip(jnp.round(wg / scale[:, None, :]), cfg.qmin, cfg.qmax)
    q = q.reshape(k, n).astype(jnp.int32)
    if bits == 4:
        packed = _qmm.pack_int4(jnp.where(q < 0, q + 16, q).astype(jnp.int32))
        return packed, scale
    return q.astype(jnp.int8), scale


def quantized_linear(
    x: jnp.ndarray,
    w_q: jnp.ndarray,
    w_scale: jnp.ndarray,
    bits: int = 4,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """y = x @ dequant(w_q). x: [..., K]. Uses the Pallas quant_matmul."""
    interp = (not on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    # pad M to a block multiple
    bm = 128 if m >= 128 else m
    pad = (-m) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = w_q.shape[1] * (2 if bits == 4 else 1)
    bn = 128 if n % 128 == 0 else n
    group = k // w_scale.shape[0]
    bk = min(512, group) if group < 512 or group % 512 else 512
    while k % bk or (group % bk and bk % group):
        bk //= 2
    y = _qmm.quant_matmul(
        x2, w_q, w_scale, bits=bits, block_m=bm, block_n=bn, block_k=bk,
        interpret=interp,
    )
    if pad:
        y = y[:m]
    return y.reshape(*lead, n).astype(x.dtype)


def decode_attend(q, kv_cache, kv_len, interpret: Optional[bool] = None):
    """Flash-decode attention over a model KV cache dict.

    q: [B, 1, H, dh] (one new token); kv_cache: {"k","v"[,"k_scale","v_scale"]}
    with k/v [B, S, KV, dh]. Returns [B, 1, H, dh].
    """
    from repro.kernels.decode_attention import decode_attention

    interp = (not on_tpu()) if interpret is None else interpret
    b, one, h, dh = q.shape
    kv = kv_cache["k"].shape[2]
    qg = q.reshape(b, kv, h // kv, dh)
    out = decode_attention(
        qg, kv_cache["k"], kv_cache["v"], kv_len,
        kv_cache.get("k_scale"), kv_cache.get("v_scale"), interpret=interp)
    return out.reshape(b, 1, h, dh)


__all__ = [
    "run_dw_qop",
    "fusable_irb",
    "run_irb_block",
    "quantize_weight_for_matmul",
    "quantized_linear",
    "decode_attend",
    "on_tpu",
]
