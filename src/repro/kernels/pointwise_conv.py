"""Quantized pointwise-convolution Pallas kernel (paper Sec. 4.1.3).

FPGA original: the pointwise CU is a matrix-multiply engine — a 1x1 conv has
no spatial window, so every output pixel is one row of an (H*W*B, C_in) x
(C_in, C_out) GEMM ("the design of this operator can be similar to the design
of a general matrix multiplication"). The Approximator & Clip unit requantizes
the int32 accumulator on the way out.

TPU adaptation: flatten the activations to [M, K] = [B*H*W, C_in] and tile an
M x N x K grid for the MXU with int8 operands and int32 accumulation. The k
axis is innermost, so each (i, j) output tile stays VMEM-resident while K
streams; the fused requant/clip epilogue runs once, on the last k step —
intermediate accumulators never visit HBM in anything but their final int
form. The same kernel serves:

  * PW ops (Head/Body expand+project, Tail pw)  — x is [B, H, W, C_in],
  * DENSE ops (Classifier)                      — x is [B, C_in],

i.e. every op the CU planner maps to a matmul engine.

Epilogue exactness: the kernel receives the INTEGER zero-point correction
`zpc = int32(z_x) * wsum` (per output channel) and computes

    y = clip( round((acc + zpc) * mult) + bias_q, 0, qmax )

which is operation-for-operation the float-multiplier branch of
`core.integer_ops.quantized_op_epilogue` — so the kernel is bit-exact with
the `int_pointwise` + epilogue reference, not merely allclose.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import requant_clip


def _largest_divisor(n: int, cap: int) -> int:
    """Largest d <= cap with n % d == 0 (d >= 1)."""
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def _pw_kernel(x_ref, w_ref, mult_ref, zpc_ref, bias_ref, o_ref,
               *, nsteps: int, qmax: int, clip: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)  # [bm, bk]
    w = w_ref[...].astype(jnp.int32)  # [bk, bn]
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.int32)

    @pl.when(k == nsteps - 1)
    def _epilogue():
        acc = o_ref[...] + zpc_ref[...].astype(jnp.int32)[None, :]
        o_ref[...] = requant_clip(
            acc, mult_ref[...], jnp.float32(0.0), bias_ref[...], qmax, clip)


@functools.partial(
    jax.jit,
    static_argnames=("qmax", "clip", "block_m", "block_n", "block_k",
                     "interpret"),
)
def pointwise_conv_q(
    x_q: jnp.ndarray,  # [..., C_in] int quantized activations
    w_q: jnp.ndarray,  # [C_in, C_out] int8 symmetric per-out-channel weights
    mult: jnp.ndarray,  # [C_out] f32 requant multiplier S_x*S_w/S_y
    zpc: jnp.ndarray,  # [C_out] i32 integer zero-point correction z_x*wsum
    bias_q: jnp.ndarray,  # [C_out] i32 bias in output units (z_y folded)
    *,
    qmax: int = 15,
    clip: bool = True,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas pointwise conv / dense matmul with the fused integer epilogue.

    Flattens leading dims to M, pads M up to a block multiple (pad rows are
    computed then discarded), and picks N/K blocks as the largest divisors
    within the requested block sizes, so any channel count compiles.
    Returns int32 in [0, qmax] with the input's leading shape + [C_out].
    """
    lead = x_q.shape[:-1]
    k_dim = x_q.shape[-1]
    n_dim = w_q.shape[-1]
    x2 = x_q.reshape(-1, k_dim)
    m = x2.shape[0]

    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    mp = m + pad
    bn = _largest_divisor(n_dim, block_n)
    bk = _largest_divisor(k_dim, block_k)

    grid = (mp // bm, n_dim // bn, k_dim // bk)
    out = pl.pallas_call(
        functools.partial(_pw_kernel, nsteps=grid[2], qmax=qmax, clip=clip),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, n_dim), jnp.int32),
        interpret=interpret,
    )(x2, w_q, mult, zpc, bias_q)
    if pad:
        out = out[:m]
    return out.reshape(*lead, n_dim)


__all__ = ["pointwise_conv_q"]
