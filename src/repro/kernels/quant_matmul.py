"""Weight-quantized matmul Pallas kernel (paper Sec. 4.1.3 + Sec. 3.2).

This is DeepDive's pointwise-convolution CU generalized to every linear
operator in the assigned LM architectures: per-output-channel (or K-grouped)
low-bit weights are stored packed in HBM, streamed to VMEM, dequantized
in-register, and fed to the MXU — "the design of this operator can be similar
to the design of a general matrix multiplication" (Sec. 4.1.3), with the
paper's range-based linear quantization supplying the scales.

Supports BW=8 (int8 weights) and BW=4 (two nibbles per uint8, unpacked
in-kernel). Grid: (M/bm, N/bn, K/bk) with output-block accumulation —
the k axis is innermost so each (i, j) output tile stays resident while the
MXU streams K.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import pack_int4, unpack_int4  # noqa: F401  (re-export)


def _qmm_kernel(x_ref, w_ref, s_ref, o_ref, *, bits: int, nsteps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)  # [bm, bk]
    if bits == 4:
        w_q = unpack_int4(w_ref[...], signed=True)  # [bk, bn] (packed on n)
    else:
        w_q = w_ref[...].astype(jnp.int32)
    # per-(k-group, n) scale for this k block — dequant BEFORE the MXU dot
    w = w_q.astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "block_m", "block_n", "block_k", "interpret"),
)
def quant_matmul(
    x: jnp.ndarray,  # [M, K] float (bf16/f32)
    w_q: jnp.ndarray,  # int8 [K, N] or packed uint8 [K, N//2] when bits == 4
    w_scale: jnp.ndarray,  # [G, N] per-k-group scales (G = K // group_size; G=1 => per-channel)
    *,
    bits: int = 8,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    m, k = x.shape
    n = w_q.shape[1] * (2 if bits == 4 else 1)
    g = w_scale.shape[0]
    if k % g:
        raise ValueError(f"K={k} not divisible by scale groups G={g}")
    group = k // g
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if bk % group and group % bk:
        raise ValueError(f"block_k={bk} must align with group size {group}")
    bk = min(bk, group) if group >= 1 else bk
    for name, dim, blk in (("M", m, bm), ("N", n, bn), ("K", k, bk)):
        if dim % blk:
            raise ValueError(f"{name}={dim} not divisible by block {blk}")

    wn = bn // 2 if bits == 4 else bn
    grid = (m // bm, n // bn, k // bk)
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, bits=bits, nsteps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, wn), lambda i, j, kk: (kk, j)),
            # one scale row per k block (bk <= group ensures single group)
            pl.BlockSpec((1, bn), lambda i, j, kk, _g=group, _bk=bk: (kk * _bk // _g, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_q, w_scale)
    return out


__all__ = ["quant_matmul", "pack_int4", "unpack_int4"]
