"""Pure-jnp oracles for every Pallas kernel in this package.

These re-express each kernel with stock jax.lax/jnp ops (no Pallas) and are
the ground truth for the allclose sweeps in tests/test_kernels_*.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import requant_clip


def depthwise_conv_q_ref(x_q, w_q, mult, zcorr, bias_q, *, kernel=3, stride=1,
                         qmax=15, clip=True):
    """Oracle for kernels.depthwise_conv.depthwise_conv_q."""
    acc = jax.lax.conv_general_dilated(
        x_q.astype(jnp.int32),
        w_q.reshape(kernel, kernel, 1, -1).astype(jnp.int32),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x_q.shape[-1],
        preferred_element_type=jnp.int32,
    )
    return requant_clip(acc, mult, zcorr, bias_q, qmax, clip)


def fused_irb_q_ref(
    x_q,
    w1_q, mult1, zcorr1, bias1,
    w2_q, mult2, zcorr2, bias2,
    w3_q, mult3, zcorr3, bias3,
    *,
    kernel=3,
    stride=1,
    qmax=15,
    residual=False,
    res_scale=None,  # (a_mult, a_off, b_mult, b_off, qmax) for the skip add
):
    """Oracle for kernels.fused_irb.fused_irb_q: pw-expand -> dw -> pw-project."""
    # stage 1: pointwise expansion (ReLU6 fused)
    acc1 = jnp.einsum(
        "bhwc,ce->bhwe", x_q.astype(jnp.int32), w1_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    e = requant_clip(acc1, mult1, zcorr1, bias1, qmax, clip=True)
    # stage 2: depthwise (ReLU6 fused)
    d = depthwise_conv_q_ref(
        e, w2_q, mult2, zcorr2, bias2, kernel=kernel, stride=stride, qmax=qmax,
        clip=True,
    )
    # stage 3: pointwise projection (linear -> asymmetric output quant)
    acc3 = jnp.einsum(
        "bhwe,eo->bhwo", d.astype(jnp.int32), w3_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    y = requant_clip(acc3, mult3, zcorr3, bias3, qmax, clip=True)
    if residual:
        a_mult, a_off, b_mult, b_off = res_scale
        a = x_q.astype(jnp.float32) * a_mult + a_off
        bq = y.astype(jnp.float32) * b_mult + b_off
        y = jnp.clip(jnp.round(a + bq), 0, qmax).astype(jnp.int32)
    return y


def quant_matmul_ref(x, w_q, w_scale, *, bits=8, group_size=None):
    """Oracle for kernels.quant_matmul.quant_matmul.

    x: [M, K] float; w_q int8 [K, N] (already unpacked); w_scale [N] or
    [K//group_size, N] for grouped quantization. y = x @ (w_q * scale).
    """
    if group_size is None:
        w = w_q.astype(jnp.float32) * w_scale[None, :]
    else:
        k, n = w_q.shape
        w = (
            w_q.astype(jnp.float32).reshape(k // group_size, group_size, n)
            * w_scale[:, None, :]
        ).reshape(k, n)
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)


def decode_attention_ref(q, k_cache, v_cache, kv_len, k_scale=None,
                         v_scale=None):
    """Oracle for kernels.decode_attention: grouped online-softmax decode.

    q [B,KV,rep,dh]; caches [B,S,KV,dh] (int8 with [B,S,KV] scales or bf16).
    """
    b, kv, rep, dh = q.shape
    s = k_cache.shape[1]
    if k_cache.dtype == jnp.int8:
        k = k_cache.astype(jnp.float32) * k_scale.astype(jnp.float32)[..., None]
        v = v_cache.astype(jnp.float32) * v_scale.astype(jnp.float32)[..., None]
    else:
        k, v = k_cache.astype(jnp.float32), v_cache.astype(jnp.float32)
    scores = jnp.einsum("bgrd,bsgd->bgrs", q.astype(jnp.float32), k) * dh**-0.5
    mask = jnp.arange(s)[None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bgrs,bsgd->bgrd", w, v).astype(q.dtype)


__all__ = ["depthwise_conv_q_ref", "fused_irb_q_ref", "quant_matmul_ref",
           "decode_attention_ref"]
