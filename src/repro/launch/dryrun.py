import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may touch jax (device count is now locked to 512) ---
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from functools import partial  # noqa: E402
from typing import Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, get_config, reduced_config  # noqa: E402
from repro.dist.sharding import named_sharding, tree_shardings, use_mesh  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.plans import plan_for  # noqa: E402
from repro.models.lm import model as M  # noqa: E402
from repro.models.lm.config import SHAPES, LMConfig, ShapeSpec  # noqa: E402
from repro.train import optimizer as O  # noqa: E402
from repro.train.train_loop import make_train_step  # noqa: E402

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell:
  1. builds the full published config (ShapeDtypeStruct only — no alloc),
  2. derives parameter/optimizer/cache shardings from the logical-axis tree,
  3. jits the train/prefill/decode step with in/out shardings,
  4. `.lower().compile()` — success proves the distribution config is
     coherent (sharding propagation, collective legality, memory layout),
  5. records memory_analysis / cost_analysis / collective-bytes into
     experiments/dryrun/*.json for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

SKIP = "SKIP"


def shape_by_name(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_status(cfg: LMConfig, shape: ShapeSpec) -> str:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return SKIP  # quadratic full attention at 512k context — excluded
    return "run"


def input_specs(cfg: LMConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    if shape.mode == "train":
        batch = {"tokens": sds((b, s), i32)}
        if cfg.family == "vlm":
            batch["embeds"] = sds((b, cfg.frontend_len, cfg.d_model), f)
        if cfg.family in ("encdec", "audio"):
            batch["enc_inputs"] = sds((b, cfg.frontend_len, cfg.d_model), f)
        return batch
    if shape.mode == "prefill":
        batch = {"tokens": sds((b, s), i32)}
        if cfg.family == "vlm":
            batch["embeds"] = sds((b, cfg.frontend_len, cfg.d_model), f)
        if cfg.family in ("encdec", "audio"):
            batch["enc_inputs"] = sds((b, cfg.frontend_len, cfg.d_model), f)
        return batch
    # decode: one new token against a seq_len-deep cache
    batch = {"token": sds((b, 1), i32), "pos": sds((), i32)}
    return batch


def fitted(mesh, axes, leaf):
    from repro.dist.sharding import _fit_spec_to_shape, logical_to_spec
    from jax.sharding import NamedSharding
    spec = _fit_spec_to_shape(logical_to_spec(axes, mesh), leaf.shape, mesh)
    return NamedSharding(mesh, spec)


def batch_shardings(batch, mesh):
    def spec(name, leaf):
        if name == "pos":
            return named_sharding(mesh, ())
        return fitted(mesh, ("batch",) + (None,) * (leaf.ndim - 1), leaf)

    return {k: spec(k, v) for k, v in batch.items()}


def cache_shardings(cache_shapes, mesh):
    """Path-keyed shardings for KV/recurrent caches (shape-fitted)."""
    from repro.dist.sharding import _fit_spec_to_shape, logical_to_spec
    from jax.sharding import NamedSharding

    def mk(axes, leaf):
        spec = _fit_spec_to_shape(
            logical_to_spec(axes, mesh), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    def one(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = leaf.ndim
        if key == "pos":
            return mk((None,) * nd, leaf)
        if key in ("k", "v"):
            # [(L,)? B, S, KV, hd]
            lead = (None,) * (nd - 4)
            return mk((*lead, "batch", None, "heads", None), leaf)
        if key in ("k_scale", "v_scale"):
            lead = (None,) * (nd - 3)
            return mk((*lead, "batch", None, "heads"), leaf)
        if key == "ssd":
            lead = (None,) * (nd - 4)
            return mk((*lead, "batch", "heads", None, None), leaf)
        if key == "conv":
            lead = (None,) * (nd - 3)
            return mk((*lead, "batch", None, "ffn"), leaf)
        if key == "h":
            lead = (None,) * (nd - 2)
            return mk((*lead, "batch", "ffn"), leaf)
        return mk((None,) * nd, leaf)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def _opt_state_shardings(param_sh, m_shapes, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    def _is_q(x):
        return isinstance(x, dict) and set(x) in (
            {"q", "scale"}, {"q", "scale", "zero"})

    def one(p_sh, m_leaf):
        if _is_q(m_leaf):  # int8 state: q shares param spec, scale per-row
            spec = p_sh.spec
            first = spec[0] if len(spec) else None
            nd = m_leaf["scale"].ndim
            scale_spec = P(first, *([None] * (nd - 1))) if nd else P()
            out = {"q": p_sh, "scale": NamedSharding(mesh, scale_spec)}
            if "zero" in m_leaf:
                out["zero"] = NamedSharding(mesh, scale_spec)
            return out
        return p_sh

    # m_shapes mirrors params 1:1 once int8 dicts are treated as leaves
    flat_p, pdef = jax.tree.flatten(param_sh)
    flat_m = jax.tree.flatten(m_shapes, is_leaf=_is_q)[0]
    return pdef.unflatten([one(p, m) for p, m in zip(flat_p, flat_m)])


def build_param_machinery(cfg: LMConfig, arch: str, mesh, fsdp: bool):
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(lambda k: M.init_params(cfg, k)[0], key)
    # logical tree from a structure-preserving reduced config (tiny, real
    # init) — must carry every flag that changes the PARAM TREE STRUCTURE
    rcfg = dataclasses.replace(
        reduced_config(arch), quant_bits=cfg.quant_bits, remat=cfg.remat,
        rglru_diagonal_gates=cfg.rglru_diagonal_gates)
    _, logical = M.init_params(rcfg, key)
    param_sh = tree_shardings(logical, mesh, fsdp=fsdp, shapes=param_shapes)
    return param_shapes, param_sh, logical


def build_cfg(arch: str, shape: ShapeSpec, plan, *, scan_unroll: bool,
              depth: Optional[int] = None) -> LMConfig:
    is_train = shape.mode == "train"
    cfg = get_config(
        arch,
        remat=plan.remat if is_train else "none",
        quant_bits=None if is_train else plan.quant_bits,
        kv_bits=None if is_train else plan.kv_bits,
        rglru_diagonal_gates=plan.rglru_diagonal_gates,
        rglru_chunk=plan.rglru_chunk,
        scan_unroll=scan_unroll,
    )
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=plan.capacity_factor)
    if plan.ssm_chunk and cfg.family == "ssm":
        cfg = dataclasses.replace(cfg, ssm_chunk=plan.ssm_chunk)
    if depth is not None:
        if cfg.family in ("encdec", "audio"):
            cfg = dataclasses.replace(
                cfg, n_layers=2 * depth, n_enc_layers=depth,
                n_dec_layers=depth)
        else:
            cfg = dataclasses.replace(cfg, n_layers=depth)
    return cfg


def depth_points(cfg: LMConfig):
    """(L1, L2, n_super_full): depths with 1 and 2 super-blocks (+ tail),
    and the full super-block count, for the two-point extrapolation
    (per-layer HLO cost is exactly linear in the super-block count)."""
    if cfg.family in ("encdec", "audio"):
        return 1, 2, cfg.n_enc_layers
    kinds = M.layer_kinds(cfg)
    pat, n_super, tail = M._kind_groups(kinds)
    p, t = len(pat), len(tail)
    return p + t, 2 * p + t, n_super


def lower_cell(arch: str, shape: ShapeSpec, *, multi_pod: bool,
               plan_overrides=None, scan_unroll: bool = True,
               depth: Optional[int] = None):
    plan = plan_for(arch, **(plan_overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cfg = build_cfg(arch, shape, plan, scan_unroll=scan_unroll, depth=depth)
    status = cell_status(cfg, shape)
    if status == SKIP:
        return {"status": "skipped",
                "reason": "quadratic attention at 512k context"}

    param_shapes, param_sh, logical = build_param_machinery(
        cfg, arch, mesh, plan.fsdp)
    batch = input_specs(cfg, shape)
    batch_sh = batch_shardings(batch, mesh)

    accum_mult = 1
    with use_mesh(mesh, fsdp=plan.fsdp):
        if shape.mode == "train":
            opt_cfg = O.AdamWConfig(state_bits=plan.opt_bits)
            opt_shapes = jax.eval_shape(
                partial(O.init_state, state_bits=plan.opt_bits), param_shapes)
            # AdamW m/v inherit the param shardings (TP [+FSDP] — ZeRO-style);
            # int8 state leaves are {"q","scale"}: q shares the param spec,
            # the per-row scale keeps only the first-axis sharding.
            m_sh = _opt_state_shardings(param_sh, opt_shapes.m, mesh)
            v_sh = _opt_state_shardings(param_sh, opt_shapes.v, mesh)
            opt_sh = O.AdamWState(named_sharding(mesh, ()), m_sh, v_sh)
            # Lower ONE microbatch and scale the roofline terms by grad_accum
            # analytically (unrolling the accumulation loop would multiply
            # HLO size for zero extra information; memory_analysis of the
            # microbatch step is the per-step peak that matters).
            accum_mult = plan.grad_accum
            if plan.grad_accum > 1:
                mb = {k: jax.ShapeDtypeStruct(
                    (v.shape[0] // plan.grad_accum, *v.shape[1:]), v.dtype)
                    for k, v in batch.items()}
                batch = mb
                batch_sh = batch_shardings(batch, mesh)
            step_fn = make_train_step(
                cfg, opt_cfg, grad_accum=1,
                accum_dtype=jnp.dtype(plan.accum_dtype))
            fn = jax.jit(
                step_fn,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(param_shapes, opt_shapes, batch)
        elif shape.mode == "prefill":
            max_len = shape.seq_len + (
                cfg.frontend_len if cfg.family == "vlm" else 0)

            def prefill_fn(params, batch):
                return M.prefill(
                    params, cfg, batch["tokens"], max_len=max_len,
                    embeds=batch.get("embeds"),
                    enc_inputs=batch.get("enc_inputs"))

            cache_shapes = jax.eval_shape(
                lambda: M.init_cache(cfg, shape.global_batch, max_len,
                                     enc_len=cfg.frontend_len))
            cache_sh = cache_shardings(cache_shapes, mesh)
            logits_shape = jax.ShapeDtypeStruct(
                (shape.global_batch, 1, M.padded_vocab(cfg)), jnp.bfloat16)
            logits_sh = fitted(mesh, ("batch", None, "vocab"), logits_shape)
            fn = jax.jit(
                prefill_fn,
                in_shardings=(param_sh, batch_sh),
                out_shardings=(logits_sh, cache_sh),
            )
            lowered = fn.lower(param_shapes, batch)
        else:  # decode
            max_len = shape.seq_len
            cache_shapes = jax.eval_shape(
                lambda: M.init_cache(cfg, shape.global_batch, max_len,
                                     enc_len=cfg.frontend_len))
            cache_sh = cache_shardings(cache_shapes, mesh)
            logits_shape = jax.ShapeDtypeStruct(
                (shape.global_batch, 1, M.padded_vocab(cfg)), jnp.bfloat16)
            logits_sh = fitted(mesh, ("batch", None, "vocab"), logits_shape)

            def decode_fn(params, token, caches, pos):
                return M.decode_step(params, cfg, token, caches, pos)

            fn = jax.jit(
                decode_fn,
                in_shardings=(param_sh, batch_sh["token"], cache_sh,
                              batch_sh["pos"]),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(2,),
            )
            lowered = fn.lower(
                param_shapes, batch["token"], cache_shapes, batch["pos"])

    return {"status": "lowered", "lowered": lowered, "cfg": cfg,
            "n_dev": n_dev, "plan": dataclasses.asdict(plan),
            "accum_mult": accum_mult}


def _extrapolate(r1, r2, n_super: int) -> "RL.Roofline":
    """full = r(L1) + (n_super - 1) * (r(L2) - r(L1)); exact because per-
    super-block HLO cost is linear in the super-block count."""
    k = n_super - 1
    detail = {
        key: int(r1.coll_detail.get(key, 0)
                 + k * (r2.coll_detail.get(key, 0) - r1.coll_detail.get(key, 0)))
        for key in set(r1.coll_detail) | set(r2.coll_detail)
    }
    return RL.Roofline(
        flops=r1.flops + k * (r2.flops - r1.flops),
        hbm_bytes=r1.hbm_bytes + k * (r2.hbm_bytes - r1.hbm_bytes),
        coll_bytes=r1.coll_bytes + k * (r2.coll_bytes - r1.coll_bytes),
        coll_detail=detail,
        n_devices=r1.n_devices,
    )


def run_cell(arch: str, shape: ShapeSpec, *, multi_pod: bool, out_dir: str,
             plan_overrides=None, tag: str = "", method: str = "twopoint"):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape.name}__{mesh_name}" + (f"__{tag}" if tag else "")
    t0 = time.time()
    try:
        if method == "twopoint":
            # cost terms: two shallow UNROLLED lowerings, extrapolated
            # exactly in depth; memory + compile-proof: the FULL config,
            # production (scanned) lowering.
            plan = plan_for(arch, **(plan_overrides or {}))
            cfg_probe = build_cfg(arch, shape, plan, scan_unroll=False)
            if cell_status(cfg_probe, shape) == SKIP:
                res = {"status": "skipped",
                       "reason": "quadratic attention at 512k context"}
            else:
                l1, l2, n_super = depth_points(cfg_probe)
                rs = []
                for d in (l1, l2):
                    rv = lower_cell(arch, shape, multi_pod=multi_pod,
                                    plan_overrides=plan_overrides,
                                    scan_unroll=True, depth=d)
                    rs.append(RL.from_compiled(rv["lowered"].compile(),
                                               rv["n_dev"]))
                res = lower_cell(arch, shape, multi_pod=multi_pod,
                                 plan_overrides=plan_overrides,
                                 scan_unroll=False)
                res["roofline_obj"] = _extrapolate(rs[0], rs[1], n_super)
        else:  # method == "unroll": single fully-unrolled lowering
            res = lower_cell(arch, shape, multi_pod=multi_pod,
                             plan_overrides=plan_overrides, scan_unroll=True)
        if res["status"] == "skipped":
            report = {"cell": cell_id, "status": "skipped",
                      "reason": res["reason"]}
        else:
            lowered = res.pop("lowered")
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            rl = res.pop("roofline_obj", None)
            if rl is None:
                rl = RL.from_compiled(compiled, res["n_dev"])
            am = res.get("accum_mult", 1)
            if am > 1:  # one lowered microbatch -> full accumulation step
                rl = RL.Roofline(rl.flops * am, rl.hbm_bytes * am,
                                 rl.coll_bytes * am, rl.coll_detail,
                                 rl.n_devices)
            cfg = res.pop("cfg")
            mf = RL.model_flops(cfg, shape, cfg.active_param_count())
            mf_per_dev = mf / res["n_dev"]
            report = {
                "cell": cell_id,
                "status": "ok",
                "arch": arch,
                "shape": shape.name,
                "mesh": mesh_name,
                "plan": res["plan"],
                "t_lower_s": round(t_lower, 1),
                "t_compile_s": round(t_compile, 1),
                "memory": {
                    "argument_bytes": mem.argument_size_in_bytes,
                    "output_bytes": mem.output_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes,
                    "peak_bytes": getattr(mem, "peak_memory_in_bytes",
                                          mem.temp_size_in_bytes),
                },
                "roofline": rl.summary(),
                "model_flops_per_device": mf_per_dev,
                "useful_flops_ratio": (
                    mf_per_dev / rl.flops if rl.flops else None),
                "params": cfg.param_count(),
                "active_params": cfg.active_param_count(),
            }
    except Exception as e:  # noqa: BLE001 — dry-run must report, not die
        report = {"cell": cell_id, "status": "error",
                  "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(report, f, indent=1)
    status = report["status"]
    extra = ""
    if status == "ok":
        r = report["roofline"]
        extra = (f" bottleneck={r['bottleneck']}"
                 f" t=({r['t_compute_s']:.2e},{r['t_memory_s']:.2e},"
                 f"{r['t_collective_s']:.2e})s"
                 f" compile={report['t_compile_s']}s")
    elif status == "error":
        extra = " " + report["error"][:160]
    print(f"[dryrun] {cell_id}: {status}{extra}", flush=True)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES], default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--method", choices=("twopoint", "unroll"),
                    default="twopoint")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [shape_by_name(args.shape)] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                path = os.path.join(
                    args.out, f"{arch}__{shape.name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            print(f"[dryrun] skip existing {path}", flush=True)
                            continue
                run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                         method=args.method)


if __name__ == "__main__":
    main()
