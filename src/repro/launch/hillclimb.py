import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

from repro.launch.dryrun import run_cell, shape_by_name  # noqa: E402

"""§Perf hillclimb driver: re-lower one cell with plan overrides and a tag.

    python -m repro.launch.hillclimb --arch qwen3-32b --shape decode_32k \
        --tag kv8 --set kv_bits=8
    python -m repro.launch.hillclimb --arch recurrentgemma-2b \
        --shape prefill_32k --tag diag --set rglru_diagonal_gates=true

Results land in experiments/perf/<cell>__<tag>.json next to the baselines in
experiments/dryrun/, so before/after deltas are directly comparable.

The driver also fronts the mixed-precision search (the act-bit analogue of
a plan-override hillclimb — candidates are per-block bit allocations and
the objective surface is the tuned-cache latency table). `--precision`
forwards every remaining flag to `python -m repro.tune --precision`:

    python -m repro.launch.hillclimb --precision --hw 32 --num-classes 10
    python -m repro.launch.hillclimb --precision --fake --out /tmp/p.json
"""


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v.lower() in ("true", "false"):
        return k, v.lower() == "true"
    if v.lower() in ("none", "null"):
        return k, None
    try:
        return k, int(v)
    except ValueError:
        try:
            return k, float(v)
        except ValueError:
            return k, v


def main():
    if "--precision" in sys.argv[1:]:
        from repro.tune.__main__ import main as tune_main
        tune_main(sys.argv[1:])
        return
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="plan override key=value (repeatable)")
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--baseline-dir", default="experiments/dryrun")
    args = ap.parse_args()

    overrides = dict(parse_override(kv) for kv in args.set)
    shape = shape_by_name(args.shape)
    report = run_cell(args.arch, shape, multi_pod=args.multi_pod,
                      out_dir=args.out, plan_overrides=overrides,
                      tag=args.tag)
    # delta vs baseline
    mesh = "2x16x16" if args.multi_pod else "16x16"
    base_path = os.path.join(args.baseline_dir,
                             f"{args.arch}__{args.shape}__{mesh}.json")
    if report.get("status") == "ok" and os.path.exists(base_path):
        with open(base_path) as f:
            base = json.load(f)
        if base.get("status") == "ok":
            b, n = base["roofline"], report["roofline"]
            for term in ("t_compute_s", "t_memory_s", "t_collective_s"):
                delta = (n[term] - b[term]) / b[term] * 100 if b[term] else 0
                print(f"  {term}: {b[term]:.3e} -> {n[term]:.3e} "
                      f"({delta:+.1f}%)")
            bt = max(b["t_compute_s"], b["t_memory_s"], b["t_collective_s"])
            nt = max(n["t_compute_s"], n["t_memory_s"], n["t_collective_s"])
            print(f"  bound: {bt:.3e} ({b['bottleneck']}) -> "
                  f"{nt:.3e} ({n['bottleneck']})  [{(nt-bt)/bt*100:+.1f}%]")


if __name__ == "__main__":
    main()
