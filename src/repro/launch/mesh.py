"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod : (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
data parallelism across pods (DCN) — only gradient all-reduces cross it.

Defined as a function so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types where this jax supports them.

    `jax.sharding.AxisType` (and the `axis_types=` kwarg) only exist in
    newer jax; older versions treat every axis as Auto already, so the
    plain call is equivalent there."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over however many devices exist (tests / examples)."""
    n = len(jax.devices())
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))


__all__ = ["make_mesh", "make_production_mesh", "make_host_mesh"]
