"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod : (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
data parallelism across pods (DCN) — only gradient all-reduces cross it.

Defined as a function so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over however many devices exist (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


__all__ = ["make_production_mesh", "make_host_mesh"]
