"""Per-architecture runtime plans (the tunable knobs the perf loop iterates).

A plan sets, per (arch [, shape]): FSDP on/off, remat policy, gradient
accumulation, serve-time weight quantization. Baselines are chosen by napkin
math to FIT (see EXPERIMENTS.md §Dry-run); §Perf iterations override these
via `apply_overrides`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class RunPlan:
    fsdp: bool = False
    remat: str = "none"  # none | dots | full
    grad_accum: int = 1
    quant_bits: Optional[int] = None  # serve-time weight quantization
    capacity_factor: float = 1.25
    # §Perf levers (default off == baseline)
    kv_bits: Optional[int] = None
    rglru_diagonal_gates: bool = False
    rglru_chunk: int = 0
    opt_bits: Optional[int] = None  # int8 AdamW m/v (8-bit-Adam style)
    accum_dtype: str = "float32"  # grad-accumulation buffer dtype
    ssm_chunk: int = 0  # override Mamba-2 SSD chunk length (0 = config default)


# Baseline plans. Napkin math (bf16 params + f32 AdamW m/v, 16 GB/chip HBM):
#   params_bytes/chip = 2N / shards;  opt = 8N / shards (fsdp shards both).
# Anything over ~2B params wants FSDP; >100B also wants grad_accum to bound
# activation+MoE-buffer memory; all train shapes use remat to cut scan
# residuals.
PLANS: Dict[str, RunPlan] = {
    "recurrentgemma-2b": RunPlan(fsdp=False, remat="full", grad_accum=4),
    "arctic-480b": RunPlan(fsdp=True, remat="full", grad_accum=8,
                           capacity_factor=1.0, opt_bits=8,
                           accum_dtype="bfloat16"),
    # §Perf cell C: capacity 1.0 + ga4 (C3) — -43% compute, fits v5p
    "qwen2-moe-a2.7b": RunPlan(fsdp=True, remat="full", grad_accum=4,
                               capacity_factor=1.0),
    "qwen3-32b": RunPlan(fsdp=True, remat="full", grad_accum=8),
    "llama3.2-1b": RunPlan(fsdp=False, remat="full", grad_accum=2),
    "granite-3-2b": RunPlan(fsdp=False, remat="full", grad_accum=4),
    "codeqwen1.5-7b": RunPlan(fsdp=True, remat="full", grad_accum=4),
    "phi-3-vision-4.2b": RunPlan(fsdp=True, remat="full", grad_accum=4),
    "seamless-m4t-large-v2": RunPlan(fsdp=False, remat="full", grad_accum=2),
    "mamba2-1.3b": RunPlan(fsdp=False, remat="full", grad_accum=4),
}


def plan_for(arch: str, **overrides) -> RunPlan:
    base = PLANS.get(arch, RunPlan())
    return dataclasses.replace(base, **overrides) if overrides else base


__all__ = ["RunPlan", "PLANS", "plan_for"]
