"""Roofline-term extraction from compiled dry-run artifacts.

    compute    = HLO_FLOPs(per device)       / peak_FLOP/s(chip)
    memory     = HLO_bytes(per device)       / HBM_bw(chip)
    collective = collective_operand_bytes    / link_bw(chip)

FLOPs/bytes come from `compiled.cost_analysis()` of the SPMD-partitioned
(= per-device) module. Collective bytes are NOT in cost_analysis: we parse
the optimized HLO text, build a symbol table of instruction result shapes,
and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+(\S+)\((.*)$")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    """'f32[16,128]{1,0}' or '(f32[2], bf16[4,4])' -> total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind over the whole module."""
    # pass 1: symbol table  name -> result shape string
    shapes: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    out = {k: 0 for k in _COLLECTIVES}
    out["n_ops"] = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_shape, opcode, rest = m.groups()
        kind = next((c for c in _COLLECTIVES if opcode.startswith(c)), None)
        if kind is None:
            continue
        # operands: %ref names inside the call parens (stop at metadata)
        args = rest.split(")", 1)[0]
        operand_names = re.findall(r"%([\w\.\-]+)", args)
        b = sum(shape_bytes(shapes.get(n, "")) for n in operand_names)
        if b == 0:  # fallback: result size (e.g. start/done pairs)
            b = shape_bytes(result_shape)
        out[kind] += b
        out["n_ops"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # per device
    hbm_bytes: float  # per device
    coll_bytes: float  # per device (operand bytes)
    coll_detail: Dict[str, int]
    n_devices: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def summary(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "collectives": self.coll_detail,
        }


def from_compiled(compiled, n_devices: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    total_coll = sum(v for k, v in coll.items() if k != "n_ops")
    return Roofline(flops, hbm, total_coll, coll, n_devices)


def model_flops(cfg, shape, n_active_params: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference) per the assignment."""
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active_params * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active_params * tokens
    # decode: one token per sequence
    return 2.0 * n_active_params * shape.global_batch


__all__ = [
    "Roofline", "from_compiled", "collective_bytes", "shape_bytes",
    "model_flops", "PEAK_FLOPS", "HBM_BW", "LINK_BW",
]
