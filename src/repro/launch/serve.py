"""Serving driver: batched requests through the Engine.

LM serving:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --requests 8 [--quant-bits 8]

Vision serving (sharded multi-replica, multi-model):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --vision --replicas 4 \
        --models mobilenet_v2,efficientnet_compact --requests 32

Serve-time weight quantization (--quant-bits) applies the paper's range-based
symmetric per-channel scheme to every linear operator — the LM analogue of
QNet deployment. --vision instead serves calibrated integer QNets through
the pipelined CU stage executors: --replicas builds a 1-D 'data' mesh and
shards every micro-batch across it; more than one --models entry routes
requests through the EDF `MultiModelEngine`. --tuned-cache serves through
a committed per-op route selection (see `repro.tune`); --tune measures one
live first. --trace-out exports the request-lifecycle Chrome trace
(Perfetto-loadable), --metrics-out the metrics registry (Prometheus text
for .prom/.txt, JSON snapshot otherwise); `python -m repro.obs summarize`
renders either into a pipeline-profile report.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, reduced_config
from repro.models.lm import model as M
from repro.serve.engine import Engine, Request

VISION_ARCHS = ("mobilenet_v2", "efficientnet_compact")


def _vision_qnet(arch: str, hw: int, seed: int = 0):
    from repro.models import efficientnet as effn, layers, mobilenet_v2 as mnv2

    if arch == "mobilenet_v2":
        net = mnv2.build(alpha=0.35, input_hw=hw, num_classes=1000)
    elif arch == "efficientnet_compact":
        net = effn.build_compact(input_hw=hw, num_classes=1000)
    else:
        raise ValueError(f"unknown vision arch {arch!r} (pick from {VISION_ARCHS})")
    return layers.make_calibrated_qnet(net, seed=seed)


def _vision_tuned(args, qnets):
    """Resolve the serving route selection: tune live (--tune), or load a
    committed cache (--tuned-cache). Returns a TunedPlan or None."""
    if args.tune:
        import functools

        from repro.tune import save_tuned, tune_qnet

        plans = [tune_qnet(q, batch=args.batch) for q in qnets.values()]
        tuned = functools.reduce(lambda a, b: a.merge(b), plans)
        if args.tuned_cache:
            save_tuned(tuned, args.tuned_cache)
            print(f"[serve-vision] tuned {len(tuned)} entries "
                  f"-> {args.tuned_cache}")
        return tuned
    if args.tuned_cache:
        from repro.tune import load_tuned

        tuned = load_tuned(args.tuned_cache)
        print(f"[serve-vision] loaded tuning cache {args.tuned_cache} "
              f"({len(tuned)} entries)")
        return tuned
    return None


def vision_main(args) -> None:
    from repro.dist.sharding import data_mesh
    from repro.serve.vision import MultiModelEngine, VisionEngine

    tracer = metrics = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()  # one tracer across models = one timeline
    if args.metrics_out:
        from repro.obs import MetricsRegistry
        metrics = MetricsRegistry()
    mesh = data_mesh(args.replicas) if args.replicas > 1 else None
    # --batch bounds the largest micro-batch; the engine rounds buckets up
    # to replica multiples itself
    buckets = tuple(sorted(
        {b for b in (1, 2, 4) if b < args.batch} | {args.batch}))
    models = [m.strip() for m in args.models.split(",") if m.strip()]
    qnets = {m: _vision_qnet(m, args.hw, args.seed) for m in models}
    tuned = _vision_tuned(args, qnets)
    if tuned is not None:
        for m, q in qnets.items():
            print(f"[serve-vision] {m}: tuned route coverage "
                  f"{tuned.coverage(q):.0%}")
    engines = {
        m: VisionEngine(qnets[m], mesh=mesh, buckets=buckets, tuned=tuned,
                        tracer=tracer, metrics=metrics, name=m)
        for m in models
    }
    router = MultiModelEngine(engines, power_budget_w=args.power_budget_w)
    if args.power_budget_w:
        print(f"[serve-vision] power cap {args.power_budget_w:.1f} W "
              f"shared across {len(models)} model(s)")
    router.warmup()
    rng = np.random.default_rng(args.seed)
    now = time.perf_counter()
    for i in range(args.requests):
        img = rng.uniform(-1, 1, (args.hw, args.hw, 3)).astype(np.float32)
        deadline = now + 5.0 if i % 3 == 0 else None
        router.submit(models[i % len(models)], img, deadline_s=deadline)
    results = router.run()
    n_ok = sum(1 for r in results.values() if r.status == "ok")
    print(f"[serve-vision] {n_ok}/{len(results)} ok over "
          f"{len(models)} model(s), {args.replicas} replica(s)")
    for m, st in sorted(router.stats().items()):
        print(f"[serve-vision] {m}: fps={st.fps:.1f} "
              f"p95={st.latency_p95_s*1e3:.1f}ms "
              f"micro_batches={st.micro_batches} replicas={st.replicas}")
        print(f"[serve-vision] {m}: "
              f"{st.energy_j_per_image*1e6:.1f} uJ/image "
              f"({st.power_source}) -> {st.watts:.1f} W, "
              f"{st.fps_per_watt:.1f} fps/W"
              + (f", shed={st.n_shed} deferred={st.n_deferred}"
                 if args.power_budget_w else ""))
    if tracer is not None:
        print(f"[serve-vision] trace -> {tracer.save(args.trace_out)} "
              f"({len(tracer)} events; load in https://ui.perfetto.dev)")
    if metrics is not None:
        print(f"[serve-vision] metrics -> {metrics.save(args.metrics_out)}")
    if tracer is not None or metrics is not None:
        from repro.obs import render_report, summarize_trace
        print(render_report(
            summarize_trace(tracer.to_chrome()) if tracer else None,
            metrics.snapshot() if metrics else None))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--vision", action="store_true",
                    help="serve integer vision QNets instead of an LM")
    ap.add_argument("--models", default="mobilenet_v2",
                    help="comma-separated vision model list "
                         f"(from {', '.join(VISION_ARCHS)})")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel replicas (vision; needs devices)")
    ap.add_argument("--hw", type=int, default=48, help="vision input H=W")
    ap.add_argument("--batch", type=int, default=8,
                    help="largest vision micro-batch bucket")
    ap.add_argument("--tune", action="store_true",
                    help="autotune per-op routes for each vision model "
                         "before serving (saved to --tuned-cache if given)")
    ap.add_argument("--power-budget-w", type=float, default=None,
                    help="shared modeled-power cap in watts for vision "
                         "serving: one rolling-window governor across all "
                         "models defers/sheds work to stay under the cap "
                         "(docs/energy.md)")
    ap.add_argument("--tuned-cache", default=None,
                    help="tuning-cache JSON to load (or write, with "
                         "--tune) for vision serving")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace of the vision serving run "
                         "(Perfetto-loadable request-lifecycle timeline)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the vision metrics registry (.prom/.txt = "
                         "Prometheus text, else JSON snapshot)")
    ap.add_argument("--arch", choices=sorted(ARCHS), default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--quant-bits", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.vision:
        return vision_main(args)

    import dataclasses
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.quant_bits:
        cfg = dataclasses.replace(cfg, quant_bits=args.quant_bits)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, batch_slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
            temperature=0.0 if i % 2 == 0 else 0.8,
        ))
    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    for rid in sorted(done):
        print(f"[serve] req {rid}: {done[rid][:8]}... ({len(done[rid])} tokens)")
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)", flush=True)
    return done


if __name__ == "__main__":
    main()
