"""Serving driver: batched requests through the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --requests 8 [--quant-bits 8]

Serve-time weight quantization (--quant-bits) applies the paper's range-based
symmetric per-channel scheme to every linear operator — the LM analogue of
QNet deployment.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, reduced_config
from repro.models.lm import model as M
from repro.serve.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--quant-bits", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import dataclasses
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.quant_bits:
        cfg = dataclasses.replace(cfg, quant_bits=args.quant_bits)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, batch_slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new=args.max_new,
            temperature=0.0 if i % 2 == 0 else 0.8,
        ))
    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    for rid in sorted(done):
        print(f"[serve] req {rid}: {done[rid][:8]}... ({len(done[rid])} tokens)")
    print(f"[serve] {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)", flush=True)
    return done


if __name__ == "__main__":
    main()
