"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
        --steps 100 --ckpt-dir /tmp/ckpt [--grad-compress] [--resume]

Production posture demonstrated on whatever devices exist (the full meshes
are exercised by the dry-run):
  * sharded params/optimizer via the same logical-axis machinery as dryrun,
  * deterministic data pipeline with restart skip (no repeated batches),
  * periodic async checkpoints + rotation, SIGTERM drain (preemption),
  * optional int8 gradient compression with error feedback,
  * bitwise-deterministic restart (see tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced_config
from repro.data.pipeline import DataConfig, lm_batch
from repro.dist.sharding import tree_shardings, use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models.lm import model as M
from repro.train import checkpoint as CKPT
from repro.train import grad_compress as GC
from repro.train import optimizer as O
from repro.train.straggler import StepWatchdog
from repro.train.train_loop import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh()
    data_cfg = DataConfig(seed=args.seed, vocab=cfg.vocab,
                          seq_len=args.seq, global_batch=args.batch)
    opt_cfg = O.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                            total_steps=args.steps)

    with use_mesh(mesh):
        params, logical = M.init_params(cfg, jax.random.PRNGKey(args.seed))
        param_sh = tree_shardings(logical, mesh)
        params = jax.tree.map(jax.device_put, params, param_sh)
        opt_state = O.init_state(params)
        err_state = GC.init_error(params) if args.grad_compress else None
        start_step = 0

        if args.resume and args.ckpt_dir:
            try:
                (params, opt_state), start_step = CKPT.restore(
                    args.ckpt_dir, (params, opt_state),
                    shardings=(param_sh, jax.tree.map(lambda _: None, opt_state))
                    if False else None)
                print(f"[train] resumed from step {start_step}", flush=True)
            except FileNotFoundError:
                print("[train] no checkpoint found; cold start", flush=True)

        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, grad_accum=args.grad_accum,
                            compress=args.grad_compress),
            donate_argnums=(0, 1),
        )

        stop = {"now": False}
        ckpt_req = {"now": False}

        def _sigterm(signum, frame):  # preemption drain
            print("[train] SIGTERM: checkpoint + exit", flush=True)
            stop["now"] = True

        signal.signal(signal.SIGTERM, _sigterm)

        def _on_straggler(step_no, dt, ema):
            print(f"[train] persistent straggler at step {step_no} "
                  f"({dt:.2f}s vs EMA {ema:.2f}s): checkpoint + advise "
                  f"evict/reshard", flush=True)
            ckpt_req["now"] = True

        watchdog = StepWatchdog(on_straggler=_on_straggler)

        pending = None
        t0 = time.time()
        losses = []
        step = start_step
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     lm_batch(data_cfg, step).items()}
            watchdog.start()
            if args.grad_compress:
                params, opt_state, err_state, metrics = step_fn(
                    params, opt_state, batch, err_state)
            else:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            watchdog.stop()
            if (step + 1) % args.log_every == 0:
                rate = (step + 1 - start_step) / (time.time() - t0)
                print(f"[train] step {step+1} loss={losses[-1]:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({rate:.2f} steps/s)", flush=True)
            want_ckpt = args.ckpt_dir and (
                (step + 1) % args.ckpt_every == 0 or stop["now"]
                or ckpt_req["now"] or step + 1 == args.steps)
            ckpt_req["now"] = False
            if want_ckpt:
                if pending is not None:
                    pending.join()
                pending = CKPT.save(
                    args.ckpt_dir, step + 1, (params, opt_state),
                    async_=True, extra={"loss": losses[-1]})
            if stop["now"]:
                break
        if pending is not None:
            pending.join()
        print(f"[train] done at step {step+1}; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}", flush=True)
        return losses


if __name__ == "__main__":
    main()
