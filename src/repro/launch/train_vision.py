"""Vision QAT training driver: train -> online-quantize -> export -> serve.

    PYTHONPATH=src python -m repro.launch.train_vision \
        --model mobilenet_v2 --hw 16 --classes 4 \
        --float-steps 40 --qat-steps 20 [--anneal-from 8] \
        --ckpt-dir /tmp/ckpt [--resume] \
        --export /tmp/mnv2.qnet [--tune]

The full paper Fig. 1 front end on whatever device exists: float
pre-training with BatchNorm, BN fusion, QAT with per-epoch online
quantization (held-out calibration through `core/calibrate`), periodic
async checkpoints with bitwise-deterministic restart, and a terminal export
that proves the frozen `.qnet` bit-exact through every serving route
(reference interpreter, prepared fast path, stage executors, tuned
`VisionEngine`) before writing it.

    PYTHONPATH=src python -m repro.launch.train_vision \
        --check-artifact /tmp/mnv2.qnet

re-opens a frozen artifact through the serve-side loader
(`VisionEngine.from_artifact`), prints its schema (build record,
provenance, op table), and re-proves route parity on a fresh batch — the
CI artifact gate. Exit status is non-zero on any parity failure.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.train import vision as V


def check_artifact(path: str, batch: int = 4, seed: int = 123) -> int:
    """Load `path` from disk alone and re-prove serving parity. Returns an
    exit code (0 = schema complete + every route bit-exact)."""
    from repro.core import cu
    from repro.core.qnet import load_qnet, read_qnet_meta

    meta = read_qnet_meta(path)
    missing = [k for k in ("net", "ops", "build") if k not in meta]
    if missing:
        print(f"[check-artifact] {path}: missing meta keys {missing}")
        return 1
    qnet = load_qnet(path)  # build record only — the serve-side route
    print(f"[check-artifact] {path}: net={meta['net']} "
          f"ops={len(meta['ops'])} build={meta['build']}")
    if "provenance" in meta:
        print(f"[check-artifact] provenance: "
              f"{json.dumps(meta['provenance'], sort_keys=True)}")
    hw = qnet.spec.input_hw
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, (batch, hw, hw, qnet.spec.input_ch)
                    ).astype(np.float32)
    try:
        report = V.verify_export(qnet, x)
    except V.ExportParityError as e:
        print(f"[check-artifact] PARITY FAILURE: {e}")
        return 1
    s, z = cu.input_qparams(qnet)
    print(f"[check-artifact] routes bit-exact: {report['routes']} "
          f"({report['stages']} stages, input S={s:.5f} z={z:.0f})")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=("mobilenet_v2", "efficientnet_compact"),
                    default="mobilenet_v2")
    ap.add_argument("--alpha", type=float, default=0.35)
    ap.add_argument("--hw", type=int, default=16, help="input H=W")
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--bits", type=int, default=4, help="weight BW")
    ap.add_argument("--act-bits", type=int, default=4,
                    help="deployment activation BW")
    ap.add_argument("--anneal-from", type=int, default=None,
                    help="start QAT at this activation BW (e.g. 8) and "
                         "anneal down to --act-bits halfway")
    ap.add_argument("--no-bn", action="store_true",
                    help="skip BatchNorm in the float phase")
    ap.add_argument("--float-steps", type=int, default=40)
    ap.add_argument("--qat-steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--qat-lr", type=float, default=5e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--calibrate-every", type=int, default=10,
                    help="QAT steps between online-quantization rounds")
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--stop-after", type=int, default=None,
                    help="checkpoint and exit after N global steps "
                         "(simulated preemption)")
    ap.add_argument("--export", default=None, metavar="PATH",
                    help="freeze the trained net to a .qnet artifact "
                         "(after proving every serving route bit-exact)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the export parity proof (NOT recommended)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune the exported net and prove the tuned "
                         "VisionEngine route too")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (overrides steps/batch)")
    ap.add_argument("--check-artifact", default=None, metavar="PATH",
                    help="no-train mode: load a frozen .qnet and re-prove "
                         "schema + route parity")
    args = ap.parse_args(argv)

    if args.check_artifact:
        return check_artifact(args.check_artifact)

    if args.stop_after is not None and not args.ckpt_dir:
        ap.error("--stop-after requires --ckpt-dir (nothing would be saved "
                 "to resume from)")

    if args.smoke:
        args.float_steps = min(args.float_steps, 6)
        args.qat_steps = min(args.qat_steps, 6)
        args.batch = min(args.batch, 16)
        args.calibrate_every = min(args.calibrate_every, 3)

    cfg = V.VisionTrainConfig(
        model=args.model, alpha=args.alpha, input_hw=args.hw,
        num_classes=args.classes, bits=args.bits, act_bits=args.act_bits,
        anneal_from=args.anneal_from, bn=not args.no_bn,
        float_steps=args.float_steps, qat_steps=args.qat_steps,
        batch=args.batch, grad_accum=args.grad_accum,
        lr=args.lr, qat_lr=args.qat_lr, seed=args.seed,
        calibrate_every=args.calibrate_every,
        calib_batches=args.calib_batches,
        ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
    )

    if args.export:
        result, qnet, report = V.train_and_export(
            cfg, ckpt_dir=args.ckpt_dir, resume=args.resume,
            stop_after=args.stop_after,
            path=args.export, verify=not args.no_verify,
            tune=args.tune, log=print)
    else:
        result = V.train(cfg, ckpt_dir=args.ckpt_dir, resume=args.resume,
                         stop_after=args.stop_after, log=print)
        qnet, report = None, {}
    losses = result.history["loss"]
    if losses:
        print(f"[train-vision] {result.step}/{cfg.total_steps} steps; "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}", flush=True)
    if not result.done:
        print("[train-vision] run preempted — resume with --resume")
        return 0
    if args.export:
        if report.get("observers_used"):
            print(f"[train-vision] exported with "
                  f"{report['online_quant_rounds']} online-quant round(s) "
                  f"of observer state")
        print(f"[train-vision] exported {args.export} "
              f"({report.get('artifact_bytes', 0)} bytes, "
              f"{qnet.model_bytes()} packed model bytes)")
        if report.get("verified"):
            print(f"[train-vision] serving routes proven bit-exact: "
                  f"{report['routes']}")
    return 0


if __name__ == "__main__":
    sys.exit(main() or 0)
