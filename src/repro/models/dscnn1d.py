"""1-D depthwise-separable CNN builders — the streaming sensor workloads.

Two families over [B, T, C] temporal tensors (DeepDive's DSCNN structure
transplanted onto the edge-sensor shapes the streaming engine serves):

  * ``dscnn_kws`` — keyword spotting over MFCC frames (Zhang et al.
    'Hello Edge' DS-CNN family): stem Conv1d stride 2, then a stack of
    identical DW1D->PW blocks at one width, tail PW + global pool,
    classifier.
  * ``dscnn_har`` — human activity recognition over raw accelerometer
    channels (the Kadoshima HAR topology): stem Conv1d, then widening
    DW1D->PW blocks that downsample by stride-2 depthwise convs, tail
    PW + global pool, classifier.

Both lower onto the existing integer kernels: DW1D runs the shifted-
multiply depthwise formulation over one axis; PW/DENSE are rank-agnostic
channel matmuls (a [B, T, C] pointwise is exactly the flattened
(B*T, C) @ (C, D) the paper's pointwise CU computes).

The CU mapping falls out of the standard recurrence rule (compile_net):
Head = stem + first DS block, Body = remaining DS blocks, Tail = pw +
global pool, Classifier = dense.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.graph import (
    CONV1D,
    DENSE,
    DW1D,
    NONE,
    PW,
    RELU6,
    BlockSpec,
    NetSpec,
    OpSpec,
)


def ds_block(name: str, in_ch: int, out_ch: int, kernel: int, stride: int,
             bits: int, residual: bool = False) -> BlockSpec:
    """One depthwise-separable 1-D block: DW1D (temporal) -> PW (channel)."""
    ops = (
        OpSpec(f"{name}/dw", DW1D, in_ch, in_ch, kernel, stride, RELU6,
               bits, bits),
        OpSpec(f"{name}/pw", PW, in_ch, out_ch, 1, 1, RELU6, bits, bits),
    )
    return BlockSpec(name, ops,
                     residual=residual and stride == 1 and in_ch == out_ch)


def build_kws(
    input_t: int = 49,
    input_ch: int = 10,
    channels: int = 64,
    n_blocks: int = 4,
    kernel: int = 3,
    stem_kernel: int = 5,
    stem_stride: int = 2,
    bits: int = 8,
    first_conv_bits: int = 8,
    num_classes: int = 12,
    last_ch: int = 0,
    residual: bool = False,
) -> NetSpec:
    """Keyword-spotting DS-CNN: one width, repeated DS blocks."""
    blocks = [
        BlockSpec("stem", (OpSpec("stem/conv", CONV1D, input_ch, channels,
                                  stem_kernel, stem_stride, RELU6,
                                  first_conv_bits, bits),)),
    ]
    for i in range(n_blocks):
        blocks.append(ds_block(f"ds{i}", channels, channels, kernel, 1,
                               bits, residual=residual))
    tail_ch = last_ch or 2 * channels
    blocks.append(BlockSpec(
        "tail", (OpSpec("tail/pw", PW, channels, tail_ch, 1, 1, RELU6,
                        bits, bits),),
        avgpool=True))
    blocks.append(BlockSpec(
        "classifier",
        (OpSpec("classifier/fc", DENSE, tail_ch, num_classes, 1, 1, NONE,
                bits, bits),)))
    return NetSpec(
        name=f"dscnn_kws_t{input_t}_c{channels}_bw{bits}",
        blocks=tuple(blocks),
        input_hw=input_t,
        input_ch=input_ch,
        num_classes=num_classes,
    )


def build_har(
    input_t: int = 128,
    input_ch: int = 3,
    stem_channels: int = 48,
    channels: Sequence[int] = (96, 128, 160),
    kernel: int = 5,
    bits: int = 8,
    first_conv_bits: int = 8,
    num_classes: int = 12,
    last_ch: int = 0,
) -> NetSpec:
    """HAR DS-CNN: widening DS blocks, stride-2 temporal downsampling."""
    blocks = [
        BlockSpec("stem", (OpSpec("stem/conv", CONV1D, input_ch,
                                  stem_channels, kernel, 1, RELU6,
                                  first_conv_bits, bits),)),
    ]
    in_ch = stem_channels
    for i, out_ch in enumerate(channels):
        blocks.append(ds_block(f"ds{i}", in_ch, int(out_ch), kernel, 2, bits))
        in_ch = int(out_ch)
    tail_ch = last_ch or 2 * in_ch
    blocks.append(BlockSpec(
        "tail", (OpSpec("tail/pw", PW, in_ch, tail_ch, 1, 1, RELU6,
                        bits, bits),),
        avgpool=True))
    blocks.append(BlockSpec(
        "classifier",
        (OpSpec("classifier/fc", DENSE, tail_ch, num_classes, 1, 1, NONE,
                bits, bits),)))
    return NetSpec(
        name=f"dscnn_har_t{input_t}_bw{bits}",
        blocks=tuple(blocks),
        input_hw=input_t,
        input_ch=input_ch,
        num_classes=num_classes,
    )


__all__ = ["build_kws", "build_har", "ds_block"]
