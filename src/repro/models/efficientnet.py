"""Compact EfficientNet NetSpec builder (paper Sec. 5.2, Fig. 3b / Fig. 19).

EfficientNet IRB = pw-expand -> dw -> [SE: global-pool -> PW-SQ -> PW-EX ->
hard-sigmoid gate] -> pw-project, with the skip-line when stride=1 and
channels match. The paper compresses the baseline with smaller width (alpha),
depth, and H ('compound model scaling') to reach an edge-deployable model:
H=128, 7.81 Mb at BW=4, 4.914 M ops/inference, Body CU invoked 9 times.

`build_compact` reproduces that 9-body-invocation structure; `build` exposes
full compound scaling (width/depth/resolution) for design exploration.
"""
from __future__ import annotations

import math
from typing import Tuple

from repro.core.graph import (
    CONV,
    DENSE,
    DW,
    NONE,
    PW,
    RELU6,
    BlockSpec,
    NetSpec,
    OpSpec,
    SESpec,
)
from repro.models.mobilenet_v2 import _make_divisible

# EfficientNet-B0 baseline stage settings:
# (expansion t, out channels c, repeats n, stride s, kernel k)
B0_SETTINGS: Tuple[Tuple[int, int, int, int, int], ...] = (
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
)


def mbconv_block(
    name: str,
    in_ch: int,
    out_ch: int,
    t: int,
    stride: int,
    kernel: int,
    bits: int,
    se_ratio: float = 0.25,
) -> BlockSpec:
    hidden = in_ch * t
    ops = []
    if t != 1:
        ops.append(OpSpec(f"{name}/expand", PW, in_ch, hidden, 1, 1, RELU6, bits, bits))
    dw_name = f"{name}/dw"
    ops.append(OpSpec(dw_name, DW, hidden, hidden, kernel, stride, RELU6, bits, bits))
    ops.append(OpSpec(f"{name}/project", PW, hidden, out_ch, 1, 1, NONE, bits, bits))
    se = None
    if se_ratio > 0:
        reduced = max(1, int(in_ch * se_ratio))
        se = SESpec(channels=hidden, reduced=reduced, bits=bits, prefix=f"{name}/se")
    residual = stride == 1 and in_ch == out_ch
    return BlockSpec(name, tuple(ops), residual=residual, se=se, se_after=dw_name)


def build(
    width: float = 1.0,
    depth: float = 1.0,
    input_hw: int = 224,
    bits: int = 4,
    first_conv_bits: int = 8,
    num_classes: int = 1000,
    se_ratio: float = 0.25,
) -> NetSpec:
    stem_ch = _make_divisible(32 * width)
    blocks = [
        BlockSpec(
            "stem",
            (OpSpec("stem/conv", CONV, 3, stem_ch, 3, 2, RELU6, first_conv_bits, bits),),
        )
    ]
    in_ch = stem_ch
    idx = 0
    for t, c, n, s, k in B0_SETTINGS:
        out_ch = _make_divisible(c * width)
        repeats = int(math.ceil(n * depth))
        for i in range(repeats):
            stride = s if i == 0 else 1
            blocks.append(
                mbconv_block(f"mb{idx}", in_ch, out_ch, t, stride, k, bits, se_ratio)
            )
            in_ch = out_ch
            idx += 1
    head_ch = _make_divisible(1280 * width)
    blocks.append(
        BlockSpec(
            "tail",
            (OpSpec("tail/pw", PW, in_ch, head_ch, 1, 1, RELU6, bits, bits),),
            avgpool=True,
        )
    )
    blocks.append(
        BlockSpec(
            "classifier",
            (OpSpec("classifier/fc", DENSE, head_ch, num_classes, 1, 1, NONE, bits, bits),),
        )
    )
    return NetSpec(
        name=f"efficientnet_w{width}_d{depth}_h{input_hw}_bw{bits}",
        blocks=tuple(blocks),
        input_hw=input_hw,
        num_classes=num_classes,
    )


def build_compact(
    input_hw: int = 128, bits: int = 4, num_classes: int = 1000
) -> NetSpec:
    """The paper's compressed EfficientNet: Body CU invoked 9 times (Fig. 19),
    i.e. 10 MBConv blocks with the first mapped into the Head CU.

    The paper does not publish its compound-scaling factors; width=0.65,
    depth=0.5 reproduces the structural constraints it does publish (9 Body
    invocations, H=128) and lands within 6% of its 7.81 Mb model size."""
    net = build(width=0.65, depth=0.5, input_hw=input_hw, bits=bits, num_classes=num_classes)
    return NetSpec(
        name=f"efficientnet_compact_h{input_hw}_bw{bits}",
        blocks=net.blocks,
        input_hw=input_hw,
        num_classes=num_classes,
    )


__all__ = ["build", "build_compact", "mbconv_block", "B0_SETTINGS"]
