"""Float-side interpreter of the NetSpec IR: init / forward / QAT forward.

This is the "network description model" side of the DeepDive flow (Fig. 1):
a pure-JAX functional CNN whose parameters are pytrees keyed by op name.
Three execution modes share one traversal:

  * mode='float' : FP32 inference (pre-trained reference; BN folded already)
  * mode='qat'   : fake-quantized weights + activations (online quantization)
  * capture=True : returns named intermediate activations for calibration
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import graph as G
from repro.core.bn_fuse import BN_EPS, BNParams, fuse_bn
from repro.core.quant import QuantConfig, fake_quant_minmax

# ---------------------------------------------------------------------------
# primitive float ops (NHWC, HWIO)
# ---------------------------------------------------------------------------


def conv2d(x, w, stride=1, padding="SAME", groups=1):
    return jax.lax.conv_general_dilated(
        x,
        w.astype(x.dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def depthwise_conv2d(x, w, stride=1, padding="SAME"):
    """w: [K, K, 1, C] — groups == C, no channel reduction (Fig. 2c)."""
    c = x.shape[-1]
    return conv2d(x, w, stride=stride, padding=padding, groups=c)


def pointwise_conv2d(x, w):
    """w: [1, 1, Cin, Cout] or [Cin, Cout] — channel-only mixing (matmul).
    Rank-agnostic: works on [B, H, W, C] and [B, T, C] alike."""
    if w.ndim == 4:
        w = w[0, 0]
    return jnp.einsum("...c,cd->...d", x, w.astype(x.dtype))


def conv1d(x, w, stride=1, padding="SAME", groups=1):
    """Temporal conv: x [B, T, C], w [K, Cin/groups, Cout]."""
    return jax.lax.conv_general_dilated(
        x,
        w.astype(x.dtype),
        window_strides=(stride,),
        padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=groups,
    )


def depthwise_conv1d(x, w, stride=1, padding="SAME"):
    """w: [K, 1, C] — groups == C, temporal-only mixing."""
    return conv1d(x, w, stride=stride, padding=padding, groups=x.shape[-1])


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def hsigmoid(x):
    """Eq. 1: ReLU6(x + 3) / 6."""
    return relu6(x + 3.0) / 6.0


def apply_act(x, act: str):
    if act == G.RELU6:
        return relu6(x)
    if act == G.HSIGMOID:
        return hsigmoid(x)
    if act == G.NONE:
        return x
    raise ValueError(f"unknown activation {act!r}")


def global_avg_pool(x):
    """Mean over the spatial/temporal axes ((1, 2) NHWC, (1,) NTC)."""
    return jnp.mean(x, axis=tuple(range(1, x.ndim - 1)))


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def init_op_params(
    key, op: G.OpSpec, dtype=jnp.float32, bn: bool = False
) -> Dict[str, jnp.ndarray]:
    shape = op.weight_shape()
    fan_in = op.kernel * op.kernel * (op.in_ch if op.kind != G.DW else 1)
    if op.kind == G.CONV1D:
        fan_in = op.kernel * op.in_ch
    elif op.kind == G.DW1D:
        fan_in = op.kernel
    elif op.kind == G.DENSE:
        fan_in = op.in_ch
    std = (2.0 / max(fan_in, 1)) ** 0.5
    w = std * jax.random.normal(key, shape, dtype)
    b = jnp.zeros((op.out_ch,), dtype)
    p = {"w": w, "b": b}
    if bn:
        p["bn"] = BNParams.init_tree(op.out_ch, dtype)
    return p


def init_params(key, net: G.NetSpec, dtype=jnp.float32, bn: bool = False):
    """Parameter tree keyed by op name.

    `bn=True` attaches BatchNorm leaves ({'gamma','beta','mean','var'}) to
    every convolutional operator (not the classifier, not the SE gate convs
    — matching where real DSCNNs place BN). Training normalizes with batch
    statistics; QAT and inference fold the running stats into (w, b) on the
    fly (Sec. 3.1 'BN-fused training'); `fuse_bn_params` folds permanently.
    """
    se_names = set()
    for b in net.blocks:
        if b.se is not None:
            se_names.update((b.se.squeeze.name, b.se.excite.name))
    params = {}
    for _, op in net.all_ops():
        key, sub = jax.random.split(key)
        op_bn = bn and op.kind != G.DENSE and op.name not in se_names
        params[op.name] = init_op_params(sub, op, dtype, bn=op_bn)
    return params


def fuse_bn_params(params):
    """Permanently fold every op's BN leaves into (w, b) — Eqs. 4-6.

    Returns a BN-free tree with the same op keys; ops without BN pass
    through untouched. This is the float-pretrain -> QAT boundary of the
    training pipeline (and the shape of every exported/quantized net)."""
    fused = {}
    for name, p in params.items():
        if "bn" in p:
            w, b = fuse_bn(p["w"], p["b"], BNParams.from_tree(p["bn"]),
                           out_axis=-1)
            fused[name] = {"w": w, "b": b}
        else:
            fused[name] = dict(p)
    return fused


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def weight_channel_axis(op: G.OpSpec) -> int:
    """Output-channel axis of the op's weight (per-channel quant axis, Fig. 5)."""
    return -1


def _apply_op(x, op: G.OpSpec, p, *, qat: bool, bn_stats=None):
    w, b = p["w"], p["b"]
    use_batch_stats = bn_stats is not None and "bn" in p
    if "bn" in p and not use_batch_stats:
        # BN-fused execution (QAT + float eval): fold the running stats
        # into the operator so fake-quant sees the deployed weights (the
        # paper's 'training with fused BN', Sec. 3.1).
        w, b = fuse_bn(w, b, BNParams.from_tree(p["bn"]), out_axis=-1)
    if qat:
        # per-output-channel symmetric weight fake-quant at the op's BW
        w = fake_quant_minmax(
            w, QuantConfig(op.bits, symmetric=True, channel_axis=weight_channel_axis(op))
        )
    if op.kind == G.CONV:
        y = conv2d(x, w, stride=op.stride)
    elif op.kind == G.DW:
        y = depthwise_conv2d(x, w, stride=op.stride)
    elif op.kind == G.CONV1D:
        y = conv1d(x, w, stride=op.stride)
    elif op.kind == G.DW1D:
        y = depthwise_conv1d(x, w, stride=op.stride)
    elif op.kind == G.PW:
        y = pointwise_conv2d(x, w)
    elif op.kind == G.DENSE:
        y = x @ w.astype(x.dtype)
    else:
        raise ValueError(op.kind)
    y = y + b.astype(y.dtype)
    if use_batch_stats:
        # float pre-training: normalize with THIS batch's moments and hand
        # them to the train step, which maintains the running stats (EMA)
        # outside the gradient tape.
        axes = tuple(range(y.ndim - 1))
        mean = jnp.mean(y, axis=axes)
        var = jnp.var(y, axis=axes)
        bn = p["bn"]
        y = (y - mean) * jax.lax.rsqrt(var + BN_EPS) * bn["gamma"] + bn["beta"]
        bn_stats[op.name] = {
            "mean": jax.lax.stop_gradient(mean),
            "var": jax.lax.stop_gradient(var),
        }
    y = apply_act(y, op.act)
    if qat and op.act != G.NONE:
        # online activation quantization at the op's activation bit-width
        y = fake_quant_minmax(y, QuantConfig(op.act_bits, False, None))
    return y


def _apply_block(x, block: G.BlockSpec, params, *, qat, capture, bn_stats):
    y = x
    for op in block.ops:
        y = _apply_op(y, op, params[op.name], qat=qat, bn_stats=bn_stats)
        if capture is not None:
            capture[op.name] = y
        if block.se is not None and block.se_after == op.name:
            y = _apply_se(y, block.se, params, qat=qat, capture=capture)
    if block.residual and x.shape == y.shape:
        y = x + y
        if capture is not None:
            capture[block.name + "/residual"] = y
    if block.avgpool:
        y = global_avg_pool(y)
        if capture is not None:
            capture[block.name + "/avgpool"] = y
    return y


def _apply_se(x, se: G.SESpec, params, *, qat, capture):
    s = global_avg_pool(x)  # squeeze: global spatial features
    s = _apply_op(s, se.squeeze, params[se.squeeze.name], qat=qat)
    s = _apply_op(s, se.excite, params[se.excite.name], qat=qat)
    if capture is not None:
        capture["se_gate"] = s
    return x * s.reshape(s.shape[0], *([1] * (x.ndim - 2)), s.shape[-1])


def forward(
    params,
    x: jnp.ndarray,
    net: G.NetSpec,
    *,
    qat: bool = False,
    capture: bool = False,
    bn_stats: Optional[Dict[str, Dict[str, jnp.ndarray]]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Run the network. Returns (logits, activations|None).

    `bn_stats`: pass a dict to run BN ops on *batch* statistics (float
    pre-training mode) — it is filled with each op's batch moments so the
    caller can update the running stats. With `bn_stats=None`, BN ops fold
    their running stats into the weights (QAT / inference mode)."""
    acts: Optional[Dict[str, jnp.ndarray]] = {} if capture else None
    y = x
    for block in net.blocks:
        y = _apply_block(y, block, params, qat=qat, capture=acts,
                         bn_stats=bn_stats)
    return y, acts


def make_calibrated_qnet(net: G.NetSpec, *, bits: int = 4, seed: int = 0,
                         n_cal: int = 2):
    """The standard demo/test deployment recipe in one call: random init
    (PRNGKey(seed)) -> calibrate activations on `n_cal` fixed random
    batches in [-1, 1] -> quantize to an integer QNet.

    Single source of truth for every driver/example/benchmark/test that
    needs a calibrated QNet from scratch — the PRNG keys and batch shapes
    are part of the contract (tests/golden/ fixtures are generated through
    this exact sequence)."""
    from repro.core.calibrate import calibrate
    from repro.core.qnet import quantize_net

    params = init_params(jax.random.PRNGKey(seed), net)

    def apply_fn(p, b):
        return forward(p, b, net, capture=True)[1]

    cal = [jax.random.uniform(jax.random.PRNGKey(i),
                              (2, *net.input_shape()), minval=-1, maxval=1)
           for i in range(n_cal)]
    obs = calibrate(apply_fn, params, cal, QuantConfig(bits, False, None))
    return quantize_net(params, net, obs)


__all__ = [
    "conv2d",
    "depthwise_conv2d",
    "conv1d",
    "depthwise_conv1d",
    "pointwise_conv2d",
    "relu6",
    "hsigmoid",
    "apply_act",
    "global_avg_pool",
    "init_params",
    "fuse_bn_params",
    "forward",
    "make_calibrated_qnet",
]
