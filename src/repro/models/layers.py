"""Float-side interpreter of the NetSpec IR: init / forward / QAT forward.

This is the "network description model" side of the DeepDive flow (Fig. 1):
a pure-JAX functional CNN whose parameters are pytrees keyed by op name.
Three execution modes share one traversal:

  * mode='float' : FP32 inference (pre-trained reference; BN folded already)
  * mode='qat'   : fake-quantized weights + activations (online quantization)
  * capture=True : returns named intermediate activations for calibration
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import graph as G
from repro.core.quant import QuantConfig, fake_quant_minmax

# ---------------------------------------------------------------------------
# primitive float ops (NHWC, HWIO)
# ---------------------------------------------------------------------------


def conv2d(x, w, stride=1, padding="SAME", groups=1):
    return jax.lax.conv_general_dilated(
        x,
        w.astype(x.dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def depthwise_conv2d(x, w, stride=1, padding="SAME"):
    """w: [K, K, 1, C] — groups == C, no channel reduction (Fig. 2c)."""
    c = x.shape[-1]
    return conv2d(x, w, stride=stride, padding=padding, groups=c)


def pointwise_conv2d(x, w):
    """w: [1, 1, Cin, Cout] or [Cin, Cout] — channel-only mixing (matmul)."""
    if w.ndim == 4:
        w = w[0, 0]
    return jnp.einsum("...c,cd->...d", x, w.astype(x.dtype))


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def hsigmoid(x):
    """Eq. 1: ReLU6(x + 3) / 6."""
    return relu6(x + 3.0) / 6.0


def apply_act(x, act: str):
    if act == G.RELU6:
        return relu6(x)
    if act == G.HSIGMOID:
        return hsigmoid(x)
    if act == G.NONE:
        return x
    raise ValueError(f"unknown activation {act!r}")


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def init_op_params(key, op: G.OpSpec, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    shape = op.weight_shape()
    fan_in = op.kernel * op.kernel * (op.in_ch if op.kind != G.DW else 1)
    if op.kind == G.DENSE:
        fan_in = op.in_ch
    std = (2.0 / max(fan_in, 1)) ** 0.5
    w = std * jax.random.normal(key, shape, dtype)
    b = jnp.zeros((op.out_ch,), dtype)
    return {"w": w, "b": b}


def init_params(key, net: G.NetSpec, dtype=jnp.float32):
    params = {}
    for _, op in net.all_ops():
        key, sub = jax.random.split(key)
        params[op.name] = init_op_params(sub, op, dtype)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def weight_channel_axis(op: G.OpSpec) -> int:
    """Output-channel axis of the op's weight (per-channel quant axis, Fig. 5)."""
    return -1


def _apply_op(x, op: G.OpSpec, p, *, qat: bool):
    w, b = p["w"], p["b"]
    if qat:
        # per-output-channel symmetric weight fake-quant at the op's BW
        w = fake_quant_minmax(
            w, QuantConfig(op.bits, symmetric=True, channel_axis=weight_channel_axis(op))
        )
    if op.kind == G.CONV:
        y = conv2d(x, w, stride=op.stride)
    elif op.kind == G.DW:
        y = depthwise_conv2d(x, w, stride=op.stride)
    elif op.kind == G.PW:
        y = pointwise_conv2d(x, w)
    elif op.kind == G.DENSE:
        y = x @ w.astype(x.dtype)
    else:
        raise ValueError(op.kind)
    y = y + b.astype(y.dtype)
    y = apply_act(y, op.act)
    if qat and op.act != G.NONE:
        # online activation quantization at the op's activation bit-width
        y = fake_quant_minmax(y, QuantConfig(op.act_bits, False, None))
    return y


def _apply_block(x, block: G.BlockSpec, params, *, qat, capture):
    y = x
    for op in block.ops:
        y = _apply_op(y, op, params[op.name], qat=qat)
        if capture is not None:
            capture[op.name] = y
        if block.se is not None and block.se_after == op.name:
            y = _apply_se(y, block.se, params, qat=qat, capture=capture)
    if block.residual and x.shape == y.shape:
        y = x + y
        if capture is not None:
            capture[block.name + "/residual"] = y
    if block.avgpool:
        y = global_avg_pool(y)
        if capture is not None:
            capture[block.name + "/avgpool"] = y
    return y


def _apply_se(x, se: G.SESpec, params, *, qat, capture):
    s = global_avg_pool(x)  # squeeze: global spatial features
    s = _apply_op(s, se.squeeze, params[se.squeeze.name], qat=qat)
    s = _apply_op(s, se.excite, params[se.excite.name], qat=qat)
    if capture is not None:
        capture["se_gate"] = s
    return x * s[:, None, None, :]


def forward(
    params,
    x: jnp.ndarray,
    net: G.NetSpec,
    *,
    qat: bool = False,
    capture: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Run the network. Returns (logits, activations|None)."""
    acts: Optional[Dict[str, jnp.ndarray]] = {} if capture else None
    y = x
    for block in net.blocks:
        y = _apply_block(y, block, params, qat=qat, capture=acts)
    return y, acts


def make_calibrated_qnet(net: G.NetSpec, *, bits: int = 4, seed: int = 0,
                         n_cal: int = 2):
    """The standard demo/test deployment recipe in one call: random init
    (PRNGKey(seed)) -> calibrate activations on `n_cal` fixed random
    batches in [-1, 1] -> quantize to an integer QNet.

    Single source of truth for every driver/example/benchmark/test that
    needs a calibrated QNet from scratch — the PRNG keys and batch shapes
    are part of the contract (tests/golden/ fixtures are generated through
    this exact sequence)."""
    from repro.core.calibrate import calibrate
    from repro.core.qnet import quantize_net

    params = init_params(jax.random.PRNGKey(seed), net)

    def apply_fn(p, b):
        return forward(p, b, net, capture=True)[1]

    hw = net.input_hw
    cal = [jax.random.uniform(jax.random.PRNGKey(i),
                              (2, hw, hw, net.input_ch), minval=-1, maxval=1)
           for i in range(n_cal)]
    obs = calibrate(apply_fn, params, cal, QuantConfig(bits, False, None))
    return quantize_net(params, net, obs)


__all__ = [
    "conv2d",
    "depthwise_conv2d",
    "pointwise_conv2d",
    "relu6",
    "hsigmoid",
    "apply_act",
    "global_avg_pool",
    "init_params",
    "forward",
    "make_calibrated_qnet",
]
