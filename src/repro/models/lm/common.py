"""Transformer building blocks shared by the assigned LM architectures.

Pure-JAX functional modules. Parameters are nested dicts; every init_*
function returns `(params, logical)` where `logical` mirrors the structure
with tuples of logical axis names consumed by dist/sharding.py (TP over
'heads'/'ffn'/'vocab', EP over 'experts', optional FSDP over 'embed').

Quantization tie-in (the paper's front-end applied to LMs): when an LMConfig
sets quant_bits, linear weights are stored as int8 (or packed int4) with
per-output-channel scales — the same range-based symmetric scheme as
core/quant.py — and dequantized in-graph next to the matmul, which cuts the
weight-side memory roofline term by 4x/8x (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.quant import unpack_int4
from repro.dist.sharding import axis_size, shard
from repro.models.lm.config import LMConfig

Params = Dict
F32 = jnp.float32


def dt(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# linear (+ weight-only quantization), norm, rope
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, ax_in: str, ax_out: str,
                cfg: LMConfig, std: Optional[float] = None):
    std = std if std is not None else d_in**-0.5
    w = std * jax.random.normal(key, (d_in, d_out), F32)
    if cfg.quant_bits in (4, 8):
        qmax = 2 ** (cfg.quant_bits - 1) - 1
        amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
        scale = jnp.where(amax > 0, amax / qmax, 1.0)
        q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
        if cfg.quant_bits == 4:
            u = jnp.where(q < 0, q + 16, q).astype(jnp.uint8)
            packed = (u[:, 0::2] & 0xF) | ((u[:, 1::2] & 0xF) << 4)
            p = {"w_q": packed, "scale": scale.astype(dt(cfg))}
            lg = {"w_q": (ax_in, ax_out), "scale": (None, ax_out)}
            return p, lg
        p = {"w_q": q, "scale": scale.astype(dt(cfg))}
        return p, {"w_q": (ax_in, ax_out), "scale": (None, ax_out)}
    return {"w": w.astype(dt(cfg))}, {"w": (ax_in, ax_out)}


def linear(x, p):
    if "w" in p:
        return x @ p["w"].astype(x.dtype)
    w_q = p["w_q"]
    if w_q.dtype == jnp.uint8:  # packed int4
        q = unpack_int4(w_q, signed=True)
    else:
        q = w_q.astype(jnp.int32)
    w = q.astype(x.dtype) * p["scale"].astype(x.dtype)
    return x @ w


def init_norm(key, d: int, cfg: LMConfig):
    return {"scale": jnp.ones((d,), dt(cfg))}, {"scale": (None,)}


def rms_norm(x, p, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    y = x.astype(F32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """x: [B, S, H, dh]; positions: [B, S] or [S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(F32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; full / blockwise-flash / local-window / cross / decode)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: LMConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.head_dim
    ks = jax.random.split(key, 6)
    p, lg = {}, {}
    p["wq"], lg["wq"] = init_linear(ks[0], d, cfg.n_heads * hd, "embed", "heads", cfg)
    p["wk"], lg["wk"] = init_linear(ks[1], d, cfg.n_kv_heads * hd, "embed", "heads", cfg)
    p["wv"], lg["wv"] = init_linear(ks[2], d, cfg.n_kv_heads * hd, "embed", "heads", cfg)
    p["wo"], lg["wo"] = init_linear(ks[3], cfg.n_heads * hd, d, "heads", "embed", cfg)
    if cfg.qk_norm:
        p["qnorm"], lg["qnorm"] = init_norm(ks[4], hd, cfg)
        p["knorm"], lg["knorm"] = init_norm(ks[5], hd, cfg)
    return p, lg


def _repeat_kv(k, n_heads):
    rep = n_heads // k.shape[2]
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


# --- int8 KV cache (the paper's quantization applied to the decode cache:
#     per-(position, kv-head) symmetric scales; halves the dominant HBM
#     traffic of memory-bound decode — §Perf lever `kv_bits`) ---------------


def kv_quant(x):
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.round(x.astype(F32) / scale[..., None]).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def kv_dequant(q, scale, dtype):
    return (q.astype(F32) * scale[..., None].astype(F32)).astype(dtype)


def _attn_core(q, k, v, mask, scale):
    """q [B,Sq,H,dh]; k/v [B,Sk,KV,dh] (KV <= H); mask [.,1,Sq,Sk].

    GQA is evaluated GROUPED — einsum over [KV, rep] — instead of
    materializing the repeated K/V. jnp.repeat made every decode step read
    rep x the cache bytes (qwen3 decode_32k: 8x64 layers ~= 550 GB/device
    per step); grouping reads each cache byte once (§Perf cell A, iter 1)."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    rep = h // kv
    # K/V stay in their storage dtype (bf16): upcasting them to f32 first
    # materializes an f32 copy of the WHOLE cache per layer (qwen3 decode:
    # ~8 GB/dev/layer). MXU-style f32 accumulation via preferred_element_type
    # reads each cache byte once (§Perf cell A, iter 2).
    if rep == 1:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                       preferred_element_type=F32) * scale
        s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v,
                          preferred_element_type=F32)
    qg = q.reshape(b, sq, kv, rep, dh)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                   preferred_element_type=F32) * scale
    s = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w.astype(v.dtype), v,
                     preferred_element_type=F32)
    return out.reshape(b, sq, h, dh)


def full_attention(q, k, v, *, causal: bool, window: int = 0,
                   kv_offset: int = 0, kv_len=None):
    """Direct attention. kv_offset = absolute position of q[0] minus k[0]
    (for decode with a cache, q position = kv_offset + i)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    qpos = kv_offset + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    mask = mask[None, None]
    if kv_len is not None:  # [B] valid cache lengths
        mask = mask & (kpos[None, None, None, :] < kv_len[:, None, None, None])
    out = _attn_core(q, k, v, mask, dh**-0.5)
    return out.astype(q.dtype)


def pos_attention(q, k, v, kpos, q_pos, window: int = 0):
    """Attention over a ring cache with explicit absolute key positions.

    kpos: [Sk] int32 (−1 = empty slot); q_pos: scalar absolute position of q.
    """
    b, sq, h, dh = q.shape
    qpos = q_pos + jnp.arange(sq)
    mask = (kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    out = _attn_core(q, k, v, mask[None, None], dh**-0.5)
    return out.astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        block_k: int = 1024):
    """Flash-style online-softmax over KV blocks (lax.scan) — keeps the
    S x S score matrix out of memory for long-context prefill."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    rep = h // kv
    pad = (-sk) % block_k  # ragged tail (e.g. VLM: 32768 tokens + 576 patches)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    skp = sk + pad
    nb = skp // block_k
    kb = k.reshape(b, nb, block_k, kv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block_k, kv, dh).transpose(1, 0, 2, 3, 4)
    qf = q.astype(F32).reshape(b, sq, kv, rep, dh)
    scale = dh**-0.5
    qpos = jnp.arange(sq)

    def step(carry, blk):
        m, l, acc = carry  # [b, kv, rep, sq], ..., [b, kv, rep, sq, dh]
        kblk, vblk, bi = blk
        kpos = bi * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qf, kblk.astype(F32)) * scale
        mask = kpos[None, :] < sk  # ignore ragged padding
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p, vblk.astype(F32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv, rep, sq), -jnp.inf, F32)
    l0 = jnp.zeros((b, kv, rep, sq), F32)
    a0 = jnp.zeros((b, kv, rep, sq, dh), F32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kb, vb, jnp.arange(nb))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # [b, kv, rep, sq, dh] -> [b, sq, h, dh]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def attention_block(p, x, cfg: LMConfig, positions, *, causal=True,
                    window: int = 0, kv_cache=None, cache_pos=None,
                    xk=None, blockwise_threshold: int = 8192):
    """Self- or cross-attention with optional KV cache.

    Returns (out, new_cache). kv_cache: dict(k=[B,Smax,KV,dh], v=...).
    cache_pos: scalar int32 — write position for decode.
    xk: memory for cross-attention (keys/values computed from xk).
    """
    hd = cfg.head_dim
    src = x if xk is None else xk
    q = linear(x, p["wq"]).reshape(*x.shape[:-1], cfg.n_heads, hd)
    k = linear(src, p["wk"]).reshape(*src.shape[:-1], cfg.n_kv_heads, hd)
    v = linear(src, p["wv"]).reshape(*src.shape[:-1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"], cfg.norm_eps)
        k = rms_norm(k, p["knorm"], cfg.norm_eps)
    q = shard(q, "batch", None,
              "heads" if cfg.n_heads % axis_size("model") == 0 else None, None)
    # GQA: only constrain kv heads when they divide the TP axis; otherwise
    # let GSPMD keep them partially replicated (avoids involuntary remat)
    kv_ax = "heads" if cfg.n_kv_heads % axis_size("model") == 0 else None
    k = shard(k, "batch", None, kv_ax, None)
    if xk is None:  # self-attention: rope
        q = rope(q, positions, cfg.rope_theta)
        if cache_pos is None:
            kpos = positions
        else:
            kpos = cache_pos + jnp.arange(k.shape[1])
        k = rope(k, kpos, cfg.rope_theta)

    new_cache = kv_cache
    quant = kv_cache is not None and "k_scale" in kv_cache

    def _store(x_new, cache_q, cache_s, idx):
        if quant:
            qv, sv = kv_quant(x_new)
            cq = jax.lax.dynamic_update_slice_in_dim(cache_q, qv, idx, axis=1)
            cs = jax.lax.dynamic_update_slice_in_dim(cache_s, sv, idx, axis=1)
            return cq, cs
        cq = jax.lax.dynamic_update_slice_in_dim(
            cache_q, x_new.astype(cache_q.dtype), idx, axis=1)
        return cq, cache_s

    def _read(cache_q, cache_s):
        if quant:
            return kv_dequant(cache_q, cache_s, q.dtype)
        return cache_q

    if kv_cache is not None:
        if cache_pos is not None:  # decode: insert this step's k/v
            size = kv_cache["k"].shape[1]
            ring = "pos" in kv_cache  # windowed ring buffer (local attention)
            idx = jnp.mod(cache_pos, size) if ring else cache_pos
            kc, ks = _store(k, kv_cache["k"], kv_cache.get("k_scale"), idx)
            vc, vs = _store(v, kv_cache["v"], kv_cache.get("v_scale"), idx)
            new_cache = {"k": kc, "v": vc}
            if quant:
                new_cache.update(k_scale=ks, v_scale=vs)
            kd, vd = _read(kc, ks), _read(vc, vs)
            if ring:
                posc = jax.lax.dynamic_update_slice_in_dim(
                    kv_cache["pos"],
                    (cache_pos + jnp.arange(k.shape[1])).astype(jnp.int32),
                    idx, axis=0)
                new_cache["pos"] = posc
                out = pos_attention(q, kd, vd, posc, cache_pos, window)
            else:
                kv_len = jnp.full((x.shape[0],), cache_pos + k.shape[1], jnp.int32)
                out = full_attention(
                    q, kd, vd, causal=False, window=window,
                    kv_offset=cache_pos, kv_len=kv_len,
                )
        else:  # prefill: fill cache from 0
            size = kv_cache["k"].shape[1]
            s = k.shape[1]
            if "pos" in kv_cache:  # ring: keep only the last `size` positions
                take = min(s, size)
                kc, ks = _store(k[:, -take:], kv_cache["k"],
                                kv_cache.get("k_scale"), 0)
                vc, vs = _store(v[:, -take:], kv_cache["v"],
                                kv_cache.get("v_scale"), 0)
                # NOTE: ring-slot alignment assumes size | s (true for the
                # assigned shapes: window 2048 divides 32768/524288 prefills)
                posc = jax.lax.dynamic_update_slice_in_dim(
                    kv_cache["pos"], jnp.arange(s - take, s, dtype=jnp.int32),
                    0, axis=0)
                new_cache = {"k": kc, "v": vc, "pos": posc}
            else:
                kc, ks = _store(k, kv_cache["k"], kv_cache.get("k_scale"), 0)
                vc, vs = _store(v, kv_cache["v"], kv_cache.get("v_scale"), 0)
                new_cache = {"k": kc, "v": vc}
            if quant:
                new_cache.update(k_scale=ks, v_scale=vs)
            out = _self_attn(q, k, v, causal, window, blockwise_threshold)
    else:
        if xk is None:
            out = _self_attn(q, k, v, causal, window, blockwise_threshold)
        else:
            out = full_attention(q, k, v, causal=False)
    out = out.reshape(*x.shape[:-1], cfg.n_heads * hd)
    return linear(out, p["wo"]), new_cache


def _self_attn(q, k, v, causal, window, threshold):
    if k.shape[1] > threshold:
        return blockwise_attention(q, k, v, causal=causal, window=window)
    return full_attention(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# SwiGLU MLP + dense decoder block
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: LMConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p, lg = {}, {}
    p["wi"], lg["wi"] = init_linear(ks[0], d, f, "embed", "ffn", cfg)
    p["wg"], lg["wg"] = init_linear(ks[1], d, f, "embed", "ffn", cfg)
    p["wo"], lg["wo"] = init_linear(ks[2], f, d, "ffn", "embed", cfg)
    return p, lg


def mlp(p, x):
    h = jax.nn.silu(linear(x, p["wg"])) * linear(x, p["wi"])
    h = shard(h, "batch", *(None,) * (h.ndim - 2), "ffn")
    return linear(h, p["wo"])


def init_dense_block(key, cfg: LMConfig):
    ks = jax.random.split(key, 4)
    p, lg = {}, {}
    p["ln1"], lg["ln1"] = init_norm(ks[0], cfg.d_model, cfg)
    p["attn"], lg["attn"] = init_attention(ks[1], cfg)
    p["ln2"], lg["ln2"] = init_norm(ks[2], cfg.d_model, cfg)
    p["mlp"], lg["mlp"] = init_mlp(ks[3], cfg)
    return p, lg


def dense_block(p, x, cfg: LMConfig, positions, *, kv_cache=None,
                cache_pos=None, window: int = 0):
    h, new_cache = attention_block(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, positions,
        causal=True, window=window, kv_cache=kv_cache, cache_pos=cache_pos,
    )
    x = x + h
    x = x + mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    x = shard(x, "batch", "seq", None)
    return x, new_cache


__all__ = [
    "init_linear", "linear", "init_norm", "rms_norm", "rope",
    "init_attention", "attention_block", "full_attention",
    "blockwise_attention", "init_mlp", "mlp", "init_dense_block",
    "dense_block", "dt",
]
