"""Unified configuration for the assigned LM-family architectures.

One dataclass covers dense / MoE / hybrid (RG-LRU) / SSM / enc-dec / VLM /
audio backbones; family-specific fields are zero/None when unused. Exact
values per architecture live in src/repro/configs/<id>.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25

    # --- hybrid (RG-LRU / Griffin) ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    conv_width: int = 4
    local_window: int = 0

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # --- encoder-decoder ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # --- modality frontend stubs ---
    frontend: Optional[str] = None  # 'vision' | 'audio' -> precomputed embeds
    frontend_len: int = 0  # number of frontend embedding positions

    # --- numerics / quantization (the paper's knobs applied to LMs) ---
    dtype: str = "bfloat16"
    quant_bits: Optional[int] = None  # None=fp; 8/4 = weight-only quantized serve
    remat: str = "none"  # none | full | dots
    # Unroll layer scans. Production keeps scan (O(1) HLO); the dry-run
    # unrolls so cost_analysis counts every layer (while bodies are counted
    # once by HloCostAnalysis — see launch/roofline.py).
    scan_unroll: bool = False

    # --- §Perf hillclimb levers (all default-off == paper-faithful baseline) ---
    kv_bits: Optional[int] = None  # int8 KV cache (paper's quant on the cache)
    rglru_diagonal_gates: bool = False  # Griffin-style diagonal r/i gates
    rglru_chunk: int = 0  # chunked RG-LRU scan (0 = full associative scan)

    def __post_init__(self):
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_causal_lm(self) -> bool:
        return self.family in ("dense", "moe", "hybrid", "ssm", "vlm")

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k decode? (SSM state / local window)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS and reporting)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d  # lm head
        hd = self.head_dim or 0

        def attn_params():
            return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

        def dense_mlp(ff):
            return 3 * d * ff  # SwiGLU: wi, wg, wo

        if self.family in ("dense", "vlm"):
            n += self.n_layers * (attn_params() + dense_mlp(self.d_ff))
        elif self.family == "moe":
            per = attn_params() + self.n_experts * dense_mlp(self.moe_d_ff)
            if self.dense_residual:
                per += dense_mlp(self.d_ff)
            if self.n_shared_experts:
                per += dense_mlp(self.shared_d_ff)
            n += self.n_layers * per
        elif self.family == "hybrid":
            n_attn = sum(
                1 for i in range(self.n_layers)
                if self.block_pattern[i % len(self.block_pattern)] == "attn"
            )
            n_rec = self.n_layers - n_attn
            rec = 2 * d * self.lru_width + self.conv_width * self.lru_width + \
                2 * self.lru_width + self.lru_width * d
            n += n_attn * attn_params() + n_rec * rec + self.n_layers * dense_mlp(self.d_ff)
        elif self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            per = d * (2 * d_in + 2 * self.ssm_state + nh) + 4 * d_in + d_in * d
            n += self.n_layers * per
        elif self.family in ("encdec", "audio"):
            enc = self.n_enc_layers * (attn_params() + dense_mlp(self.d_ff))
            dec = self.n_dec_layers * (2 * attn_params() + dense_mlp(self.d_ff))
            n += enc + dec
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top-k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.head_dim or 0
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        per = attn + self.top_k * 3 * d * self.moe_d_ff
        if self.dense_residual:
            per += 3 * d * self.d_ff
        if self.n_shared_experts:
            per += 3 * d * self.shared_d_ff
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        return n + self.n_layers * per


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


__all__ = ["LMConfig", "ShapeSpec", "SHAPES"]
