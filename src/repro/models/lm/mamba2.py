"""Mamba-2 block via the SSD (state-space duality) algorithm (arXiv:2405.21060).

The SSD form evaluates the selective SSM

    h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t  x_t^T)        (per head)
    y_t = C_t^T h_t + D * x_t

with a *chunked, matmul-dominant* algorithm: intra-chunk terms become an
attention-like quadratic form (MXU-friendly), inter-chunk terms reduce to a
short `lax.scan` over chunk states — exactly the restructuring that makes an
SSM map well to a systolic/matrix unit, mirroring how DeepDive re-maps sparse
operators onto the right compute unit.

State for decode is O(H * P * N) per sequence — constant in context length,
which is why mamba2 runs the long_500k cell.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.lm.config import LMConfig
from repro.models.lm.common import dt, init_linear, init_norm, linear, rms_norm

F32 = jnp.float32


def dims(cfg: LMConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba2_block(key, cfg: LMConfig):
    d = cfg.d_model
    d_in, nh, hp, ns = dims(cfg)
    ks = jax.random.split(key, 6)
    p, lg = {}, {}
    # fused input projection: [z (gate), x, B, C, dt]
    proj_out = 2 * d_in + 2 * ns + nh
    p["in_proj"], lg["in_proj"] = init_linear(ks[0], d, proj_out, "embed", "ffn", cfg)
    p["conv_w"] = 0.1 * jax.random.normal(ks[1], (cfg.conv_width, d_in + 2 * ns), F32).astype(dt(cfg))
    lg["conv_w"] = (None, "ffn")
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(F32)
    lg["A_log"] = ("heads",)
    p["D"] = jnp.ones((nh,), F32)
    lg["D"] = ("heads",)
    p["dt_bias"] = jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
        ks[3], (nh,), F32, jnp.log(1e-3), jnp.log(1e-1))))).astype(F32)
    lg["dt_bias"] = ("heads",)
    p["norm"], lg["norm"] = init_norm(ks[4], d_in, cfg)
    p["out_proj"], lg["out_proj"] = init_linear(ks[5], d_in, d, "ffn", "embed", cfg)
    return p, lg


def _segsum(dtA):
    """dtA: [..., Q] -> cumulative decay matrix log L[i, j] = sum_{j<k<=i} dtA_k
    (lower-triangular; -inf above diagonal)."""
    q = dtA.shape[-1]
    cs = jnp.cumsum(dtA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [..., i, j] = sum_(j, i]
    ii = jnp.arange(q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dtv, A, B, C, chunk: int):
    """Chunked SSD scan.

    x  : [b, s, h, p]    (pre-discretized input; we fold dt into x and B)
    dtv: [b, s, h]       softplus'd step sizes
    A  : [h]             negative decay rates
    B,C: [b, s, n]       (single group, broadcast over heads)
    Returns y [b, s, h, p], final_state [b, h, n, p].
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:  # ragged tail: dt=0 is state-neutral (decay 1, update 0)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    nc = s_pad // q

    xr = x.reshape(b, nc, q, h, p).astype(F32)
    dtr = dtv.reshape(b, nc, q, h).astype(F32)
    Br = B.reshape(b, nc, q, n).astype(F32)
    Cr = C.reshape(b, nc, q, n).astype(F32)

    dtA = dtr * A[None, None, None, :]  # [b, nc, q, h]  (A < 0)
    # intra-chunk (attention-like, causal with decay):
    L = jnp.exp(_segsum(dtA.transpose(0, 1, 3, 2)))  # [b, nc, h, q, q]
    scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br)  # [b, nc, q, q]
    att = scores[:, :, None] * L  # [b, nc, h, i, j]
    y_intra = jnp.einsum("bchij,bcjh,bcjhp->bcihp", att, dtr, xr)

    # chunk states: S_c = sum_j exp(sum_{j<k<q} dtA) * dt_j * B_j x_j^T
    decay_to_end = jnp.exp(
        jnp.cumsum(dtA, axis=2)[:, :, -1:, :] - jnp.cumsum(dtA, axis=2)
    )  # [b, nc, q, h]
    states = jnp.einsum(
        "bcjh,bcjn,bcjhp->bchnp", decay_to_end * dtr, Br, xr
    )  # [b, nc, h, n, p]

    # inter-chunk: scan chunk-level recurrence  S_out = S_in * decay + S_c
    chunk_decay = jnp.exp(jnp.sum(dtA, axis=2))  # [b, nc, h]

    def step(carry, inp):
        st, dec = inp  # [b,h,n,p], [b,h]
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    init = jnp.zeros((b, h, n, p), F32)
    final, entering = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    entering = entering.transpose(1, 0, 2, 3, 4)  # [b, nc, h, n, p]

    # contribution of the entering state to each position in the chunk
    decay_from_start = jnp.exp(jnp.cumsum(dtA, axis=2))  # [b, nc, q, h]
    y_inter = jnp.einsum(
        "bcin,bchnp,bcih->bcihp", Cr, entering, decay_from_start
    )
    y = (y_intra + y_inter).reshape(b, s_pad, h, p)[:, :s]
    return y, final


def ssd_step(x, dtv, A, B, C, state):
    """One decode step. x: [b, 1, h, p]; state: [b, h, n, p] f32."""
    dtA = (dtv[:, 0].astype(F32) * A[None, :])  # [b, h]
    dec = jnp.exp(dtA)
    upd = jnp.einsum("bn,bhp->bhnp", B[:, 0].astype(F32),
                     dtv[:, 0, :, None].astype(F32) * x[:, 0].astype(F32))
    new_state = state * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C[:, 0].astype(F32), new_state)
    return y[:, None], new_state


def mamba2_block(p, x, cfg: LMConfig, state: Optional[dict] = None):
    """Full block. state: {'conv': [B, K-1, d_conv_in], 'ssd': [B,H,N,P]}."""
    from repro.models.lm.rglru import _causal_conv1d

    b, s, d = x.shape
    d_in, nh, hp, ns = dims(cfg)
    zxbcdt = linear(x, p["in_proj"])
    z, xin, Bc, Cc, dtv = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + ns, 2 * d_in + 2 * ns], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    decode = state is not None and s == 1
    conv_state = state["conv"] if decode else None
    conv_out, new_conv = _causal_conv1d(conv_in, p["conv_w"].astype(F32), conv_state)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    xin, Bc, Cc = jnp.split(conv_out, [d_in, d_in + ns], axis=-1)
    xh = xin.reshape(b, s, nh, hp)
    xh = shard(xh, "batch", None, "heads", None)
    dtv = jax.nn.softplus(dtv.astype(F32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    if decode:
        y, ssd_state = ssd_step(xh, dtv, A, Bc, Cc, state["ssd"])
    else:
        y, ssd_state = ssd_chunked(xh, dtv, A, Bc, Cc, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(F32)
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = linear(y, p["out_proj"])
    new_state = {
        "conv": (new_conv if new_conv is not None else jnp.zeros(
            (b, cfg.conv_width - 1, d_in + 2 * ns), dt(cfg))),
        "ssd": ssd_state,
    }
    return out, new_state


__all__ = ["init_mamba2_block", "mamba2_block", "ssd_chunked", "ssd_step", "dims"]
