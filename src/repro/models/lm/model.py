"""LM model assembly: init / train forward / prefill / decode per family.

Structure mirrors the paper's CU decomposition (DESIGN.md §4): Head CU =
embedding (+ modality frontend stub), Body CU = the repeated block executed
via `jax.lax.scan` over stacked layer parameters (the exact analogue of
'host schedules the Body CU j times'), Tail CU = final norm, Classifier CU =
the LM head. Scan keeps the HLO O(1) in depth, which is what makes the
480B-class dry-runs compile quickly.

Every init_* returns (params, logical) where logical mirrors params with
tuples of logical axis names (see dist/sharding.py). Stacked layer params
get a leading `None` (the scan axis is never sharded).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.lm import common as C
from repro.models.lm import mamba2 as M2
from repro.models.lm import moe as MOE
from repro.models.lm import rglru as RG
from repro.models.lm.config import LMConfig

F32 = jnp.float32


# ---------------------------------------------------------------------------
# per-family single-layer init/apply
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: LMConfig, kind: str):
    """kind: dense | moe | rec | attn_local | ssm | enc | dec."""
    ks = jax.random.split(key, 6)
    p, lg = {}, {}
    if kind == "ssm":
        p["ln1"], lg["ln1"] = C.init_norm(ks[0], cfg.d_model, cfg)
        p["mix"], lg["mix"] = M2.init_mamba2_block(ks[1], cfg)
        return p, lg
    p["ln1"], lg["ln1"] = C.init_norm(ks[0], cfg.d_model, cfg)
    if kind == "rec":
        p["mix"], lg["mix"] = RG.init_rglru_block(ks[1], cfg)
    else:
        p["mix"], lg["mix"] = C.init_attention(ks[1], cfg)
    p["ln2"], lg["ln2"] = C.init_norm(ks[2], cfg.d_model, cfg)
    if kind == "moe":
        p["ffn"], lg["ffn"] = MOE.init_moe(ks[3], cfg)
    else:
        p["ffn"], lg["ffn"] = C.init_mlp(ks[3], cfg)
    if kind == "dec":  # cross-attention sublayer
        p["ln_x"], lg["ln_x"] = C.init_norm(ks[4], cfg.d_model, cfg)
        p["xattn"], lg["xattn"] = C.init_attention(ks[5], cfg)
    return p, lg


def _apply_layer(p, x, cfg: LMConfig, kind: str, positions, *,
                 cache=None, cache_pos=None, memory=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), F32)
    h = C.rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = cache
    if kind == "ssm":
        out, new_cache = M2.mamba2_block(p["mix"], h, cfg, state=cache)
        if cache is None:  # training: no state carried
            new_cache = None
        return x + out, new_cache, aux
    if kind == "rec":
        out, new_cache = RG.rglru_block(p["mix"], h, cfg, state=cache)
        if cache is None:
            new_cache = None
    else:
        window = cfg.local_window if kind == "attn_local" else 0
        causal = kind != "enc"
        out, new_cache = C.attention_block(
            p["mix"], h, cfg, positions, causal=causal, window=window,
            kv_cache=cache.get("self") if isinstance(cache, dict) and "self" in cache else cache,
            cache_pos=cache_pos,
        )
    x = x + out
    if kind == "dec" and memory is not None:
        hx = C.rms_norm(x, p["ln_x"], cfg.norm_eps)
        if isinstance(cache, dict) and "cross" in cache:
            # cross K/V are precomputed at prefill; reuse
            xout = _cross_from_cache(p["xattn"], hx, cfg, cache["cross"])
            new_cache = {"self": new_cache, "cross": cache["cross"]}
        else:
            xout, _ = C.attention_block(
                p["xattn"], hx, cfg, positions, causal=False, xk=memory)
            if cache is not None:
                new_cache = {"self": new_cache,
                             "cross": _make_cross_cache(p["xattn"], cfg, memory)}
        x = x + xout
    hf = C.rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        out, aux = MOE.moe_ffn(p["ffn"], hf, cfg)
    else:
        out = C.mlp(p["ffn"], hf)
    x = x + out
    x = shard(x, "batch", "seq", None)
    return x, new_cache, aux


def _make_cross_cache(p_attn, cfg, memory):
    hd = cfg.head_dim
    k = C.linear(memory, p_attn["wk"]).reshape(*memory.shape[:-1], cfg.n_kv_heads, hd)
    v = C.linear(memory, p_attn["wv"]).reshape(*memory.shape[:-1], cfg.n_kv_heads, hd)
    return {"k": k, "v": v}


def _cross_from_cache(p_attn, x, cfg, cross):
    hd = cfg.head_dim
    q = C.linear(x, p_attn["wq"]).reshape(*x.shape[:-1], cfg.n_heads, hd)
    if cfg.qk_norm:
        q = C.rms_norm(q, p_attn["qnorm"], cfg.norm_eps)
    out = C.full_attention(q, cross["k"], cross["v"], causal=False)
    out = out.reshape(*x.shape[:-1], cfg.n_heads * hd)
    return C.linear(out, p_attn["wo"])


# ---------------------------------------------------------------------------
# layer-kind schedule per family
# ---------------------------------------------------------------------------


def layer_kinds(cfg: LMConfig) -> Tuple[str, ...]:
    if cfg.family == "moe":
        return tuple("moe" for _ in range(cfg.n_layers))
    if cfg.family == "ssm":
        return tuple("ssm" for _ in range(cfg.n_layers))
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("attn",)
        return tuple(
            ("attn_local" if pat[i % len(pat)] == "attn" else "rec")
            for i in range(cfg.n_layers)
        )
    return tuple("dense" for _ in range(cfg.n_layers))


def _kind_groups(kinds: Tuple[str, ...]):
    """Group layers into a repeating super-block for scan + an unrolled tail."""
    if len(set(kinds)) == 1:
        return (kinds[0],), len(kinds), ()
    pat = _pattern_period(kinds)
    n_super = len(kinds) // len(pat)
    tail = kinds[n_super * len(pat):]
    return pat, n_super, tail


def _pattern_period(kinds):
    """Smallest prefix that tiles the whole layer-kind sequence."""
    for plen in range(1, len(kinds) + 1):
        if all(kinds[i] == kinds[i % plen] for i in range(len(kinds))):
            return kinds[:plen]
    return kinds


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------


def padded_vocab(cfg: LMConfig) -> int:
    """Megatron-style vocab padding so the TP axis always divides V.

    The published vocab size is kept for the loss/sampling semantics (pad
    logits are masked to -inf in `logits_from_hidden`)."""
    return -(-cfg.vocab // 512) * 512


def init_params(cfg: LMConfig, key) -> Tuple[Dict, Dict]:
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    lg: Dict[str, Any] = {}
    std = cfg.d_model**-0.5
    vp = padded_vocab(cfg)
    p["embed"] = (std * jax.random.normal(ks[0], (vp, cfg.d_model), F32)
                  ).astype(C.dt(cfg))
    lg["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings:
        p["lm_head"], lg["lm_head"] = C.init_linear(
            ks[1], cfg.d_model, vp, "embed", "vocab", cfg)
    p["ln_f"], lg["ln_f"] = C.init_norm(ks[2], cfg.d_model, cfg)

    def stack(key, kind, n):
        keys = jax.random.split(key, max(n, 1))
        _, single_lg = _init_layer(keys[0], cfg, kind)
        stacked = jax.vmap(lambda k: _init_layer(k, cfg, kind)[0])(keys)
        stacked_lg = jax.tree.map(
            lambda ax: (None, *ax), single_lg,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x),
        )
        return stacked, stacked_lg

    if cfg.family in ("encdec", "audio"):
        p["enc"], lg["enc"] = stack(ks[3], "enc", cfg.n_enc_layers)
        p["dec"], lg["dec"] = stack(ks[4], "dec", cfg.n_dec_layers)
        p["ln_enc"], lg["ln_enc"] = C.init_norm(ks[5], cfg.d_model, cfg)
        return p, lg

    kinds = layer_kinds(cfg)
    pat, n_super, tail = _kind_groups(kinds)
    if len(pat) == 1:
        p["layers"], lg["layers"] = stack(ks[3], pat[0], n_super)
    else:
        sup_p, sup_lg = {}, {}
        for i, kind in enumerate(pat):
            sup_p[f"l{i}"], sup_lg[f"l{i}"] = stack(
                jax.random.fold_in(ks[3], i), kind, n_super)
        p["layers"], lg["layers"] = sup_p, sup_lg
    for i, kind in enumerate(tail):
        p[f"tail{i}"], lg[f"tail{i}"] = _init_layer(
            jax.random.fold_in(ks[4], i), cfg, kind)
    if cfg.frontend:
        # modality frontend STUB: a single projection from precomputed
        # patch/frame embeddings into d_model (the real encoder is external)
        p["frontend_proj"], lg["frontend_proj"] = C.init_linear(
            ks[6], cfg.d_model, cfg.d_model, None, "embed", cfg)
    return p, lg


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _remat(fn, cfg: LMConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _run_stack(params, x, cfg, positions, *, caches=None, cache_pos=None):
    """Scan the (super-)block stack. caches: pytree aligned with layers or None.

    Returns (x, new_caches, aux_sum)."""
    kinds = layer_kinds(cfg)
    pat, n_super, tail = _kind_groups(kinds)

    def super_body(carry, xs):
        xx, aux = carry
        layer_p, layer_c = xs
        new_c = {}
        if len(pat) == 1:
            xx, nc, a = _apply_layer(
                layer_p, xx, cfg, pat[0], positions,
                cache=layer_c, cache_pos=cache_pos)
            new_c = nc
            aux = aux + a
        else:
            for i, kind in enumerate(pat):
                ci = layer_c[f"l{i}"] if layer_c is not None else None
                xx, nc, a = _apply_layer(
                    layer_p[f"l{i}"], xx, cfg, kind, positions,
                    cache=ci, cache_pos=cache_pos)
                new_c[f"l{i}"] = nc
                aux = aux + a
        return (xx, aux), new_c

    layer_caches = caches["layers"] if caches is not None else None
    (x, aux), new_layer_caches = jax.lax.scan(
        _remat(super_body, cfg),
        (x, jnp.zeros((), F32)),
        (params["layers"], layer_caches),
        unroll=cfg.scan_unroll,
    )
    new_caches = {"layers": new_layer_caches} if caches is not None else None
    for i, kind in enumerate(tail):
        ci = caches[f"tail{i}"] if caches is not None else None
        x, nc, a = _apply_layer(
            params[f"tail{i}"], x, cfg, kind, positions,
            cache=ci, cache_pos=cache_pos)
        aux = aux + a
        if caches is not None:
            new_caches[f"tail{i}"] = nc
    return x, new_caches, aux


def embed_tokens(params, cfg: LMConfig, tokens, embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(C.dt(cfg))
    if cfg.family in ("vlm",) and embeds is not None:
        fe = C.linear(embeds.astype(C.dt(cfg)), params["frontend_proj"])
        x = jnp.concatenate([fe, x], axis=1)
    x = shard(x, "batch", "seq", None)
    return x


def logits_from_hidden(params, cfg: LMConfig, x):
    x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(x.dtype).T
    else:
        logits = C.linear(x, params["lm_head"])
    vp = padded_vocab(cfg)
    if vp != cfg.vocab:  # mask the padding rows out of the softmax
        mask = jnp.arange(vp) < cfg.vocab
        logits = jnp.where(mask, logits, -1e30)
    return shard(logits, "batch", None, "vocab")


def forward_train(params, cfg: LMConfig, tokens, embeds=None, enc_inputs=None):
    """Causal LM (or enc-dec) forward. Returns logits [B, S, V]."""
    if cfg.family in ("encdec", "audio"):
        return _encdec_forward(params, cfg, tokens, enc_inputs)
    x = embed_tokens(params, cfg, tokens, embeds)
    positions = jnp.arange(x.shape[1])
    x, _, aux = _run_stack(params, x, cfg, positions)
    return logits_from_hidden(params, cfg, x), aux


def _enc_layer_body(cfg):
    def body(x, layer_p):
        xx, _, _ = _apply_layer(layer_p, x, cfg, "enc", jnp.arange(x.shape[1]))
        return xx, None
    return body


def _encdec_forward(params, cfg: LMConfig, tokens, enc_inputs):
    enc_x = enc_inputs.astype(C.dt(cfg))  # [B, S_enc, D] precomputed frames
    enc_x = shard(enc_x, "batch", "seq", None)
    enc_x, _ = jax.lax.scan(
        _remat(_enc_layer_body(cfg), cfg), enc_x, params["enc"],
        unroll=cfg.scan_unroll)
    memory = C.rms_norm(enc_x, params["ln_enc"], cfg.norm_eps)

    x = embed_tokens(params, cfg, tokens)
    positions = jnp.arange(x.shape[1])

    def dec_body(carry, layer_p):
        xx = carry
        xx, _, _ = _apply_layer(layer_p, xx, cfg, "dec", positions, memory=memory)
        return xx, None

    x, _ = jax.lax.scan(_remat(dec_body, cfg), x, params["dec"],
                        unroll=cfg.scan_unroll)
    return logits_from_hidden(params, cfg, x), jnp.zeros((), F32)


def loss_fn(params, cfg: LMConfig, batch):
    """Next-token cross-entropy. batch: dict(tokens [B,S] [, embeds, enc_inputs])."""
    tokens = batch["tokens"]
    logits, aux = forward_train(
        params, cfg, tokens,
        embeds=batch.get("embeds"), enc_inputs=batch.get("enc_inputs"))
    # align: predict tokens[:, 1:] from logits[:, :-1] (vlm: last S positions)
    if cfg.family == "vlm" and batch.get("embeds") is not None:
        logits = logits[:, -tokens.shape[1]:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(F32), axis=-1)
    lp = shard(lp, "batch", None, "vocab")
    tgt = tokens[:, 1:]
    # one-hot contraction instead of take_along_axis: keeps the vocab axis
    # sharded (TP) with a tiny psum instead of an all-gather of the logits.
    # The one-hot itself MUST carry the vocab sharding constraint or GSPMD
    # materializes it replicated: [B,S,V] f32 was the peak-memory term of
    # every train cell (e.g. llama train_4k 33.2 GB/chip -> fits; §Perf #0).
    onehot = shard(jax.nn.one_hot(tgt, lp.shape[-1], dtype=lp.dtype),
                   "batch", None, "vocab")
    ll = jnp.sum(lp * onehot, axis=-1)
    loss = -ll.mean()
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# caches: init / prefill / decode
# ---------------------------------------------------------------------------


def _layer_cache(cfg: LMConfig, kind: str, batch: int, max_len: int):
    hd, kvh = cfg.head_dim or 0, cfg.n_kv_heads
    dtype = C.dt(cfg)
    if kind == "ssm":
        d_in, nh, hp, ns = M2.dims(cfg)
        return {
            "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * ns), dtype),
            "ssd": jnp.zeros((batch, nh, ns, hp), F32),
        }
    if kind == "rec":
        return {
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
            "h": jnp.zeros((batch, cfg.lru_width), F32),
        }
    kv_dtype = jnp.int8 if cfg.kv_bits == 8 else dtype
    if kind == "attn_local":
        size = min(max_len, cfg.local_window)
        cache = {
            "k": jnp.zeros((batch, size, kvh, hd), kv_dtype),
            "v": jnp.zeros((batch, size, kvh, hd), kv_dtype),
            "pos": jnp.full((size,), -1, jnp.int32),
        }
        if cfg.kv_bits == 8:
            cache["k_scale"] = jnp.zeros((batch, size, kvh), jnp.bfloat16)
            cache["v_scale"] = jnp.zeros((batch, size, kvh), jnp.bfloat16)
        return cache
    cache = {
        "k": jnp.zeros((batch, max_len, kvh, hd), kv_dtype),
        "v": jnp.zeros((batch, max_len, kvh, hd), kv_dtype),
    }
    if cfg.kv_bits == 8:
        cache["k_scale"] = jnp.zeros((batch, max_len, kvh), jnp.bfloat16)
        cache["v_scale"] = jnp.zeros((batch, max_len, kvh), jnp.bfloat16)
    if kind == "dec":
        return {"self": cache, "cross": None}  # cross filled at prefill
    return cache


def init_cache(cfg: LMConfig, batch: int, max_len: int, enc_len: int = 0):
    kinds = layer_kinds(cfg)
    if cfg.family in ("encdec", "audio"):
        dtype = C.dt(cfg)
        hd, kvh = cfg.head_dim, cfg.n_kv_heads
        per = {
            "self": {
                "k": jnp.zeros((cfg.n_dec_layers, batch, max_len, kvh, hd), dtype),
                "v": jnp.zeros((cfg.n_dec_layers, batch, max_len, kvh, hd), dtype),
            },
            "cross": {
                "k": jnp.zeros((cfg.n_dec_layers, batch, enc_len, kvh, hd), dtype),
                "v": jnp.zeros((cfg.n_dec_layers, batch, enc_len, kvh, hd), dtype),
            },
        }
        return per
    pat, n_super, tail = _kind_groups(kinds)
    if len(pat) == 1:
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_super, *x.shape)).copy(),
            _layer_cache(cfg, pat[0], batch, max_len))
        caches = {"layers": stacked}
    else:
        caches = {"layers": {}}
        for i, kind in enumerate(pat):
            single = _layer_cache(cfg, kind, batch, max_len)
            caches["layers"][f"l{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_super, *x.shape)).copy(), single)
    for i, kind in enumerate(tail):
        caches[f"tail{i}"] = _layer_cache(cfg, kind, batch, max_len)
    return caches


def cache_logical(cfg: LMConfig):
    """Logical axes for cache leaves (batch-sharded, heads model-sharded)."""
    def leaf_axes(x):
        if x.ndim >= 4:  # [(L,)? B, S, KV, hd] or ssd [(L,)? B, H, N, P]
            lead = (None,) * (x.ndim - 4)
            return (*lead, "batch", None, "heads", None)
        if x.ndim >= 2:
            return ("batch",) + (None,) * (x.ndim - 1)
        return (None,) * x.ndim
    return leaf_axes


def prefill(params, cfg: LMConfig, tokens, max_len: int, embeds=None,
            enc_inputs=None):
    """Run the prompt, fill caches. Returns (last_logits, cache)."""
    b = tokens.shape[0]
    if cfg.family in ("encdec", "audio"):
        return _encdec_prefill(params, cfg, tokens, max_len, enc_inputs)
    caches = init_cache(cfg, b, max_len)
    x = embed_tokens(params, cfg, tokens, embeds)
    positions = jnp.arange(x.shape[1])
    x, new_caches, _ = _run_stack(params, x, cfg, positions, caches=caches)
    logits = logits_from_hidden(params, cfg, x[:, -1:])
    return logits, new_caches


def decode_step(params, cfg: LMConfig, token, caches, pos):
    """token: [B, 1] int32; pos: scalar int32 (current absolute position)."""
    if cfg.family in ("encdec", "audio"):
        return _encdec_decode(params, cfg, token, caches, pos)
    x = embed_tokens(params, cfg, token)
    positions = pos + jnp.arange(1)
    x, new_caches, _ = _run_stack(
        params, x, cfg, positions, caches=caches, cache_pos=pos)
    logits = logits_from_hidden(params, cfg, x)
    return logits, new_caches


def _encdec_prefill(params, cfg, tokens, max_len, enc_inputs):
    enc_x = enc_inputs.astype(C.dt(cfg))
    enc_x, _ = jax.lax.scan(_enc_layer_body(cfg), enc_x, params["enc"],
                            unroll=cfg.scan_unroll)
    memory = C.rms_norm(enc_x, params["ln_enc"], cfg.norm_eps)
    b, s_enc = memory.shape[0], memory.shape[1]
    caches = init_cache(cfg, b, max_len, enc_len=s_enc)
    hd, kvh = cfg.head_dim, cfg.n_kv_heads

    def cross_body(_, layer_p):
        k = C.linear(memory, layer_p["xattn"]["wk"]).reshape(b, s_enc, kvh, hd)
        v = C.linear(memory, layer_p["xattn"]["wv"]).reshape(b, s_enc, kvh, hd)
        return None, {"k": k, "v": v}

    _, cross = jax.lax.scan(cross_body, None, params["dec"],
                            unroll=cfg.scan_unroll)
    caches["cross"] = cross

    x = embed_tokens(params, cfg, tokens)
    positions = jnp.arange(x.shape[1])

    def dec_body(xx, xs):
        layer_p, self_c, cross_c = xs
        hh = C.rms_norm(xx, layer_p["ln1"], cfg.norm_eps)
        out, new_self = C.attention_block(
            layer_p["mix"], hh, cfg, positions, causal=True,
            kv_cache=self_c, cache_pos=None)
        xx = xx + out
        hx = C.rms_norm(xx, layer_p["ln_x"], cfg.norm_eps)
        xx = xx + _cross_from_cache(layer_p["xattn"], hx, cfg, cross_c)
        xx = xx + C.mlp(layer_p["ffn"], C.rms_norm(xx, layer_p["ln2"], cfg.norm_eps))
        return xx, new_self

    x, new_self = jax.lax.scan(
        dec_body, x,
        (params["dec"],
         {"k": caches["self"]["k"], "v": caches["self"]["v"]},
         cross),
        unroll=cfg.scan_unroll,
    )
    caches = {"self": new_self, "cross": cross}
    logits = logits_from_hidden(params, cfg, x[:, -1:])
    return logits, caches


def _encdec_decode(params, cfg, token, caches, pos):
    x = embed_tokens(params, cfg, token)
    positions = pos + jnp.arange(1)

    def dec_body(xx, xs):
        layer_p, self_c, cross_c = xs
        hh = C.rms_norm(xx, layer_p["ln1"], cfg.norm_eps)
        out, new_self = C.attention_block(
            layer_p["mix"], hh, cfg, positions, causal=True,
            kv_cache=self_c, cache_pos=pos)
        xx = xx + out
        hx = C.rms_norm(xx, layer_p["ln_x"], cfg.norm_eps)
        xx = xx + _cross_from_cache(layer_p["xattn"], hx, cfg, cross_c)
        xx = xx + C.mlp(layer_p["ffn"], C.rms_norm(xx, layer_p["ln2"], cfg.norm_eps))
        return xx, new_self

    x, new_self = jax.lax.scan(
        dec_body, x, (params["dec"], caches["self"], caches["cross"]),
        unroll=cfg.scan_unroll)
    caches = {"self": new_self, "cross": caches["cross"]}
    logits = logits_from_hidden(params, cfg, x)
    return logits, caches


__all__ = [
    "init_params", "forward_train", "loss_fn", "init_cache", "prefill",
    "decode_step", "layer_kinds", "cache_logical",
]
