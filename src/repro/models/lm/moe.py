"""Mixture-of-Experts FFN with capacity-based dispatch (GShard-style).

Used by arctic-480b (128 routed experts, top-2, plus a *dense residual* MLP in
parallel) and qwen2-moe-a2.7b (60 routed experts, top-4, plus shared experts).

Expert-parallel design: the expert buffer [E, C, D] carries logical axis
'experts' -> mesh axis 'model', so expert weights and expert compute shard
E-ways while attention shards over heads — tokens move between data and
expert shards via the XLA-inserted all-to-all around the scatter/gather.
Capacity-based dropping keeps every shape static (required for pjit).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.lm.config import LMConfig
from repro.models.lm.common import dt, init_mlp, mlp

F32 = jnp.float32


def init_moe(key, cfg: LMConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    std = d**-0.5
    p = {
        "router": {"w": (std * jax.random.normal(ks[0], (d, e), F32)).astype(dt(cfg))},
        "wi": (std * jax.random.normal(ks[1], (e, d, f), F32)).astype(dt(cfg)),
        "wg": (std * jax.random.normal(ks[2], (e, d, f), F32)).astype(dt(cfg)),
        "wo": (f**-0.5 * jax.random.normal(ks[3], (e, f, d), F32)).astype(dt(cfg)),
    }
    # NOTE: experts and ffn would both map to 'model' — EP wins (the paper's
    # heterogeneity principle: give each operator ITS parallelism axis); the
    # per-expert FFN stays unsharded inside its expert shard.
    lg = {
        "router": {"w": ("embed", None)},
        "wi": ("experts", "embed", None),
        "wg": ("experts", "embed", None),
        "wo": ("experts", None, "embed"),
    }
    if cfg.n_shared_experts:
        p["shared"], lg["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.shared_d_ff)
    if cfg.dense_residual:
        p["dense"], lg["dense"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff)
    return p, lg


def moe_ffn(p, x, cfg: LMConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y, aux_loss). Capacity-dropped top-k routing."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt @ p["router"]["w"].astype(xt.dtype)).astype(F32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((e,), F32).at[idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # capacity per expert
    cap = int(max(1, round(k * t * cfg.capacity_factor / e)))
    cap = min(cap, t)

    # position of each (token, choice) within its expert's buffer
    flat_e = idx.reshape(-1)  # [T*k], token-major order
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # [T*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1  # positions per expert
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < cap
    safe_pos = jnp.where(keep, flat_pos, 0)

    # dispatch: scatter tokens into the expert buffer [E, C, D]
    xk = jnp.repeat(xt, k, axis=0)  # [T*k, D] (token-major, matches flat_e)
    contrib = jnp.where(keep[:, None], xk, 0)
    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[flat_e, safe_pos].add(jnp.where(keep[:, None], contrib, 0))
    buf = shard(buf, "experts", None, None)

    # expert compute (batched over E; shards E-ways)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(buf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(buf.dtype))
    h = shard(h, "experts", None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(buf.dtype))
    out_buf = shard(out_buf, "experts", None, None)

    # combine: gather back and weight by the gate
    y_tk = out_buf[flat_e, safe_pos]  # [T*k, D]
    y_tk = jnp.where(keep[:, None], y_tk, 0)
    y = (y_tk.reshape(t, k, d) * gate[..., None].astype(xt.dtype)).sum(1)

    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], xt)
    if cfg.dense_residual:
        y = y + mlp(p["dense"], xt)
    return y.reshape(b, s, d), aux


__all__ = ["init_moe", "moe_ffn"]
