"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (diagonal linear RNN with input and recurrence gates):

    r_t = sigmoid(W_a x_t + b_a)                (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)      (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Block: two parallel input projections (value branch + gelu gate branch);
the value branch passes a short causal depthwise conv1d then the RG-LRU;
output = W_o (h * gelu(gate)). Training/prefill evaluates the recurrence with
`jax.lax.associative_scan` (parallel prefix — sub-quadratic and TPU-friendly);
decode is an O(1) state update. This is the sub-quadratic path that makes
long_500k runnable for the hybrid architecture.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro.models.lm.config import LMConfig
from repro.models.lm.common import dt, init_linear, linear

F32 = jnp.float32
_C = 8.0


def init_rglru_block(key, cfg: LMConfig):
    d, r = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    p, lg = {}, {}
    p["wx"], lg["wx"] = init_linear(ks[0], d, r, "embed", "ffn", cfg)
    p["wgate"], lg["wgate"] = init_linear(ks[1], d, r, "embed", "ffn", cfg)
    p["conv_w"] = 0.1 * jax.random.normal(ks[2], (cfg.conv_width, r), F32).astype(dt(cfg))
    lg["conv_w"] = (None, "ffn")
    if cfg.rglru_diagonal_gates:
        # Griffin-style per-dimension gates: elementwise, collective-free
        # under TP (the [R,R] gate matmuls contract over the sharded R axis
        # and cost one psum per layer — see EXPERIMENTS.md §Perf)
        p["wa"] = 0.05 * jax.random.normal(ks[3], (r,), F32).astype(dt(cfg))
        p["wi"] = 0.05 * jax.random.normal(ks[4], (r,), F32).astype(dt(cfg))
        lg["wa"] = ("ffn",)
        lg["wi"] = ("ffn",)
    else:
        # gate matrices contract over the sharded R axis (row-parallel; one psum)
        p["wa"], lg["wa"] = init_linear(ks[3], r, r, "ffn", None, cfg, std=0.05)
        p["wi"], lg["wi"] = init_linear(ks[4], r, r, "ffn", None, cfg, std=0.05)
    # Lambda parameterized so a_t starts in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (r,), F32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    p["lam"] = lam.astype(F32)
    lg["lam"] = ("ffn",)
    p["wo"], lg["wo"] = init_linear(ks[6], r, d, "ffn", "embed", cfg)
    return p, lg


def _causal_conv1d(x, w, state=None):
    """x: [B, S, R]; w: [K, R] depthwise. state: [B, K-1, R] for decode."""
    kw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(kw)
    )
    new_state = xp[:, -(kw - 1) :, :] if kw > 1 else None
    return y, new_state


def _rglru_gates(p, xc):
    if not isinstance(p["wa"], dict):  # diagonal gates (collective-free, TP)
        r_gate = jax.nn.sigmoid((xc * p["wa"]).astype(F32))
        i_gate = jax.nn.sigmoid((xc * p["wi"]).astype(F32))
    else:
        r_gate = jax.nn.sigmoid(linear(xc, p["wa"]).astype(F32))
        i_gate = jax.nn.sigmoid(linear(xc, p["wi"]).astype(F32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r_gate  # [B, S, R]
    a = jnp.exp(log_a)
    gated_x = i_gate * xc.astype(F32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    return a, b


def _comb(l, r):
    al, bl = l
    ar, br = r
    return al * ar, bl * ar + br


def rglru_scan(p, xc, chunk: int = 0):
    """Parallel evaluation of h_t = a_t h_{t-1} + b_t over the sequence.

    chunk == 0: one associative scan over the whole sequence (log2(S) sweep
    levels -> O(S log S) intermediate traffic). chunk > 0: associative scan
    within chunks + a sequential lax.scan carrying the chunk-boundary state —
    the memory-traffic structure of SSD, a §Perf lever."""
    a, b = _rglru_gates(p, xc)
    if not chunk or xc.shape[1] <= chunk:
        _, h = jax.lax.associative_scan(_comb, (a, b), axis=1)
        return h.astype(xc.dtype), h[:, -1].astype(F32)

    bsz, s, r = xc.shape
    pad = (-s) % chunk
    if pad:  # a=1, b=0 is recurrence-neutral
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    ac = a.reshape(bsz, nc, chunk, r).transpose(1, 0, 2, 3)
    bc = b.reshape(bsz, nc, chunk, r).transpose(1, 0, 2, 3)

    def step(h0, ab):
        aa, bb = ab
        a_cum, b_cum = jax.lax.associative_scan(_comb, (aa, bb), axis=1)
        h = a_cum * h0[:, None, :] + b_cum  # fold in the carried state
        return h[:, -1], h

    h_last, hs = jax.lax.scan(step, jnp.zeros((bsz, r), F32), (ac, bc))
    h = hs.transpose(1, 0, 2, 3).reshape(bsz, nc * chunk, r)[:, :s]
    return h.astype(xc.dtype), h_last.astype(F32)


def rglru_step(p, xc, h_prev):
    """One decode step. xc: [B, 1, R]; h_prev: [B, R] f32."""
    a, b = _rglru_gates(p, xc)
    h = a[:, 0] * h_prev + b[:, 0]
    return h[:, None, :].astype(xc.dtype), h


def rglru_block(p, x, cfg: LMConfig, state: Optional[dict] = None):
    """Full recurrent block. state: {'conv': [B,K-1,R], 'h': [B,R]} or None.

    Returns (out, new_state)."""
    xv = linear(x, p["wx"])
    xv = shard(xv, "batch", None, "ffn")
    g = jax.nn.gelu(linear(x, p["wgate"]))
    # decode = single-token step against carried state; train/prefill = scan
    # (prefill passes a zero-initialized state, which the scan path assumes)
    decode = state is not None and x.shape[1] == 1
    conv_state = state["conv"] if decode else None
    xc, new_conv = _causal_conv1d(xv, p["conv_w"].astype(F32), conv_state)
    if decode:
        h, h_last = rglru_step(p, xc, state["h"])
    else:
        h, h_last = rglru_scan(p, xc, chunk=cfg.rglru_chunk)
    out = linear(h.astype(g.dtype) * g, p["wo"])
    new_state = {
        "conv": (new_conv if new_conv is not None else jnp.zeros(
            (x.shape[0], cfg.conv_width - 1, cfg.lru_width), dt(cfg))),
        "h": h_last,
    }
    return out, new_state


__all__ = ["init_rglru_block", "rglru_block", "rglru_scan", "rglru_step"]
