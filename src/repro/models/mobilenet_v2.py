"""MobileNet-V2 NetSpec builder with the paper's tunable knobs (Sec. 2, 5.1).

Knobs:  alpha (width multiplier, scales channel counts), H (input resolution),
        BW (bit-width; first normal conv at 8 bits, the rest at BW — Sec. 5.1).

Topology follows the original [Sandler et al. 2018] inverted-residual stack:
    stem conv 3x3 s2 -> 17 IRBs -> pw 1280 -> avgpool -> classifier.
The paper's CU mapping (Fig. 15): Head = stem conv + IRB_0 (the special t=1
block, 'called once'); Body = the remaining 16 IRBs; Tail = pw-1280 + avgpool;
Classifier = dense 1280 -> k.
"""
from __future__ import annotations

from typing import Tuple

from repro.core.graph import (
    CONV,
    DENSE,
    DW,
    NONE,
    PW,
    RELU6,
    BlockSpec,
    NetSpec,
    OpSpec,
)

# (expansion t, out channels c, repeats n, first stride s)
IRB_SETTINGS: Tuple[Tuple[int, int, int, int], ...] = (
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
)


def _make_divisible(v: float, divisor: int = 8) -> int:
    """Standard MobileNet channel rounding (keeps channels MXU/SIMD friendly)."""
    new_v = max(divisor, int(v + divisor / 2) // divisor * divisor)
    if new_v < 0.9 * v:
        new_v += divisor
    return new_v


def irb_block(
    name: str, in_ch: int, out_ch: int, t: int, stride: int, bits: int
) -> BlockSpec:
    """Inverted Residual Block (Fig. 3a): pw-expand -> dw -> pw-project."""
    hidden = in_ch * t
    ops = []
    if t != 1:
        ops.append(OpSpec(f"{name}/expand", PW, in_ch, hidden, 1, 1, RELU6, bits, bits))
    ops.append(OpSpec(f"{name}/dw", DW, hidden, hidden, 3, stride, RELU6, bits, bits))
    # projection conv is linear (no activation) — embeds into lower dimension
    ops.append(OpSpec(f"{name}/project", PW, hidden, out_ch, 1, 1, NONE, bits, bits))
    residual = stride == 1 and in_ch == out_ch
    return BlockSpec(name, tuple(ops), residual=residual)


def build(
    alpha: float = 1.0,
    input_hw: int = 224,
    bits: int = 4,
    first_conv_bits: int = 8,
    num_classes: int = 1000,
    round_nearest: int = 8,
) -> NetSpec:
    stem_ch = _make_divisible(32 * alpha, round_nearest)
    blocks = []
    # --- Head: stem normal conv (the single 'normal convolution' of a DSCNN) ---
    blocks.append(
        BlockSpec(
            "stem",
            (
                OpSpec(
                    "stem/conv", CONV, 3, stem_ch, 3, 2, RELU6, first_conv_bits, bits
                ),
            ),
        )
    )
    in_ch = stem_ch
    idx = 0
    for t, c, n, s in IRB_SETTINGS:
        out_ch = _make_divisible(c * alpha, round_nearest)
        for i in range(n):
            stride = s if i == 0 else 1
            blocks.append(irb_block(f"irb{idx}", in_ch, out_ch, t, stride, bits))
            in_ch = out_ch
            idx += 1
    # --- Tail: pw 1280 + global average pool ---
    last_ch = _make_divisible(1280 * max(1.0, alpha), round_nearest)
    blocks.append(
        BlockSpec(
            "tail",
            (OpSpec("tail/pw", PW, in_ch, last_ch, 1, 1, RELU6, bits, bits),),
            avgpool=True,
        )
    )
    # --- Classifier ---
    blocks.append(
        BlockSpec(
            "classifier",
            (OpSpec("classifier/fc", DENSE, last_ch, num_classes, 1, 1, NONE, bits, bits),),
        )
    )
    return NetSpec(
        name=f"mobilenet_v2_a{alpha}_h{input_hw}_bw{bits}",
        blocks=tuple(blocks),
        input_hw=input_hw,
        num_classes=num_classes,
    )


__all__ = ["build", "irb_block", "IRB_SETTINGS"]
