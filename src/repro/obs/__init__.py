"""Observability layer: request-lifecycle tracing + a metrics registry.

Zero-dependency substrate the serving / streaming / tuning / training
subsystems report into (the benchmark harness reads the
``serve_fps_per_watt`` gauge out of it for the CI energy gate):

  * `trace`   — span-based `Tracer` with an injectable clock, exported as
                Chrome trace-event JSON (Perfetto-loadable); `NULL` no-op
                tracer keeps the hot path untouched when tracing is off.
  * `metrics` — counters / gauges / fixed-bucket histograms with
                Prometheus text exposition and a JSON-safe snapshot
                (`NULL_REGISTRY` when disabled).
  * `summary` — `python -m repro.obs summarize` pipeline-profile reports
                (top-N slowest spans, queue-wait percentiles); `validate`
                schema-checks exported traces in CI.

Invariants the tests pin:

  * **Off means off** — with `NULL` / `NULL_REGISTRY`, instrumented code
    performs zero clock reads and zero allocations on the hot path; the
    obs-on overhead budget (<5% of serving FPS) gates in the benchmark
    smoke.
  * **Byte-determinism under fake clocks** — every timestamp comes from
    the injected clock, so two runs with the same fake clock export
    byte-identical traces and snapshots (no wall-clock reads anywhere).
  * Exported traces must pass `python -m repro.obs validate` — the same
    schema CI gates on.

See docs/serving.md (Observability section) for the metric and span
naming conventions, and docs/benchmarks.md for what gates.
"""
from repro.obs.metrics import (
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_REGISTRY,
    NullRegistry,
)
from repro.obs.summary import render_report, span_groups, summarize_trace
from repro.obs.trace import (
    NULL,
    NullTracer,
    Tracer,
    validate_chrome_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "NULL",
    "NULL_REGISTRY",
    "NullRegistry",
    "NullTracer",
    "Tracer",
    "render_report",
    "span_groups",
    "summarize_trace",
    "validate_chrome_trace",
]
