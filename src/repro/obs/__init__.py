"""Observability layer: request-lifecycle tracing + a metrics registry.

Zero-dependency substrate the serving / tuning / training subsystems report
into (and the ROADMAP's autoscaling replica manager and energy CI gate will
read from):

  * `trace`   — span-based `Tracer` with an injectable clock, exported as
                Chrome trace-event JSON (Perfetto-loadable); `NULL` no-op
                tracer keeps the hot path untouched when tracing is off.
  * `metrics` — counters / gauges / fixed-bucket histograms with
                Prometheus text exposition and a JSON-safe snapshot
                (`NULL_REGISTRY` when disabled).
  * `summary` — `python -m repro.obs summarize` pipeline-profile reports
                (top-N slowest spans, queue-wait percentiles); `validate`
                schema-checks exported traces in CI.
"""
from repro.obs.metrics import (
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_REGISTRY,
    NullRegistry,
)
from repro.obs.summary import render_report, span_groups, summarize_trace
from repro.obs.trace import (
    NULL,
    NullTracer,
    Tracer,
    validate_chrome_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "NULL",
    "NULL_REGISTRY",
    "NullRegistry",
    "NullTracer",
    "Tracer",
    "render_report",
    "span_groups",
    "summarize_trace",
    "validate_chrome_trace",
]
