"""Observability CLI.

    PYTHONPATH=src python -m repro.obs summarize \
        --trace trace.json --metrics metrics.json [--top 10]

renders the pipeline profile of one serving run (top-N slowest span groups,
queue-wait / latency percentiles, the FPS and FPS/Watt-proxy gauges).

    PYTHONPATH=src python -m repro.obs validate --trace trace.json

schema-checks an exported Chrome trace (exit 1 on any violation) — the CI
gate over the bench-smoke trace artifact.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs.summary import load_json, render_report, summarize_trace
from repro.obs.trace import validate_chrome_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summarize", help="render a pipeline-profile report")
    s.add_argument("--trace", default=None, help="Chrome trace JSON")
    s.add_argument("--metrics", default=None, help="metrics snapshot JSON")
    s.add_argument("--top", type=int, default=10,
                   help="span groups to show, by total time")

    v = sub.add_parser("validate", help="schema-check a Chrome trace")
    v.add_argument("--trace", required=True, help="Chrome trace JSON")

    args = ap.parse_args(argv)
    if args.cmd == "validate":
        errors = validate_chrome_trace(load_json(args.trace))
        for e in errors:
            print(f"[obs-validate] {e}", file=sys.stderr)
        print(f"[obs-validate] {args.trace}: "
              + ("OK" if not errors else f"{len(errors)} violation(s)"))
        return 1 if errors else 0

    if not args.trace and not args.metrics:
        ap.error("summarize needs --trace and/or --metrics")
    trace_summary = None
    if args.trace:
        trace_summary = summarize_trace(load_json(args.trace), top=args.top)
    metrics = load_json(args.metrics) if args.metrics else None
    try:
        print(render_report(trace_summary, metrics, top=args.top))
    except BrokenPipeError:  # `... | head` closed the pipe: not an error
        sys.stderr.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
