"""Metrics registry: counters, gauges, fixed-bucket histograms.

Prometheus-style instruments with zero dependencies, designed to be safe to
touch on the serving hot path: `inc`/`set`/`observe` are a handful of float
ops and a bisect — no allocation, no locks (the serving loop is
single-threaded by construction), no label parsing at observe time (labels
are frozen at registration, so an instrument handle is grabbed once at
engine construction and hammered thereafter).

Two export surfaces:

  * `to_prometheus()` — the text exposition format (`# TYPE` lines,
    cumulative `_bucket{le=...}` histogram rows) for scraping or a
    `--metrics-out metrics.prom` dump.
  * `snapshot()` — a JSON-safe dict (non-finite values become None, so
    `json.dumps(snapshot, allow_nan=False)` always succeeds — a registry
    snapshot is well-defined at zero completions by construction).

Instruments are get-or-create: registering the same (name, labels) twice
returns the same handle; re-registering under a different type (or a
histogram under different buckets) raises — silent double-registration is
how two subsystems end up splitting one logical counter.
"""
from __future__ import annotations

import math
from bisect import bisect_left
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

# Default latency buckets (seconds): 100us .. 10s, roughly log-spaced — the
# serving path spans sub-ms CPU micro-batches to multi-second cold drains.
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labels: Optional[Mapping[str, str]]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((labels or {}).items()))


def _label_str(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


def _json_num(v: float) -> Optional[float]:
    return float(v) if math.isfinite(v) else None


class Counter:
    """Monotone counter. `inc` with a negative amount raises — a counter
    that can go down is a gauge wearing the wrong type."""

    __slots__ = ("name", "help", "labels", "value")

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[Tuple[str, str], ...] = ()):
        self.name, self.help, self.labels = name, help, labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({n})")
        self.value += n


class Gauge:
    __slots__ = ("name", "help", "labels", "value")

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[Tuple[str, str], ...] = ()):
        self.name, self.help, self.labels = name, help, labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket histogram with Prometheus `le` (inclusive upper bound)
    semantics. `counts[i]` is the NON-cumulative count of the i-th bucket;
    the implicit +Inf bucket is `counts[-1]`. Export cumulates."""

    __slots__ = ("name", "help", "labels", "buckets", "counts", "sum",
                 "count")

    def __init__(self, name: str, buckets: Sequence[float], help: str = "",
                 labels: Tuple[Tuple[str, str], ...] = ()):
        bounds = tuple(float(b) for b in buckets)
        if not bounds:
            raise ValueError(f"histogram {name}: need at least one bucket")
        if any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(
                f"histogram {name}: bucket bounds must be strictly "
                f"increasing, got {bounds}")
        if any(not math.isfinite(b) for b in bounds):
            raise ValueError(f"histogram {name}: bounds must be finite "
                             f"(+Inf is implicit), got {bounds}")
        self.name, self.help, self.labels = name, help, labels
        self.buckets = bounds
        self.counts = [0] * (len(bounds) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Pointwise sum under identical bounds (associative, commutative;
        the merge of shard-local histograms IS the fleet histogram)."""
        if self.buckets != other.buckets:
            raise ValueError(
                f"cannot merge histograms with different buckets: "
                f"{self.buckets} vs {other.buckets}")
        out = Histogram(self.name, self.buckets, self.help, self.labels)
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.sum = self.sum + other.sum
        out.count = self.count + other.count
        return out

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-resolution quantile estimate: the upper bound of the
        first bucket whose cumulative count reaches q*count (linear
        interpolation inside the bucket; the +Inf bucket reports the top
        finite bound). None with zero observations — never NaN."""
        if self.count == 0:
            return None
        q = min(max(q, 0.0), 1.0)
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            prev_cum = cum
            cum += c
            if cum >= target and c > 0:
                if i >= len(self.buckets):
                    return self.buckets[-1]
                lo = 0.0 if i == 0 else self.buckets[i - 1]
                hi = self.buckets[i]
                frac = (target - prev_cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self.buckets[-1]


class _NullInstrument:
    """Shared no-op stand-in for every instrument type (what a
    `NullRegistry` hands out): the hot path calls observe/inc/set
    unconditionally and pays one empty method call when metrics are off."""

    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        pass

    def dec(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


NULL_INSTRUMENT = _NullInstrument()


class MetricsRegistry:
    def __init__(self):
        # (name, labelkey) -> instrument; name -> type for conflict checks
        self._instruments: Dict[Tuple[str, Tuple], object] = {}
        self._types: Dict[str, type] = {}
        self._help: Dict[str, str] = {}

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self._instruments)

    def _get(self, cls, name: str, help: str,
             labels: Optional[Mapping[str, str]], **kwargs):
        lk = _label_key(labels)
        inst = self._instruments.get((name, lk))
        if inst is not None:
            if not isinstance(inst, cls):
                raise ValueError(
                    f"{name} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            if (isinstance(inst, Histogram) and "buckets" in kwargs
                    and tuple(kwargs["buckets"]) != inst.buckets):
                raise ValueError(
                    f"histogram {name} already registered with buckets "
                    f"{inst.buckets}")
            return inst
        if self._types.setdefault(name, cls) is not cls:
            raise ValueError(
                f"{name} already registered as "
                f"{self._types[name].__name__}, not {cls.__name__}")
        if help:
            self._help.setdefault(name, help)
        inst = cls(name, help=help or self._help.get(name, ""),
                   labels=lk, **kwargs)
        self._instruments[(name, lk)] = inst
        return inst

    def counter(self, name: str, help: str = "",
                labels: Optional[Mapping[str, str]] = None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Mapping[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Mapping[str, str]] = None,
                  buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def instruments(self) -> Iterable[object]:
        return self._instruments.values()

    # -- export ------------------------------------------------------------

    def snapshot(self) -> Dict[str, Dict]:
        """JSON-safe state dump: {'counters': {...}, 'gauges': {...},
        'histograms': {...}} keyed by label-qualified metric name. Every
        value is finite-or-None (`json.dumps(..., allow_nan=False)` safe),
        and histograms carry bucket-estimate p50/p95/p99 (None when
        empty — a snapshot at zero completions has no NaN anywhere)."""
        out: Dict[str, Dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for inst in self._instruments.values():
            key = inst.name + _label_str(inst.labels)
            if isinstance(inst, Counter):
                out["counters"][key] = _json_num(inst.value)
            elif isinstance(inst, Gauge):
                out["gauges"][key] = _json_num(inst.value)
            elif isinstance(inst, Histogram):
                out["histograms"][key] = {
                    "buckets": list(inst.buckets),
                    "counts": list(inst.counts),
                    "sum": _json_num(inst.sum),
                    "count": inst.count,
                    "p50": inst.quantile(0.5),
                    "p95": inst.quantile(0.95),
                    "p99": inst.quantile(0.99),
                }
        return out

    def to_prometheus(self) -> str:
        """Text exposition format (one # HELP/# TYPE block per name)."""
        by_name: Dict[str, List] = {}
        for inst in self._instruments.values():
            by_name.setdefault(inst.name, []).append(inst)
        lines: List[str] = []
        for name in sorted(by_name):
            group = by_name[name]
            kind = {Counter: "counter", Gauge: "gauge",
                    Histogram: "histogram"}[type(group[0])]
            help_text = next((g.help for g in group if g.help), "")
            if help_text:
                lines.append(f"# HELP {name} {help_text}")
            lines.append(f"# TYPE {name} {kind}")
            for inst in sorted(group, key=lambda g: g.labels):
                ls = _label_str(inst.labels)
                if isinstance(inst, Histogram):
                    cum = 0
                    for bound, c in zip(inst.buckets, inst.counts):
                        cum += c
                        lb = dict(inst.labels, le=repr(bound))
                        lines.append(
                            f"{name}_bucket"
                            + _label_str(tuple(sorted(lb.items())))
                            + f" {cum}")
                    lb = dict(inst.labels, le="+Inf")
                    lines.append(
                        f"{name}_bucket"
                        + _label_str(tuple(sorted(lb.items())))
                        + f" {inst.count}")
                    lines.append(f"{name}_sum{ls} {inst.sum}")
                    lines.append(f"{name}_count{ls} {inst.count}")
                else:
                    lines.append(f"{name}{ls} {inst.value}")
        return "\n".join(lines) + ("\n" if lines else "")

    def save(self, path: str) -> str:
        """Write the registry to `path`: Prometheus text for .prom/.txt,
        JSON snapshot otherwise."""
        import json as _json
        if path.endswith((".prom", ".txt")):
            body = self.to_prometheus()
        else:
            body = _json.dumps(self.snapshot(), indent=1, allow_nan=False)
        with open(path, "w") as f:
            f.write(body)
        return path


class NullRegistry:
    """Falsy registry returning the shared no-op instrument — lets call
    sites register instruments unconditionally and keep the hot path
    branch-free when metrics are disabled."""

    def __bool__(self) -> bool:
        return False

    def counter(self, *a, **k) -> _NullInstrument:
        return NULL_INSTRUMENT

    def gauge(self, *a, **k) -> _NullInstrument:
        return NULL_INSTRUMENT

    def histogram(self, *a, **k) -> _NullInstrument:
        return NULL_INSTRUMENT


NULL_REGISTRY = NullRegistry()


__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "NULL_INSTRUMENT",
    "NULL_REGISTRY",
    "NullRegistry",
]
