"""Pipeline-profile reports over exported traces + metrics snapshots.

`python -m repro.obs summarize --trace t.json --metrics m.json` renders the
human view of one serving run: top-N slowest span groups (where did the
wall time go, stage by stage), per-request queue-wait and end-to-end
latency percentiles reconstructed from the request-lifecycle spans, and the
headline FPS / FPS-per-Watt-proxy gauges from the metrics snapshot. The
same functions are importable (the bench harness folds `summarize_trace`
output into the BENCH report; tests assert on the dicts, not the text).
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional, Sequence


def load_json(path: str) -> Any:
    with open(path) as f:
        return json.load(f)


def _percentile(sorted_vals: Sequence[float], p: float) -> Optional[float]:
    if not sorted_vals:
        return None
    return sorted_vals[max(0, math.ceil(p * len(sorted_vals)) - 1)]


def span_groups(events: List[Dict], top: Optional[int] = None) -> List[Dict]:
    """Group "X" spans by name: count / total / mean / max duration (us),
    sorted by total descending — the 'top-N slowest stages' table."""
    groups: Dict[str, Dict] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        g = groups.setdefault(ev["name"], {
            "name": ev["name"], "count": 0, "total_us": 0.0, "max_us": 0.0})
        dur = float(ev.get("dur", 0.0))
        g["count"] += 1
        g["total_us"] += dur
        g["max_us"] = max(g["max_us"], dur)
    out = sorted(groups.values(), key=lambda g: (-g["total_us"], g["name"]))
    for g in out:
        g["mean_us"] = g["total_us"] / g["count"] if g["count"] else 0.0
    return out[:top] if top else out


def async_durations(events: List[Dict], name: str,
                    cat: str = "request") -> Dict[Any, float]:
    """Durations (seconds) of completed async b/e span pairs, keyed by
    (cat, id). `cat` matches exactly or as a `cat:qualifier` prefix — the
    engine qualifies the request category per model ("request:mnv2"), and
    ids (rids) are only unique within one model's category. Unmatched
    begins are dropped (an unfinished request has no duration yet)."""
    begins: Dict[Any, float] = {}
    durs: Dict[Any, float] = {}
    for ev in events:
        ec = ev.get("cat")
        if (ev.get("name") != name or not isinstance(ec, str)
                or (ec != cat and not ec.startswith(cat + ":"))):
            continue
        key = (ec, ev.get("id"))
        if ev.get("ph") == "b":
            begins[key] = float(ev["ts"])
        elif ev.get("ph") == "e" and key in begins:
            durs[key] = (float(ev["ts"]) - begins.pop(key)) * 1e-6
    return durs


def summarize_trace(doc: Dict, top: int = 10) -> Dict[str, Any]:
    """The structured profile of one trace document."""
    events = doc.get("traceEvents", [])
    queue_waits = sorted(async_durations(events, "queue_wait").values())
    req_durs = sorted(async_durations(events, "request").values())
    statuses: Dict[str, int] = {}
    for ev in events:
        if (ev.get("ph") == "e" and ev.get("name") == "request"
                and isinstance(ev.get("args"), dict)):
            status = ev["args"].get("status", "unknown")
            statuses[status] = statuses.get(status, 0) + 1
    return {
        "n_events": len(events),
        "spans": span_groups(events, top=top),
        "requests": {
            "completed": len(req_durs),
            "by_status": statuses,
            "latency_p50_s": _percentile(req_durs, 0.50),
            "latency_p95_s": _percentile(req_durs, 0.95),
            "latency_p99_s": _percentile(req_durs, 0.99),
        },
        "queue_wait": {
            "n": len(queue_waits),
            "p50_s": _percentile(queue_waits, 0.50),
            "p95_s": _percentile(queue_waits, 0.95),
            "p99_s": _percentile(queue_waits, 0.99),
        },
    }


def _fmt_s(v: Optional[float]) -> str:
    if v is None:
        return "n/a"
    return f"{v * 1e3:.3f}ms"


def render_report(trace_summary: Optional[Dict] = None,
                  metrics_snapshot: Optional[Dict] = None,
                  top: int = 10) -> str:
    """Text report over `summarize_trace` output + a registry snapshot."""
    lines: List[str] = []
    if trace_summary:
        ts = trace_summary
        lines.append(f"== trace: {ts['n_events']} events ==")
        req = ts["requests"]
        lines.append(
            f"requests: {req['completed']} completed {req['by_status']} "
            f"latency p50={_fmt_s(req['latency_p50_s'])} "
            f"p95={_fmt_s(req['latency_p95_s'])} "
            f"p99={_fmt_s(req['latency_p99_s'])}")
        qw = ts["queue_wait"]
        lines.append(
            f"queue wait: n={qw['n']} p50={_fmt_s(qw['p50_s'])} "
            f"p95={_fmt_s(qw['p95_s'])} p99={_fmt_s(qw['p99_s'])}")
        lines.append(f"top {top} span groups by total time:")
        name_w = max([len(g["name"]) for g in ts["spans"][:top]] + [4])
        lines.append(f"  {'name':<{name_w}}  {'count':>6}  {'total':>10}  "
                     f"{'mean':>9}  {'max':>9}")
        for g in ts["spans"][:top]:
            lines.append(
                f"  {g['name']:<{name_w}}  {g['count']:>6}  "
                f"{g['total_us'] / 1e3:>8.2f}ms  {g['mean_us']:>7.1f}us  "
                f"{g['max_us']:>7.1f}us")
    if metrics_snapshot:
        lines.append("== metrics ==")
        gauges = metrics_snapshot.get("gauges", {})
        counters = metrics_snapshot.get("counters", {})
        for key in sorted(gauges):
            lines.append(f"  gauge {key} = {gauges[key]}")
        for key in sorted(counters):
            lines.append(f"  counter {key} = {counters[key]}")
        for key, h in sorted(metrics_snapshot.get("histograms", {}).items()):
            lines.append(
                f"  histogram {key}: count={h['count']} sum={h['sum']} "
                f"p50={h['p50']} p95={h['p95']} p99={h['p99']}")
    return "\n".join(lines)


__all__ = [
    "async_durations",
    "load_json",
    "render_report",
    "span_groups",
    "summarize_trace",
]
