"""Span-based request-lifecycle tracer, exported as Chrome trace-event JSON.

The paper measures *where* time goes on real hardware (per-CU invocation
latency over AXI, DDR stalls); the serving analogue is a trace of the
request lifecycle through the pipelined executor: submit -> queue wait ->
batch formation -> per-stage CU dispatch -> harvest -> complete. This
module records those spans in the Chrome trace-event format ("Trace Event
Format", the `traceEvents` JSON array), which Perfetto / chrome://tracing
load directly — drop the file into https://ui.perfetto.dev and every
track/span below renders on a timeline.

Design constraints, in order:

  * **Injectable clock.** Every timestamp comes either from an explicit
    caller-supplied time (the engine records spans with ITS clock, so one
    time source rules engine stats, deadlines, and trace alike) or from the
    tracer's own clock, which tests replace with a fake — the exported
    trace of a fake-clock run is byte-deterministic.
  * **Cheap when off.** `NULL` is a no-op tracer that is falsy; hot-path
    call sites guard their extra clock reads with `if tracer:` so a
    tracing-disabled engine performs exactly the clock reads it always did.
  * **Zero dependencies.** Events are plain dicts; export is `json.dump`.

Event vocabulary (all standard trace-event phases):

  * `complete(name, t0, t1)`    -> "X" duration span on a named track
  * `instant(name, t)`          -> "i" instant marker
  * `counter(name, {k: v}, t)`  -> "C" counter track (e.g. queue depth)
  * `async_begin/async_end`     -> "b"/"e" async spans keyed by id: one
                                   per-request lifecycle span that overlaps
                                   freely with other requests
  * `name_track(tid, name)`     -> "M" metadata naming a track
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional

# Well-known track ids for the serving pipeline (metadata-named on first
# use; stage executors get TID_STAGE0 + stage index).
TID_ENGINE = 0
TID_REQUESTS = 1
TID_SCHED = 2
TID_TUNE = 3
TID_TRAIN = 4
TID_STAGE0 = 10


class NullTracer:
    """No-op tracer: every record method does nothing, truthiness is False
    so call sites can skip the extra clock reads tracing needs."""

    __slots__ = ()

    def __bool__(self) -> bool:
        return False

    def now(self) -> float:
        return 0.0

    def complete(self, *a, **k) -> None:
        pass

    def instant(self, *a, **k) -> None:
        pass

    def counter(self, *a, **k) -> None:
        pass

    def async_begin(self, *a, **k) -> None:
        pass

    def async_end(self, *a, **k) -> None:
        pass

    def name_track(self, *a, **k) -> None:
        pass

    @contextmanager
    def span(self, *a, **k):
        yield

    def to_chrome(self) -> Dict[str, Any]:
        return {"traceEvents": []}

    def save(self, path: str) -> None:
        raise ValueError("cannot save the null tracer (tracing is off)")


NULL = NullTracer()


class Tracer:
    """Collects trace events; `to_chrome()`/`save()` export Perfetto JSON.

    `clock` returns seconds (perf_counter-like). Timestamps passed to the
    record methods are in the SAME time base as `clock`; the tracer
    subtracts its construction-time origin and scales to microseconds (the
    trace-event unit). `pid` tags every event (one tracer per process is
    the normal shape; a shared tracer across engines puts them on one
    timeline, which is exactly what the multi-model router wants)."""

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 *, process_name: str = "repro-serve", pid: int = 0,
                 origin_s: Optional[float] = None):
        self._clock = time.perf_counter if clock is None else clock
        self._origin = self._clock() if origin_s is None else origin_s
        self.pid = pid
        self.events: List[Dict[str, Any]] = []
        self._tracks: Dict[int, str] = {}
        self._meta: List[Dict[str, Any]] = [{
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": process_name},
        }]

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self.events)

    def now(self) -> float:
        return self._clock()

    def _ts(self, t_s: Optional[float]) -> float:
        t = self._clock() if t_s is None else t_s
        return (t - self._origin) * 1e6

    # -- record methods ----------------------------------------------------

    def name_track(self, tid: int, name: str) -> None:
        if self._tracks.get(tid) == name:
            return
        self._tracks[tid] = name
        self._meta.append({
            "ph": "M", "name": "thread_name", "pid": self.pid, "tid": tid,
            "args": {"name": name},
        })

    def complete(self, name: str, start_s: float, end_s: float, *,
                 cat: str = "", tid: int = TID_ENGINE,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """One finished span with explicit start/end times ("X" event)."""
        ev: Dict[str, Any] = {
            "ph": "X", "name": name, "cat": cat, "pid": self.pid,
            "tid": tid, "ts": self._ts(start_s),
            "dur": max(0.0, (end_s - start_s) * 1e6),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, t_s: Optional[float] = None, *,
                cat: str = "", tid: int = TID_ENGINE,
                args: Optional[Dict[str, Any]] = None) -> None:
        ev: Dict[str, Any] = {
            "ph": "i", "name": name, "cat": cat, "pid": self.pid,
            "tid": tid, "ts": self._ts(t_s), "s": "t",
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, values: Dict[str, float],
                t_s: Optional[float] = None, *, tid: int = TID_ENGINE) -> None:
        self.events.append({
            "ph": "C", "name": name, "pid": self.pid, "tid": tid,
            "ts": self._ts(t_s), "args": dict(values),
        })

    def async_begin(self, name: str, span_id: int,
                    t_s: Optional[float] = None, *, cat: str = "request",
                    args: Optional[Dict[str, Any]] = None) -> None:
        """Open an async span (nestable "b"); pairs with `async_end` by
        (cat, id) — the per-request lifecycle span, one id per rid."""
        ev: Dict[str, Any] = {
            "ph": "b", "name": name, "cat": cat, "id": span_id,
            "pid": self.pid, "tid": TID_REQUESTS, "ts": self._ts(t_s),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def async_end(self, name: str, span_id: int,
                  t_s: Optional[float] = None, *, cat: str = "request",
                  args: Optional[Dict[str, Any]] = None) -> None:
        ev: Dict[str, Any] = {
            "ph": "e", "name": name, "cat": cat, "id": span_id,
            "pid": self.pid, "tid": TID_REQUESTS, "ts": self._ts(t_s),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    @contextmanager
    def span(self, name: str, *, cat: str = "", tid: int = TID_ENGINE,
             args: Optional[Dict[str, Any]] = None):
        """Context-managed span timed on the tracer's own clock (for call
        sites without their own time source, e.g. the tuner / trainer)."""
        t0 = self._clock()
        try:
            yield
        finally:
            self.complete(name, t0, self._clock(), cat=cat, tid=tid,
                          args=args)

    # -- export ------------------------------------------------------------

    def to_chrome(self) -> Dict[str, Any]:
        """The Perfetto-loadable document: metadata first (track names),
        then events in record order (the format does not require sorting)."""
        return {
            "traceEvents": self._meta + self.events,
            "displayTimeUnit": "ms",
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, allow_nan=False)
        return path


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema check over an exported trace document; returns the list of
    violations (empty == loadable). This is what the CI bench-smoke job and
    `python -m repro.obs validate` run against the artifact it uploads."""
    errors: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not an object with a 'traceEvents' array"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not an array"]
    open_async: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "C", "b", "e", "M"):
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing name")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                errors.append(f"{where}: missing integer {key!r}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{where}: missing numeric ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X event needs dur >= 0")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            errors.append(f"{where}: C event needs an args value dict")
        if ph in ("b", "e"):
            if "id" not in ev or not ev.get("cat"):
                errors.append(f"{where}: async event needs id and cat")
            else:
                key = (ev["cat"], ev["id"], ev["name"])
                if ph == "b":
                    open_async[key] = open_async.get(key, 0) + 1
                else:
                    n = open_async.get(key, 0)
                    if n <= 0:
                        errors.append(f"{where}: async end without begin "
                                      f"for {key}")
                    else:
                        open_async[key] = n - 1
    for key, n in sorted(open_async.items()):
        if n > 0:
            errors.append(f"async span {key} opened {n} time(s) without end")
    return errors


__all__ = [
    "NULL",
    "NullTracer",
    "TID_ENGINE",
    "TID_REQUESTS",
    "TID_SCHED",
    "TID_STAGE0",
    "TID_TRAIN",
    "TID_TUNE",
    "Tracer",
    "validate_chrome_trace",
]
