"""Batched serving engine: prefill + decode with continuous slot management.

The engine keeps a fixed pool of B decode slots (static shapes for jit).
Requests queue up; free slots are prefilled (one jitted prefill per prompt
bucket) and then advance together through a single fused decode step — the
Body-CU-invoked-j-times pattern applied to serving. Greedy or temperature
sampling; per-slot stop handling; straggler-free because every slot advances
in lockstep (a finished slot is immediately recycled).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import model as M
from repro.models.lm.config import LMConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int = 32
    temperature: float = 0.0
    out: Optional[List[int]] = None


class Engine:
    def __init__(self, cfg: LMConfig, params, batch_slots: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(partial(M.decode_step, cfg=cfg))
        self._queue: List[Request] = []
        self._done: Dict[int, List[int]] = {}

    def submit(self, req: Request):
        req.out = []
        self._queue.append(req)

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue (simple bucketed batching: group by prompt len)."""
        while self._queue:
            batch = self._queue[: self.b]
            self._queue = self._queue[self.b :]
            self._run_batch(batch)
        done, self._done = self._done, {}
        return done

    def _run_batch(self, reqs: List[Request]):
        cfg = self.cfg
        plen = max(len(r.prompt) for r in reqs)
        b = len(reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):  # left-pad-free: right-align prompts
            toks[i, plen - len(r.prompt):] = r.prompt
        logits, cache = M.prefill(
            self.params, cfg, jnp.asarray(toks), max_len=self.max_len)
        pos = plen
        live = np.ones(b, bool)
        max_new = max(r.max_new for r in reqs)
        cur = self._sample(logits[:, 0], reqs)
        for i, r in enumerate(reqs):
            r.out.append(int(cur[i]))
        for _ in range(max_new - 1):
            logits, cache = self._decode(
                self.params, token=jnp.asarray(cur)[:, None],
                caches=cache, pos=jnp.int32(pos))
            pos += 1
            cur = self._sample(logits[:, 0], reqs)
            for i, r in enumerate(reqs):
                if live[i] and len(r.out) < r.max_new:
                    r.out.append(int(cur[i]))
                if len(r.out) >= r.max_new:
                    live[i] = False
            if not live.any():
                break
        for r in reqs:
            self._done[r.rid] = r.out

    def _sample(self, logits, reqs) -> np.ndarray:
        temps = np.array([r.temperature for r in reqs], np.float32)
        if (temps == 0).all():
            return np.asarray(jnp.argmax(logits, -1))
        self.key, sub = jax.random.split(self.key)
        scaled = logits / jnp.maximum(jnp.asarray(temps)[:, None], 1e-6)
        sampled = jax.random.categorical(sub, scaled)
        greedy = jnp.argmax(logits, -1)
        return np.asarray(jnp.where(jnp.asarray(temps) == 0, greedy, sampled))


__all__ = ["Engine", "Request"]
