"""Streaming 1-D DSCNN serving: ring-buffer incremental inference.

The production shape for edge-sensor DSCNNs (keyword spotting, HAR) is a
stream of *overlapping* windows: hop H over window W, so naive serving
recomputes (W - H)/W of every window. This module makes the steady-state
per-window cost O(H + halo) instead of O(W): each session keeps the
integer activation buffer of every temporal operator, and a new window
recomputes only the frames that SAME-padding edge effects and the H new
input frames can reach — everything else is served from the cached buffer
of the previous window, bit-exact with the full-window reference route.

Halo math (per temporal op: kernel k, stride s, SAME pad (pl, pr), input
length Tin, output length Tout, input hop Hin with s | Hin, Hout = Hin/s).
Let [0, Lin) and [Tin - Rin, Tin) be the input regions whose values differ
from the previous window's buffer shifted by Hin (base case at the raw
input: Lin = 0, Rin = H). Output j of the new window reads input taps
[j*s - pl, j*s - pl + k); it equals cached output j + Hout iff

  * every tap lands at or right of Lin        (j*s - pl >= Lin),
  * no tap lands in [Tin - Rin, Tin)          (j*s - pl + k <= Tin - Rin,
    vacuous when Rin == 0; taps in the right SAME padding are zero in both
    windows, so they never invalidate),
  * the cached output exists                  (j < Tout - Hout).

Hence Lout = ceil((Lin + pl) / s) and Rout = Tout - min(Tout - Hout,
floor((Tin - Rin - k + pl) / s) + 1). Pointwise ops (k = 1, s = 1,
pl = 0) give Lout = Lin, Rout = Rin — the halo only grows on the cheap
depthwise/stem convs, never on the MAC-dominant pointwise layers, which
is what makes the steady-state speedup land. Residual adds are
elementwise, and within a residual block every op has stride 1, so the
regions are monotone and the post-add invalid region equals the last
op's. Integer arithmetic is order-free, so the recomputed edge segments
(explicit-pad VALID convolutions over buffer slices) are bit-identical
to the full-window formulation — `tests/test_streaming.py` fuzzes this
end to end against `cu.run_qnet`.

Batched stepping: the halo geometry above is a function of the *plan*,
not the session — every session of one (net, hop) pair shares identical
buffer shapes, so one traced step program advances any group of them
stacked on a leading batch axis (every op in the step is batch-row
independent, and the integer/f32-exact arithmetic makes each row bitwise
the single-session result). `StreamEngine.drain()` is the fleet
scheduler on top: `push(..., defer=True)` stages frames without
stepping, and each drain round groups the ready sessions into bucketed
batch sizes (full max-bucket chunks, the tail padded up to the smallest
covering bucket — the same bucket-rounding discipline the vision
pipeline uses to bound jit retraces), gathers their per-session buffer
pytrees onto the batch axis, runs ONE jitted prime/step per group, and
scatters the buffers back. A tail of one falls back to the
single-session program, so stragglers never pay padding.

Invariants this module guarantees (and tests assert):

  * **bit-exactness** — every window's logits, primed, stepped, or
    batch-stepped, equal `cu.run_qnet` on that window bitwise;
  * **bounded retraces** — jitted program count is bounded by
    2 + 2 * len(batch_buckets) regardless of fleet size or traffic;
  * **determinism under fake clocks** — `clock=` injects the only time
    source; all stats, traces, and the modeled energy/FPS-per-Watt in
    `stats()` (see `repro.energy`) replay identically under a fake clock.

Guide: docs/streaming.md; energy accounting: docs/energy.md.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cu
from repro.core import graph as G
from repro.core.integer_ops import (
    int_conv1d,
    int_conv1d_f32,
    int_depthwise1d_shifts,
    int_pointwise,
    int_pointwise_f32,
    quantized_op_epilogue,
)
from repro.core.qnet import QNet
from repro.energy import model as EM
from repro.energy.power import PowerModel, default_power_model
from repro.kernels.common import same_pad_amount
from repro.obs import metrics as OM
from repro.obs import trace as OT


class StreamError(ValueError):
    """A net/hop combination the streaming planner refuses."""


# ---------------------------------------------------------------------------
# static stream plan: per-op ring-buffer geometry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SegSpec:
    """One edge segment to recompute: input slice [lo, hi) of the op's
    (updated) input buffer, explicit zero pad, and the output count."""

    lo: int
    hi: int
    pad: Tuple[int, int]
    n_out: int


@dataclasses.dataclass(frozen=True)
class MergedSeg:
    """Fused left+right edge recompute: both input slices concatenated
    with `gap` zero frames between them so one kernel dispatch covers
    both edges. The gap is sized so (a) the left segment's tail taps
    read zeros exactly where its overflow pad would be, and (b) the
    first right output lands on output index `j0` with its receptive
    field aligned to the right slice's stride phase — outputs in
    [lout, j0) are discarded seam garbage. One dispatch instead of two
    halves the op count of the steady-state step (the left segments are
    a few frames each: pure dispatch overhead as separate kernels)."""

    gap: int   # zero frames inserted between the two input slices
    j0: int    # output index where the right segment's outputs begin
    pad: Tuple[int, int]  # explicit pad of the fused conv


@dataclasses.dataclass(frozen=True)
class OpStream:
    """Ring-buffer geometry of one temporal op (or residual pseudo-op)."""

    name: str
    tin: int
    tout: int
    hout: int  # buffer shift per step, in output frames
    lout: int  # left invalid (recomputed) outputs
    rout: int  # right invalid (recomputed) outputs
    left: Optional[SegSpec]
    right: Optional[SegSpec]
    merged: Optional[MergedSeg] = None


@dataclasses.dataclass(frozen=True)
class BlockStream:
    block: G.BlockSpec
    ops: Tuple[OpStream, ...]
    res: Optional[OpStream]  # elementwise skip-add region (residual blocks)
    in_s: float
    in_z: float


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Static per-(net, window, hop) geometry driving `prime`/`step`."""

    window: int
    hop: int
    blocks: Tuple[BlockStream, ...]  # temporal blocks (incl. the pool block)
    post_blocks: Tuple[G.BlockSpec, ...]  # after the global pool (classifier)
    pool_s: float  # quantizer of the tensor entering the post blocks
    pool_z: float
    frames_full: int  # conv output frames computed per full-window inference
    frames_step: int  # conv output frames computed per streaming step
    macs_full: int
    macs_step: int
    buffer_bytes: int  # uint8 ring buffers per session
    # activation traffic per window (bytes written + raw input read; weights
    # are device-resident, not streamed) — the energy model's memory term
    bytes_full: int = 0
    bytes_step: int = 0

    @property
    def reuse_fraction(self) -> float:
        return 1.0 - self.frames_step / max(self.frames_full, 1)


def _op_geometry(op: G.OpSpec, tin: int, lin: int, rin: int,
                 hin: int) -> Tuple[OpStream, int, int, int, int]:
    """Apply the halo recurrence to one op; returns (OpStream, tout, lout,
    rout, hout)."""
    if op.kind in (G.DW1D, G.CONV1D):
        k, s = op.kernel, op.stride
        pl, _pr, tout = same_pad_amount(tin, k, s)
    elif op.kind == G.PW:
        k, s, pl, tout = 1, 1, 0, tin
    else:
        raise StreamError(
            f"op {op.name} ({op.kind}) is not streamable before the pool")
    if hin % s:
        raise StreamError(
            f"op {op.name}: stride {s} does not divide the layer hop {hin} "
            f"— pick a hop divisible by the cumulative stride")
    hout = hin // s
    lout = -(-(lin + pl) // s)  # ceil
    first_bad = tout - hout
    if rin > 0:
        first_bad = min(first_bad, (tin - rin - k + pl) // s + 1)
    rout = tout - first_bad
    if lout + rout >= tout:
        # degenerate geometry (halo covers the buffer): recompute everything
        lout, rout = tout, 0
    left = None
    if lout > 0:
        a_hi = (lout - 1) * s - pl + k
        left = SegSpec(0, min(tin, a_hi), (pl, max(0, a_hi - tin)), lout)
    right = None
    if rout > 0:
        a_lo = (tout - rout) * s - pl
        a_hi = (tout - 1) * s - pl + k
        right = SegSpec(max(0, a_lo), min(tin, a_hi),
                        (max(0, -a_lo), max(0, a_hi - tin)), rout)
    merged = None
    if left is not None and right is not None and right.pad[0] == 0:
        ll = left.hi - left.lo
        j0 = max(lout, -(-(ll + left.pad[1] + pl) // s))  # ceil
        gap = j0 * s - pl - ll  # >= left.pad[1] by construction
        rl = right.hi - right.lo
        tout_m = (ll + gap + rl + pl + right.pad[1] - k) // s + 1
        assert tout_m == j0 + rout, (op.name, tout_m, j0, rout)
        merged = MergedSeg(gap=gap, j0=j0, pad=(pl, right.pad[1]))
    return (OpStream(op.name, tin, tout, hout, lout, rout, left, right,
                     merged),
            tout, lout, rout, hout)


def plan_stream(qnet: QNet, hop: int) -> StreamPlan:
    """Derive the static ring-buffer plan for `qnet` at the given hop.

    Refuses anything the bit-exactness proof does not cover: 2-D nets,
    SE branches, hops the cumulative stride does not divide, nets without
    a global-pool boundary."""
    spec = qnet.spec
    if spec.spatial_rank != 1:
        raise StreamError(
            f"streaming requires a 1-D (temporal) net; {spec.name} is "
            f"rank {spec.spatial_rank}")
    window = spec.input_hw
    if not 1 <= hop <= window:
        raise StreamError(f"hop {hop} outside [1, window={window}]")

    block_streams: List[BlockStream] = []
    post: List[G.BlockSpec] = []
    t, lin, rin, hin = window, 0, hop, hop
    cur_s, cur_z = cu.input_qparams(qnet)
    pool_s = pool_z = None
    pooled = False
    frames_full = frames_step = macs_full = macs_step = 0
    # activation bytes per window: raw input frames read + every op's
    # output frames written (1 byte/elem — uint8 ring buffers). Mirrors
    # the frames accounting; the step column only pays the recomputed
    # halo/hop frames, which is what makes streaming's J/window land.
    bytes_full = window * spec.input_ch
    bytes_step = hop * spec.input_ch
    # activations never exceed 8 bits (act_bits <= 8), so ring buffers are
    # stored uint8 — 4x less shuffle traffic and session memory than the
    # int32 the compute ops use; the up-cast happens on the (small) edge
    # slices only
    buffer_bytes = window * spec.input_ch
    for block in spec.blocks:
        if pooled:
            post.append(block)
            for op in block.ops:
                macs_full += op.macs(1, 1)
                macs_step += op.macs(1, 1)
                bytes_full += op.out_ch
                bytes_step += op.out_ch
            continue
        if block.se is not None:
            raise StreamError(
                f"block {block.name} has a squeeze-excitation branch — "
                f"SE pools over the whole window, so no frame is reusable")
        if all(op.kind == G.DENSE for op in block.ops):
            raise StreamError(
                f"dense block {block.name} before the global pool — "
                f"streaming needs a pool boundary to collapse time")
        if block.residual and any(op.stride != 1 for op in block.ops):
            raise StreamError(f"residual block {block.name} has stride != 1")
        in_s, in_z = cur_s, cur_z
        ops: List[OpStream] = []
        for op in block.ops:
            if op.act == G.HSIGMOID:
                raise StreamError(f"op {op.name}: hsigmoid is not streamable")
            os_, t, lin, rin, hin = _op_geometry(op, t, lin, rin, hin)
            ops.append(os_)
            per_frame = op.macs(1, 1)
            frames_full += os_.tout
            # merged edge compute also pays for the (few) seam-garbage
            # outputs in [lout, j0) — account them honestly
            frames_step += (os_.merged.j0 + os_.rout if os_.merged
                            else os_.lout + os_.rout)
            macs_full += os_.tout * per_frame
            macs_step += (os_.merged.j0 + os_.rout if os_.merged
                          else os_.lout + os_.rout) * per_frame
            buffer_bytes += os_.tout * op.out_ch
            bytes_full += os_.tout * op.out_ch
            bytes_step += (os_.merged.j0 + os_.rout if os_.merged
                           else os_.lout + os_.rout) * op.out_ch
            qop = qnet.ops[op.name]
            cur_s, cur_z = qop.out_scale, qop.out_zp
        res = None
        if block.residual:
            last = ops[-1]
            res = OpStream(block.name + "/residual", last.tout, last.tout,
                           last.hout, last.lout, last.rout, None, None)
            buffer_bytes += last.tout * block.out_ch
            bytes_full += last.tout * block.out_ch
            bytes_step += (last.lout + last.rout) * block.out_ch
            cur_s, cur_z = qnet.res_q[block.name]
        block_streams.append(BlockStream(block, tuple(ops), res, in_s, in_z))
        if block.avgpool:
            pooled = True
            pool_s, pool_z = cur_s, cur_z
    if not pooled:
        raise StreamError(
            f"{spec.name} has no global-pool block — streaming needs the "
            f"temporal/collapsed boundary")
    return StreamPlan(
        window=window, hop=hop, blocks=tuple(block_streams),
        post_blocks=tuple(post), pool_s=pool_s, pool_z=pool_z,
        frames_full=frames_full, frames_step=frames_step,
        macs_full=macs_full, macs_step=macs_step, buffer_bytes=buffer_bytes,
        bytes_full=bytes_full, bytes_step=bytes_step)


# ---------------------------------------------------------------------------
# traced compute: full-window prime + incremental step
# ---------------------------------------------------------------------------


def _pad_qop(x: jnp.ndarray, pop: cu.PreparedQOp, pad: Tuple[int, int],
             fixed_point: bool) -> jnp.ndarray:
    """Apply one op to an int32 edge slice with an explicit pad, running
    the same epilogue as `cu._run_qop`. Integer accumulation is
    order-free, so each output frame is bit-identical to the
    corresponding frame of the full-window op output."""
    op = pop.spec
    if op.kind == G.DW1D:
        acc = int_depthwise1d_shifts(x, pop.w_kern, stride=op.stride,
                                     padding=pad)
    elif op.kind == G.CONV1D:
        if pop.f32_exact:
            acc = int_conv1d_f32(x, pop.w_q, stride=op.stride,
                                 padding=pad)
        else:
            acc = int_conv1d(x, pop.w_q, stride=op.stride, padding=pad)
    elif op.kind == G.PW:
        assert pad == (0, 0)
        if pop.f32_exact:
            acc = int_pointwise_f32(x, pop.w_kern)
        else:
            acc = int_pointwise(x, pop.w_kern)
    else:
        raise StreamError(op.kind)
    return quantized_op_epilogue(
        acc, z_x=pop.z_x, wsum=pop.wsum, bias_q=pop.bias_q, mult=pop.mult,
        qmax=pop.qmax, z_y=jnp.asarray(0, jnp.int32),
        fixed_point=fixed_point,
        mantissa=pop.mantissa if fixed_point else None,
        shift=pop.shift if fixed_point else None,
        clip_output=True)


def _seg_qop(x_buf: jnp.ndarray, pop: cu.PreparedQOp, seg: SegSpec,
             fixed_point: bool) -> jnp.ndarray:
    """Recompute one edge segment from the op's (already updated, uint8)
    input buffer."""
    x = jax.lax.slice_in_dim(x_buf, seg.lo, seg.hi, axis=1
                             ).astype(jnp.int32)
    return _pad_qop(x, pop, seg.pad, fixed_point)


def _merged_qop(x_buf: jnp.ndarray, pop: cu.PreparedQOp, os_: OpStream,
                fixed_point: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Recompute BOTH edge segments with one kernel dispatch (see
    `MergedSeg`): concatenate the two input slices around the seam gap,
    run the op once, slice out the two valid output ranges."""
    m = os_.merged
    xl = jax.lax.slice_in_dim(x_buf, os_.left.lo, os_.left.hi, axis=1)
    xr = jax.lax.slice_in_dim(x_buf, os_.right.lo, os_.right.hi, axis=1)
    parts = [xl, xr] if m.gap == 0 else [
        xl, jnp.zeros((xl.shape[0], m.gap, xl.shape[2]), x_buf.dtype), xr]
    y = _pad_qop(jnp.concatenate(parts, axis=1).astype(jnp.int32),
                 pop, m.pad, fixed_point)
    return (jax.lax.slice_in_dim(y, 0, os_.lout, axis=1),
            jax.lax.slice_in_dim(y, m.j0, m.j0 + os_.rout, axis=1))


def _pool_stream(plan: StreamPlan) -> Tuple[OpStream, bool]:
    """(final pre-pool OpStream, whether the global mean can be updated
    incrementally). Incremental pooling carries the per-channel integer
    sum of the final ring buffer across steps and adjusts it with the
    edge slices only. It reproduces `round(mean(...))` bit-for-bit as
    long as every partial sum stays below 2**24: all intermediate f32
    sums are then exact integers, so summation order cannot change the
    quotient fed to round(). Past that bound the f32 mean itself is
    order-dependent and we fall back to the full reduce."""
    bs = plan.blocks[-1]
    fs = bs.res if bs.res is not None else bs.ops[-1]
    qmax = 2 ** bs.block.ops[-1].act_bits - 1
    return fs, fs.tout * qmax < 2 ** 24


def _channel_sum(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x.astype(jnp.int32), axis=1)


def _residual_args(bs: BlockStream, qnet) -> Tuple:
    last = qnet.ops[bs.block.ops[-1].name]
    y_s, y_z = qnet.res_q[bs.block.name]
    qmax = 2 ** bs.block.ops[-1].act_bits - 1
    return last.out_scale, last.out_zp, y_s, y_z, qmax


def _finish(pooled: jnp.ndarray, plan: StreamPlan, pq, fixed_point: bool
            ) -> jnp.ndarray:
    y, s, z = cu.run_blocks(pooled, plan.post_blocks, pq,
                            plan.pool_s, plan.pool_z, fixed_point)
    return (y.astype(jnp.float32) + z) * s


def _prime_impl(x: jnp.ndarray, plan: StreamPlan, pq, in_s: float,
                in_z: float, input_bits: int, fixed_point: bool):
    """Full-window pass that also captures every ring buffer. The op walk
    mirrors `cu.run_block` exactly (no SE by plan construction), so the
    logits match `cu.run_qnet` bit-for-bit."""
    bufs: Dict[str, jnp.ndarray] = {}
    y = cu.quantize_input(x, in_s, in_z, input_bits)
    bufs["input"] = y.astype(jnp.uint8)
    for bs in plan.blocks:
        x_block = y
        for op in bs.block.ops:
            y = cu._run_qop(y, pq.ops[op.name], fixed_point)
            bufs[op.name] = y.astype(jnp.uint8)
        if bs.res is not None:
            c_s, c_z, y_s, y_z, qmax = _residual_args(bs, pq.qnet)
            fixed = pq.res_fixed[bs.block.name] if fixed_point else None
            y = cu._residual_add(x_block, bs.in_s, bs.in_z, y, c_s, c_z,
                                 y_s, y_z, qmax, fixed_consts=fixed)
            bufs[bs.res.name] = y.astype(jnp.uint8)
    _, pool_inc = _pool_stream(plan)
    if pool_inc:
        bufs["pool_sum"] = _channel_sum(y)
    pooled = jnp.round(jnp.mean(y.astype(jnp.float32), axis=(1,))
                       ).astype(jnp.int32)
    return _finish(pooled, plan, pq, fixed_point), bufs


def _step_impl(bufs: Dict[str, jnp.ndarray], new: jnp.ndarray,
               plan: StreamPlan, pq, in_s: float, in_z: float,
               input_bits: int, fixed_point: bool):
    """One hop: quantize the H new raw frames, shift every ring buffer by
    its per-layer hop, recompute only the invalid edge segments, and
    finish from the final buffer. Input quantization lives INSIDE the
    traced step so the steady-state path is one compiled program per hop
    (eager per-hop dispatch would rival the step compute itself)."""
    out: Dict[str, jnp.ndarray] = {}
    new_q = cu.quantize_input(new, in_s, in_z, input_bits)
    y = jnp.concatenate([bufs["input"][:, plan.hop:],
                         new_q.astype(jnp.uint8)], axis=1)
    out["input"] = y

    def assemble(os_: OpStream, left, right, old):
        # ring buffers live as uint8; freshly computed edge segments are
        # int32 out of the epilogue (already clipped to [0, qmax]) and
        # cast down losslessly here
        pieces = []
        if left is not None:
            pieces.append(left.astype(jnp.uint8))
        mid_lo, mid_hi = os_.lout + os_.hout, os_.tout - os_.rout + os_.hout
        if mid_hi > mid_lo:
            pieces.append(jax.lax.slice_in_dim(old, mid_lo, mid_hi, axis=1))
        if right is not None:
            pieces.append(right.astype(jnp.uint8))
        return pieces[0] if len(pieces) == 1 else jnp.concatenate(
            pieces, axis=1)

    for bs in plan.blocks:
        x_block = y
        for os_ in bs.ops:
            pop = pq.ops[os_.name]
            if os_.merged is not None:
                left, right = _merged_qop(y, pop, os_, fixed_point)
            else:
                left = (_seg_qop(y, pop, os_.left, fixed_point)
                        if os_.left is not None else None)
                right = (_seg_qop(y, pop, os_.right, fixed_point)
                         if os_.right is not None else None)
            y = assemble(os_, left, right, bufs[os_.name])
            out[os_.name] = y
        if bs.res is not None:
            rs = bs.res
            c_s, c_z, y_s, y_z, qmax = _residual_args(bs, pq.qnet)
            fixed = pq.res_fixed[bs.block.name] if fixed_point else None

            def radd(a, b):
                return cu._residual_add(a.astype(jnp.int32), bs.in_s,
                                        bs.in_z, b.astype(jnp.int32), c_s,
                                        c_z, y_s, y_z, qmax,
                                        fixed_consts=fixed)

            left = (radd(jax.lax.slice_in_dim(x_block, 0, rs.lout, axis=1),
                         jax.lax.slice_in_dim(y, 0, rs.lout, axis=1))
                    if rs.lout > 0 else None)
            right = (radd(
                jax.lax.slice_in_dim(x_block, rs.tin - rs.rout, rs.tin, axis=1),
                jax.lax.slice_in_dim(y, rs.tin - rs.rout, rs.tin, axis=1))
                if rs.rout > 0 else None)
            y = assemble(rs, left, right, bufs[rs.name])
            out[rs.name] = y
    fs, pool_inc = _pool_stream(plan)
    if pool_inc:
        # the mid region of the final buffer holds unchanged VALUES
        # (shifted positions), so the channel sum moves only by the
        # frames that left and the edges that were recomputed
        old = bufs[fs.name]
        mid_lo = min(fs.lout + fs.hout, fs.tout)
        mid_hi = max(fs.tout - fs.rout + fs.hout, mid_lo)
        s_new = (bufs["pool_sum"]
                 - _channel_sum(old[:, :mid_lo])
                 - _channel_sum(old[:, mid_hi:])
                 + _channel_sum(y[:, :fs.lout])
                 + _channel_sum(y[:, fs.tout - fs.rout:]))
        out["pool_sum"] = s_new
        pooled = jnp.round(s_new.astype(jnp.float32)
                           / jnp.float32(fs.tout)).astype(jnp.int32)
    else:
        pooled = jnp.round(jnp.mean(y.astype(jnp.float32), axis=(1,))
                           ).astype(jnp.int32)
    return _finish(pooled, plan, pq, fixed_point), out


def _split_rows(bufs: Dict[str, jnp.ndarray], b: int
                ) -> List[Dict[str, jnp.ndarray]]:
    """Scatter a stacked buffer pytree back into per-session [1, ...]
    rows. Traced inside the batched prime/step programs, so XLA fuses the
    slices into the surrounding computation."""
    return [{k: jax.lax.slice_in_dim(v, i, i + 1, axis=0)
             for k, v in bufs.items()} for i in range(b)]


def reference_windows(qnet, frames: np.ndarray, window: int, hop: int,
                      fixed_point: bool = False, input_bits: int = 8
                      ) -> np.ndarray:
    """Full-window reference logits for every hop-aligned window of a
    frame stream — the oracle the streaming route is proven against."""
    n = (len(frames) - window) // hop + 1
    outs = [np.asarray(cu.run_qnet(
        qnet, jnp.asarray(frames[i * hop: i * hop + window])[None],
        fixed_point=fixed_point, input_bits=input_bits))[0]
        for i in range(n)]
    return np.stack(outs) if outs else np.zeros((0, qnet.spec.num_classes))


# ---------------------------------------------------------------------------
# session table + engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StreamResult:
    """Logits for one completed window of one session."""

    sid: str
    window: int  # per-session window index (0 == the priming window)
    logits: np.ndarray  # [num_classes] dequantized
    streamed: bool  # False for the priming (full) window


@dataclasses.dataclass
class _Session:
    sid: str
    buffers: Optional[Dict[str, jnp.ndarray]]
    pending: np.ndarray  # raw frames not yet consumed, [n, C]
    last_used: float
    windows: int
    span_id: int


class StreamEngine:
    """Stateful streaming front end over a prepared 1-D QNet.

    Grows a session table (LRU eviction at `max_sessions`); each session
    owns the per-layer integer ring buffers plus its input quantizer
    state. `push(sid, frames)` consumes arbitrary-length frame chunks and
    returns one `StreamResult` per completed window: the first window of
    a session runs the full `prime` pass, every later one the O(hop +
    halo) `step` pass — both through ONE shared jitted trace across all
    sessions. Outputs are bit-exact with `cu.run_qnet` on each window.

    Fleet mode: `push(sid, frames, defer=True)` stages frames without
    advancing, and `drain()` advances every ready session — priming
    windows and incremental steps alike — through BATCHED jitted
    programs that stack whole session groups on a leading batch axis
    (`batch_buckets` bounds the traced batch shapes, exactly like the
    vision engine's micro-batch buckets). `step_many(sids)` is the
    explicit one-hop batched advance for callers that schedule
    themselves. Batched rows are bit-exact with the single-session path.
    """

    def __init__(
        self,
        qnet: QNet,
        hop: int,
        *,
        fixed_point: bool = False,
        input_bits: int = 8,
        max_sessions: int = 64,
        batch_buckets: Sequence[int] = (2, 4, 8),
        clock=None,
        tracer: Optional[OT.Tracer] = None,
        metrics: Optional[OM.MetricsRegistry] = None,
        name: str = "default",
        power_model: Optional[PowerModel] = None,
    ):
        if max_sessions < 1:
            raise ValueError(f"max_sessions {max_sessions} < 1")
        if any(int(b) < 1 for b in batch_buckets):
            raise ValueError(f"bad batch_buckets {batch_buckets}")
        self.pq = cu.prepare_qnet(qnet, input_bits=input_bits)
        self.qnet = self.pq.qnet
        self.plan = plan_stream(self.qnet, hop)
        self.window, self.hop = self.plan.window, int(hop)
        self.input_ch = self.qnet.spec.input_ch
        self.fixed_point = fixed_point
        self.input_bits = input_bits
        self.max_sessions = max_sessions
        # bucket 1 is implicit — a group of one takes the single-session
        # program (no padding, no extra trace)
        self.batch_buckets = tuple(sorted(
            {int(b) for b in batch_buckets if int(b) > 1}))
        self.name = name
        self._clock = time.perf_counter if clock is None else clock
        self.tracer = tracer if tracer is not None else OT.NULL
        self._reg = metrics if metrics is not None else OM.NULL_REGISTRY
        # device power curve for the modeled J/window and FPS/Watt in
        # stats() (see docs/energy.md); injectable for determinism
        self.power = (power_model if power_model is not None
                      else default_power_model())
        in_s, in_z = cu.input_qparams(self.qnet)
        self._in_s, self._in_z = in_s, in_z

        plan, pq = self.plan, self.pq
        self._prime = jax.jit(lambda x: _prime_impl(
            x, plan, pq, in_s, in_z, input_bits, fixed_point))
        self._step = jax.jit(lambda bufs, new: _step_impl(
            bufs, new, plan, pq, in_s, in_z, input_bits, fixed_point))
        self._prime_many_cache: Dict[int, object] = {}
        self._step_many_cache: Dict[int, object] = {}

        self._sessions: "OrderedDict[str, _Session]" = OrderedDict()
        self._sid_counter = itertools.count()
        self._span_ids = itertools.count(1)
        self._windows = 0
        self._primes = 0
        self._evicted = 0
        self._prime_s = 0.0
        self._step_s = 0.0
        self._frames_computed = 0
        self._frames_reused = 0
        self._windows_batched = 0
        self._batched_calls = 0
        self._batched_traces = 0
        self._pad_rows = 0
        self._init_obs()

    def warm(self, batches: Sequence[int] = ()) -> None:
        """Pay the XLA compilations (prime + step, plus any batched
        `batches` sizes) up front, outside any session — so a live
        stream's first windows never stall on a trace."""
        zeros = np.zeros((1, self.window, self.input_ch), np.float32)
        _, bufs = self._prime(zeros)
        jax.block_until_ready(
            self._step(bufs, zeros[:, :self.hop])[0])
        for b in sorted({int(x) for x in batches}):
            if b < 2:
                continue
            xb = np.zeros((b, self.window, self.input_ch), np.float32)
            _, outs = self._prime_many_fn(b)(xb)
            jax.block_until_ready(self._step_many_fn(b)(
                list(outs), xb[:, :self.hop])[0])

    def _init_obs(self) -> None:
        lbl = {"model": self.name}
        self._m_active = self._reg.gauge(
            "stream_sessions_active", "open streaming sessions", labels=lbl)
        self._m_computed = self._reg.counter(
            "stream_frames_computed_total",
            "conv output frames actually computed", labels=lbl)
        self._m_reused = self._reg.counter(
            "stream_frames_reused_total",
            "conv output frames served from ring buffers", labels=lbl)
        self._m_windows = self._reg.counter(
            "stream_windows_total", "windows answered with logits",
            labels=lbl)
        self._m_evicted = self._reg.counter(
            "stream_sessions_evicted_total", "LRU session evictions",
            labels=lbl)
        self._m_batch = self._reg.histogram(
            "stream_batch_size",
            "real sessions advanced per jitted prime/step dispatch",
            labels=lbl, buckets=(1, 2, 4, 8, 16, 32, 64))
        self._m_pad = self._reg.counter(
            "stream_pad_rows_total",
            "bucket-padding waste rows in batched prime/step calls",
            labels=lbl)
        self._m_fpw = self._reg.gauge(
            "stream_fps_per_watt",
            "modeled windows per second per watt (calibrated energy model)",
            labels=lbl)
        self._m_watts = self._reg.gauge(
            "stream_watts",
            "modeled average device watts at the achieved window rate",
            labels=lbl)
        self.tracer.name_track(OT.TID_ENGINE, f"stream:{self.name}")

    # -- session lifecycle ------------------------------------------------

    def open_session(self, sid: Optional[str] = None) -> str:
        """Open (or re-open) a session; evicts the LRU session when full."""
        if sid is None:
            # skip counter values that collide with user-supplied sids —
            # handing out "s3" when a caller already opened "s3" would
            # silently alias a foreign session's buffers and pending
            sid = f"s{next(self._sid_counter)}"
            while sid in self._sessions:
                sid = f"s{next(self._sid_counter)}"
        if sid in self._sessions:
            sess = self._sessions[sid]
            self._sessions.move_to_end(sid)
            sess.last_used = self._clock()  # re-open refreshes recency too
            return sid
        while len(self._sessions) >= self.max_sessions:
            old_sid, old = self._sessions.popitem(last=False)
            self._evicted += 1
            self._m_evicted.inc()
            self.tracer.async_end(f"stream_session:{self.name}",
                                  old.span_id, args={"sid": old_sid,
                                                     "evicted": True})
            self._m_active.set(len(self._sessions))
        span_id = next(self._span_ids)
        self.tracer.async_begin(f"stream_session:{self.name}", span_id,
                                args={"sid": sid})
        self._sessions[sid] = _Session(
            sid=sid, buffers=None,
            pending=np.zeros((0, self.input_ch), np.float32),
            last_used=self._clock(), windows=0, span_id=span_id)
        self._m_active.set(len(self._sessions))
        return sid

    def close_session(self, sid: str) -> None:
        sess = self._sessions.pop(sid, None)
        if sess is None:
            raise KeyError(f"unknown session {sid!r}")
        self.tracer.async_end(f"stream_session:{self.name}", sess.span_id,
                              args={"sid": sid, "evicted": False})
        self._m_active.set(len(self._sessions))

    @property
    def sessions_active(self) -> int:
        return len(self._sessions)

    def session_table_buffer_bytes(self) -> int:
        """Resident ring-buffer bytes across primed sessions."""
        return sum(self.plan.buffer_bytes for s in self._sessions.values()
                   if s.buffers is not None)

    def session_table_pending_bytes(self) -> int:
        """float32 staging frames awaiting a full window/hop, all
        sessions (a cold session that never primes still holds up to
        window-1 frames here — eviction-by-bytes must see them)."""
        return sum(s.pending.nbytes for s in self._sessions.values())

    def session_table_bytes(self) -> int:
        """Total resident session memory: primed ring buffers PLUS the
        pending staging arrays (see `stats()` for the breakdown)."""
        return (self.session_table_buffer_bytes()
                + self.session_table_pending_bytes())

    # -- inference --------------------------------------------------------

    def push(self, sid: str, frames: np.ndarray, *,
             defer: bool = False) -> List[StreamResult]:
        """Feed raw frames ([n, C] float, calibrated input range) into a
        session; returns a result per window completed by this chunk.

        With `defer=True` the frames are only staged (returns []) — a
        later `drain()` / `step_many()` advances the session, batched
        with every other ready session. Frame consumption is
        transactional either way: if the jitted prime/step raises, the
        staged frames stay pending and the session remains consistent."""
        sess = self._sessions.get(sid)
        if sess is None:
            raise KeyError(f"unknown session {sid!r}; open_session first")
        frames = np.asarray(frames, np.float32)
        if frames.ndim != 2 or frames.shape[1] != self.input_ch:
            raise ValueError(
                f"frames shape {frames.shape} != (n, {self.input_ch})")
        self._sessions.move_to_end(sid)
        sess.last_used = self._clock()
        sess.pending = np.concatenate([sess.pending, frames], axis=0)
        if defer:
            return []
        results: List[StreamResult] = []
        while True:
            if sess.buffers is None:
                if len(sess.pending) < self.window:
                    break
                results += self._prime_sessions((sid,), 0)
            else:
                if len(sess.pending) < self.hop:
                    break
                results += self._step_sessions((sid,), 0)
        return results

    # -- batched stepping --------------------------------------------------

    def _prime_many_fn(self, b: int):
        """Jitted prime over B stacked windows, buffers scattered back to
        per-session rows inside the trace. One cache entry per batch
        size (the buckets bound how many exist)."""
        fn = self._prime_many_cache.get(b)
        if fn is None:
            plan, pq = self.plan, self.pq
            in_s, in_z = self._in_s, self._in_z
            input_bits, fixed_point = self.input_bits, self.fixed_point

            def impl(x):
                self._batched_traces += 1  # python runs at trace time only
                logits, bufs = _prime_impl(x, plan, pq, in_s, in_z,
                                           input_bits, fixed_point)
                return logits, _split_rows(bufs, b)

            fn = jax.jit(impl)
            self._prime_many_cache[b] = fn
        return fn

    def _step_many_fn(self, b: int):
        """Jitted step over B sessions: gather the per-session buffer
        pytrees onto the batch axis, run the (batch-polymorphic) step
        once, scatter the updated buffers back — all one XLA program."""
        fn = self._step_many_cache.get(b)
        if fn is None:
            plan, pq = self.plan, self.pq
            in_s, in_z = self._in_s, self._in_z
            input_bits, fixed_point = self.input_bits, self.fixed_point

            def impl(bufs_list, new):
                self._batched_traces += 1
                stacked = {
                    k: jnp.concatenate([bl[k] for bl in bufs_list], axis=0)
                    for k in bufs_list[0]}
                logits, out = _step_impl(stacked, new, plan, pq, in_s, in_z,
                                         input_bits, fixed_point)
                return logits, _split_rows(out, b)

            fn = jax.jit(impl)
            self._step_many_cache[b] = fn
        return fn

    def _buckets_of(self, sids: Sequence[str]
                    ) -> List[Tuple[Tuple[str, ...], int]]:
        """Split ready sids into (group, pad) dispatches: full max-bucket
        chunks, then the tail rounded UP to the smallest covering bucket
        (the vision pipeline's bucket-rounding discipline — the jit
        trace cache stays one entry per bucket). A tail of one takes the
        single-session program instead of paying padding."""
        sids = tuple(sids)
        bs = self.batch_buckets
        if not bs:
            return [((sid,), 0) for sid in sids]
        groups: List[Tuple[Tuple[str, ...], int]] = []
        i, n = 0, len(sids)
        maxb = bs[-1]
        while n - i >= maxb:
            groups.append((sids[i:i + maxb], 0))
            i += maxb
        rem = n - i
        if rem == 1:
            groups.append((sids[i:], 0))
        elif rem > 1:
            cover = min(x for x in bs if x >= rem)
            groups.append((sids[i:], cover - rem))
        return groups

    def _note_window(self, sess: _Session,
                     logits_row: np.ndarray) -> StreamResult:
        self._windows += 1
        self._m_windows.inc()
        r = StreamResult(sid=sess.sid, window=sess.windows,
                         logits=logits_row, streamed=sess.windows > 0)
        sess.windows += 1
        return r

    def _prime_sessions(self, group: Sequence[str],
                        pad: int) -> List[StreamResult]:
        """Run the priming window for a group of sessions in one jitted
        call (`pad` extra zero rows round the batch up to a bucket)."""
        sess = [self._sessions[sid] for sid in group]
        b = len(sess) + pad
        xs = [s.pending[:self.window] for s in sess]
        if pad:
            xs += [np.zeros((self.window, self.input_ch), np.float32)] * pad
        x = jnp.asarray(np.stack(xs))
        t0 = self._clock()
        if b == 1:
            logits, bufs = self._prime(x)
            outs = [bufs]
        else:
            logits, outs = self._prime_many_fn(b)(x)
        logits = np.asarray(jax.block_until_ready(logits))
        t1 = self._clock()
        results = []
        for i, s in enumerate(sess):
            # consume ONLY after the jitted call returned: a failed prime
            # (device OOM, bad buffer state) must not lose frames
            s.pending = s.pending[self.window:]
            s.buffers = outs[i]
            self._sessions.move_to_end(s.sid)
            s.last_used = t1
            results.append(self._note_window(s, logits[i]))
        self._primes += len(sess)
        self._prime_s += t1 - t0
        frames = self.plan.frames_full * b
        self._frames_computed += frames
        self._m_computed.inc(frames)
        self._m_batch.observe(len(sess))
        if b > 1:
            self._batched_calls += 1
            self._windows_batched += len(sess)
        if pad:
            self._pad_rows += pad
            self._m_pad.inc(pad)
        if b == 1:
            self.tracer.complete(
                "stream_prime", t0, t1, cat="stream", tid=OT.TID_ENGINE,
                args={"sid": group[0], "frames": frames})
        else:
            self.tracer.complete(
                "stream_prime_batched", t0, t1, cat="stream",
                tid=OT.TID_ENGINE,
                args={"sids": list(group), "batch": len(sess), "pad": pad,
                      "frames": frames})
        return results

    def _step_sessions(self, group: Sequence[str],
                       pad: int) -> List[StreamResult]:
        """Advance a group of primed sessions by one hop in one jitted
        call. Padding rows replicate the first session's buffers; their
        outputs are discarded (batch rows are independent, so the real
        rows stay bit-exact)."""
        sess = [self._sessions[sid] for sid in group]
        b = len(sess) + pad
        news = [s.pending[:self.hop] for s in sess]
        if pad:
            news += [np.zeros((self.hop, self.input_ch), np.float32)] * pad
        new = jnp.asarray(np.stack(news))
        t0 = self._clock()
        if b == 1:
            logits, out = self._step(sess[0].buffers, new)
            outs = [out]
        else:
            bufs_list = [s.buffers for s in sess]
            if pad:
                bufs_list += [sess[0].buffers] * pad
            logits, outs = self._step_many_fn(b)(bufs_list, new)
        logits = np.asarray(jax.block_until_ready(logits))
        t1 = self._clock()
        results = []
        for i, s in enumerate(sess):
            s.pending = s.pending[self.hop:]  # transactional: after success
            s.buffers = outs[i]
            self._sessions.move_to_end(s.sid)
            s.last_used = t1
            results.append(self._note_window(s, logits[i]))
        self._step_s += t1 - t0
        frames = self.plan.frames_step * b
        reused = (self.plan.frames_full - self.plan.frames_step) * len(sess)
        self._frames_computed += frames
        self._frames_reused += reused
        self._m_computed.inc(frames)
        self._m_reused.inc(reused)
        self._m_batch.observe(len(sess))
        if b > 1:
            self._batched_calls += 1
            self._windows_batched += len(sess)
        if pad:
            self._pad_rows += pad
            self._m_pad.inc(pad)
        if b == 1:
            self.tracer.complete(
                "stream_step", t0, t1, cat="stream", tid=OT.TID_ENGINE,
                args={"sid": group[0], "frames": frames})
        else:
            self.tracer.complete(
                "stream_step_batched", t0, t1, cat="stream",
                tid=OT.TID_ENGINE,
                args={"sids": list(group), "batch": len(sess), "pad": pad,
                      "frames": frames})
        return results

    def _ready_sids(self) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
        primes, steps = [], []
        for sid, s in self._sessions.items():
            if s.buffers is None:
                if len(s.pending) >= self.window:
                    primes.append(sid)
            elif len(s.pending) >= self.hop:
                steps.append(sid)
        return tuple(primes), tuple(steps)

    def step_many(self, sids: Sequence[str]) -> List[StreamResult]:
        """Advance each named session by ONE hop, grouped into bucketed
        batched step calls. Sessions that are unprimed or hold fewer than
        `hop` pending frames are skipped (push their frames first, or use
        `drain()` which also primes); unknown sids raise KeyError."""
        ready, seen = [], set()
        for sid in sids:
            sess = self._sessions.get(sid)
            if sess is None:
                raise KeyError(f"unknown session {sid!r}; open_session first")
            if sid in seen:
                continue
            seen.add(sid)
            if sess.buffers is not None and len(sess.pending) >= self.hop:
                ready.append(sid)
        results: List[StreamResult] = []
        for group, pad in self._buckets_of(ready):
            results += self._step_sessions(group, pad)
        return results

    def drain(self) -> List[StreamResult]:
        """Advance EVERY ready session until none can move: each round
        groups the sessions ready to prime and the sessions ready to
        step into bucketed batched calls (mixed-phase fleets work — a
        session primed in round k steps in round k+1 if it still holds a
        hop of frames). Returns all completed windows; per session they
        are in window order."""
        results: List[StreamResult] = []
        while True:
            primes, steps = self._ready_sids()
            if not primes and not steps:
                break
            for group, pad in self._buckets_of(primes):
                results += self._prime_sessions(group, pad)
            for group, pad in self._buckets_of(steps):
                results += self._step_sessions(group, pad)
        return results

    # -- reporting --------------------------------------------------------

    def energy_j_per_window(self) -> float:
        """Modeled energy of one steady-state streaming step.

        Compute term: the measured average step wall time priced at the
        device's busy watts (falling back to analytic pJ/MAC over the
        plan's per-step MACs before any step has run); memory term: the
        plan's per-step activation traffic at DRAM pJ/byte. The same
        accounting as `repro.energy.estimate_energy`, specialized to the
        ring-buffer step geometry."""
        mem_j = self.plan.bytes_step * EM.PJ_PER_BYTE * 1e-12
        steps = self._windows - self._primes
        if steps and self._step_s > 0:
            return self.power.busy_w * (self._step_s / steps) + mem_j
        bits = max((op.bits for b in self.qnet.spec.blocks for op in b.ops),
                   default=8)
        pj = EM.PJ_PER_MAC.get(bits, EM.PJ_PER_MAC_DEFAULT)
        return self.plan.macs_step * pj * 1e-12 + mem_j

    def stats(self) -> Dict[str, float]:
        steps = self._windows - self._primes
        wps = (steps / self._step_s
               if steps and self._step_s > 0 else 0.0)
        energy_j = self.energy_j_per_window()
        watts = self.power.idle_w + energy_j * wps
        fps_per_watt = wps / watts if watts > 0 else 0.0
        self._m_fpw.set(fps_per_watt)
        self._m_watts.set(watts)
        return {
            "sessions_active": float(len(self._sessions)),
            "sessions_evicted": float(self._evicted),
            "windows": float(self._windows),
            "primes": float(self._primes),
            "steps": float(steps),
            # fleet mode: windows advanced through batched (B>1) calls,
            # how many such dispatches ran, the bucket-padding waste, and
            # how many times a batched program actually traced (bounded
            # by 2 * len(batch_buckets) when the scheduler is healthy)
            "windows_batched": float(self._windows_batched),
            "batched_calls": float(self._batched_calls),
            "batched_traces": float(self._batched_traces),
            "pad_rows": float(self._pad_rows),
            "frames_computed_total": float(self._frames_computed),
            "frames_reused_total": float(self._frames_reused),
            "frames_per_window_full": float(self.plan.frames_full),
            "frames_per_window_step": float(self.plan.frames_step),
            "reuse_fraction": self.plan.reuse_fraction,
            "macs_per_window_full": float(self.plan.macs_full),
            "macs_per_window_step": float(self.plan.macs_step),
            "session_buffer_bytes": float(self.plan.buffer_bytes),
            # resident memory breakdown: uint8 ring buffers of primed
            # sessions + float32 pending staging of ALL sessions — the
            # total is what an eviction-by-bytes policy must budget
            "session_table_buffer_bytes":
                float(self.session_table_buffer_bytes()),
            "session_table_pending_bytes":
                float(self.session_table_pending_bytes()),
            "session_table_bytes": float(self.session_table_bytes()),
            "prime_s": self._prime_s,
            "step_s": self._step_s,
            "fps_streamed": wps,
            # calibrated energy model (docs/energy.md): per-step modeled
            # joules, average modeled draw at the achieved window rate,
            # and the paper's headline windows-per-second-per-watt
            "bytes_per_window_full": float(self.plan.bytes_full),
            "bytes_per_window_step": float(self.plan.bytes_step),
            "energy_j_per_window_step": energy_j,
            "watts": watts,
            "fps_per_watt": fps_per_watt,
        }


def frames_for_windows(n_windows: int, window: int, hop: int) -> int:
    """Stream length that yields exactly `n_windows` hop-aligned windows."""
    return window + (n_windows - 1) * hop


__all__ = [
    "StreamError",
    "StreamPlan",
    "StreamEngine",
    "StreamResult",
    "plan_stream",
    "reference_windows",
    "frames_for_windows",
]
