"""Vision serving subsystem: continuous-batching integer DSCNN inference
over pipelined CU stages (paper Sec. 4's CU invocation schedule, serving
form).

Layers, bottom-up:

  * `stages`   — the stage compiler: lowers a `CUPlan` schedule into one
                 jitted, bucket-batched executor per CU role
                 (Head / Body / Tail / Classifier).
  * `pipeline` — the software-pipelined scheduler: streams micro-batches
                 through the CU stages with every stage in flight at once
                 (the paper's double-buffered CU invocation schedule).
  * `engine`   — the continuous-batching front end: request queue, dynamic
                 batch former with shape/bucket admission, per-request
                 deadlines, and throughput/latency/energy-proxy stats
                 (the Table 6 FPS / FPS-per-Watt view). `mesh=` shards
                 micro-batches data-parallel across a `dist.sharding`
                 'data' mesh (constants replicated, bit-exact); the
                 `MultiModelEngine` router serves several models through
                 per-model pipelines under one EDF dispatch policy.
"""
from repro.serve.vision.engine import (
    AdmissionError,
    EngineStats,
    MultiModelEngine,
    RequestResult,
    VisionEngine,
    VisionRequest,
)
from repro.serve.vision.pipeline import PipelinedExecutor
from repro.serve.vision.stages import CompiledStage, compile_stages

__all__ = [
    "AdmissionError",
    "CompiledStage",
    "EngineStats",
    "MultiModelEngine",
    "PipelinedExecutor",
    "RequestResult",
    "VisionEngine",
    "VisionRequest",
    "compile_stages",
]
