"""Continuous-batching front end for integer DSCNN serving.

Requests (single images) enter a queue; the dynamic batch former groups them
into bucket-sized micro-batches (earliest-deadline-first), pads odd tails up
to the nearest bucket so every stage executor sees one of a fixed set of
batch shapes, and feeds the software-pipelined CU executor. Results are
un-padded back to per-request logits with latency accounting.

Admission control mirrors what a fixed-function accelerator can accept:
images must match the compiled network's input signature exactly (HxWxC),
and the queue is bounded. Expired deadlines are dropped at batch-forming
time — the accelerator never burns CU invocations on work nobody waits for.

Two scaling axes beyond the single-device engine:

  * **replication** (`mesh=`): a 1-D 'data' mesh from `dist.sharding`
    replicates the whole integer datapath — constants on every replica,
    micro-batch rows sharded along 'data' through every stage executor
    (`jax.jit` with `NamedSharding` in/out). The multi-device analogue of
    DeepDive's parallel channel/filter CU replication; results stay
    bit-exact because every image's arithmetic is replica-local.
  * **multi-model** (`MultiModelEngine`): requests tagged by model are
    routed to per-model stage pipelines sharing the device(s); micro-batch
    dispatch order across models follows the same EDF deadline policy the
    single-model batch former uses.

`EngineStats` reports the paper's Table 6 serving quantities: FPS, latency
percentiles, per-stage invocation counts, and modeled energy from the
calibrated `repro.energy` model (autotuner route timings x analytic
bytes-moved x a device power curve) — J/image, average watts, and the
paper's headline FPS/Watt. With `power_budget_w=` the batch former
consults a `PowerGovernor` before every dispatch and defers (or sheds
lowest-SLO) work so the modeled rolling-window watt estimate never
crosses the budget. See docs/energy.md and docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler as CC
from repro.core import graph as G
from repro.core.qnet import QNet
from repro.dist.sharding import batch_sharding
from repro.energy import EnergyReport, PowerGovernor, PowerModel, estimate_energy
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.serve.vision.pipeline import PipelinedExecutor
from repro.serve.vision.stages import CompiledStage, compile_stages


def _percentile(sorted_lat: Sequence[float], p: float) -> float:
    """Nearest-rank percentile over pre-sorted latencies.

    NaN-safe: with zero completions (every request expired before a batch
    formed) there is no latency distribution — report NaN rather than a
    misleading 0.0 or a divide-by-zero downstream."""
    if not sorted_lat:
        return float("nan")
    return sorted_lat[max(0, math.ceil(p * len(sorted_lat)) - 1)]


class AdmissionError(ValueError):
    """Request rejected at admission (shape mismatch / queue full)."""


@dataclasses.dataclass
class VisionRequest:
    rid: int
    image: np.ndarray  # [H, W, C] float, in the calibrated input range
    deadline_s: Optional[float] = None  # absolute time.perf_counter() time
    arrival_s: float = 0.0
    # SLO class: higher is more important. Under a power budget the
    # governor may shed requests at or below the engine's shed class;
    # work above it is only ever deferred, never dropped.
    slo: int = 0


@dataclasses.dataclass
class RequestResult:
    rid: int
    status: str  # "ok" | "expired" | "shed"
    logits: Optional[np.ndarray]  # [num_classes] float, None unless ok
    latency_s: float


@dataclasses.dataclass
class EngineStats:
    n_ok: int
    n_expired: int
    wall_s: float
    fps: float
    latency_p50_s: float
    latency_p95_s: float
    micro_batches: int
    pad_fraction: float  # padded rows / dispatched rows
    stage_invocations: Dict[str, int]
    harvest_wait_s: float
    macs_per_image: int
    # calibrated energy model (repro.energy): J/image from route timings x
    # bytes-moved x the device power curve; watts = idle + dispatched J /
    # wall; fps_per_watt is the paper's headline metric
    energy_j_per_image: float
    watts: float
    fps_per_watt: float
    power_source: str
    energy_tuned_fraction: float  # fraction of ops priced from measured routes
    replicas: int = 1  # mesh 'data' extent the engine shards over
    latency_p99_s: float = float("nan")
    # traces at non-bucketed shapes per stage (should stay all-zero; see
    # CompiledStage.allowed_batches — a nonzero count is a retrace leak)
    stage_retraces: Dict[str, int] = dataclasses.field(default_factory=dict)
    # power-capped scheduling outcomes (zero unless power_budget_w is set)
    n_shed: int = 0
    n_deferred: int = 0
    power_budget_w: Optional[float] = None

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class VisionEngine:
    """Serve a calibrated QNet through the pipelined CU stage executors.

    `mesh`: a 1-D 'data' mesh (see `dist.sharding.data_mesh`) shards every
    micro-batch data-parallel across its replicas; each requested bucket is
    rounded up to the next replica multiple (rows are bucket-padded anyway,
    so every batch splits evenly — no caller has to special-case counts).
    `clock`: injectable time source (returns seconds, perf_counter-like) —
    deadlines, latencies, and wall time all read it; tests pass a fake.
    `tuned`: a `repro.tune.TunedPlan` — measured per-op route selection
    replaces the stage compiler's hard-coded kernel heuristics (ops with
    no cache entry keep the defaults; see `compile_stages`). The same
    cache feeds the energy model's per-op timings.
    `power_model` / `energy`: override the device power curve or the whole
    `EnergyReport` (defaults: RAPL-calibrated or per-backend constants,
    and `estimate_energy` over this plan + cache).
    `power_budget_w`: power-capped mode — before each dispatch the batch
    former asks a `PowerGovernor` whether the modeled rolling-window
    (`power_window_s`) watt estimate would cross the budget; if so,
    requests with `slo <= shed_slo` are shed (terminal "shed" status) and
    the rest are deferred back to the queue for a later `run()`.
    """

    @classmethod
    def from_artifact(cls, path: str, net: Optional[G.NetSpec] = None,
                      **kwargs) -> "VisionEngine":
        """Serve a frozen `.qnet` deployment artifact straight from disk.

        Artifacts written by the training export pipeline
        (`repro.train.vision.export`) carry their own build record, so no
        NetSpec is needed; record-less fixtures pass `net=` explicitly.
        All engine knobs (`buckets`, `mesh`, `tuned`, ...) pass through."""
        from repro.core.qnet import load_qnet
        return cls(load_qnet(path, net), **kwargs)

    def __init__(
        self,
        qnet: QNet,
        plan: Optional[CC.CUPlan] = None,
        *,
        buckets: Sequence[int] = (1, 2, 4, 8),
        fixed_point: bool = False,
        input_bits: int = 8,
        body_fast_path: str = "auto",
        op_kernels: str = "auto",
        prepare: bool = True,
        donate: str = "auto",
        interpret: Optional[bool] = None,
        mesh=None,
        tuned=None,
        clock: Optional[Callable[[], float]] = None,
        max_queue: int = 4096,
        tracer: Optional[OT.Tracer] = None,
        metrics: Optional[OM.MetricsRegistry] = None,
        name: str = "default",
        power_model: Optional[PowerModel] = None,
        energy: Optional[EnergyReport] = None,
        power_budget_w: Optional[float] = None,
        power_window_s: float = 1.0,
        shed_slo: int = 0,
    ):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"bad buckets {buckets}")
        self.qnet = qnet
        self.plan = plan if plan is not None else CC.compile_net(qnet.spec)
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.mesh = mesh
        self.replicas = 1
        self._batch_sharding = None
        if mesh is not None:
            self.replicas = int(dict(mesh.shape).get("data", 1))
            # every bucket rounds up to the next replica multiple: batches
            # are bucket-padded regardless, so each shard gets equal rows
            self.buckets = tuple(sorted(
                {-(-b // self.replicas) * self.replicas
                 for b in self.buckets}))
            self._batch_sharding = batch_sharding(mesh)
        self._clock = time.perf_counter if clock is None else clock
        self.max_queue = max_queue
        self.stages: List[CompiledStage] = compile_stages(
            qnet, self.plan, fixed_point=fixed_point, input_bits=input_bits,
            body_fast_path=body_fast_path, op_kernels=op_kernels,
            prepare=prepare, donate=donate, interpret=interpret, mesh=mesh,
            tuned=tuned)
        self.name = name
        self.tracer = tracer if tracer is not None else OT.NULL
        self.metrics = metrics
        self._reg = metrics if metrics is not None else OM.NULL_REGISTRY
        self.pipe = PipelinedExecutor(self.stages, clock=self._clock,
                                      tracer=tracer, metrics=metrics)
        net = qnet.spec
        self.input_shape = net.input_shape()  # (H, W, C) or (T, C)
        # calibrated energy model: tuned route timings (when a cache is in
        # hand) x analytic bytes x the device power curve
        self.energy = energy if energy is not None else estimate_energy(
            qnet, self.plan, tuned=tuned, power=power_model)
        self.power_budget_w = power_budget_w
        self.shed_slo = shed_slo
        self._governor: Optional[PowerGovernor] = None
        if power_budget_w is not None:
            self._governor = PowerGovernor(
                power_budget_w, window_s=power_window_s,
                idle_w=self.energy.power.idle_w)
        self._queue: List[VisionRequest] = []
        self._rid = itertools.count()
        self._results: Dict[int, RequestResult] = {}
        # cumulative counters (across run() calls)
        self._n_ok = 0
        self._n_expired = 0
        self._n_shed = 0
        self._n_deferred = 0
        self._dispatched_j = 0.0  # modeled energy of every dispatched row
        self._latencies: List[float] = []
        self._micro_batches = 0
        self._rows = 0
        self._pad_rows = 0
        self._wall_s = 0.0
        self._init_obs()

    def _init_obs(self) -> None:
        """Register instruments, arm retrace-leak detection, name the trace
        tracks, and tie stage dispatch spans back to request ids."""
        reg, lbl = self._reg, {"model": self.name}
        self._m_submitted = reg.counter(
            "serve_requests_submitted_total", "requests admitted", labels=lbl)
        self._m_expired = reg.counter(
            "serve_requests_expired_total",
            "requests dropped at batch forming (EDF deadline expiry)",
            labels=lbl)
        self._m_completed = reg.counter(
            "serve_requests_completed_total", "requests answered with logits",
            labels=lbl)
        self._m_qdepth = reg.gauge(
            "serve_queue_depth", "requests waiting for batch formation",
            labels=lbl)
        self._m_qwait = reg.histogram(
            "serve_queue_wait_seconds",
            "arrival to batch-formation wait", labels=lbl)
        self._m_latency = reg.histogram(
            "serve_request_latency_seconds",
            "arrival to harvested-logits latency", labels=lbl)
        self._m_batches = reg.counter(
            "serve_micro_batches_total", "bucket-padded micro-batches formed",
            labels=lbl)
        self._m_rows = reg.counter(
            "serve_dispatched_rows_total",
            "rows dispatched incl. bucket padding", labels=lbl)
        self._m_pad = reg.counter(
            "serve_pad_rows_total", "bucket-padding waste rows", labels=lbl)
        self._m_fps = reg.gauge(
            "serve_fps", "completed images per second of drain wall time",
            labels=lbl)
        self._m_fpw = reg.gauge(
            "serve_fps_per_watt",
            "modeled FPS per watt (calibrated energy model, incl. idle draw)",
            labels=lbl)
        self._m_watts = reg.gauge(
            "serve_watts",
            "modeled average device watts over serving wall time", labels=lbl)
        self._m_shed = reg.counter(
            "serve_requests_shed_total",
            "low-SLO requests shed by the power governor", labels=lbl)
        self._m_deferred = reg.counter(
            "serve_requests_deferred_total",
            "requests deferred to a later run() by the power governor",
            labels=lbl)
        # retrace-leak detection: every stage knows the legal batch shapes
        # (the padded buckets); a trace outside them is a leak past the
        # batch former — counted, warned, and surfaced in stats()
        allowed = frozenset(self.buckets)
        for st in self.stages:
            st.allowed_batches = allowed
            st.on_retrace = self._note_retrace(reg.counter(
                "serve_stage_retraces_total",
                "stage traces at non-bucketed batch shapes (retrace leak)",
                labels={"model": self.name, "cu": st.spec.cu}))
        if self.tracer:
            self.tracer.name_track(OT.TID_ENGINE, "engine")
            self.tracer.name_track(OT.TID_REQUESTS, "requests")
            self.tracer.name_track(OT.TID_SCHED, "scheduler")
            self.pipe.tag_info = lambda reqs: {"rids": [r.rid for r in reqs]}

    def _note_retrace(self, metric) -> Callable:
        def _hook(stage: CompiledStage, shape: Tuple[int, ...]) -> None:
            metric.inc()
            if self.tracer:
                self.tracer.instant(
                    f"retrace:{stage.spec.cu}", self._clock(),
                    cat="retrace", tid=OT.TID_ENGINE,
                    args={"shape": list(shape),
                          "buckets": sorted(stage.allowed_batches)})
        return _hook

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, image: np.ndarray, *, deadline_s: Optional[float] = None,
               now: Optional[float] = None, slo: int = 0) -> int:
        """Admit one image; returns its request id.

        `slo` is the request's service class (higher = more important);
        under a power budget only classes at or below `shed_slo` may be
        shed. Raises AdmissionError when the image does not match the
        compiled input signature or the queue is full."""
        image = np.asarray(image)
        if image.shape != self.input_shape:
            raise AdmissionError(
                f"image shape {image.shape} != compiled input signature "
                f"{self.input_shape} (HxWxC)")
        if not np.issubdtype(image.dtype, np.floating):
            raise AdmissionError(
                f"expected float image in the calibrated input range, got "
                f"dtype {image.dtype}")
        if len(self._queue) >= self.max_queue:
            raise AdmissionError(f"queue full ({self.max_queue})")
        rid = next(self._rid)
        arrival = self._clock() if now is None else now
        self._queue.append(VisionRequest(
            rid=rid, image=image, deadline_s=deadline_s, arrival_s=arrival,
            slo=slo))
        self._m_submitted.inc()
        self._m_qdepth.set(len(self._queue))
        if self.tracer:
            # per-request lifecycle span opens at admission (async "b",
            # closed at expiry or completion); arrival is already read —
            # no extra clock reads on the admission path
            self.tracer.async_begin(
                "request", rid, arrival, cat=f"request:{self.name}",
                args={"model": self.name, "deadline_s": deadline_s})
            self.tracer.counter(
                f"queue_depth:{self.name}", {"pending": len(self._queue)},
                arrival)
        return rid

    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    # batch forming
    # ------------------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        """Smallest bucket that covers n, else the largest bucket."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _place(self, x: np.ndarray) -> jax.Array:
        """Host micro-batch -> device: single-device upload, or sharded
        along the mesh 'data' axis (each replica receives only its rows)."""
        if self._batch_sharding is None:
            return jnp.asarray(x)
        return jax.device_put(x, self._batch_sharding)

    def _form_batches(self) -> Iterator[Tuple[List[VisionRequest], jax.Array]]:
        """Drain the queue into bucket-padded micro-batches, EDF-ordered.

        Lazily, one micro-batch per next() — so under the pipelined
        executor, forming batch k+1 overlaps the accelerator running
        batch k. One sort per drain: submit() cannot interleave with
        run(), so deadlines are fixed for the whole drain."""
        self._queue.sort(
            key=lambda r: r.deadline_s if r.deadline_s is not None
            else float("inf"))
        pending, self._queue = self._queue, []
        self._m_qdepth.set(0)
        head = 0
        while head < len(pending):
            now = self._clock()
            live: List[VisionRequest] = []
            while head < len(pending) and len(live) < self.buckets[-1]:
                req = pending[head]
                head += 1
                if req.deadline_s is not None and now > req.deadline_s:
                    self._results[req.rid] = RequestResult(
                        req.rid, "expired", None, now - req.arrival_s)
                    self._n_expired += 1
                    self._m_expired.inc()
                    if self.tracer:
                        self.tracer.async_end(
                            "request", req.rid, now,
                            cat=f"request:{self.name}",
                            args={"status": "expired"})
                    continue
                live.append(req)
            if not live:
                continue
            bucket = self._bucket_for(len(live))
            if self._governor is not None:
                # power-capped dispatch: every padded row costs modeled
                # J/image on the device; if this batch would push the
                # rolling-window watt estimate over the budget, shed the
                # sheddable SLO classes and defer everything else — the
                # budget is never crossed at any dispatch point.
                batch_j = bucket * self.energy.j_per_image
                if self._governor.would_exceed(batch_j, now):
                    self._shed_or_defer(live, pending[head:], now)
                    return
                self._governor.record(batch_j, now)
            self._dispatched_j += bucket * self.energy.j_per_image
            x = np.zeros((bucket, *self.input_shape), np.float32)
            for i, req in enumerate(live):
                x[i] = req.image
            self._micro_batches += 1
            self._rows += bucket
            self._pad_rows += bucket - len(live)
            self._m_batches.inc()
            self._m_rows.inc(bucket)
            self._m_pad.inc(bucket - len(live))
            for req in live:
                self._m_qwait.observe(now - req.arrival_s)
            if self.tracer:
                # batch-formation span covers the host-side gather+pad; the
                # per-request queue waits nest as b/e pairs on timestamps
                # already read (arrival, now) — zero extra clock reads
                tf1 = self._clock()
                self.tracer.complete(
                    "form_batch", now, tf1, cat="pipeline", tid=OT.TID_SCHED,
                    args={"model": self.name, "bucket": bucket,
                          "live": len(live), "pad": bucket - len(live),
                          "rids": [r.rid for r in live]})
                for req in live:
                    self.tracer.async_begin(
                        "queue_wait", req.rid, req.arrival_s,
                        cat=f"request:{self.name}")
                    self.tracer.async_end(
                        "queue_wait", req.rid, now,
                        cat=f"request:{self.name}")
            yield live, self._place(x)

    def _shed_or_defer(self, live: List[VisionRequest],
                       rest: List[VisionRequest], now: float) -> None:
        """Over-budget batch: shed classes <= shed_slo (terminal), defer
        the remainder back to the queue for a later run()."""
        deferred: List[VisionRequest] = []
        for req in live:
            if req.slo <= self.shed_slo:
                self._results[req.rid] = RequestResult(
                    req.rid, "shed", None, now - req.arrival_s)
                self._n_shed += 1
                self._m_shed.inc()
                if self.tracer:
                    self.tracer.async_end(
                        "request", req.rid, now, cat=f"request:{self.name}",
                        args={"status": "shed"})
            else:
                deferred.append(req)
        deferred.extend(rest)
        if deferred:
            # deferral is not terminal: requests keep their arrival and
            # deadline, and re-enter EDF ordering on the next drain
            self._queue.extend(deferred)
            self._n_deferred += len(deferred)
            self._m_deferred.inc(len(deferred))
            self._m_qdepth.set(len(self._queue))
        if self.tracer:
            self.tracer.instant(
                "power_cap", now, cat="governor", tid=OT.TID_SCHED,
                args={"model": self.name,
                      "watts": self._governor.watts(now),
                      "budget_w": self.power_budget_w,
                      "shed": self._n_shed, "deferred": len(deferred)})

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def _record_batch(self, reqs: List[VisionRequest], y: jax.Array,
                      done: float) -> None:
        """Un-pad a finished micro-batch into per-request results."""
        logits = np.asarray(y)
        for i, req in enumerate(reqs):
            self._results[req.rid] = RequestResult(
                req.rid, "ok", logits[i], done - req.arrival_s)
            self._latencies.append(done - req.arrival_s)
            self._n_ok += 1
            self._m_completed.inc()
            self._m_latency.observe(done - req.arrival_s)
            if self.tracer:
                self.tracer.async_end(
                    "request", req.rid, done, cat=f"request:{self.name}",
                    args={"status": "ok"})

    def _collect_results(self) -> Dict[int, RequestResult]:
        results, self._results = self._results, {}
        return results

    def run(self) -> Dict[int, RequestResult]:
        """Drain the queue through the pipelined CU stages; return results
        (keyed by request id) for everything completed by this call."""
        t0 = self._clock()
        for reqs, y in self.pipe.stream(self._form_batches()):
            self._record_batch(reqs, y, self._clock())
        t1 = self._clock()
        self._wall_s += t1 - t0
        if self.tracer:
            self.tracer.complete(
                "drain", t0, t1, cat="engine", tid=OT.TID_ENGINE,
                args={"model": self.name})
        return self._collect_results()

    def warmup(self) -> None:
        """Pre-trace every stage at every bucket size (avoids paying XLA
        tracing on the serving path)."""
        for b in self.buckets:
            self.pipe.warmup(
                self._place(np.zeros((b, *self.input_shape), np.float32)))

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> EngineStats:
        lat = sorted(self._latencies)
        macs = self.qnet.spec.count_macs()
        energy_j = self.energy.j_per_image
        fps = self._n_ok / self._wall_s if self._wall_s > 0 else 0.0
        # modeled draw over the serving window: static idle floor plus the
        # dispatched (bucket-padded) rows' modeled joules amortized over
        # wall time — rate-dependent, exactly like measured board power
        watts = self.energy.power.idle_w + (
            self._dispatched_j / self._wall_s if self._wall_s > 0 else 0.0)
        fps_per_watt = fps / watts if watts > 0 else 0.0
        self._m_fps.set(fps)
        self._m_fpw.set(fps_per_watt)
        self._m_watts.set(watts)
        return EngineStats(
            n_ok=self._n_ok,
            n_expired=self._n_expired,
            wall_s=self._wall_s,
            fps=fps,
            latency_p50_s=_percentile(lat, 0.50),
            latency_p95_s=_percentile(lat, 0.95),
            micro_batches=self._micro_batches,
            pad_fraction=(self._pad_rows / self._rows) if self._rows else 0.0,
            stage_invocations={
                s.spec.cu: s.invocations for s in self.stages},
            harvest_wait_s=self.pipe.harvest_wait_s,
            macs_per_image=macs,
            energy_j_per_image=energy_j,
            watts=watts,
            fps_per_watt=fps_per_watt,
            power_source=self.energy.power.source,
            energy_tuned_fraction=self.energy.tuned_fraction,
            replicas=self.replicas,
            latency_p99_s=_percentile(lat, 0.99),
            stage_retraces={s.spec.cu: s.retraces for s in self.stages},
            n_shed=self._n_shed,
            n_deferred=self._n_deferred,
            power_budget_w=self.power_budget_w,
        )


class MultiModelEngine:
    """EDF router over per-model `VisionEngine`s sharing the device (mesh).

    Requests are tagged by model name at submit time and drain through that
    model's own stage pipeline. One `run()` drains every model's queue:
    each scheduler round ticks every pipeline once (so no model starves),
    and the order models dispatch within a round is earliest-deadline-first
    over each model's next pending micro-batch — the model holding the
    tightest deadline enqueues its CU invocations into the shared device
    stream first, extending the single-model EDF policy across models.

    `dispatch_log` records (model, live_rows) per dispatched micro-batch in
    dispatch order for the LAST drain (reset at each run()) — the
    scheduling trace the fairness tests assert on.

    One time source rules the fleet: an explicit `clock` is propagated down
    to every engine (wall time, latencies, and deadline expiry must never
    mix clocks); with `clock=None` the router adopts the engines' shared
    clock and refuses construction if they disagree.

    `power_budget_w` installs ONE shared `PowerGovernor` across every
    engine: the rolling-window watt estimate sums all models' dispatches,
    so the fleet as a whole stays under the budget (an engine that already
    has its own governor is refused — two books over one device would
    both be wrong).
    """

    def __init__(self, engines: Dict[str, VisionEngine],
                 clock: Optional[Callable[[], float]] = None,
                 *, power_budget_w: Optional[float] = None,
                 power_window_s: float = 1.0):
        if not engines:
            raise ValueError("need at least one model engine")
        self.engines = dict(engines)
        if clock is None:
            clocks = {id(e._clock) for e in self.engines.values()}
            if len(clocks) != 1:
                raise ValueError(
                    "engines hold different clocks — pass an explicit "
                    "clock= to unify the router's time source")
            self._clock = next(iter(self.engines.values()))._clock
        else:
            for eng in self.engines.values():
                # rebinding the clock over prior activity would mix time
                # domains: arrivals/deadlines in flight, or wall/expiry
                # counters already accrued under the old clock
                if (eng.pending() or eng._latencies or eng._results
                        or eng._wall_s or eng._n_ok or eng._n_expired
                        or eng.pipe.busy):
                    raise ValueError(
                        "cannot rebind the clock of an engine with pending "
                        "requests or recorded activity — construct the "
                        "router before serving")
            self._clock = clock
            for eng in self.engines.values():
                eng._clock = clock
                eng.pipe._clock = clock
        self.governor: Optional[PowerGovernor] = None
        if power_budget_w is not None:
            owned = sorted(m for m, e in self.engines.items()
                           if e._governor is not None)
            if owned:
                raise ValueError(
                    f"engines {owned} already run their own power governor "
                    f"— a fleet budget needs one shared book; construct "
                    f"them without power_budget_w")
            idle = max(e.energy.power.idle_w for e in self.engines.values())
            self.governor = PowerGovernor(
                power_budget_w, window_s=power_window_s, idle_w=idle)
            for eng in self.engines.values():
                eng._governor = self.governor
                eng.power_budget_w = power_budget_w
        self.dispatch_log: List[Tuple[str, int]] = []
        # router dispatch decisions, counted into each engine's registry
        # (engines sharing a registry/tracer yield one fleet-wide view)
        self._m_dispatch = {
            m: e._reg.counter(
                "router_dispatch_total",
                "micro-batches the EDF router dispatched for this model",
                labels={"model": m})
            for m, e in self.engines.items()}

    # -- admission ---------------------------------------------------------

    def submit(self, model: str, image: np.ndarray, *,
               deadline_s: Optional[float] = None,
               now: Optional[float] = None,
               slo: int = 0) -> Tuple[str, int]:
        """Admit one image for `model`; returns the (model, rid) handle."""
        eng = self.engines.get(model)
        if eng is None:
            raise AdmissionError(
                f"unknown model {model!r}; serving {sorted(self.engines)}")
        return model, eng.submit(image, deadline_s=deadline_s, now=now,
                                 slo=slo)

    def pending(self) -> Dict[str, int]:
        return {m: e.pending() for m, e in self.engines.items()}

    def warmup(self) -> None:
        for eng in self.engines.values():
            eng.warmup()

    # -- scheduling --------------------------------------------------------

    @staticmethod
    def _edf_key(batch) -> float:
        """Earliest live deadline in a formed micro-batch (inf if none)."""
        if batch is None:
            return float("inf")
        deadlines = [r.deadline_s for r in batch[0] if r.deadline_s is not None]
        return min(deadlines) if deadlines else float("inf")

    def run(self) -> Dict[Tuple[str, int], RequestResult]:
        """Drain every model's queue; results keyed by (model, rid)."""
        t0 = self._clock()
        self.dispatch_log = []  # trace of THIS drain only (bounded)
        formers: Dict[str, Iterator] = {}
        peeked: Dict[str, Optional[Tuple]] = {}
        for m, eng in self.engines.items():
            if eng.pending():
                formers[m] = eng._form_batches()
                peeked[m] = next(formers[m], None)
        active = set(formers)

        def live_models() -> List[str]:
            return [m for m, e in self.engines.items()
                    if peeked.get(m) is not None or e.pipe.busy]

        try:
            while True:
                models = live_models()
                if not models:
                    break
                # EDF across models: tightest next-batch deadline
                # dispatches first this round; name-ordered tie-break keeps
                # it deterministic (and round-robin-fair for deadline-less
                # load).
                for m in sorted(models,
                                key=lambda m: (self._edf_key(peeked.get(m)), m)):
                    eng = self.engines[m]
                    finished = eng.pipe.advance()
                    batch = peeked.get(m)
                    if batch is not None:
                        eng.pipe.inject(batch)
                        self.dispatch_log.append((m, len(batch[0])))
                        self._m_dispatch[m].inc()
                        if eng.tracer:
                            edf = self._edf_key(batch)
                            eng.tracer.instant(
                                "router_dispatch", self._clock(),
                                cat="router", tid=OT.TID_SCHED,
                                args={"model": m, "rows": len(batch[0]),
                                      "edf_deadline_s":
                                          edf if math.isfinite(edf)
                                          else None})
                        peeked[m] = next(formers[m], None)
                    if finished is not None:
                        eng.pipe.harvest(finished)
                        eng._record_batch(
                            finished[0], finished[1], eng._clock())
        finally:
            # mirror stream()'s abandoned-drain contract for the tick-level
            # drive: an escaping exception must not leave stale in-flight
            # batches to replay into a later run()'s results
            for m in self.engines:
                self.engines[m].pipe.reset()
        t1 = self._clock()
        wall = t1 - t0
        results: Dict[Tuple[str, int], RequestResult] = {}
        for m, eng in self.engines.items():
            if m in active:
                # the drain shared the device, so the full drain wall is
                # each participating model's serving window
                eng._wall_s += wall
                if eng.tracer:
                    eng.tracer.complete(
                        "drain", t0, t1, cat="engine", tid=OT.TID_ENGINE,
                        args={"model": m})
            for rid, res in eng._collect_results().items():
                results[(m, rid)] = res
        return results

    def stats(self) -> Dict[str, EngineStats]:
        return {m: e.stats() for m, e in self.engines.items()}


__all__ = [
    "AdmissionError",
    "VisionRequest",
    "RequestResult",
    "EngineStats",
    "VisionEngine",
    "MultiModelEngine",
]
