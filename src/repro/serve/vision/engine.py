"""Continuous-batching front end for integer DSCNN serving.

Requests (single images) enter a queue; the dynamic batch former groups them
into bucket-sized micro-batches (earliest-deadline-first), pads odd tails up
to the nearest bucket so every stage executor sees one of a fixed set of
batch shapes, and feeds the software-pipelined CU executor. Results are
un-padded back to per-request logits with latency accounting.

Admission control mirrors what a fixed-function accelerator can accept:
images must match the compiled network's input signature exactly (HxWxC),
and the queue is bounded. Expired deadlines are dropped at batch-forming
time — the accelerator never burns CU invocations on work nobody waits for.

`EngineStats` reports the paper's Table 6 serving quantities: FPS, latency
percentiles, per-stage invocation counts, and an energy proxy (J/image from
the MAC count at an assumed pJ/MAC for the integer datapath) giving
FPS-per-Watt-proxy — on real silicon replace the proxy with measured power.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler as CC
from repro.core import graph as G
from repro.core.qnet import QNet
from repro.serve.vision.pipeline import PipelinedExecutor
from repro.serve.vision.stages import CompiledStage, compile_stages

# Energy proxy for the integer datapath, pJ per MAC by operand bit-width.
# Ballpark 45nm-class numbers (Horowitz, ISSCC'14: int8 MAC ~= 0.2pJ add +
# mul); scaled linearly for int4. A proxy for FPS/W ranking only.
_PJ_PER_MAC = {8: 0.23, 4: 0.12, 3: 0.10, 6: 0.18, 5: 0.15}


def _energy_j_per_image(net: G.NetSpec) -> float:
    """MAC-weighted energy proxy: each op's MACs priced at its bit-width
    (mirrors `NetSpec.count_macs`' shape walk)."""
    h = net.input_hw
    pj = 0.0
    for block in net.blocks:
        for op in block.ops:
            if op.kind == G.DENSE:
                pj += op.macs(1, 1) * _PJ_PER_MAC.get(op.bits, 0.2)
                continue
            h_out = -(-h // op.stride)
            pj += op.macs(h_out, h_out) * _PJ_PER_MAC.get(op.bits, 0.2)
            h = h_out
        if block.se is not None:
            pj += (block.se.squeeze.macs(1, 1) + block.se.excite.macs(1, 1)
                   ) * _PJ_PER_MAC.get(block.se.bits, 0.2)
    return pj * 1e-12


class AdmissionError(ValueError):
    """Request rejected at admission (shape mismatch / queue full)."""


@dataclasses.dataclass
class VisionRequest:
    rid: int
    image: np.ndarray  # [H, W, C] float, in the calibrated input range
    deadline_s: Optional[float] = None  # absolute time.perf_counter() time
    arrival_s: float = 0.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    status: str  # "ok" | "expired"
    logits: Optional[np.ndarray]  # [num_classes] float, None unless ok
    latency_s: float


@dataclasses.dataclass
class EngineStats:
    n_ok: int
    n_expired: int
    wall_s: float
    fps: float
    latency_p50_s: float
    latency_p95_s: float
    micro_batches: int
    pad_fraction: float  # padded rows / dispatched rows
    stage_invocations: Dict[str, int]
    harvest_wait_s: float
    macs_per_image: int
    energy_j_per_image_proxy: float
    fps_per_watt_proxy: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class VisionEngine:
    """Serve a calibrated QNet through the pipelined CU stage executors."""

    def __init__(
        self,
        qnet: QNet,
        plan: Optional[CC.CUPlan] = None,
        *,
        buckets: Sequence[int] = (1, 2, 4, 8),
        fixed_point: bool = False,
        input_bits: int = 8,
        body_fast_path: str = "auto",
        op_kernels: str = "auto",
        prepare: bool = True,
        donate: str = "auto",
        interpret: Optional[bool] = None,
        max_queue: int = 4096,
    ):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"bad buckets {buckets}")
        self.qnet = qnet
        self.plan = plan if plan is not None else CC.compile_net(qnet.spec)
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_queue = max_queue
        self.stages: List[CompiledStage] = compile_stages(
            qnet, self.plan, fixed_point=fixed_point, input_bits=input_bits,
            body_fast_path=body_fast_path, op_kernels=op_kernels,
            prepare=prepare, donate=donate, interpret=interpret)
        self.pipe = PipelinedExecutor(self.stages)
        net = qnet.spec
        self.input_shape = (net.input_hw, net.input_hw, net.input_ch)
        self._queue: List[VisionRequest] = []
        self._rid = itertools.count()
        self._results: Dict[int, RequestResult] = {}
        # cumulative counters (across run() calls)
        self._n_ok = 0
        self._n_expired = 0
        self._latencies: List[float] = []
        self._micro_batches = 0
        self._rows = 0
        self._pad_rows = 0
        self._wall_s = 0.0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, image: np.ndarray, *, deadline_s: Optional[float] = None,
               now: Optional[float] = None) -> int:
        """Admit one image; returns its request id.

        Raises AdmissionError when the image does not match the compiled
        input signature or the queue is full."""
        image = np.asarray(image)
        if image.shape != self.input_shape:
            raise AdmissionError(
                f"image shape {image.shape} != compiled input signature "
                f"{self.input_shape} (HxWxC)")
        if not np.issubdtype(image.dtype, np.floating):
            raise AdmissionError(
                f"expected float image in the calibrated input range, got "
                f"dtype {image.dtype}")
        if len(self._queue) >= self.max_queue:
            raise AdmissionError(f"queue full ({self.max_queue})")
        rid = next(self._rid)
        self._queue.append(VisionRequest(
            rid=rid, image=image, deadline_s=deadline_s,
            arrival_s=time.perf_counter() if now is None else now))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------
    # batch forming
    # ------------------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        """Smallest bucket that covers n, else the largest bucket."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _form_batches(self) -> Iterator[Tuple[List[VisionRequest], jax.Array]]:
        """Drain the queue into bucket-padded micro-batches, EDF-ordered.

        Lazily, one micro-batch per next() — so under the pipelined
        executor, forming batch k+1 overlaps the accelerator running
        batch k. One sort per drain: submit() cannot interleave with
        run(), so deadlines are fixed for the whole drain."""
        self._queue.sort(
            key=lambda r: r.deadline_s if r.deadline_s is not None
            else float("inf"))
        pending, self._queue = self._queue, []
        head = 0
        while head < len(pending):
            now = time.perf_counter()
            live: List[VisionRequest] = []
            while head < len(pending) and len(live) < self.buckets[-1]:
                req = pending[head]
                head += 1
                if req.deadline_s is not None and now > req.deadline_s:
                    self._results[req.rid] = RequestResult(
                        req.rid, "expired", None, now - req.arrival_s)
                    self._n_expired += 1
                    continue
                live.append(req)
            if not live:
                continue
            bucket = self._bucket_for(len(live))
            x = np.zeros((bucket, *self.input_shape), np.float32)
            for i, req in enumerate(live):
                x[i] = req.image
            self._micro_batches += 1
            self._rows += bucket
            self._pad_rows += bucket - len(live)
            yield live, jnp.asarray(x)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def run(self) -> Dict[int, RequestResult]:
        """Drain the queue through the pipelined CU stages; return results
        (keyed by request id) for everything completed by this call."""
        t0 = time.perf_counter()
        for reqs, y in self.pipe.stream(self._form_batches()):
            done = time.perf_counter()
            logits = np.asarray(y)
            for i, req in enumerate(reqs):
                self._results[req.rid] = RequestResult(
                    req.rid, "ok", logits[i], done - req.arrival_s)
                self._latencies.append(done - req.arrival_s)
                self._n_ok += 1
        self._wall_s += time.perf_counter() - t0
        results, self._results = self._results, {}
        return results

    def warmup(self) -> None:
        """Pre-trace every stage at every bucket size (avoids paying XLA
        tracing on the serving path)."""
        for b in self.buckets:
            self.pipe.warmup(jnp.zeros((b, *self.input_shape), jnp.float32))

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> EngineStats:
        lat = sorted(self._latencies)

        def pct(p: float) -> float:
            if not lat:
                return 0.0
            return lat[max(0, math.ceil(p * len(lat)) - 1)]  # nearest-rank

        macs = self.qnet.spec.count_macs()
        energy_j = _energy_j_per_image(self.qnet.spec)
        fps = self._n_ok / self._wall_s if self._wall_s > 0 else 0.0
        # FPS/W == (img/s)/(J/s) == images per joule: under an energy-only
        # proxy it is 1/J-per-image by construction, independent of the
        # achieved rate (real silicon adds a static-power term that would
        # make it rate-dependent).
        return EngineStats(
            n_ok=self._n_ok,
            n_expired=self._n_expired,
            wall_s=self._wall_s,
            fps=fps,
            latency_p50_s=pct(0.50),
            latency_p95_s=pct(0.95),
            micro_batches=self._micro_batches,
            pad_fraction=(self._pad_rows / self._rows) if self._rows else 0.0,
            stage_invocations={
                s.spec.cu: s.invocations for s in self.stages},
            harvest_wait_s=self.pipe.harvest_wait_s,
            macs_per_image=macs,
            energy_j_per_image_proxy=energy_j,
            fps_per_watt_proxy=(1.0 / energy_j) if energy_j > 0 else 0.0,
        )


__all__ = [
    "AdmissionError",
    "VisionRequest",
    "RequestResult",
    "EngineStats",
    "VisionEngine",
]
