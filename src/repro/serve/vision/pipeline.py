"""Software-pipelined scheduler over the CU stage executors.

The paper's host double-buffers CU invocations: while the Body CU crunches
micro-batch k, the Head CU already streams micro-batch k+1 out of DDR. On
XLA the same overlap falls out of asynchronous dispatch — every stage call
returns a future-backed Array immediately — provided the driver *keeps
multiple micro-batches in flight* instead of blocking batch-by-batch.

`PipelinedExecutor.stream` does exactly that: one scheduler tick advances
every occupied pipeline slot by one stage (walking stages back-to-front so
a micro-batch moves exactly one stage per tick) and then injects the next
micro-batch into the Head slot. All dispatches inside a tick are enqueued
without synchronisation; the only blocking point is harvesting a finished
Classifier output, by which time the ticks have already queued Head/Body
work for the following micro-batches.

Observability (`tracer=` / `metrics=`, see `repro.obs`): each stage
dispatch becomes a span on that CU's trace track (dispatch/enqueue time —
XLA dispatch is asynchronous, so stage *compute* shows up as harvest wait
at the sync point, which is also traced), plus per-stage dispatch-seconds
and bytes-moved instruments and a harvest-wait histogram. All extra clock
reads are guarded by `if tracer` / registered-instrument no-ops: with
observability off the executor performs exactly the clock reads it always
did (fake-clock tests stay bitwise).
"""
from __future__ import annotations

import time
from typing import Any, Iterable, Iterator, List, Optional, Tuple

import jax

from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.serve.vision.stages import CompiledStage


def _stage_bytes_per_row(stage: CompiledStage) -> int:
    """Analytic uint8 activation traffic of one batch row through a stage:
    input read + output write at the stage boundary (the DDR view of the
    paper's CU invocation; intra-stage intermediates stay 'on-chip')."""
    sig = stage.spec.signature
    n_in = (sig.in_hw or 1) * (sig.in_hw or 1) * sig.in_ch
    n_out = (sig.out_hw or 1) * (sig.out_hw or 1) * sig.out_ch
    return n_in + n_out


class PipelinedExecutor:
    def __init__(self, stages: List[CompiledStage], clock=None,
                 tracer: Optional[OT.Tracer] = None, metrics=None):
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = stages
        self._slots: List[Optional[Tuple[Any, jax.Array]]] = \
            [None] * len(stages)
        # harvest_wait_s reads the same injectable clock as the engine —
        # one time source for every stat (see VisionEngine's clock)
        self._clock = time.perf_counter if clock is None else clock
        self._streaming = False
        # wall time spent blocked on finished outputs (pipeline stall proxy)
        self.harvest_wait_s = 0.0
        self.tracer = tracer if tracer is not None else OT.NULL
        # optional tag -> trace-args hook: the engine installs one mapping
        # its (reqs, x) batch tags to request ids, tying every stage
        # dispatch span back to the requests riding the micro-batch
        self.tag_info = None
        reg = metrics if metrics is not None else OM.NULL_REGISTRY
        self._m_harvest = reg.histogram(
            "serve_harvest_wait_seconds",
            "wall time blocked on a finished stage output (the pipeline's "
            "only sync point)")
        self._m_ticks = reg.counter(
            "serve_pipeline_ticks_total", "scheduler ticks advanced")
        self._stage_row_bytes = [_stage_bytes_per_row(s) for s in stages]
        self._m_stage_dispatch = []
        self._m_stage_bytes = []
        for i, stage in enumerate(stages):
            cu = stage.spec.cu
            lbl = {"cu": cu}
            self._m_stage_dispatch.append(reg.histogram(
                "serve_stage_dispatch_seconds",
                "per-stage dispatch (enqueue) wall time", labels=lbl))
            self._m_stage_bytes.append(reg.counter(
                "serve_stage_bytes_moved_total",
                "analytic uint8 activation bytes in+out of the stage",
                labels=lbl))
            if self.tracer:
                self.tracer.name_track(OT.TID_STAGE0 + i, f"stage:{cu}")

    @property
    def depth(self) -> int:
        return len(self.stages)

    @property
    def busy(self) -> bool:
        """True while any micro-batch is still in flight."""
        return any(s is not None for s in self._slots)

    # -- tick-level API (used directly by the multi-model router) ----------

    def advance(self) -> Optional[Tuple[Any, jax.Array]]:
        """One scheduler tick: every occupied slot advances exactly one
        stage (back-to-front, all dispatches async). Frees the Head slot.
        Returns the (tag, y) that left the last stage this tick, if any —
        NOT yet blocked on; callers harvest via `harvest`."""
        finished = None
        self._m_ticks.inc()
        for i in reversed(range(self.depth)):
            if self._slots[i] is None:
                continue
            tag, x = self._slots[i]
            self._slots[i] = None
            rows = int(x.shape[0])
            if self.tracer:
                t0 = self._clock()
                y = self.stages[i](x)  # async dispatch — returns immediately
                t1 = self._clock()
                args = {"rows": rows}
                if self.tag_info is not None:
                    args.update(self.tag_info(tag))
                self.tracer.complete(
                    f"dispatch:{self.stages[i].spec.cu}", t0, t1,
                    cat="stage", tid=OT.TID_STAGE0 + i, args=args)
                self._m_stage_dispatch[i].observe(t1 - t0)
            else:
                y = self.stages[i](x)  # async dispatch — returns immediately
            self._m_stage_bytes[i].inc(rows * self._stage_row_bytes[i])
            if i + 1 < self.depth:
                self._slots[i + 1] = (tag, y)
            else:
                finished = (tag, y)
        return finished

    def inject(self, batch: Tuple[Any, jax.Array]) -> None:
        """Occupy the Head slot with the next micro-batch."""
        if self._slots[0] is not None:
            raise RuntimeError("Head slot occupied — advance() first")
        self._slots[0] = batch

    def reset(self) -> None:
        """Drop every in-flight micro-batch (abandoned drain): a later
        stream()/run() must never replay stale tags into its results."""
        self._slots = [None] * self.depth

    def harvest(self, finished: Tuple[Any, jax.Array]) -> Tuple[Any, jax.Array]:
        """Block until a finished output is ready (the only sync point)."""
        t0 = self._clock()
        jax.block_until_ready(finished[1])
        t1 = self._clock()
        self.harvest_wait_s += t1 - t0
        self._m_harvest.observe(t1 - t0)
        if self.tracer:
            self.tracer.complete("harvest", t0, t1, cat="pipeline",
                                 tid=OT.TID_SCHED)
        return finished

    # -- streaming driver ---------------------------------------------------

    def stream(
        self, batches: Iterable[Tuple[Any, jax.Array]],
    ) -> Iterator[Tuple[Any, jax.Array]]:
        """Stream (tag, x) micro-batches through the stages; yield
        (tag, y) in completion order (== submission order: the pipeline
        is in-order). Outputs are harvested ready — iterating does not
        add synchronisation beyond the final stage itself."""
        if self._streaming or self.busy:
            raise RuntimeError(
                "PipelinedExecutor is already draining — one stream() (or "
                "tick-level drive) at a time")
        self._streaming = True
        it = iter(batches)
        exhausted = False
        try:
            while True:
                finished = self.advance()
                if not exhausted:
                    try:
                        self.inject(next(it))
                    except StopIteration:
                        exhausted = True
                if finished is not None:
                    yield self.harvest(finished)
                if exhausted and not self.busy:
                    return
        finally:
            # abandoned mid-drain (caller broke out / exception): slots
            # used to be local per call; instance slots must be cleared to
            # keep that contract
            self._streaming = False
            self.reset()

    def run(self, batches: Iterable[jax.Array]) -> List[jax.Array]:
        """Convenience: pipeline a list of micro-batches, return outputs."""
        tagged = ((i, x) for i, x in enumerate(batches))
        return [y for _, y in self.stream(tagged)]

    def warmup(self, example: jax.Array) -> None:
        """Trace every stage at `example`'s batch size (one bucket).

        Bypasses `__call__` so warmup traces don't count as CU
        invocations in the serving stats. With tracing on, each stage is
        blocked on before the next — the one place per-stage *compute*
        wall time is observable without breaking pipelining, so the spans
        land on the stage tracks as `warmup:{cu}`."""
        x = example
        for i, stage in enumerate(self.stages):
            if self.tracer:
                t0 = self._clock()
                x = jax.block_until_ready(stage._fn(x))
                self.tracer.complete(
                    f"warmup:{stage.spec.cu}", t0, self._clock(),
                    cat="stage", tid=OT.TID_STAGE0 + i,
                    args={"rows": int(example.shape[0])})
            else:
                x = stage._fn(x)
        jax.block_until_ready(x)


__all__ = ["PipelinedExecutor"]
