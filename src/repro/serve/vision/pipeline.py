"""Software-pipelined scheduler over the CU stage executors.

The paper's host double-buffers CU invocations: while the Body CU crunches
micro-batch k, the Head CU already streams micro-batch k+1 out of DDR. On
XLA the same overlap falls out of asynchronous dispatch — every stage call
returns a future-backed Array immediately — provided the driver *keeps
multiple micro-batches in flight* instead of blocking batch-by-batch.

`PipelinedExecutor.stream` does exactly that: one scheduler tick advances
every occupied pipeline slot by one stage (walking stages back-to-front so
a micro-batch moves exactly one stage per tick) and then injects the next
micro-batch into the Head slot. All dispatches inside a tick are enqueued
without synchronisation; the only blocking point is harvesting a finished
Classifier output, by which time the ticks have already queued Head/Body
work for the following micro-batches.
"""
from __future__ import annotations

import time
from typing import Any, Iterable, Iterator, List, Optional, Tuple

import jax

from repro.serve.vision.stages import CompiledStage


class PipelinedExecutor:
    def __init__(self, stages: List[CompiledStage], clock=None):
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = stages
        self._slots: List[Optional[Tuple[Any, jax.Array]]] = \
            [None] * len(stages)
        # harvest_wait_s reads the same injectable clock as the engine —
        # one time source for every stat (see VisionEngine's clock)
        self._clock = time.perf_counter if clock is None else clock
        self._streaming = False
        # wall time spent blocked on finished outputs (pipeline stall proxy)
        self.harvest_wait_s = 0.0

    @property
    def depth(self) -> int:
        return len(self.stages)

    @property
    def busy(self) -> bool:
        """True while any micro-batch is still in flight."""
        return any(s is not None for s in self._slots)

    # -- tick-level API (used directly by the multi-model router) ----------

    def advance(self) -> Optional[Tuple[Any, jax.Array]]:
        """One scheduler tick: every occupied slot advances exactly one
        stage (back-to-front, all dispatches async). Frees the Head slot.
        Returns the (tag, y) that left the last stage this tick, if any —
        NOT yet blocked on; callers harvest via `harvest`."""
        finished = None
        for i in reversed(range(self.depth)):
            if self._slots[i] is None:
                continue
            tag, x = self._slots[i]
            self._slots[i] = None
            y = self.stages[i](x)  # async dispatch — returns immediately
            if i + 1 < self.depth:
                self._slots[i + 1] = (tag, y)
            else:
                finished = (tag, y)
        return finished

    def inject(self, batch: Tuple[Any, jax.Array]) -> None:
        """Occupy the Head slot with the next micro-batch."""
        if self._slots[0] is not None:
            raise RuntimeError("Head slot occupied — advance() first")
        self._slots[0] = batch

    def reset(self) -> None:
        """Drop every in-flight micro-batch (abandoned drain): a later
        stream()/run() must never replay stale tags into its results."""
        self._slots = [None] * self.depth

    def harvest(self, finished: Tuple[Any, jax.Array]) -> Tuple[Any, jax.Array]:
        """Block until a finished output is ready (the only sync point)."""
        t0 = self._clock()
        jax.block_until_ready(finished[1])
        self.harvest_wait_s += self._clock() - t0
        return finished

    # -- streaming driver ---------------------------------------------------

    def stream(
        self, batches: Iterable[Tuple[Any, jax.Array]],
    ) -> Iterator[Tuple[Any, jax.Array]]:
        """Stream (tag, x) micro-batches through the stages; yield
        (tag, y) in completion order (== submission order: the pipeline
        is in-order). Outputs are harvested ready — iterating does not
        add synchronisation beyond the final stage itself."""
        if self._streaming or self.busy:
            raise RuntimeError(
                "PipelinedExecutor is already draining — one stream() (or "
                "tick-level drive) at a time")
        self._streaming = True
        it = iter(batches)
        exhausted = False
        try:
            while True:
                finished = self.advance()
                if not exhausted:
                    try:
                        self.inject(next(it))
                    except StopIteration:
                        exhausted = True
                if finished is not None:
                    yield self.harvest(finished)
                if exhausted and not self.busy:
                    return
        finally:
            # abandoned mid-drain (caller broke out / exception): slots
            # used to be local per call; instance slots must be cleared to
            # keep that contract
            self._streaming = False
            self.reset()

    def run(self, batches: Iterable[jax.Array]) -> List[jax.Array]:
        """Convenience: pipeline a list of micro-batches, return outputs."""
        tagged = ((i, x) for i, x in enumerate(batches))
        return [y for _, y in self.stream(tagged)]

    def warmup(self, example: jax.Array) -> None:
        """Trace every stage at `example`'s batch size (one bucket).

        Bypasses `__call__` so warmup traces don't count as CU
        invocations in the serving stats."""
        x = example
        for stage in self.stages:
            x = stage._fn(x)
        jax.block_until_ready(x)


__all__ = ["PipelinedExecutor"]
