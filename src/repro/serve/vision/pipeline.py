"""Software-pipelined scheduler over the CU stage executors.

The paper's host double-buffers CU invocations: while the Body CU crunches
micro-batch k, the Head CU already streams micro-batch k+1 out of DDR. On
XLA the same overlap falls out of asynchronous dispatch — every stage call
returns a future-backed Array immediately — provided the driver *keeps
multiple micro-batches in flight* instead of blocking batch-by-batch.

`PipelinedExecutor.stream` does exactly that: one scheduler tick advances
every occupied pipeline slot by one stage (walking stages back-to-front so
a micro-batch moves exactly one stage per tick) and then injects the next
micro-batch into the Head slot. All dispatches inside a tick are enqueued
without synchronisation; the only blocking point is harvesting a finished
Classifier output, by which time the ticks have already queued Head/Body
work for the following micro-batches.
"""
from __future__ import annotations

import time
from typing import Any, Iterable, Iterator, List, Optional, Tuple

import jax

from repro.serve.vision.stages import CompiledStage


class PipelinedExecutor:
    def __init__(self, stages: List[CompiledStage]):
        if not stages:
            raise ValueError("need at least one stage")
        self.stages = stages
        # wall time spent blocked on finished outputs (pipeline stall proxy)
        self.harvest_wait_s = 0.0

    @property
    def depth(self) -> int:
        return len(self.stages)

    def stream(
        self, batches: Iterable[Tuple[Any, jax.Array]],
    ) -> Iterator[Tuple[Any, jax.Array]]:
        """Stream (tag, x) micro-batches through the stages; yield
        (tag, y) in completion order (== submission order: the pipeline
        is in-order). Outputs are harvested ready — iterating does not
        add synchronisation beyond the final stage itself."""
        it = iter(batches)
        slots: List[Optional[Tuple[Any, jax.Array]]] = [None] * self.depth
        exhausted = False
        while True:
            finished = None
            # back-to-front: each occupied slot advances exactly one stage
            for i in reversed(range(self.depth)):
                if slots[i] is None:
                    continue
                tag, x = slots[i]
                slots[i] = None
                y = self.stages[i](x)  # async dispatch — returns immediately
                if i + 1 < self.depth:
                    slots[i + 1] = (tag, y)
                else:
                    finished = (tag, y)
            if not exhausted:
                try:
                    slots[0] = next(it)
                except StopIteration:
                    exhausted = True
            if finished is not None:
                t0 = time.perf_counter()
                jax.block_until_ready(finished[1])
                self.harvest_wait_s += time.perf_counter() - t0
                yield finished
            if exhausted and all(s is None for s in slots):
                return

    def run(self, batches: Iterable[jax.Array]) -> List[jax.Array]:
        """Convenience: pipeline a list of micro-batches, return outputs."""
        tagged = ((i, x) for i, x in enumerate(batches))
        return [y for _, y in self.stream(tagged)]

    def warmup(self, example: jax.Array) -> None:
        """Trace every stage at `example`'s batch size (one bucket).

        Bypasses `__call__` so warmup traces don't count as CU
        invocations in the serving stats."""
        x = example
        for stage in self.stages:
            x = stage._fn(x)
        jax.block_until_ready(x)


__all__ = ["PipelinedExecutor"]
