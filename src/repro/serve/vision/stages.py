"""Stage compiler: `CUPlan` schedule -> one jitted executor per CU role.

The FPGA runs each CU as fixed silicon reconfigured per invocation over
AXI-Lite; the XLA analogue is one jitted function per CU *stage* (the
contiguous run of same-role invocations in the schedule), traced once per
batch bucket. All intra-stage intermediates stay on-chip, exactly like the
FPGA's FIFO-streamed operator pipeline.

The integer datapath runs on one of three op implementations per stage:

  * prepared XLA fast path (default) — `cu.prepare_qnet` lowers the QNet to
    device-resident constants once at plan-build time, and the CU runners
    switch to the compiled integer formulations (shifted-slice depthwise,
    exactness-gated f32 matmul/conv). Bit-exact with the reference; this is
    what makes the hot loop fast off-TPU.
  * per-op Pallas kernels (`op_kernels`) — DW through the row-tiled
    depthwise kernel, PW/DENSE (Head/Body/Tail/Classifier) through the
    pointwise-CU kernel. "auto" enables them on a real TPU.
  * fused-IRB Pallas kernel (`body_fast_path`) — canonical Body blocks as
    one kernel that pins the t*C-expanded intermediate into VMEM.

Quantizer handoff between stages is static: `cu.propagate_qparams` derives
each stage's (scale, zp) contract from QNet metadata alone, so a stage
function is a pure array -> array map and the executor chain is bit-exact
with the monolithic `cu.run_qnet` reference. On accelerators, stage inputs
are donated at the stage boundary (`donate="auto"`): an intermediate
activation buffer is dead the moment the next stage consumes it, so XLA can
reuse it for the stage's own output instead of allocating fresh HBM.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import compiler as CC
from repro.dist.sharding import batch_sharding
from repro.core import cu
from repro.core import graph as G
from repro.core.qnet import QNet
from repro.kernels import ops as K


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """Everything needed to (re)trace one CU stage executor."""

    cu: str
    blocks: Tuple[G.BlockSpec, ...]
    in_scale: float
    in_zp: float
    out_scale: float
    out_zp: float
    quantizes_input: bool  # Head: float image -> int activations
    dequantizes_output: bool  # Classifier: int logits -> float logits
    signature: CC.StageSignature


class CompiledStage:
    """One CU stage as a jitted callable.

    Batch-polymorphic by bucketing: jax retraces per input shape, and the
    engine only ever presents bucket-padded batches, so the trace cache
    stays one entry per (stage, bucket)."""

    def __init__(self, spec: StageSpec, qnet: Union[QNet, cu.PreparedQNet],
                 *, fixed_point: bool, input_bits: int, fast_path: bool,
                 op_kernels: bool, interpret: Optional[bool],
                 donate: bool = False, mesh=None, tuned: bool = False,
                 fused_blocks: frozenset = frozenset()):
        self.spec = spec
        self._qnet = qnet
        self._fixed_point = fixed_point
        self._input_bits = input_bits
        self._fast_path = fast_path and spec.cu == CC.BODY
        self._op_kernels = op_kernels
        self._interpret = interpret
        self._tuned = tuned
        self._fused_blocks = fused_blocks
        self.mesh = mesh
        self.invocations = 0  # CU invocations dispatched (micro-batches)
        self.traces = 0  # jit cache misses (should stay == #buckets)
        # retrace-leak detection: the engine pins the batch sizes it may
        # legally present (its buckets); a trace at any other leading dim
        # is a leak — some caller slipped a non-bucketed shape through and
        # is silently paying an XLA retrace per novel shape.
        self.allowed_batches: Optional[frozenset] = None
        self.retraces = 0  # traces outside `allowed_batches`
        self.on_retrace: Optional[Callable[["CompiledStage", Tuple[int, ...]],
                                           None]] = None
        jit_kwargs = dict(donate_argnums=(0,) if donate else ())
        if mesh is not None:
            # data-parallel replication: micro-batch rows split along the
            # mesh 'data' axis in AND out, so an N-replica mesh runs N
            # shards of every CU invocation concurrently. Constants are
            # replicated (prepare_qnet(mesh=...)), activations stay sharded
            # across the whole executor chain — no resharding between CUs.
            ns = batch_sharding(mesh)
            jit_kwargs.update(in_shardings=ns, out_shardings=ns)
        self._fn = jax.jit(self._trace, **jit_kwargs)

    def _trace(self, x: jax.Array) -> jax.Array:
        self.traces += 1
        if (self.allowed_batches is not None
                and x.shape[0] not in self.allowed_batches):
            # a retrace leak, not an error: serving stays correct (jax just
            # traces again), but every novel shape pays a fresh compile on
            # the hot path — surface it loudly instead of hiding the stall
            self.retraces += 1
            warnings.warn(
                f"stage {self.spec.cu}: retrace at non-bucketed batch "
                f"shape {tuple(x.shape)} (buckets "
                f"{sorted(self.allowed_batches)}) — a caller bypassed the "
                f"batch former; every novel shape recompiles this stage",
                RuntimeWarning, stacklevel=2)
            if self.on_retrace is not None:
                self.on_retrace(self, tuple(x.shape))
        spec = self.spec
        y = x
        if spec.quantizes_input:
            y = cu.quantize_input(
                y, spec.in_scale, spec.in_zp, self._input_bits)
        s, z = spec.in_scale, spec.in_zp
        for block in spec.blocks:
            if self._tuned:
                # measured route selection: the TunedPlan's per-op routes
                # ride on the PreparedQNet (cu.run_block dispatches them);
                # fused-IRB block choices are honored here. Ops/blocks
                # without a cache entry fall back to the default route.
                if block.name in self._fused_blocks and K.fusable_irb(block):
                    y, s, z = K.run_irb_block(
                        y, block, self._qnet, s, z,
                        interpret=self._interpret)
                else:
                    y, s, z = cu.run_block(
                        y, block, self._qnet, s, z, self._fixed_point,
                        interpret=self._interpret)
            elif self._fast_path and K.fusable_irb(block):
                y, s, z = K.run_irb_block(
                    y, block, self._qnet, s, z, interpret=self._interpret)
            elif self._op_kernels:
                y, s, z = K.run_block_kernels(
                    y, block, self._qnet, s, z, interpret=self._interpret)
            else:
                y, s, z = cu.run_block(
                    y, block, self._qnet, s, z, self._fixed_point)
        if spec.dequantizes_output:
            y = (y.astype(jnp.float32) + z) * s
        return y

    def __call__(self, x: jax.Array) -> jax.Array:
        self.invocations += 1
        return self._fn(x)


def _resolve(flag: str, name: str) -> bool:
    if flag not in ("auto", "on", "off"):
        raise ValueError(f"{name}={flag!r}")
    return K.on_tpu() if flag == "auto" else flag == "on"


def compile_stages(
    qnet: Union[QNet, cu.PreparedQNet],
    plan: Optional[CC.CUPlan] = None,
    *,
    fixed_point: bool = False,
    input_bits: int = 8,
    body_fast_path: str = "auto",  # "auto" | "on" | "off"
    op_kernels: str = "auto",  # "auto" | "on" | "off"
    prepare: bool = True,
    donate: str = "auto",  # "auto" | "on" | "off"
    interpret: Optional[bool] = None,
    mesh=None,
    tuned=None,
) -> List[CompiledStage]:
    """Lower a CUPlan into the ordered list of jitted stage executors.

    `body_fast_path`: route fusable Body blocks through the Pallas fused-IRB
    kernel. `op_kernels`: route DW/PW/DENSE ops through the per-op Pallas
    kernels in every stage. Both are "auto" == only on a real TPU (in
    interpret mode the kernels are emulated and slower than the compiled XLA
    path, though still bit-exact); "on"/"off" force either way.

    `tuned` (a `repro.tune.TunedPlan`, or carried on `plan.tuned`) REPLACES
    those hard-coded heuristics with measured cache lookup: each op runs the
    route the autotuner verified bit-exact and timed fastest for its
    (kind, shape, act_bits, backend) key; fusable Body blocks honor the
    block-level fused-IRB decision. Ops/blocks with no cache entry fall
    back to today's defaults, so a partial or foreign-backend cache is
    always safe. Tuned routes are float-requant formulations, so
    `fixed_point=True` is refused, and routes bind to prepared constants,
    so `prepare=False` is refused too.

    `prepare`: lower the QNet with `cu.prepare_qnet` first (device-resident
    constants + compiled integer formulations). Default on — this is the
    serving configuration; "off" reproduces the PR-1 reference stages.

    `donate`: donate each non-Head stage's input buffer to XLA ("auto" ==
    only on accelerator backends; the CPU runtime cannot reuse donations
    and would warn).

    `mesh`: a 1-D 'data' mesh (`dist.sharding.data_mesh`) replicates the
    whole executor chain: constants replicated on every device, micro-batch
    rows sharded along 'data' in and out of every stage. Batch sizes must
    divide by the replica count. `None` (default) is the single-device
    configuration, byte-identical to previous behavior.
    """
    if mesh is not None and "data" not in mesh.axis_names:
        raise ValueError(f"mesh needs a 'data' axis, got {mesh.axis_names}")
    if plan is None:
        plan = CC.compile_net(qnet.spec)
    if tuned is None:
        tuned = getattr(plan, "tuned", None)
    fused_blocks: frozenset = frozenset()
    fast = _resolve(body_fast_path, "body_fast_path")
    kerns = _resolve(op_kernels, "op_kernels")
    op_routes = None
    if tuned is not None:
        if fixed_point:
            raise ValueError(
                "tuned= carries float-requant routes only and cannot "
                "serve fixed_point=True")
        if not prepare:
            raise ValueError(
                "tuned= requires prepare=True (routes bind to PreparedQOp "
                "device constants)")
        # one resolve, with cache MISSES filled by the heuristic defaults
        # (on TPU an uncovered op keeps the default-tile Pallas route, an
        # uncovered fusable block keeps the fused kernel) — a partial or
        # foreign-backend cache can never silently degrade a route below
        # what the non-tuned heuristics would run
        op_routes, fused = tuned.resolve_with_defaults(
            qnet, plan, op_kernels=kerns, body_fast_path=fast)
        if not op_routes and not fused:
            tuned = op_routes = None  # nothing to route: pure heuristics
        fused_blocks = frozenset(fused or ())
    if fixed_point and (fast or kerns):
        # the Pallas kernels' requant epilogue is float-multiplier only; a
        # silent fallback would break bit-exactness with
        # run_qnet(fixed_point=True)
        if body_fast_path == "on" or op_kernels == "on":
            raise ValueError(
                "body_fast_path/op_kernels='on' is incompatible with "
                "fixed_point=True (the Pallas kernels have no fixed-point "
                "requant mode)")
        fast = kerns = False
    if donate not in ("auto", "on", "off"):
        raise ValueError(f"donate={donate!r}")
    donate_ok = (jax.default_backend() != "cpu") if donate == "auto" \
        else donate == "on"
    if prepare:
        qnet = cu.prepare_qnet(qnet, input_bits=input_bits, mesh=mesh,
                               routes=op_routes)
    elif mesh is not None and isinstance(qnet, cu.PreparedQNet):
        qnet = cu.replicate_prepared(qnet, mesh)

    sigs = plan.stage_signatures()
    stages: List[CompiledStage] = []
    s, z = cu.input_qparams(qnet)
    for i, sig in enumerate(sigs):
        out_s, out_z = cu.propagate_qparams(sig.blocks, qnet, s, z)
        spec = StageSpec(
            cu=sig.cu,
            blocks=sig.blocks,
            in_scale=s,
            in_zp=z,
            out_scale=out_s,
            out_zp=out_z,
            quantizes_input=(i == 0),
            dequantizes_output=(i == len(sigs) - 1),
            signature=sig,
        )
        stages.append(CompiledStage(
            spec, qnet, fixed_point=fixed_point, input_bits=input_bits,
            fast_path=fast, op_kernels=kerns, interpret=interpret,
            donate=donate_ok and i > 0, mesh=mesh,
            tuned=tuned is not None, fused_blocks=fused_blocks))
        s, z = out_s, out_z
    return stages


__all__ = ["StageSpec", "CompiledStage", "compile_stages"]
