"""Stage compiler: `CUPlan` schedule -> one jitted executor per CU role.

The FPGA runs each CU as fixed silicon reconfigured per invocation over
AXI-Lite; the XLA analogue is one jitted function per CU *stage* (the
contiguous run of same-role invocations in the schedule), traced once per
batch bucket. All intra-stage intermediates stay on-chip, exactly like the
FPGA's FIFO-streamed operator pipeline — the Body stage can additionally
route canonical expand->dw->project blocks through the `kernels/fused_irb`
Pallas kernel, which pins the t*C-expanded intermediate into VMEM.

Quantizer handoff between stages is static: `cu.propagate_qparams` derives
each stage's (scale, zp) contract from QNet metadata alone, so a stage
function is a pure array -> array map and the executor chain is bit-exact
with the monolithic `cu.run_qnet` reference.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compiler as CC
from repro.core import cu
from repro.core import graph as G
from repro.core.qnet import QNet
from repro.kernels import ops as K


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """Everything needed to (re)trace one CU stage executor."""

    cu: str
    blocks: Tuple[G.BlockSpec, ...]
    in_scale: float
    in_zp: float
    out_scale: float
    out_zp: float
    quantizes_input: bool  # Head: float image -> int activations
    dequantizes_output: bool  # Classifier: int logits -> float logits
    signature: CC.StageSignature


class CompiledStage:
    """One CU stage as a jitted callable.

    Batch-polymorphic by bucketing: jax retraces per input shape, and the
    engine only ever presents bucket-padded batches, so the trace cache
    stays one entry per (stage, bucket)."""

    def __init__(self, spec: StageSpec, qnet: QNet, *, fixed_point: bool,
                 input_bits: int, fast_path: bool,
                 interpret: Optional[bool]):
        self.spec = spec
        self._qnet = qnet
        self._fixed_point = fixed_point
        self._input_bits = input_bits
        self._fast_path = fast_path and spec.cu == CC.BODY
        self._interpret = interpret
        self.invocations = 0  # CU invocations dispatched (micro-batches)
        self._fn = jax.jit(self._trace)

    def _trace(self, x: jax.Array) -> jax.Array:
        spec = self.spec
        y = x
        if spec.quantizes_input:
            y = cu.quantize_input(
                y, spec.in_scale, spec.in_zp, self._input_bits)
        s, z = spec.in_scale, spec.in_zp
        for block in spec.blocks:
            if self._fast_path and K.fusable_irb(block):
                y, s, z = K.run_irb_block(
                    y, block, self._qnet, s, z, interpret=self._interpret)
            else:
                y, s, z = cu.run_block(
                    y, block, self._qnet, s, z, self._fixed_point)
        if spec.dequantizes_output:
            y = (y.astype(jnp.float32) + z) * s
        return y

    def __call__(self, x: jax.Array) -> jax.Array:
        self.invocations += 1
        return self._fn(x)


def compile_stages(
    qnet: QNet,
    plan: Optional[CC.CUPlan] = None,
    *,
    fixed_point: bool = False,
    input_bits: int = 8,
    body_fast_path: str = "auto",  # "auto" | "on" | "off"
    interpret: Optional[bool] = None,
) -> List[CompiledStage]:
    """Lower a CUPlan into the ordered list of jitted stage executors.

    `body_fast_path`: route fusable Body blocks through the Pallas fused-IRB
    kernel. "auto" enables it only on a real TPU (in interpret mode the
    kernel is emulated and slower than the plain XLA path, though still
    bit-exact); "on"/"off" force it either way.
    """
    if plan is None:
        plan = CC.compile_net(qnet.spec)
    if body_fast_path not in ("auto", "on", "off"):
        raise ValueError(f"body_fast_path={body_fast_path!r}")
    fast = K.on_tpu() if body_fast_path == "auto" else body_fast_path == "on"
    if fixed_point and fast:
        # the fused kernel's requant epilogue is float-multiplier only; a
        # silent fallback would break bit-exactness with
        # run_qnet(fixed_point=True)
        if body_fast_path == "on":
            raise ValueError(
                "body_fast_path='on' is incompatible with fixed_point=True "
                "(the fused IRB kernel has no fixed-point requant mode)")
        fast = False

    sigs = plan.stage_signatures()
    stages: List[CompiledStage] = []
    s, z = cu.input_qparams(qnet)
    for i, sig in enumerate(sigs):
        out_s, out_z = cu.propagate_qparams(sig.blocks, qnet, s, z)
        spec = StageSpec(
            cu=sig.cu,
            blocks=sig.blocks,
            in_scale=s,
            in_zp=z,
            out_scale=out_s,
            out_zp=out_z,
            quantizes_input=(i == 0),
            dequantizes_output=(i == len(sigs) - 1),
            signature=sig,
        )
        stages.append(CompiledStage(
            spec, qnet, fixed_point=fixed_point, input_bits=input_bits,
            fast_path=fast, interpret=interpret))
        s, z = out_s, out_z
    return stages


__all__ = ["StageSpec", "CompiledStage", "compile_stages"]
