"""Sharding-aware checkpoint / restore with async write + rotation.

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json       tree structure, shapes, dtypes, step, metadata
        arrays.npz          all leaves (host-gathered)
    <dir>/LATEST            text file with the newest complete step dir

Fault-tolerance contract (see tests/test_checkpoint.py):
  * writes are atomic: tmp dir + rename, LATEST updated last — a preempted
    writer never corrupts the restore path;
  * `restore` device_puts each leaf with the caller's NamedShardings, so a
    restart on a different mesh (elastic resize) re-shards transparently;
  * `keep` rotation bounds disk; an async thread overlaps write with step
    compute (the compute/IO overlap trick).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't round-trip ML dtypes through savez (they pickle to void);
# store them bit-cast to a same-width integer + a dtype tag in the manifest
_BITCAST = {
    np.dtype(ml_dtypes.bfloat16): ("bfloat16", np.uint16),
    np.dtype(ml_dtypes.float8_e4m3fn): ("float8_e4m3fn", np.uint8),
    np.dtype(ml_dtypes.float8_e5m2): ("float8_e5m2", np.uint8),
}
_BITCAST_BACK = {tag: (dt, np.dtype(src)) for src, (tag, dt) in
                 [(k, v) for k, v in _BITCAST.items()]}


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _encode(arr: np.ndarray):
    if arr.dtype in _BITCAST:
        tag, view = _BITCAST[arr.dtype]
        return arr.view(view), tag
    return arr, str(arr.dtype)


def _decode(arr: np.ndarray, tag: str):
    if tag in _BITCAST_BACK:
        _, orig = _BITCAST_BACK[tag]
        return arr.view(orig)
    return arr


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         async_: bool = False, extra: Optional[dict] = None):
    """Host-gather and write a checkpoint. Returns the thread when async."""
    leaves, treedef = _flatten(tree)
    encoded = [_encode(np.asarray(jax.device_get(x))) for x in leaves]
    np_leaves = [e[0] for e in encoded]
    dtype_tags = [e[1] for e in encoded]

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, a in enumerate(np_leaves)})
        manifest = {
            "step": step,
            "n_leaves": len(np_leaves),
            "treedef": str(treedef),
            "dtypes": dtype_tags,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
                   os.path.join(ckpt_dir, "LATEST"))
        _rotate(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _rotate(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    marker = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, target_tree: Any, *, step: Optional[int] = None,
            shardings: Any = None):
    """Load into the structure of target_tree; device_put with shardings."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = [z[f"a{i}"] for i in range(len(z.files))]
    tags = manifest.get("dtypes") or [str(a.dtype) for a in arrays]
    arrays = [_decode(a, t) for a, t in zip(arrays, tags)]
    leaves, treedef = _flatten(target_tree)
    if len(arrays) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, target expects {len(leaves)}")
    if shardings is not None:
        shard_leaves = treedef.flatten_up_to(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        arrays = [jax.device_put(a) for a in arrays]
    return treedef.unflatten(arrays), step


__all__ = ["save", "restore", "latest_step"]
