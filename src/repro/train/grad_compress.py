"""Gradient compression with error feedback (distributed-optimization trick).

Int8 symmetric per-tensor quantization of gradients before the data-parallel
reduction, with an error-feedback residual so the compression bias does not
accumulate (1-bit-Adam / EF-SGD style):

    c_t   = Q(g_t + e_{t-1})          (int8 + f32 scale -> 4x fewer bytes
                                       on the all-reduce wire)
    e_t   = (g_t + e_{t-1}) - deQ(c_t)
    step uses deQ(c_t)

Under pjit the DP reduction is implicit in the backward pass, so the wire
saving is realized when paired with the shard_map reduction in
`compressed_psum` (used by launch/train.py when --grad-compress is set);
`compress_tree` alone models the numerics and is what the convergence tests
exercise.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
QMAX = 127.0


def _q(x):
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / QMAX, 1.0)
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def _dq(q, scale):
    return q.astype(F32) * scale


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=F32), params)


def compress_tree(grads, error) -> Tuple[Any, Any]:
    """Returns (dequantized compressed grads, new error residuals)."""

    def one(g, e):
        corrected = g.astype(F32) + e
        q, s = _q(corrected)
        deq = _dq(q, s)
        return deq, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compressed_psum(grads, error, axis_name: str):
    """shard_map-side compressed all-reduce: quantize locally, psum the int8
    payload (4x wire bytes saved vs f32), dequantize, keep residual."""

    def one(g, e):
        corrected = g.astype(F32) + e
        q, s = _q(corrected)
        # sum of per-shard dequantized payloads == dequantize(sum) with
        # per-shard scales carried alongside (scale vector is tiny)
        summed = jax.lax.psum(_dq(q, s), axis_name)
        return summed, corrected - _dq(q, s)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


__all__ = ["init_error", "compress_tree", "compressed_psum"]
