"""Optimizers + LR schedules (own implementation — no external deps).

AdamW with decoupled weight decay, global-norm clipping, and ZeRO-1-friendly
state layout (m/v mirror the param tree, so `dist.sharding.tree_shardings`
can shard them over the data axis independently of the param sharding).
Integer/quantized leaves (w_q int8 etc.) are held frozen — the paper's QNet
weights are deployment artifacts, not trained in the float domain.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant
    # 8-bit optimizer states (the paper's range-based quantization applied to
    # AdamW m/v, 8-bit-Adam style): m stored int8 symmetric per row, v stored
    # uint8 asymmetric per row (v >= 0). Cuts optimizer HBM from 8 to 2
    # bytes/param — what lets arctic-480b training fit the mesh (§Perf).
    state_bits: Optional[int] = None


def _trainable(leaf) -> bool:
    return jnp.issubdtype(leaf.dtype, jnp.floating)


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def _red_axes(x):
    return tuple(range(1, x.ndim)) if x.ndim > 1 else (0,)


def _quantize_state_leaf(x):
    """First moment m: linear symmetric int8 with per-row scale."""
    red = _red_axes(x)
    amax = jnp.max(jnp.abs(x), axis=red, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(F32)}


def _dq8(leaf):
    return leaf["q"].astype(F32) * leaf["scale"]


_VLOG_FLOOR = 1e-24


def _quantize_v_leaf(v):
    """Second moment v >= 0: uint8 in LOG space (per-row asymmetric).

    Linear int8 on v fails catastrophically: entries below the row's
    quantization floor dequantize to 0, so the Adam denominator collapses to
    eps and those parameters blow up. Log-domain quantization keeps ~±1%
    relative error across v's many decades of dynamic range."""
    red = _red_axes(v)
    lv = jnp.log(v + _VLOG_FLOOR)
    lo = jnp.min(lv, axis=red, keepdims=True)
    hi = jnp.max(lv, axis=red, keepdims=True)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-8)
    q = jnp.clip(jnp.round((lv - lo) / scale), 0, 255).astype(jnp.uint8)
    return {"q": q, "scale": scale.astype(F32), "zero": lo.astype(F32)}


def _dq8_v(leaf):
    return jnp.exp(leaf["q"].astype(F32) * leaf["scale"] + leaf["zero"]) - _VLOG_FLOOR


def _is_qleaf(x):
    return isinstance(x, dict) and set(x) in ({"q", "scale"}, {"q", "scale", "zero"})


def init_state(params, state_bits: Optional[int] = None) -> AdamWState:
    def zero(p, quantizer):
        if not _trainable(p):
            return jnp.zeros((), F32)
        if state_bits == 8:
            return quantizer(jnp.zeros(p.shape, F32))
        return jnp.zeros_like(p, dtype=F32)

    zeros = jax.tree.map(lambda p: zero(p, _quantize_state_leaf), params)
    z2 = jax.tree.map(lambda p: zero(p, _quantize_v_leaf), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=z2)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1**step.astype(F32)
    b2c = 1 - cfg.b2**step.astype(F32)

    def upd(p, g, m, v):
        if not _trainable(p):
            return p, m, v
        quant = _is_qleaf(m)
        if quant:
            m = _dq8(m)
            v = _dq8_v(v)
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        new_p = (p.astype(F32) - lr * delta).astype(p.dtype)
        if quant:
            return new_p, _quantize_state_leaf(m), _quantize_v_leaf(v)
        return new_p, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics


__all__ = ["AdamWConfig", "AdamWState", "init_state", "apply_updates",
           "lr_at", "global_norm"]
