"""Straggler detection + mitigation policy (fault-tolerance substrate).

SPMD training is synchronous: one slow chip stalls every step. The two
production mitigations this framework implements:

  1. detect — `StepWatchdog` tracks an EMA of step wall-times and flags
     steps beyond `threshold` x EMA (transient stragglers: network blips,
     preemption warnings, thermal throttling);
  2. act — persistent stragglers trigger the checkpoint-evict-resume path:
     the launcher checkpoints (async, already hot), the scheduler drops or
     replaces the slow host, and training resumes with the SAME data stream
     (deterministic skip) on the resized data-parallel mesh (elastic
     re-shard on restore, tests/test_fault_tolerance.py).

The watchdog is runtime-cheap (host-side timing only) and drives the
`on_straggler` callback — launch/train.py wires it to checkpoint-now.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StepWatchdog:
    threshold: float = 2.0  # flag steps slower than threshold x EMA
    ema_beta: float = 0.9
    patience: int = 3  # consecutive flags => persistent straggler
    warmup: int = 5  # steps before flagging starts
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    _ema: Optional[float] = None
    _steps: int = 0
    _consecutive: int = 0
    _t0: Optional[float] = None
    flagged: List[int] = dataclasses.field(default_factory=list)

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Record one step; returns True if a persistent straggler fired."""
        dt = time.perf_counter() - self._t0
        return self.observe(dt)

    def observe(self, dt: float) -> bool:
        self._steps += 1
        if self._ema is None:
            self._ema = dt
            return False
        slow = (self._steps > self.warmup
                and dt > self.threshold * self._ema)
        if slow:
            self.flagged.append(self._steps)
            self._consecutive += 1
        else:
            self._consecutive = 0
            # only fold healthy steps into the EMA, so a straggly stretch
            # cannot normalize itself away
            self._ema = self.ema_beta * self._ema + (1 - self.ema_beta) * dt
        if self._consecutive >= self.patience:
            if self.on_straggler is not None:
                self.on_straggler(self._steps, dt, self._ema)
            self._consecutive = 0
            return True
        return False

    @property
    def ema(self) -> Optional[float]:
        return self._ema


__all__ = ["StepWatchdog"]
