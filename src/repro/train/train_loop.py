"""Train-step builder: microbatched grads + AdamW + (optional) compression.

`make_train_step(cfg, opt_cfg, grad_accum)` returns a pure function

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

with gradient accumulation over `grad_accum` microbatches via `lax.scan`
(bounds activation memory for the 480B-class configs), donate-friendly
signature, and deterministic semantics suitable for checkpoint/restart
bitwise-continuation tests.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import model as M
from repro.models.lm.config import LMConfig
from repro.train import optimizer as O
from repro.train import grad_compress as GC

F32 = jnp.float32


def _split_microbatches(batch, n: int):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} % grad_accum {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(
    cfg: Optional[LMConfig],
    opt_cfg: O.AdamWConfig,
    grad_accum: int = 1,
    loss_fn: Optional[Callable] = None,
    compress: bool = False,
    accum_dtype=F32,
    has_aux: bool = False,
):
    """`cfg=None` is allowed when `loss_fn` is given — the vision QAT
    pipeline reuses this exact accumulation/update path with its own loss.
    `has_aux` declares a `loss_fn -> (loss, aux)` signature; the aux tree is
    microbatch-averaged and returned in metrics['aux'] (the BN running-stat
    moments ride here)."""
    if loss_fn is None:
        if cfg is None:
            raise ValueError("need an LMConfig or an explicit loss_fn")
        loss_fn = lambda p, b: M.loss_fn(p, cfg, b)  # noqa: E731
    unroll = getattr(cfg, "scan_unroll", 1) if cfg is not None else 1

    def value_grad(params, mb):
        out, grads = jax.value_and_grad(loss_fn, has_aux=has_aux)(params, mb)
        loss, aux = out if has_aux else (out, None)
        return loss, aux, grads

    def grads_of(params, batch):
        if grad_accum == 1:
            return value_grad(params, batch)

        micro = _split_microbatches(batch, grad_accum)

        def body(carry, mb):
            acc, loss_acc, aux_acc = carry
            loss, aux, grads = value_grad(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(accum_dtype), acc, grads)
            if has_aux:
                aux_acc = jax.tree.map(lambda a, x: a + x, aux_acc, aux)
            return (acc, loss_acc + loss, aux_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        aux0 = None
        if has_aux:
            # abstract shape probe: the aux tree structure comes from the
            # loss itself; eval_shape never executes the forward/backward
            aux_shape = jax.eval_shape(
                lambda p, b: value_grad(p, b)[1], params,
                jax.tree.map(lambda m: m[0], micro))
            aux0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                aux_shape)
        (gacc, loss_sum, aux_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), F32), aux0), micro, unroll=unroll)
        inv = 1.0 / grad_accum
        aux_mean = (jax.tree.map(lambda a: a * inv, aux_sum)
                    if has_aux else None)
        return loss_sum * inv, aux_mean, jax.tree.map(lambda g: g * inv, gacc)

    def train_step(params, opt_state, batch, err_state=None):
        loss, aux, grads = grads_of(params, batch)
        if compress:
            grads, err_state = GC.compress_tree(grads, err_state)
        params, opt_state, metrics = O.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss)
        if has_aux:
            metrics["aux"] = aux
        if compress:
            return params, opt_state, err_state, metrics
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: LMConfig, loss_fn: Optional[Callable] = None):
    loss_fn = loss_fn or (lambda p, b: M.loss_fn(p, cfg, b))

    def eval_step(params, batch):
        return loss_fn(params, batch)

    return eval_step


__all__ = ["make_train_step", "make_eval_step"]
