"""Train-step builder: microbatched grads + AdamW + (optional) compression.

`make_train_step(cfg, opt_cfg, grad_accum)` returns a pure function

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

with gradient accumulation over `grad_accum` microbatches via `lax.scan`
(bounds activation memory for the 480B-class configs), donate-friendly
signature, and deterministic semantics suitable for checkpoint/restart
bitwise-continuation tests.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import model as M
from repro.models.lm.config import LMConfig
from repro.train import optimizer as O
from repro.train import grad_compress as GC

F32 = jnp.float32


def _split_microbatches(batch, n: int):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} % grad_accum {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(
    cfg: LMConfig,
    opt_cfg: O.AdamWConfig,
    grad_accum: int = 1,
    loss_fn: Optional[Callable] = None,
    compress: bool = False,
    accum_dtype=F32,
):
    loss_fn = loss_fn or (lambda p, b: M.loss_fn(p, cfg, b))

    def grads_of(params, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads

        micro = _split_microbatches(batch, grad_accum)

        def body(carry, mb):
            acc, loss_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(accum_dtype), acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (gacc, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), F32)), micro, unroll=cfg.scan_unroll)
        inv = 1.0 / grad_accum
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gacc)

    def train_step(params, opt_state, batch, err_state=None):
        loss, grads = grads_of(params, batch)
        if compress:
            grads, err_state = GC.compress_tree(grads, err_state)
        params, opt_state, metrics = O.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss)
        if compress:
            return params, opt_state, err_state, metrics
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: LMConfig, loss_fn: Optional[Callable] = None):
    loss_fn = loss_fn or (lambda p, b: M.loss_fn(p, cfg, b))

    def eval_step(params, batch):
        return loss_fn(params, batch)

    return eval_step


__all__ = ["make_train_step", "make_eval_step"]
