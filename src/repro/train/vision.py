"""FPGA-aware QAT vision training: train -> online-quantize -> export (Fig. 1).

The paper's front end, end to end, over the repo's existing pieces:

  1. **Float pre-training** with BatchNorm on batch statistics
     (`models/layers.forward(bn_stats=...)`), running stats maintained by
     the train step; microbatched grad accumulation and AdamW are the SAME
     `train/train_loop.make_train_step` + `train/optimizer` machinery the LM
     configs use.
  2. **BN fusion** at the float -> QAT boundary (`layers.fuse_bn_params`,
     Eqs. 4-6): QAT fake-quant sees the deployed weights.
  3. **QAT with online quantization**: fake-quantized forward at the target
     bit-widths, with an optional activation-bit anneal (8 -> 4, the
     paper's UInt4 recipe, via `graph.with_act_bits`); every
     `calibrate_every` steps the held-out calibration stream is driven
     through `core/calibrate.ActObserver` (EMA mode) and the ReLU6-fused
     qparams are re-derived — the per-epoch 'online quantization' loop.
     The observers are CHECKPOINTED TRAINING STATE: once every one has
     seen a round (`observers_ready`), their EMA-tracked ranges become the
     exported artifact's activation quantizers — bitwise reproducible
     across restart like the parameters themselves.
  4. **Checkpoint/restart**: periodic async checkpoints through
     `train/checkpoint.py`; restarting from any checkpoint continues the
     parameter stream bitwise (deterministic counter-based data + donated
     jitted step), across the BN-fusion boundary too.
  5. **Export**: calibrate -> `quantize_net` -> prove the frozen artifact
     bit-exact through the reference interpreter, `prepare_qnet`, the
     jitted stage executors, and a (tuned) `VisionEngine` — only then write
     the `.qnet` (with a build record + training provenance) to disk.

`tests/regen_golden.py` derives the golden conformance fixtures through
`stage_vectors` below, so the frozen test vectors and the training export
share one code path by construction.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler as CC
from repro.core import cu
from repro.core import graph as G
from repro.core import qnet as Q
from repro.core.calibrate import ActObserver, calibrate, relu6_fused_qparams
from repro.core.quant import QuantConfig
from repro.data.pipeline import image_batch
from repro.models import layers
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.train import checkpoint as CKPT
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step


# ---------------------------------------------------------------------------
# configuration + phase schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VisionTrainConfig:
    """One deterministic training run — every derived quantity (phase
    boundaries, data stream, calibration stream) is a pure function of this
    config, which is what makes checkpoint restart bitwise and the export
    reproducible."""

    model: str = "mobilenet_v2"  # mobilenet_v2 | efficientnet_compact
    alpha: float = 0.35  # mobilenet width multiplier
    input_hw: int = 16
    num_classes: int = 4
    bits: int = 4  # weight BW
    act_bits: int = 4  # deployment activation BW
    # heterogeneous deployment: sorted ((op_name, act_bits), ...) pairs
    # applied on top of the uniform `act_bits` base (tuple-of-pairs so the
    # frozen config stays hashable; `alloc` exposes the dict view). Rides
    # the build record, so mixed-precision artifacts self-describe.
    op_act_bits: Optional[Tuple[Tuple[str, int], ...]] = None
    anneal_from: Optional[int] = None  # e.g. 8: first half of QAT at 8b acts
    bn: bool = True  # float phase trains with BatchNorm, fused before QAT
    float_steps: int = 40
    qat_steps: int = 20
    batch: int = 32
    grad_accum: int = 1
    lr: float = 2e-3
    qat_lr: float = 5e-4
    weight_decay: float = 0.0
    warmup_steps: int = 5
    bn_momentum: float = 0.9
    seed: int = 0  # param init
    data_seed: int = 0  # training stream
    calib_seed: int = 1  # held-out calibration stream (disjoint from data)
    calib_batches: int = 4
    calib_momentum: Optional[float] = 0.9  # EMA observers for online quant
    calibrate_every: int = 0  # QAT steps between online-quant rounds; 0=off
    ckpt_every: int = 0  # global steps between checkpoints; 0 = off
    ckpt_keep: int = 3

    @property
    def total_steps(self) -> int:
        return self.float_steps + self.qat_steps

    @property
    def alloc(self) -> Optional[Dict[str, int]]:
        """The per-op activation allocation as a dict, or None (uniform)."""
        if not self.op_act_bits:
            return None
        return {str(k): int(v) for k, v in self.op_act_bits}


@dataclasses.dataclass(frozen=True)
class Phase:
    name: str
    start: int  # first global step of this phase
    stop: int  # one past the last
    qat: bool
    act_bits: int
    lr: float


def build_net(cfg: VisionTrainConfig, act_bits: Optional[int] = None) -> G.NetSpec:
    """The deployment NetSpec (weight BW = cfg.bits, activation BW =
    cfg.act_bits, plus the per-op `op_act_bits` allocation when the config
    carries one); `act_bits` overrides the activation BW for anneal
    phases — an anneal phase at a different uniform width trains WITHOUT
    the allocation (the 8-bit warm phase is uniform; the allocation lands
    with the deployment width). ONE dispatch for both directions: the spec
    trained against is by construction the spec `load_qnet(path)` rebuilds
    from the artifact's build record — the record cannot drift from the
    builder call."""
    rec = build_record(cfg)
    if act_bits is not None:
        rec["act_bits"] = act_bits
        if act_bits != cfg.act_bits:
            rec.pop("op_act_bits", None)
    return Q.build_netspec(rec)


def build_record(cfg: VisionTrainConfig) -> Dict[str, Any]:
    """The artifact's self-description (`core.qnet.build_netspec` inverse).

    `act_bits` rides the record so a config deploying at a different
    activation BW than its weight BW (e.g. bits=4, act_bits=8) rebuilds
    the exact trained spec from the file alone."""
    rec: Dict[str, Any] = {"model": cfg.model, "input_hw": cfg.input_hw,
                           "bits": cfg.bits, "num_classes": cfg.num_classes,
                           "act_bits": cfg.act_bits}
    if cfg.model == "mobilenet_v2":
        rec["alpha"] = cfg.alpha
    if cfg.alloc:
        rec["op_act_bits"] = cfg.alloc
    return rec


def phase_schedule(cfg: VisionTrainConfig) -> Tuple[Phase, ...]:
    phases: List[Phase] = []
    if cfg.float_steps:
        phases.append(Phase("float", 0, cfg.float_steps, False,
                            cfg.act_bits, cfg.lr))
    q0 = cfg.float_steps
    if cfg.qat_steps:
        if cfg.anneal_from is not None and cfg.anneal_from != cfg.act_bits:
            n1 = cfg.qat_steps // 2
            if n1:
                phases.append(Phase(f"qat_act{cfg.anneal_from}", q0, q0 + n1,
                                    True, cfg.anneal_from, cfg.qat_lr))
            phases.append(Phase(f"qat_act{cfg.act_bits}", q0 + n1,
                                q0 + cfg.qat_steps, True, cfg.act_bits,
                                cfg.qat_lr))
        else:
            phases.append(Phase("qat", q0, q0 + cfg.qat_steps, True,
                                cfg.act_bits, cfg.qat_lr))
    if not phases:
        raise ValueError("config trains for zero steps")
    return tuple(phases)


def phase_at(cfg: VisionTrainConfig, step: int) -> int:
    """Index of the phase a run with `step` completed steps resumes into."""
    phases = phase_schedule(cfg)
    for i, ph in enumerate(phases):
        if step < ph.stop:
            return i
    return len(phases) - 1


# ---------------------------------------------------------------------------
# data + train step
# ---------------------------------------------------------------------------


def train_batch(cfg: VisionTrainConfig, step: int) -> Dict[str, jnp.ndarray]:
    b = image_batch(cfg.data_seed, step, cfg.batch, cfg.input_hw,
                    cfg.num_classes)
    return {"images": jnp.asarray(b["images"]),
            "labels": jnp.asarray(b["labels"])}


def eval_accuracy(
    params,
    net: G.NetSpec,
    cfg: VisionTrainConfig,
    *,
    qat: bool = True,
    eval_seed: int = 2,
    eval_batches: int = 4,
) -> float:
    """Held-out top-1 accuracy of the fake-quantized forward.

    The evaluation stream is a seed stream disjoint from both the training
    stream (`data_seed`) and the calibration stream (`calib_seed`), fixed
    by (`eval_seed`, batch index) — so the number is a pure function of
    (params, net, cfg) and comparable across mixed-precision candidates.
    `qat=True` evaluates through the per-op fake-quant path, i.e. at the
    net's (possibly heterogeneous) deployment activation widths."""
    correct = total = 0
    for i in range(eval_batches):
        b = image_batch(eval_seed, i, cfg.batch, cfg.input_hw,
                        cfg.num_classes)
        logits, _ = layers.forward(params, jnp.asarray(b["images"]), net,
                                   qat=qat)
        pred = np.asarray(jnp.argmax(logits, axis=-1))
        correct += int((pred == b["labels"]).sum())
        total += int(b["labels"].size)
    return correct / total if total else 0.0


def calibration_batches(cfg: VisionTrainConfig) -> List[jnp.ndarray]:
    """Held-out calibration stream — a seed stream disjoint from training,
    fixed for the whole run (so export is a pure function of the params)."""
    return [jnp.asarray(image_batch(cfg.calib_seed, i, cfg.batch,
                                    cfg.input_hw, cfg.num_classes)["images"])
            for i in range(cfg.calib_batches)]


def make_vision_train_step(
    net: G.NetSpec,
    opt_cfg: O.AdamWConfig,
    *,
    qat: bool,
    grad_accum: int = 1,
    bn_batch: bool = False,
    bn_momentum: float = 0.9,
) -> Callable:
    """Microbatched QAT/float train step over `train_loop.make_train_step`.

    `bn_batch=True` (float pre-training) runs BN on batch statistics and
    folds the microbatch-averaged moments into the running stats by EMA —
    after the optimizer update, so the stats never see weight decay."""

    def loss_fn(params, batch):
        bn_stats: Optional[Dict] = {} if bn_batch else None
        logits, _ = layers.forward(params, batch["images"], net, qat=qat,
                                   bn_stats=bn_stats)
        lp = jax.nn.log_softmax(logits)
        loss = -jnp.take_along_axis(lp, batch["labels"][:, None], 1).mean()
        return (loss, bn_stats) if bn_batch else loss

    base = make_train_step(None, opt_cfg, grad_accum=grad_accum,
                           loss_fn=loss_fn, has_aux=bn_batch)
    if not bn_batch:
        return base

    def step(params, opt_state, batch):
        prev = params  # pre-update running stats (optimizer never owns them)
        params, opt_state, metrics = base(params, opt_state, batch)
        moments = metrics.pop("aux")
        m = bn_momentum
        params = dict(params)
        for name, mom in moments.items():
            old = prev[name]["bn"]
            p = dict(params[name])
            p["bn"] = {
                "gamma": params[name]["bn"]["gamma"],
                "beta": params[name]["bn"]["beta"],
                "mean": m * old["mean"] + (1 - m) * mom["mean"],
                "var": m * old["var"] + (1 - m) * mom["var"],
            }
            params[name] = p
        return params, opt_state, metrics

    return step


# ---------------------------------------------------------------------------
# online quantization (per-epoch calibration during QAT)
# ---------------------------------------------------------------------------


def observer_keys(net: G.NetSpec) -> Tuple[str, ...]:
    """Every activation name the capture forward emits, derived from the
    spec alone (mirrors `layers._apply_block`'s traversal). This is what
    lets the observer set be part of the checkpoint template: its shape is
    a pure function of the config, like everything else in the run."""
    keys: List[str] = []
    for block in net.blocks:
        for op in block.ops:
            keys.append(op.name)
            if block.se is not None and block.se_after == op.name:
                keys.append("se_gate")
        if block.residual:
            keys.append(block.name + "/residual")
        if block.avgpool:
            keys.append(block.name + "/avgpool")
    return tuple(dict.fromkeys(keys))


def init_observers(cfg: VisionTrainConfig) -> Dict[str, ActObserver]:
    """Untouched (±inf range) EMA observers for every capture key."""
    return {k: ActObserver.init((), momentum=cfg.calib_momentum)
            for k in observer_keys(build_net(cfg))}


def _obs_tree(observers: Dict[str, ActObserver]):
    """Checkpointable pytree view (momentum is config, not state)."""
    return {k: {"mn": o.min_val, "mx": o.max_val}
            for k, o in observers.items()}


def _obs_from_tree(tree, momentum: Optional[float]) -> Dict[str, ActObserver]:
    return {k: ActObserver(v["mn"], v["mx"], momentum)
            for k, v in tree.items()}


def observers_ready(observers: Dict[str, ActObserver]) -> bool:
    """True once at least one full calibration round ran: every observer
    holds a finite range (an untouched observer still sits at ±inf)."""
    return bool(observers) and all(
        bool(np.isfinite(np.asarray(o.min_val)).all())
        and bool(np.isfinite(np.asarray(o.max_val)).all())
        for o in observers.values())


_CFG_MOMENTUM = object()  # sentinel: "use cfg.calib_momentum"


def run_calibration(
    params,
    net: G.NetSpec,
    cfg: VisionTrainConfig,
    observers: Optional[Dict[str, ActObserver]] = None,
    act_bits: Optional[int] = None,
    momentum=_CFG_MOMENTUM,
) -> Tuple[Dict[str, ActObserver], Dict[str, Any]]:
    """One calibration round: drive the held-out stream through the
    BN-fused float forward, update the observers, and re-derive the
    ReLU6-fused activation qparams. Returns (observers, round summary).

    Default momentum comes from the config (the EMA online-quantization
    mode); `momentum=None` forces true-min/max observers (the from-scratch
    export recalibration). The ONE calibration recipe every caller —
    training rounds, export, tests — goes through."""
    bw = act_bits if act_bits is not None else cfg.act_bits
    acfg = QuantConfig(bw, symmetric=False, channel_axis=None)

    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]

    m = cfg.calib_momentum if momentum is _CFG_MOMENTUM else momentum
    observers = calibrate(apply_fn, params, calibration_batches(cfg), acfg,
                          observers=observers, momentum=m)
    s6, z6 = relu6_fused_qparams(acfg)
    summary = {
        "act_bits": bw,
        "relu6_scale": float(s6),
        "relu6_zp": float(z6),
        "n_observers": len(observers),
        "ranges": {
            name: (float(obs.min_val), float(obs.max_val))
            for name, obs in sorted(observers.items())[:4]
        },
    }
    return observers, summary


# ---------------------------------------------------------------------------
# training orchestrator (checkpoint / restart / preemption)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainResult:
    params: Any
    net: G.NetSpec  # deployment spec (final act bits)
    cfg: VisionTrainConfig
    step: int  # global steps completed
    history: Dict[str, Any]
    observers: Dict[str, ActObserver]

    @property
    def done(self) -> bool:
        return self.step >= self.cfg.total_steps


def _has_bn(params) -> bool:
    return any("bn" in p for p in params.values())


def _ckpt_extra(ckpt_dir: str, step: int) -> Dict[str, Any]:
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f).get("extra", {})


def _template(cfg: VisionTrainConfig, fused: bool):
    """The parameter tree shape at a checkpoint: replays init (+ BN fusion
    when the checkpoint is past the float -> QAT boundary)."""
    params = layers.init_params(jax.random.PRNGKey(cfg.seed), build_net(cfg),
                                bn=cfg.bn)
    if fused and cfg.bn:
        params = layers.fuse_bn_params(params)
    return params


def train(
    cfg: VisionTrainConfig,
    *,
    ckpt_dir: Optional[str] = None,
    resume: bool = False,
    stop_after: Optional[int] = None,
    log: Optional[Callable[[str], None]] = None,
    tracer: Optional[OT.Tracer] = None,
    metrics: Optional[OM.MetricsRegistry] = None,
) -> TrainResult:
    """Run (or resume) the full schedule. `stop_after=k` checkpoints and
    returns after k global steps — the simulated-preemption hook the
    restart-continuation tests kill the run with.

    `tracer`/`metrics` (see `repro.obs`) record phase / calibration /
    checkpoint spans on the `train` track plus per-step loss, the act-bit
    anneal position, calibration-round counts, observer readiness, and
    checkpoint duration — observability only, never training state."""
    say = log or (lambda s: None)
    tracer = tracer if tracer is not None else OT.NULL
    reg = metrics if metrics is not None else OM.NULL_REGISTRY
    if tracer:
        tracer.name_track(OT.TID_TRAIN, "train")
    m_loss = reg.gauge("train_loss", "last train-step loss")
    m_steps = reg.counter("train_steps_total",
                          "global train steps run by this process")
    m_act_bits = reg.gauge(
        "train_act_bits",
        "activation bit-width of the current phase (the QAT anneal path)")
    m_calib = reg.counter("train_calibration_rounds_total",
                          "online-quantization calibration rounds")
    m_obs_ready = reg.gauge(
        "train_observers_ready",
        "1 once every activation observer holds a finite range")
    m_ckpt = reg.histogram(
        "train_checkpoint_seconds",
        "save_ckpt wall time (incl. waiting out the prior async write)")
    if stop_after is not None and not ckpt_dir:
        # a preemption point without a checkpoint directory would discard
        # the run while claiming it is resumable — refuse up front
        raise ValueError("stop_after requires ckpt_dir (nothing would be "
                         "saved to resume from)")
    phases = phase_schedule(cfg)
    history: Dict[str, Any] = {"loss": [], "phases": [], "calibration": []}
    observers = init_observers(cfg)

    start = 0
    if resume and ckpt_dir and CKPT.latest_step(ckpt_dir) is not None:
        start = CKPT.latest_step(ckpt_dir)
        extra = _ckpt_extra(ckpt_dir, start)
        template = _template(cfg, fused=extra.get("fused", not cfg.bn))
        (params, opt_state, obs_tree), _ = CKPT.restore(
            ckpt_dir, (template, O.init_state(template),
                       _obs_tree(observers)), step=start)
        # observer state rides the checkpoint: a resumed run's online-
        # quantization rounds (and therefore its export quantizers) are
        # bitwise those of the uninterrupted run
        observers = _obs_from_tree(obs_tree, cfg.calib_momentum)
        # the run log rides the manifest, so a resumed run's history (and
        # the provenance derived from it — loss curve, round counts) spans
        # the WHOLE run, not just the post-resume tail. JSON round-trips
        # tuples as lists; consumers treat entries as plain data.
        history = extra.get("history", history)
        say(f"[train-vision] resumed at step {start} "
            f"(phase {phases[phase_at(cfg, start)].name})")
    else:
        params = layers.init_params(jax.random.PRNGKey(cfg.seed),
                                    build_net(cfg), bn=cfg.bn)
        opt_state = None  # initialized at phase entry

    pending = None  # in-flight async checkpoint writer
    completed = start  # global steps finished so far
    stopped = False

    def save_ckpt(step_done: int, loss: float):
        nonlocal pending
        if not ckpt_dir:
            return
        tc0 = time.perf_counter()
        with tracer.span("checkpoint", cat="train", tid=OT.TID_TRAIN,
                         args={"step": step_done}):
            if pending is not None:
                pending.join()
            pending = CKPT.save(
                ckpt_dir, step_done,
                (params, opt_state, _obs_tree(observers)),
                keep=cfg.ckpt_keep, async_=True,
                extra={"fused": not _has_bn(params), "loss": loss,
                       # JSON round-trip = deep snapshot: the async writer
                       # must not see later in-place mutations (and tuples
                       # normalize to lists, same as at restore)
                       "history": json.loads(json.dumps(history)),
                       "phase": phases[min(phase_at(cfg, step_done),
                                           len(phases) - 1)].name})
        m_ckpt.observe(time.perf_counter() - tc0)

    for ph in phases:
        if stopped or completed >= ph.stop:
            continue
        if ph.qat and _has_bn(params):
            # float -> QAT boundary: fold BN so fake-quant trains the
            # deployed weights (Sec. 3.1). Changes the tree shape, which is
            # why checkpoints record whether they are pre- or post-fusion.
            params = layers.fuse_bn_params(params)
            say(f"[train-vision] fused BN into weights at step {completed}")
        net_ph = build_net(cfg, act_bits=ph.act_bits)
        n_ph = ph.stop - ph.start
        opt_cfg = O.AdamWConfig(
            lr=ph.lr, warmup_steps=min(cfg.warmup_steps, max(n_ph // 5, 1)),
            total_steps=n_ph, weight_decay=cfg.weight_decay)
        if opt_state is None or completed == ph.start:
            # fresh optimizer per phase (own schedule; also what keeps the
            # restored-state step counter aligned within the phase)
            opt_state = O.init_state(params)
        step_fn = jax.jit(make_vision_train_step(
            net_ph, opt_cfg, qat=ph.qat, grad_accum=cfg.grad_accum,
            bn_batch=(not ph.qat) and cfg.bn and _has_bn(params),
            bn_momentum=cfg.bn_momentum))
        if not any(e["name"] == ph.name for e in history["phases"]):
            # (a resumed run restores the entry with the rest of the log)
            history["phases"].append(
                {"name": ph.name, "start": ph.start, "stop": ph.stop,
                 "act_bits": ph.act_bits, "qat": ph.qat})
        m_act_bits.set(ph.act_bits)
        ph_t0 = tracer.now() if tracer else 0.0
        ph_from = completed

        for gs in range(completed, ph.stop):
            batch = train_batch(cfg, gs)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            history["loss"].append(loss)
            completed = gs + 1
            m_loss.set(loss)
            m_steps.inc()
            if ph.qat and cfg.calibrate_every and (
                    (completed - ph.start) % cfg.calibrate_every == 0):
                with tracer.span("calibration_round", cat="train",
                                 tid=OT.TID_TRAIN,
                                 args={"step": completed,
                                       "act_bits": ph.act_bits}):
                    observers, summary = run_calibration(
                        params, net_ph, cfg, observers, act_bits=ph.act_bits)
                history["calibration"].append(dict(summary, step=completed))
                m_calib.inc()
                if reg:
                    m_obs_ready.set(1.0 if observers_ready(observers)
                                    else 0.0)
                say(f"[train-vision] online-quant round at step {completed}: "
                    f"act{summary['act_bits']} relu6 S="
                    f"{summary['relu6_scale']:.5f}")
            if stop_after is not None and completed >= stop_after:
                save_ckpt(completed, loss)
                stopped = True
                say(f"[train-vision] preempted at step {completed} "
                    f"(checkpointed)")
                break
            if cfg.ckpt_every and (completed % cfg.ckpt_every == 0
                                   or completed == cfg.total_steps):
                save_ckpt(completed, loss)

        if tracer:
            tracer.complete(
                f"phase:{ph.name}", ph_t0, tracer.now(), cat="train",
                tid=OT.TID_TRAIN,
                args={"act_bits": ph.act_bits, "qat": ph.qat,
                      "steps": completed - ph_from})

    if pending is not None:
        pending.join()
    return TrainResult(params=params, net=build_net(cfg), cfg=cfg,
                       step=completed, history=history, observers=observers)


# ---------------------------------------------------------------------------
# export: calibrate -> quantize -> prove bit-exact -> freeze
# ---------------------------------------------------------------------------


class ExportParityError(AssertionError):
    """A serving route disagreed with the reference interpreter bitwise."""


def stage_vectors(qnet: Q.QNet, x: np.ndarray):
    """(stage CU names, per-stage integer activations, float logits) from
    the reference `cu.run_blocks` walk — the semantic ground truth every
    other route is proven against. The golden conformance fixtures under
    tests/golden/ are generated through THIS function (tests/regen_golden.py
    is a thin wrapper), so trained exports and frozen test vectors share one
    derivation."""
    plan = CC.compile_net(qnet.spec)
    sigs = plan.stage_signatures()
    s, z = cu.input_qparams(qnet)
    y = cu.quantize_input(jnp.asarray(x), s, z, 8)
    acts, cus = [], []
    for sig in sigs:
        y, s, z = cu.run_blocks(y, sig.blocks, qnet, s, z)
        acts.append(np.asarray(y))
        cus.append(sig.cu)
    logits = (acts[-1].astype(np.float32) + np.float32(z)) * np.float32(s)
    return cus, acts, logits


def _check_equal(name: str, got: np.ndarray, want: np.ndarray,
                 report: List[str]):
    got, want = np.asarray(got), np.asarray(want)
    if got.shape != want.shape:
        raise ExportParityError(
            f"{name}: shape {got.shape} != reference {want.shape}")
    if not np.array_equal(got, want):
        n = int(np.sum(got != want))
        d = float(np.max(np.abs(got.astype(np.float64)
                                - want.astype(np.float64))))
        raise ExportParityError(
            f"{name}: {n} elements differ from the reference "
            f"(max |delta| {d:.3g}); routes proven so far: {report}")
    report.append(name)


def verify_export(qnet: Q.QNet, x: np.ndarray, *, tuned=None) -> Dict[str, Any]:
    """Prove one input batch bit-exact across every serving route:
    reference interpreter, `prepare_qnet` fast path, jitted stage
    executors, and a `VisionEngine` (tuned when a plan is given). Raises
    `ExportParityError` on the first route that drifts one LSB."""
    from repro.serve.vision import VisionEngine, compile_stages

    x = np.asarray(x, np.float32)
    cus, acts, logits = stage_vectors(qnet, x)
    proven: List[str] = ["reference"]

    pq = cu.prepare_qnet(qnet)
    _check_equal("prepared", cu.run_qnet(pq, jnp.asarray(x)), logits, proven)

    stages = compile_stages(qnet)
    y = jnp.asarray(x)
    for i, st in enumerate(stages):
        y = st(y)
        if i < len(stages) - 1:
            _check_equal(f"stage[{i}:{st.spec.cu}]", y,
                         acts[i].astype(np.int32), proven)
    _check_equal("stage-executors", y, logits, proven)

    eng = VisionEngine(qnet, buckets=(x.shape[0],), tuned=tuned)
    rids = [eng.submit(img) for img in x]
    res = eng.run()
    got = np.stack([res[r].logits for r in rids])
    _check_equal("engine[tuned]" if tuned is not None else "engine",
                 got, logits, proven)

    return {"routes": proven, "stages": len(cus), "cus": cus,
            "logits": logits,
            "tuned_entries": len(tuned) if tuned is not None else 0}


def export(
    params,
    net: G.NetSpec,
    cfg: VisionTrainConfig,
    *,
    path: Optional[str] = None,
    observers: Optional[Dict[str, ActObserver]] = None,
    verify: bool = True,
    verify_batch: Optional[np.ndarray] = None,
    tuned=None,
    tune: bool = False,
    measure=None,
    provenance: Optional[Dict[str, Any]] = None,
    tracer: Optional[OT.Tracer] = None,
) -> Tuple[Q.QNet, Dict[str, Any]]:
    """Terminal export step: BN-fuse (if still unfused) -> calibrate on the
    held-out stream -> `quantize_net` -> prove every serving route bit-exact
    -> freeze to `path`.

    `observers`: pass the run's online-quantization observers
    (`TrainResult.observers`, once `observers_ready`) to export with the
    ranges the per-epoch calibration rounds tracked — they are checkpointed
    training state, so they too are bitwise identical after a restart.
    `observers=None` recalibrates from scratch on the config's held-out
    stream with true-min/max observers. Either way the artifact is a pure
    function of (run state, cfg).
    `tune=True` autotunes the freshly exported net (`repro.tune.tune_qnet`)
    and proves the tuned engine too; `tuned=` passes a ready plan instead.
    The artifact is written only after every proof passes."""
    if _has_bn(params):
        params = layers.fuse_bn_params(params)
    if observers is None:
        observers, _ = run_calibration(params, net, cfg, momentum=None)
    qnet = Q.quantize_net(params, net, observers)

    if tune and tuned is None:
        from repro.tune import tune_qnet
        tuned = tune_qnet(qnet, batch=min(cfg.batch, 8), repeats=1,
                          measure=measure,
                          include_pallas=jax.default_backend() == "tpu",
                          tracer=tracer)

    report: Dict[str, Any] = {"verified": False}
    if verify:
        if verify_batch is None:
            verify_batch = np.asarray(calibration_batches(cfg)[0])
        report = verify_export(qnet, verify_batch, tuned=tuned)
        report["verified"] = True

    if path is not None:
        prov = {"model": cfg.model, "total_steps": cfg.total_steps,
                "float_steps": cfg.float_steps, "qat_steps": cfg.qat_steps,
                "act_bits": cfg.act_bits, "bits": cfg.bits,
                "anneal_from": cfg.anneal_from, "bn": cfg.bn,
                "seed": cfg.seed, "data_seed": cfg.data_seed,
                "calib_seed": cfg.calib_seed,
                "calib_batches": cfg.calib_batches,
                "op_act_bits": cfg.alloc,
                "verified_routes": report.get("routes", [])}
        if provenance:
            prov.update(provenance)
        Q.save_qnet(qnet, path, build=build_record(cfg), provenance=prov)
        report["path"] = path
        report["artifact_bytes"] = os.path.getsize(path)
    return qnet, report


def train_and_export(
    cfg: VisionTrainConfig,
    *,
    ckpt_dir: Optional[str] = None,
    resume: bool = False,
    stop_after: Optional[int] = None,
    path: Optional[str] = None,
    verify: bool = True,
    tune: bool = False,
    measure=None,
    log: Optional[Callable[[str], None]] = None,
    tracer: Optional[OT.Tracer] = None,
    metrics: Optional[OM.MetricsRegistry] = None,
) -> Tuple[TrainResult, Optional[Q.QNet], Dict[str, Any]]:
    """The whole Fig. 1 front end in one call (the launch driver's body)."""
    result = train(cfg, ckpt_dir=ckpt_dir, resume=resume,
                   stop_after=stop_after, log=log,
                   tracer=tracer, metrics=metrics)
    if not result.done:
        return result, None, {"verified": False, "reason": "preempted"}
    # online-quantization rounds feed the export: once every observer saw a
    # full calibration round, the EMA-tracked ranges become the artifact's
    # activation quantizers (else recalibrate from scratch)
    obs = result.observers if observers_ready(result.observers) else None
    rounds = len(result.history["calibration"])
    qnet, report = export(result.params, result.net, cfg, path=path,
                          observers=obs, verify=verify, tune=tune,
                          measure=measure, tracer=tracer,
                          provenance={"final_loss": result.history["loss"][-1]
                                      if result.history["loss"] else None,
                                      "online_quant_rounds": rounds})
    report["online_quant_rounds"] = rounds
    report["observers_used"] = obs is not None
    return result, qnet, report


__all__ = [
    "VisionTrainConfig",
    "Phase",
    "TrainResult",
    "ExportParityError",
    "build_net",
    "build_record",
    "phase_schedule",
    "phase_at",
    "train_batch",
    "calibration_batches",
    "eval_accuracy",
    "make_vision_train_step",
    "observer_keys",
    "init_observers",
    "observers_ready",
    "run_calibration",
    "train",
    "stage_vectors",
    "verify_export",
    "export",
    "train_and_export",
]
