"""Route autotuning: measured per-op kernel selection with a committed
tuning cache (see `autotune.tune_qnet` and `cache.TunedPlan`).

    plan = tune_qnet(qnet, batch=8)          # measure + verify bit-exact
    save_tuned(plan, "experiments/tuned/my_cpu.json")
    engine = VisionEngine(qnet, tuned=load_tuned(...))  # cache lookup

`python -m repro.tune` regenerates the committed caches;
`python -m repro.tune --precision` runs the mixed-precision search
(`precision.search_precision`) over the cached timings.
"""
from repro.tune.autotune import (
    Candidate,
    DW_BLOCK_H_SWEEP,
    PW_TILE_SWEEP,
    op_candidates,
    tune_qnet,
    wall_measure,
)
from repro.tune.cache import (
    CACHE_VERSION,
    DW_SHIFTS,
    FUSED_IRB,
    INT_F32,
    INT_REF,
    PALLAS_DW,
    PALLAS_PW,
    PER_OP,
    RouteChoice,
    TunedPlan,
    irb_key,
    load_tuned,
    op_key,
    save_tuned,
)
from repro.tune.precision import (
    LatencyTable,
    PrecisionPoint,
    PrecisionResult,
    QATFinetuneAccuracy,
    check_pareto_artifact,
    export_point,
    pareto_front,
    search_precision,
    write_pareto,
)

__all__ = [
    "Candidate",
    "DW_BLOCK_H_SWEEP",
    "PW_TILE_SWEEP",
    "op_candidates",
    "tune_qnet",
    "wall_measure",
    "CACHE_VERSION",
    "DW_SHIFTS",
    "FUSED_IRB",
    "INT_F32",
    "INT_REF",
    "PALLAS_DW",
    "PALLAS_PW",
    "PER_OP",
    "RouteChoice",
    "TunedPlan",
    "irb_key",
    "load_tuned",
    "op_key",
    "save_tuned",
    "LatencyTable",
    "PrecisionPoint",
    "PrecisionResult",
    "QATFinetuneAccuracy",
    "check_pareto_artifact",
    "export_point",
    "pareto_front",
    "search_precision",
    "write_pareto",
]
