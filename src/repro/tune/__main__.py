"""Regenerate the committed tuning caches under experiments/tuned/.

    # the golden-fixture nets (what the tier-1 parity tests consume);
    # --models filters to a subset, e.g. just the 1-D KWS fixture:
    PYTHONPATH=src python -m repro.tune --golden
    PYTHONPATH=src python -m repro.tune --golden --models dscnn_kws

    # the benchmark nets (mnv2 a0.35 at hw 48 + the hw-32 smoke shape),
    # merged into one cache the benchmarks/CI consume:
    PYTHONPATH=src python -m repro.tune --bench

    # ad-hoc: one model/shape to a chosen path
    PYTHONPATH=src python -m repro.tune --models mobilenet_v2 --hw 48 \
        --bits 4 --batch 8 --out experiments/tuned/custom.json

    # energy-delay-product objective (docs/energy.md): same nets, routes
    # ranked by EDP instead of latency; files gain an `_edp` suffix so
    # both cache families live side by side
    PYTHONPATH=src python -m repro.tune --golden --objective edp

    # mixed-precision search (docs/tuning.md "Per-layer precision"):
    # per-block act-bit allocation over the cached timings, Pareto
    # artifact under experiments/precision/; --precision-export also
    # writes the best mixed allocation as a conformant .qnet
    PYTHONPATH=src python -m repro.tune --precision --hw 32 \
        --num-classes 10 --choices 4,6,8
    PYTHONPATH=src python -m repro.tune --precision --fake --out /tmp/p.json
    PYTHONPATH=src python -m repro.tune --check-pareto \
        experiments/precision/mobilenet_v2_cpu_pareto.json

Caches are backend-keyed (a cache tuned on CPU resolves nothing on TPU),
so the filenames carry the backend suffix.
"""
from __future__ import annotations

import argparse
import functools
import os
import sys

import jax

TUNED_DIR = os.path.join("experiments", "tuned")


def _suffix(args) -> str:
    """Cache filename suffix: latency caches keep their historic names,
    EDP caches gain `_edp` so both families coexist."""
    return "" if args.objective == "latency" else f"_{args.objective}"


def _build_qnet(model: str, hw: int, bits: int, num_classes: int):
    from repro.models import efficientnet as effn, layers, mobilenet_v2 as mnv2

    if model == "mobilenet_v2":
        net = mnv2.build(alpha=0.35, input_hw=hw, bits=bits,
                         num_classes=num_classes)
    elif model == "efficientnet_compact":
        net = effn.build_compact(input_hw=hw, bits=bits,
                                 num_classes=num_classes)
    else:
        raise SystemExit(f"unknown model {model!r}")
    return layers.make_calibrated_qnet(net, bits=bits)


def tune_golden(args) -> None:
    """One cache per frozen golden fixture net (the conformance contract)."""
    from repro.core import qnet as Q
    from repro.tune import save_tuned, tune_qnet
    from tests.regen_golden import CASES, build_net, fixture_paths

    backend = jax.default_backend()
    suffix = _suffix(args)
    wanted = set(args.models.split(",")) if args.models else None
    for model, bits in CASES:
        if wanted and model not in wanted:
            continue
        qnet_path, _ = fixture_paths(model, bits)
        qnet = Q.load_qnet(qnet_path, build_net(model, bits))
        plan = tune_qnet(qnet, batch=args.batch, repeats=args.repeats,
                         seed=args.seed, verbose=args.verbose,
                         objective=args.objective)
        out = os.path.join(
            TUNED_DIR, f"{model}_act{bits}_{backend}{suffix}.json")
        save_tuned(plan, out)
        print(f"[tune] {model} act{bits}: {len(plan)} entries -> {out}")


def tune_bench(args) -> None:
    """One merged cache covering the benchmark serving shapes."""
    from repro.tune import save_tuned, tune_qnet

    backend = jax.default_backend()
    plans = []
    for hw in (48, 32):  # full benchmark + the CI smoke geometry
        qnet = _build_qnet("mobilenet_v2", hw, 4, 1000)
        plans.append(tune_qnet(qnet, batch=args.batch, repeats=args.repeats,
                               seed=args.seed, verbose=args.verbose,
                               objective=args.objective))
        print(f"[tune] mobilenet_v2 hw{hw}: {len(plans[-1])} entries",
              file=sys.stderr)
    merged = functools.reduce(lambda a, b: a.merge(b), plans)
    out = os.path.join(TUNED_DIR, f"bench_{backend}{_suffix(args)}.json")
    save_tuned(merged, out)
    print(f"[tune] bench cache: {len(merged)} entries -> {out}")


def tune_custom(args) -> None:
    from repro.tune import save_tuned, tune_qnet

    backend = jax.default_backend()
    plans = []
    for model in args.models.split(","):
        qnet = _build_qnet(model.strip(), args.hw, args.bits,
                           args.num_classes)
        plans.append(tune_qnet(qnet, batch=args.batch, repeats=args.repeats,
                               seed=args.seed, verbose=args.verbose,
                               objective=args.objective))
    merged = functools.reduce(lambda a, b: a.merge(b), plans)
    out = args.out or os.path.join(
        TUNED_DIR, f"custom_{backend}{_suffix(args)}.json")
    save_tuned(merged, out)
    print(f"[tune] {args.models}: {len(merged)} entries -> {out}")


def tune_precision(args) -> None:
    """Mixed-precision search driver (`repro.tune.precision`)."""
    import glob

    from repro.train.vision import VisionTrainConfig
    from repro.tune import load_tuned
    from repro.tune import precision as P

    backend = jax.default_backend()
    choices = tuple(int(c) for c in args.choices.split(","))
    model = (args.models or "mobilenet_v2").split(",")[0].strip()
    if args.fake:
        # tiny but non-zero training budget: the search itself scores with
        # fake_accuracy, but --precision-export still fine-tunes + verifies
        # through the real QAT/export path
        cfg = VisionTrainConfig(model=model, input_hw=8, num_classes=4,
                                bits=args.bits, act_bits=min(choices),
                                float_steps=6, qat_steps=4,
                                calibrate_every=0, ckpt_every=0, batch=8)
        measure, accuracy_fn, tuned = P.fake_measure, P.fake_accuracy, None
    else:
        cfg = VisionTrainConfig(
            model=model, input_hw=args.hw, num_classes=args.num_classes,
            bits=args.bits, act_bits=min(choices),
            float_steps=args.float_steps, qat_steps=args.qat_steps,
            batch=args.batch)
        measure, accuracy_fn = None, None
        tuned = None
        # seed the latency table from every committed cache of this model
        # family on this backend (the per-width `{model}_act{n}` files)
        paths = sorted(glob.glob(os.path.join(
            TUNED_DIR, f"{model}_act*_{backend}.json")))
        for p in paths:
            t = load_tuned(p)
            tuned = t if tuned is None else tuned.merge(t)
            print(f"[precision] seeded {len(t)} entries from {p}",
                  file=sys.stderr)
    result = P.search_precision(
        cfg, choices=choices, tuned=tuned, backend=backend,
        accuracy_fn=accuracy_fn, measure=measure,
        ladder_budget=args.ladder_budget,
        tune_batch=args.batch, tune_repeats=args.repeats,
        finetune_steps=args.finetune_steps,
        log=lambda s: print(s, file=sys.stderr))
    out = args.out or P.pareto_path(model, backend)
    P.write_pareto(result, out)
    dom = P.find_domination(list(result.points))
    print(f"[precision] {len(result.points)} points, front: "
          f"{', '.join(result.front)} -> {out}")
    if dom:
        m, u = dom
        print(f"[precision] {m} dominates {u} on (latency, model_bytes) "
              f"at >= accuracy")
    if args.precision_export:
        # headline = the dominating mixed point if one exists, else the
        # first mixed allocation on the front (export must exercise a
        # genuinely heterogeneous net), else the front head
        name = dom[0] if dom else next(
            (n for n in result.front if n.startswith("mix")),
            result.front[0])
        best = next(p for p in result.points if p.name == name)
        impl = None
        if args.fake:
            # smoke still exports through the REAL conformance path —
            # only the search-time scoring was faked
            impl = P.QATFinetuneAccuracy(cfg, steps=0)
        report = P.export_point(cfg, best, args.precision_export,
                                accuracy_impl=impl)
        print(f"[precision] exported {best.name} -> "
              f"{args.precision_export} (routes: "
              f"{', '.join(report.get('routes', []))})")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--golden", action="store_true",
                    help="tune the 4 frozen golden-fixture nets")
    ap.add_argument("--bench", action="store_true",
                    help="tune the benchmark nets into one merged cache")
    ap.add_argument("--precision", action="store_true",
                    help="per-block mixed-precision search over the cached "
                         "timings (writes a Pareto artifact)")
    ap.add_argument("--choices", default="4,6,8",
                    help="act-bit widths the precision search draws from")
    ap.add_argument("--float-steps", type=int, default=40)
    ap.add_argument("--qat-steps", type=int, default=20)
    ap.add_argument("--ladder-budget", type=int, default=5,
                    help="mixed candidates per savings ladder")
    ap.add_argument("--finetune-steps", type=int, default=10,
                    help="QAT fine-tune steps per candidate allocation")
    ap.add_argument("--fake", action="store_true",
                    help="deterministic fake measure + accuracy (CI smoke)")
    ap.add_argument("--precision-export", default=None, metavar="PATH",
                    help="also export the headline allocation as a .qnet "
                         "(full 4-route conformance gate)")
    ap.add_argument("--check-pareto", default=None, metavar="PATH",
                    help="schema-check a Pareto artifact and exit")
    ap.add_argument("--models", default=None,
                    help="comma-separated models for an ad-hoc tune")
    ap.add_argument("--hw", type=int, default=48)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--num-classes", type=int, default=1000)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--objective", choices=("latency", "edp"),
                    default="latency",
                    help="route ranking metric: measured latency (default) "
                         "or energy-delay product (docs/energy.md)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.check_pareto:
        from repro.tune import precision as P
        P.check_pareto_artifact(args.check_pareto)
        print(f"[precision] OK {args.check_pareto}")
        return
    if args.precision:
        tune_precision(args)
        return
    if args.golden:
        args_g = argparse.Namespace(**{**vars(args), "batch": 2})
        tune_golden(args_g)  # golden fixtures serve batch 2
    if args.bench:
        tune_bench(args)
    if args.models and not args.golden:  # with --golden, --models filters it
        tune_custom(args)
    if not (args.golden or args.bench or args.models):
        ap.error("pick at least one of --golden / --bench / --models "
                 "/ --precision / --check-pareto")


if __name__ == "__main__":
    main()
