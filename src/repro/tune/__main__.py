"""Regenerate the committed tuning caches under experiments/tuned/.

    # the golden-fixture nets (what the tier-1 parity tests consume);
    # --models filters to a subset, e.g. just the 1-D KWS fixture:
    PYTHONPATH=src python -m repro.tune --golden
    PYTHONPATH=src python -m repro.tune --golden --models dscnn_kws

    # the benchmark nets (mnv2 a0.35 at hw 48 + the hw-32 smoke shape),
    # merged into one cache the benchmarks/CI consume:
    PYTHONPATH=src python -m repro.tune --bench

    # ad-hoc: one model/shape to a chosen path
    PYTHONPATH=src python -m repro.tune --models mobilenet_v2 --hw 48 \
        --bits 4 --batch 8 --out experiments/tuned/custom.json

    # energy-delay-product objective (docs/energy.md): same nets, routes
    # ranked by EDP instead of latency; files gain an `_edp` suffix so
    # both cache families live side by side
    PYTHONPATH=src python -m repro.tune --golden --objective edp

Caches are backend-keyed (a cache tuned on CPU resolves nothing on TPU),
so the filenames carry the backend suffix.
"""
from __future__ import annotations

import argparse
import functools
import os
import sys

import jax

TUNED_DIR = os.path.join("experiments", "tuned")


def _suffix(args) -> str:
    """Cache filename suffix: latency caches keep their historic names,
    EDP caches gain `_edp` so both families coexist."""
    return "" if args.objective == "latency" else f"_{args.objective}"


def _build_qnet(model: str, hw: int, bits: int, num_classes: int):
    from repro.models import efficientnet as effn, layers, mobilenet_v2 as mnv2

    if model == "mobilenet_v2":
        net = mnv2.build(alpha=0.35, input_hw=hw, bits=bits,
                         num_classes=num_classes)
    elif model == "efficientnet_compact":
        net = effn.build_compact(input_hw=hw, bits=bits,
                                 num_classes=num_classes)
    else:
        raise SystemExit(f"unknown model {model!r}")
    return layers.make_calibrated_qnet(net, bits=bits)


def tune_golden(args) -> None:
    """One cache per frozen golden fixture net (the conformance contract)."""
    from repro.core import qnet as Q
    from repro.tune import save_tuned, tune_qnet
    from tests.regen_golden import CASES, build_net, fixture_paths

    backend = jax.default_backend()
    suffix = _suffix(args)
    wanted = set(args.models.split(",")) if args.models else None
    for model, bits in CASES:
        if wanted and model not in wanted:
            continue
        qnet_path, _ = fixture_paths(model, bits)
        qnet = Q.load_qnet(qnet_path, build_net(model, bits))
        plan = tune_qnet(qnet, batch=args.batch, repeats=args.repeats,
                         seed=args.seed, verbose=args.verbose,
                         objective=args.objective)
        out = os.path.join(
            TUNED_DIR, f"{model}_act{bits}_{backend}{suffix}.json")
        save_tuned(plan, out)
        print(f"[tune] {model} act{bits}: {len(plan)} entries -> {out}")


def tune_bench(args) -> None:
    """One merged cache covering the benchmark serving shapes."""
    from repro.tune import save_tuned, tune_qnet

    backend = jax.default_backend()
    plans = []
    for hw in (48, 32):  # full benchmark + the CI smoke geometry
        qnet = _build_qnet("mobilenet_v2", hw, 4, 1000)
        plans.append(tune_qnet(qnet, batch=args.batch, repeats=args.repeats,
                               seed=args.seed, verbose=args.verbose,
                               objective=args.objective))
        print(f"[tune] mobilenet_v2 hw{hw}: {len(plans[-1])} entries",
              file=sys.stderr)
    merged = functools.reduce(lambda a, b: a.merge(b), plans)
    out = os.path.join(TUNED_DIR, f"bench_{backend}{_suffix(args)}.json")
    save_tuned(merged, out)
    print(f"[tune] bench cache: {len(merged)} entries -> {out}")


def tune_custom(args) -> None:
    from repro.tune import save_tuned, tune_qnet

    backend = jax.default_backend()
    plans = []
    for model in args.models.split(","):
        qnet = _build_qnet(model.strip(), args.hw, args.bits,
                           args.num_classes)
        plans.append(tune_qnet(qnet, batch=args.batch, repeats=args.repeats,
                               seed=args.seed, verbose=args.verbose,
                               objective=args.objective))
    merged = functools.reduce(lambda a, b: a.merge(b), plans)
    out = args.out or os.path.join(
        TUNED_DIR, f"custom_{backend}{_suffix(args)}.json")
    save_tuned(merged, out)
    print(f"[tune] {args.models}: {len(merged)} entries -> {out}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--golden", action="store_true",
                    help="tune the 4 frozen golden-fixture nets")
    ap.add_argument("--bench", action="store_true",
                    help="tune the benchmark nets into one merged cache")
    ap.add_argument("--models", default=None,
                    help="comma-separated models for an ad-hoc tune")
    ap.add_argument("--hw", type=int, default=48)
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--num-classes", type=int, default=1000)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--objective", choices=("latency", "edp"),
                    default="latency",
                    help="route ranking metric: measured latency (default) "
                         "or energy-delay product (docs/energy.md)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.golden:
        args_g = argparse.Namespace(**{**vars(args), "batch": 2})
        tune_golden(args_g)  # golden fixtures serve batch 2
    if args.bench:
        tune_bench(args)
    if args.models and not args.golden:  # with --golden, --models filters it
        tune_custom(args)
    if not (args.golden or args.bench or args.models):
        ap.error("pick at least one of --golden / --bench / --models")


if __name__ == "__main__":
    main()
