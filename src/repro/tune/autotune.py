"""Route autotuner: measured per-op kernel selection (DeepDive co-design).

The paper's CU architecture is specialized per operator class *and* per
layer shape; the compiled analogue is that every op in a `CUPlan` has
several bit-exact routes (reference integer XLA ops, the exactness-gated
f32 formulations, the Pallas pointwise/depthwise kernels at several tile
sizes, and the fused-IRB kernel for canonical Body blocks) whose relative
speed depends on shape and backend. This module *measures* the choice
instead of hard-coding it:

  for each op (keyed by kind/shape/act_bits/backend):
      run every eligible candidate once on real intermediate activations
      -> any candidate that drifts one LSB from the reference op output is
         DISQUALIFIED (recorded, never timed, never selectable)
      -> time the survivors (best-of-N wall clock, injectable for tests)
      -> the fastest bit-exact candidate becomes the cache entry

Block-level, each fusable IRB additionally races the fused Pallas kernel
against the composite of the per-op winners. The result is a `TunedPlan`
(see `repro.tune.cache`) that `prepare_qnet` / `compile_stages` consume;
the whole tuned network is verified bit-exact against `cu.run_qnet` before
the plan is returned — a tuner bug can fail loudly but never emit a plan
that changes a logit.

`objective="edp"` swaps the ranking metric from latency to energy-delay
product, scored by the shared `repro.energy.edp_score` (busy-power x time
plus DRAM bytes, times delay). Per-op candidates share one byte count, so
EDP ranking there degenerates to latency ranking; the term that can flip
a winner is block-level traffic — the fused IRB keeps intermediates
on-chip while the per-op composite spills them — which is exactly the
paper's co-design argument for fusion. Everything else is objective-
independent and unchanged:

  * **bit-exactness gating** — a drifting candidate is disqualified
    before it is ever timed, under either objective;
  * **hysteresis** — the `margin` fraction applies to the EDP score the
    same way it applies to latency;
  * **cache format** — entries still record measured `us`; the objective
    is recorded in `TunedPlan.meta["objective"]`, and EDP caches are
    committed alongside latency ones (`*_edp.json`).

Guide: docs/tuning.md; energy model: docs/energy.md.
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler as CC
from repro.core import cu
from repro.core import graph as G
from repro.core.qnet import QNet
from repro.energy import model as EM
from repro.energy.power import PowerModel, default_power_model
from repro.kernels import ops as K
from repro.obs import trace as OT
from repro.tune.cache import (
    DW_SHIFTS, FUSED_IRB, INT_F32, INT_REF, PALLAS_DW, PALLAS_PW, PER_OP,
    RouteChoice, TunedPlan, irb_key, op_key,
)

# small tile sweeps for the Pallas kernels (the kernels clamp each block to
# the largest divisor that fits, so every config compiles for every shape)
PW_TILE_SWEEP: Tuple[Tuple[int, int, int], ...] = (
    (128, 128, 128), (64, 64, 128), (256, 128, 64))
DW_BLOCK_H_SWEEP: Tuple[int, ...] = (4, 8, 16)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One runnable route candidate: `fn(x_q) -> y_q` for the full op."""

    route: str
    params: Dict[str, int]
    fn: Callable[[jnp.ndarray], jnp.ndarray]

    @property
    def label(self) -> str:
        if not self.params:
            return self.route
        inner = ",".join(f"{k}={v}" for k, v in sorted(self.params.items()))
        return f"{self.route}[{inner}]"


def wall_measure(repeats: int = 3):
    """Best-of-N wall-clock timer (the default `measure`).

    One untimed call first — it pays XLA compilation, so timing never
    includes a trace. Tests inject a deterministic fake instead."""

    def measure(fn, x, candidate: Optional[Candidate] = None) -> float:
        jax.block_until_ready(fn(x))
        best = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            best = min(best, time.perf_counter() - t0)
        return best

    return measure


def op_candidates(pop: cu.PreparedQOp, *, interpret: Optional[bool] = None,
                  include_pallas: bool = True) -> List[Candidate]:
    """Bit-exact-eligible candidate routes for one prepared op.

    Eligibility is structural here (e.g. `int_f32` only under the 2^24
    accumulation bound); the tuner still *verifies* every candidate's
    output against the reference before it may win."""
    op = pop.spec
    if op.act == G.HSIGMOID:
        return []  # the gate runs the float-hsigmoid reference path only

    def routed(name: str):
        return lambda x: cu._run_qop(x, pop, False, route=(name, {}))

    cands = [Candidate(INT_REF, {}, routed(INT_REF))]
    if op.kind == G.DW:
        cands.append(Candidate(DW_SHIFTS, {}, routed(DW_SHIFTS)))
        if include_pallas:
            for bh in DW_BLOCK_H_SWEEP:
                params = {"block_h": bh}
                cands.append(Candidate(
                    PALLAS_DW, params,
                    lambda x, p=dict(params): K.run_dw_qop(
                        x, pop, interpret=interpret, **p)))
    elif op.kind in (G.PW, G.DENSE):
        if pop.f32_exact:
            cands.append(Candidate(INT_F32, {}, routed(INT_F32)))
        if include_pallas:
            for bm, bn, bk in PW_TILE_SWEEP:
                params = {"block_m": bm, "block_n": bn, "block_k": bk}
                cands.append(Candidate(
                    PALLAS_PW, params,
                    lambda x, p=dict(params): K.run_pw_qop(
                        x, pop, interpret=interpret, **p)))
    elif op.kind == G.DW1D:
        # temporal depthwise: shifted-multiply formulation vs reference;
        # the Pallas kernels are 2-D, so they never compete here
        cands.append(Candidate(DW_SHIFTS, {}, routed(DW_SHIFTS)))
    elif op.kind in (G.CONV, G.CONV1D):
        if pop.f32_exact:
            cands.append(Candidate(INT_F32, {}, routed(INT_F32)))
    return cands


def default_route(pop: cu.PreparedQOp, backend: str, rank: int = 2) -> str:
    """The route today's heuristics would run for this op on `backend`
    (what `cu._accumulate` / the TPU `op_kernels` path picks). `rank` is
    the net's spatial rank — 1-D nets never default onto the 2-D Pallas
    kernels."""
    op = pop.spec
    if op.kind == G.DW:
        return PALLAS_DW if backend == "tpu" else DW_SHIFTS
    if op.kind == G.DW1D:
        return DW_SHIFTS  # prepared default on every backend
    if op.kind in (G.PW, G.DENSE):
        if backend == "tpu" and rank != 1:
            return PALLAS_PW
        return INT_F32 if pop.f32_exact else INT_REF
    return INT_F32 if pop.f32_exact else INT_REF  # CONV / CONV1D


def _select(cands: Sequence[Candidate], x: jnp.ndarray, ref: np.ndarray,
            measure, default: Optional[str] = None,
            margin: float = 0.1, tracer: OT.Tracer = OT.NULL,
            span_key: str = "",
            scorer: Optional[Callable[[float, Candidate], float]] = None,
            ) -> Optional[RouteChoice]:
    """Verify-then-time every candidate; return the best exact one.

    `scorer(seconds, candidate) -> score` replaces raw time as the ranking
    metric (the EDP objective); `None` ranks by latency. The exactness
    gate, tie-breaking, and `margin` hysteresis all operate on the score.

    Exactness is the hard gate: a candidate whose output differs from the
    reference in any element (or that fails to run) is disqualified before
    it is ever timed — a drifting route can never be preferred, however
    fast. Ties break on the candidate label, so selection is deterministic
    under a deterministic timer.

    Candidates are verified AND timed under `jax.jit`: serving always runs
    a route inside a jitted stage trace, so eager dispatch overhead must
    not influence the ranking (and the jitted output is the contract that
    matters — the hsigmoid requant lesson).

    `default` names the route today's heuristics would pick; a challenger
    replaces it only by beating it by more than `margin` (isolated per-op
    timings flatter routes that XLA cannot fuse across op boundaries in a
    real stage trace, and wall clocks are noisy — within the margin, the
    proven in-context default is the better bet)."""
    timed: List[Tuple[float, Candidate]] = []
    disqualified: List[str] = []
    for c in cands:
        fn = jax.jit(c.fn)
        t0 = tracer.now() if tracer else 0.0
        measured = None
        try:
            out = np.asarray(jax.block_until_ready(fn(x)))
        except Exception:  # noqa: BLE001 — a route that cannot run loses
            out = None
        if (out is None or out.shape != ref.shape
                or not np.array_equal(out, ref)):
            disqualified.append(c.label)
        else:
            measured = float(measure(fn, x, c))
            timed.append((measured, c))
        if tracer:
            # per-candidate provenance span (verify + timing wall on the
            # tracer's clock): who competed, how fast, who was disqualified
            tracer.complete(
                f"tune:{span_key or 'select'}", t0, tracer.now(),
                cat="tune", tid=OT.TID_TUNE,
                args={"candidate": c.label,
                      "us": None if measured is None else measured * 1e6,
                      "disqualified": measured is None})
    if not timed:
        return None
    score_of = scorer if scorer is not None else (lambda t, c: t)
    scored = [(score_of(t, c), t, c) for t, c in timed]
    scored.sort(key=lambda stc: (stc[0], stc[2].label))
    us_ref = next((t * 1e6 for _, t, c in scored if c.route == INT_REF), None)
    best_s, best_t, best = scored[0]
    if default is not None and best.route != default:
        default_scored = [stc for stc in scored if stc[2].route == default]
        if default_scored and best_s > default_scored[0][0] * (1.0 - margin):
            best_s, best_t, best = default_scored[0]
    return RouteChoice.make(
        best.route, best.params, us=best_t * 1e6, us_ref=us_ref,
        n_candidates=len(cands), disqualified=tuple(disqualified))


def tune_qnet(
    qnet: QNet,
    plan: Optional[CC.CUPlan] = None,
    *,
    batch: int = 8,
    input_bits: int = 8,
    seed: int = 0,
    repeats: int = 3,
    measure=None,
    candidates_fn=None,
    margin: float = 0.1,
    include_pallas: bool = True,
    interpret: Optional[bool] = None,
    backend: Optional[str] = None,
    verify_end_to_end: bool = True,
    verbose: bool = False,
    tracer: Optional[OT.Tracer] = None,
    objective: str = "latency",
    power: Optional[PowerModel] = None,
) -> TunedPlan:
    """Tune every op (and fusable IRB block) of `qnet`; return a TunedPlan.

    Walks the network with the *reference* interpreter so each candidate is
    verified and timed on the true intermediate activations of its layer.
    `measure(fn, x, candidate) -> seconds` and `candidates_fn(prepared_op)
    -> [Candidate]` are injectable (deterministic fakes in tests).
    `margin` is the selection hysteresis: a challenger route replaces the
    heuristic default only by beating it by more than this fraction.
    `objective` ranks candidates by `"latency"` (measured seconds) or
    `"edp"` (energy-delay product via `repro.energy.edp_score`, using
    `power` — default: the device's calibrated/fallback curve); the
    bit-exactness gate and the hysteresis semantics are identical under
    both.
    `verify_end_to_end` re-runs the whole net through the resolved plan and
    raises on any logit drift — the tuner never returns a plan it has not
    proven bit-exact.
    `tracer` (see `repro.obs.trace`) records one span per candidate
    verify+time on the `autotune` track plus a winner instant per cache
    entry — exportable provenance for every selection in the plan.
    """
    if isinstance(qnet, cu.PreparedQNet):
        qnet = qnet.qnet
    backend = backend or jax.default_backend()
    if objective not in ("latency", "edp"):
        raise ValueError(f"unknown objective {objective!r} "
                         f"(want 'latency' or 'edp')")
    if objective == "edp" and power is None:
        power = default_power_model(backend)
    tracer = tracer if tracer is not None else OT.NULL
    if tracer:
        tracer.name_track(OT.TID_TUNE, "autotune")
    plan = plan if plan is not None else CC.compile_net(qnet.spec)
    pq = cu.prepare_qnet(qnet, input_bits=input_bits)
    measure = measure or wall_measure(repeats)
    if candidates_fn is None:
        def candidates_fn(pop):
            return op_candidates(pop, interpret=interpret,
                                 include_pallas=include_pallas)
    in_hw_by_op = {op.name: in_hw
                   for _, _, op, in_hw in plan.op_descriptors()}
    block_in_hw: Dict[str, Optional[int]] = {}
    for _, block, op, in_hw in plan.op_descriptors():
        block_in_hw.setdefault(block.name, in_hw)

    spec = qnet.spec
    rank = spec.spatial_rank

    def op_scorer(op: G.OpSpec, in_hw: Optional[int]):
        """EDP scorer for one op's candidates (None under latency). Every
        candidate of one op moves the same bytes, so here EDP is monotone
        in time — the objective's real leverage is block-level."""
        if objective != "edp":
            return None
        nbytes = EM.op_bytes_moved(op, in_hw, rank)
        return lambda t, c: EM.edp_score(t, nbytes, power)

    def block_scorer(block: G.BlockSpec, in_hw: Optional[int]):
        """EDP scorer for the per_op-vs-fused block race: the per-op
        composite pays DDR traffic for every intermediate activation,
        the fused kernel only the block's input/output + weights — the
        byte gap that lets EDP prefer a slightly slower fused route."""
        if objective != "edp":
            return None
        per_op_b, hw = 0, in_hw
        for op in block.ops:
            per_op_b += EM.op_bytes_moved(op, hw, rank)
            if hw is not None and op.kind != G.DENSE:
                hw = -(-hw // op.stride)
        w_bytes = sum(op.n_params(with_bias=False) + 4 * op.out_ch
                      for op in block.ops)
        first, last = block.ops[0], block.ops[-1]
        if in_hw is None or hw is None:
            n_in, n_out = first.in_ch, last.out_ch
        else:
            n_in = (in_hw * in_hw if rank == 2 else in_hw) * first.in_ch
            n_out = (hw * hw if rank == 2 else hw) * last.out_ch
        by_route = {PER_OP: per_op_b, FUSED_IRB: n_in + n_out + w_bytes}
        return lambda t, c: EM.edp_score(
            t, by_route.get(c.route, per_op_b), power)

    x = jax.random.uniform(
        jax.random.PRNGKey(seed),
        (batch, *spec.input_shape()),
        minval=-1, maxval=1)
    in_s, in_z = cu.input_qparams(qnet)
    y = cu.quantize_input(x, in_s, in_z, input_bits)

    entries: Dict[str, RouteChoice] = {}
    s, z = in_s, in_z
    for block in spec.blocks:
        x_block, s_block, z_block = y, s, z
        block_routes: Dict[str, Tuple[str, Dict[str, int]]] = {}
        for op in block.ops:
            qop = qnet.ops[op.name]  # host reference: the ground truth
            pop = pq.ops[op.name]
            ref = np.asarray(jax.block_until_ready(
                cu._run_qop(y, qop, False)))
            cands = candidates_fn(pop)
            if cands:
                key = op_key(op, in_hw_by_op[op.name], backend, rank=rank)
                if key in entries:
                    # an identical-shape op was already measured (repeated
                    # Body blocks): shape keys exist precisely so tuning
                    # cost scales with unique shapes, and re-measuring
                    # would let wall-clock noise flip the recorded winner
                    choice = entries[key]
                else:
                    choice = _select(cands, y, ref, measure,
                                     default=default_route(pop, backend,
                                                           rank=rank),
                                     margin=margin, tracer=tracer,
                                     span_key=key,
                                     scorer=op_scorer(
                                         op, in_hw_by_op[op.name]))
                    if choice is not None and tracer:
                        tracer.instant(
                            "tune_winner", tracer.now(), cat="tune",
                            tid=OT.TID_TUNE,
                            args={"key": key, "route": choice.route,
                                  "params": dict(choice.params),
                                  "us": choice.us})
                if choice is not None:
                    entries[key] = choice
                    block_routes[op.name] = (choice.route,
                                             choice.params_dict)
                    if verbose:
                        print(f"[tune] {key} -> {choice.route}"
                              f"{dict(choice.params) or ''} "
                              f"{choice.us:.1f}us", file=sys.stderr)
            y = jnp.asarray(ref)
            s, z = qop.out_scale, qop.out_zp
            if block.se is not None and block.se_after == op.name:
                # SE branch runs the reference path (not tuned) — mirror
                # cu.run_block exactly so downstream activations are true
                sq = qnet.ops[block.se.squeeze.name]
                ex = qnet.ops[block.se.excite.name]
                sp_axes = tuple(range(1, y.ndim - 1))
                pooled = jnp.round(jnp.mean(
                    y.astype(jnp.float32), axis=sp_axes)).astype(jnp.int32)
                gate_q = cu._run_qop(cu._run_qop(pooled, sq, False), ex, False)
                gate_b = gate_q.reshape(
                    gate_q.shape[0], *([1] * len(sp_axes)), gate_q.shape[-1])
                y = jnp.round(
                    y.astype(jnp.float32)
                    * gate_b.astype(jnp.float32)
                    * ex.out_scale
                ).astype(jnp.int32)
        if block.residual:
            y_s, y_z = qnet.res_q[block.name]
            qmax = 2 ** block.ops[-1].act_bits - 1
            y = cu._residual_add(
                x_block, s_block, z_block, y, s, z, y_s, y_z, qmax)
            s, z = y_s, y_z
        # block-level: race the fused-IRB kernel against the composite of
        # the per-op winners (both verified against the reference output)
        if K.fusable_irb(block):
            bkey = irb_key(block, block_in_hw[block.name], backend)
            if bkey in entries:
                continue  # identical-shape block already raced
            ref_block = np.asarray(y)
            pq_routed = dataclasses.replace(pq, routes=block_routes)

            def per_op_fn(xb, _b=block, _s=s_block, _z=z_block,
                          _q=pq_routed):
                return cu.run_block(xb, _b, _q, _s, _z, False,
                                    interpret=interpret)[0]

            def fused_fn(xb, _b=block, _s=s_block, _z=z_block):
                return K.run_irb_block(xb, _b, pq, _s, _z,
                                       interpret=interpret)[0]

            choice = _select(
                [Candidate(PER_OP, {}, per_op_fn),
                 Candidate(FUSED_IRB, {}, fused_fn)],
                x_block, ref_block, measure,
                default=FUSED_IRB if backend == "tpu" else PER_OP,
                margin=margin, tracer=tracer, span_key=bkey,
                scorer=block_scorer(block, block_in_hw[block.name]))
            if choice is not None:
                entries[bkey] = choice
                if tracer:
                    tracer.instant(
                        "tune_winner", tracer.now(), cat="tune",
                        tid=OT.TID_TUNE,
                        args={"key": bkey, "route": choice.route,
                              "params": dict(choice.params),
                              "us": choice.us})
        if block.avgpool:
            y = jnp.round(jnp.mean(
                y.astype(jnp.float32),
                axis=tuple(range(1, y.ndim - 1)))).astype(jnp.int32)

    tuned = TunedPlan(
        backend=backend,
        nets=(spec.name,),
        tuned_batch=batch,
        entries=entries,
        meta={"jax": jax.__version__, "input_hw": spec.input_hw,
              "input_bits": input_bits, "seed": seed,
              "fixed_point": False, "objective": objective,
              **({"power": power.as_dict()} if objective == "edp" else {})},
    )

    if verify_end_to_end:
        ref_logits = np.asarray(cu.run_qnet(qnet, x, input_bits=input_bits))
        pq_tuned = cu.prepare_qnet(qnet, input_bits=input_bits, tuned=tuned)
        got = np.asarray(cu.run_qnet(pq_tuned, x, input_bits=input_bits))
        if not np.array_equal(got, ref_logits):
            raise RuntimeError(
                "tuned plan drifted from run_qnet on the monolithic route — "
                "refusing to emit it")
        # the stage-executor route additionally exercises fused-IRB choices
        from repro.serve.vision.stages import compile_stages
        ys = x
        for stage in compile_stages(qnet, plan, tuned=tuned):
            ys = stage(ys)
        if not np.array_equal(np.asarray(ys), ref_logits):
            raise RuntimeError(
                "tuned plan drifted from run_qnet on the stage-executor "
                "route — refusing to emit it")
    return tuned


__all__ = [
    "Candidate",
    "PW_TILE_SWEEP",
    "DW_BLOCK_H_SWEEP",
    "default_route",
    "wall_measure",
    "op_candidates",
    "tune_qnet",
]
