"""Tuning cache: measured per-op route selections as a committed artifact.

A `TunedPlan` is the output of the route autotuner (`repro.tune.autotune`):
for every operator in a `CUPlan` — keyed by op kind, input shape, act bits
and backend, NOT by op name — it records which bit-exact route won the
measurement (reference integer ops, the exactness-gated f32 formulations,
the Pallas pointwise/depthwise kernels at a specific tile size, or the
fused-IRB kernel at the block level) and the timings that justified the
choice.

Shape-based keys make the cache a *portable* artifact: two nets sharing an
op shape resolve to the same entry, and an op with no entry simply falls
back to the default (heuristic) route — a cache can be partial, stale, or
empty without ever being wrong. Backend is part of the key, so a CPU cache
consulted on TPU resolves nothing and the TPU defaults apply.

The JSON files live under `experiments/tuned/` and are committed, so CI and
the benchmarks exercise the tuned path deterministically instead of
re-measuring on every run.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional, Set, Tuple

from repro.core import compiler as CC
from repro.core import graph as G

# v2: `irb_key` carries all three act bit-widths of the fused block
# (expand/dw/project) instead of collapsing them into the project op's —
# a heterogeneous-bit block no longer aliases a uniform-bit block. Any
# v1 cache must be regenerated (`python -m repro.tune --golden --bench`).
CACHE_VERSION = 2

# route identifiers understood by the routed executor (core.cu._run_qop)
INT_REF = "int_ref"  # reference XLA integer ops (conv/dot_general, s32)
INT_F32 = "int_f32"  # exactness-gated f32 formulation (2^24 bound)
DW_SHIFTS = "dw_shifts"  # K x K unrolled shifted multiplies (depthwise)
PALLAS_PW = "pallas_pw"  # pointwise-CU Pallas kernel (tile params)
PALLAS_DW = "pallas_dw"  # row-tiled depthwise Pallas kernel (block_h)
FUSED_IRB = "fused_irb"  # whole-block fused Body-CU kernel (block entry)
PER_OP = "per_op"  # block entry: keep the per-op selections


def op_key(op: G.OpSpec, in_hw: Optional[int], backend: str,
           rank: int = 2) -> str:
    """Cache key for one operator: kind + full shape + act bits + backend.

    `in_hw` is the op's input spatial size (0 once collapsed), which
    together with (in_ch, out_ch, kernel, stride) pins the exact workload
    the timing was measured on. `rank` selects the spatial-slot spelling:
    2-D entries say `hw{n}` (side length), 1-D entries say `t{n}` (frame
    count) — so a temporal op never resolves a timing measured on a 2-D
    op that happens to share the numbers (PW/DENSE kinds appear in both
    ranks, and a [B,T,C] pointwise is a very different workload from the
    [B,H,W,C] one at H=W=T)."""
    sp = 0 if in_hw is None else int(in_hw)
    slot = f"t{sp}" if rank == 1 else f"hw{sp}"
    return (f"{op.kind}:{slot}:cin{op.in_ch}:cout{op.out_ch}"
            f":k{op.kernel}:s{op.stride}:a{op.act_bits}:{backend}")


def irb_key(block: G.BlockSpec, in_hw: Optional[int], backend: str) -> str:
    """Cache key for a whole fusable IRB (expand -> dw -> project) block.

    All three stage act bit-widths are in the key: the fused kernel's
    timing (and its eligibility — `fusable_irb` requires one width) is a
    function of every stage's BW, so a mixed-bit block must never resolve
    a route measured on a uniform-bit block that happens to share the
    project op's width."""
    e, d, p = block.ops
    hw = 0 if in_hw is None else int(in_hw)
    return (f"irb:hw{hw}:c{e.in_ch}x{e.out_ch}x{p.out_ch}"
            f":k{d.kernel}:s{d.stride}"
            f":a{e.act_bits}x{d.act_bits}x{p.act_bits}"
            f":r{int(block.residual)}:{backend}")


@dataclasses.dataclass(frozen=True)
class RouteChoice:
    """One measured selection: the winning route and the evidence."""

    route: str
    params: Tuple[Tuple[str, int], ...] = ()  # sorted (name, value) pairs
    us: float = 0.0  # best measured wall time of the winner
    us_ref: Optional[float] = None  # the reference route's time, if timed
    n_candidates: int = 0
    disqualified: Tuple[str, ...] = ()  # candidates that drifted vs reference

    @property
    def params_dict(self) -> Dict[str, int]:
        return dict(self.params)

    @staticmethod
    def make(route: str, params: Optional[Dict[str, int]] = None,
             **kw) -> "RouteChoice":
        items = tuple(sorted((params or {}).items()))
        return RouteChoice(route=route, params=items, **kw)

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["params"] = dict(self.params)
        d["disqualified"] = list(self.disqualified)
        return d

    @staticmethod
    def from_json(d: Dict) -> "RouteChoice":
        return RouteChoice(
            route=d["route"],
            params=tuple(sorted(
                (str(k), int(v)) for k, v in (d.get("params") or {}).items())),
            us=float(d.get("us", 0.0)),
            us_ref=(None if d.get("us_ref") is None else float(d["us_ref"])),
            n_candidates=int(d.get("n_candidates", 0)),
            disqualified=tuple(d.get("disqualified", ())),
        )


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """Measured per-op (and per-fusable-block) route selections.

    `entries` maps `op_key`/`irb_key` strings to the winning `RouteChoice`.
    `resolve` projects the shape-keyed cache onto a concrete net.
    """

    backend: str
    nets: Tuple[str, ...]
    tuned_batch: int
    entries: Dict[str, RouteChoice]
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.entries)

    # ------------------------------------------------------------------
    # projection onto a concrete net
    # ------------------------------------------------------------------

    def resolve(
        self, qnet, plan: Optional[CC.CUPlan] = None,
        backend: Optional[str] = None,
    ) -> Tuple[Dict[str, Tuple[str, Dict[str, int]]], Set[str]]:
        """Project the cache onto `qnet` (anything with a `.spec` NetSpec).

        Returns (op_routes, fused_blocks):
          * op_routes: op name -> (route, params) for every op with a
            matching cache entry on this backend,
          * fused_blocks: names of fusable IRB blocks whose block-level
            entry selected the fused kernel.
        Ops/blocks without entries are absent — callers fall back to the
        default route. Entries recorded on a different backend never match
        (the backend is part of the key)."""
        import jax

        from repro.kernels.ops import fusable_irb

        spec = qnet.spec if hasattr(qnet, "spec") else qnet
        if plan is None:
            plan = CC.compile_net(spec)
        backend = backend or jax.default_backend()
        rank = spec.spatial_rank
        op_routes: Dict[str, Tuple[str, Dict[str, int]]] = {}
        block_in_hw: Dict[str, Optional[int]] = {}
        for _, block, op, in_hw in plan.op_descriptors():
            block_in_hw.setdefault(block.name, in_hw)
            entry = self.entries.get(op_key(op, in_hw, backend, rank=rank))
            if entry is not None:
                op_routes[op.name] = (entry.route, entry.params_dict)
        fused: Set[str] = set()
        for block in spec.blocks:
            if not fusable_irb(block):
                continue
            entry = self.entries.get(
                irb_key(block, block_in_hw.get(block.name), backend))
            if entry is not None and entry.route == FUSED_IRB:
                fused.add(block.name)
        return op_routes, fused

    def resolve_with_defaults(
        self, qnet, plan: Optional[CC.CUPlan] = None,
        backend: Optional[str] = None, *,
        op_kernels: bool = False, body_fast_path: bool = False,
    ) -> Tuple[Dict[str, Tuple[str, Dict[str, int]]], Set[str]]:
        """`resolve`, then fill cache MISSES with the heuristic default.

        This is the 'ops with no cache entry keep today's defaults'
        contract for stage compilation: when the heuristics would run the
        per-op Pallas kernels (`op_kernels` resolved on, i.e. TPU),
        uncovered DW/PW/DENSE ops get the default-tile Pallas route
        instead of silently degrading to the XLA reference formulation;
        when `body_fast_path` is on, fusable IRB blocks with no block
        entry at all keep the fused kernel (a block whose entry says
        `per_op` was measured and stays per-op). Off-TPU the defaults are
        exactly what `cu.run_block` does for an unrouted op, so no fill
        is needed."""
        import jax

        from repro.kernels.ops import fusable_irb

        spec = qnet.spec if hasattr(qnet, "spec") else qnet
        if plan is None:
            plan = CC.compile_net(spec)
        backend = backend or jax.default_backend()
        op_routes, fused = self.resolve(spec, plan, backend=backend)
        block_in_hw: Dict[str, Optional[int]] = {}
        rank1 = spec.spatial_rank == 1
        for _, block, op, in_hw in plan.op_descriptors():
            block_in_hw.setdefault(block.name, in_hw)
            if not op_kernels or op.name in op_routes or rank1:
                # the Pallas kernels are 2-D ([B,H,W,C]) — a 1-D net's
                # uncovered ops keep the XLA/shifts defaults
                continue
            if op.act == G.HSIGMOID:
                continue  # the gate stays on the reference path
            if op.kind == G.DW:
                op_routes[op.name] = (PALLAS_DW, {})
            elif op.kind in (G.PW, G.DENSE):
                op_routes[op.name] = (PALLAS_PW, {})
        if body_fast_path:
            for block in spec.blocks:
                if not fusable_irb(block) or block.name in fused:
                    continue
                if irb_key(block, block_in_hw.get(block.name),
                           backend) not in self.entries:
                    fused.add(block.name)
        return op_routes, fused

    def coverage(self, qnet, plan: Optional[CC.CUPlan] = None,
                 backend: Optional[str] = None) -> float:
        """Fraction of this net's tunable ops with a cache entry."""
        spec = qnet.spec if hasattr(qnet, "spec") else qnet
        if plan is None:
            plan = CC.compile_net(spec)
        op_routes, _ = self.resolve(spec, plan, backend=backend)
        tunable = [op for _, _, op, _ in plan.op_descriptors()
                   if op.act != G.HSIGMOID]
        return len(op_routes) / len(tunable) if tunable else 0.0

    # ------------------------------------------------------------------
    # merge / persist
    # ------------------------------------------------------------------

    def merge(self, other: "TunedPlan") -> "TunedPlan":
        """Union of two caches; on a key collision the faster entry wins."""
        if self.backend != other.backend:
            raise ValueError(
                f"cannot merge caches for different backends: "
                f"{self.backend!r} vs {other.backend!r}")
        entries = dict(self.entries)
        for key, choice in other.entries.items():
            if key not in entries or choice.us < entries[key].us:
                entries[key] = choice
        return TunedPlan(
            backend=self.backend,
            nets=tuple(sorted(set(self.nets) | set(other.nets))),
            tuned_batch=self.tuned_batch,
            entries=entries,
            meta={**self.meta, **other.meta},
        )

    def to_json(self) -> Dict:
        return {
            "version": CACHE_VERSION,
            "backend": self.backend,
            "nets": list(self.nets),
            "tuned_batch": self.tuned_batch,
            "meta": dict(self.meta),
            "entries": {k: self.entries[k].to_json()
                        for k in sorted(self.entries)},
        }

    @staticmethod
    def from_json(d: Dict) -> "TunedPlan":
        version = d.get("version")
        if version != CACHE_VERSION:
            raise ValueError(
                f"tuning cache version {version!r} != {CACHE_VERSION} — "
                f"regenerate with `python -m repro.tune`")
        return TunedPlan(
            backend=d["backend"],
            nets=tuple(d.get("nets", ())),
            tuned_batch=int(d.get("tuned_batch", 0)),
            entries={k: RouteChoice.from_json(v)
                     for k, v in d.get("entries", {}).items()},
            meta=dict(d.get("meta", {})),
        )


def save_tuned(plan: TunedPlan, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(plan.to_json(), f, indent=1, sort_keys=True)
        f.write("\n")


def load_tuned(path: str) -> TunedPlan:
    with open(path) as f:
        return TunedPlan.from_json(json.load(f))


__all__ = [
    "CACHE_VERSION",
    "INT_REF", "INT_F32", "DW_SHIFTS", "PALLAS_PW", "PALLAS_DW",
    "FUSED_IRB", "PER_OP",
    "op_key", "irb_key",
    "RouteChoice", "TunedPlan",
    "save_tuned", "load_tuned",
]
