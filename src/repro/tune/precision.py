"""Per-layer mixed-precision search: heterogeneous act-bit allocation.

The paper's quantization recipe is uniform (one 8 -> 4 activation anneal
for the whole net), but its own CU-heterogeneity argument applies to
precision too: different operators have different accuracy sensitivity
and different latency/energy returns per bit. This module searches
per-block activation bit-width assignments (e.g. {4, 6, 8}) over a
NetSpec and emits a Pareto artifact, scoring every candidate with

  * **latency** from a table assembled out of existing tuned-cache
    entries (`op_key` already carries `a{bits}`, so the autotuner's
    measured route times are reusable verbatim; the few missing keys are
    timed by running the autotuner over the uniform-width variants —
    injectable fake measure in CI),
  * **energy** through `repro.energy.estimate_energy` / `edp_score`
    (the PR-9 model, now act-bit aware), and
  * **accuracy** from a short QAT fine-tune through `train/vision.py`'s
    phase machinery on the held-out evaluation stream (injectable fake
    in CI).

Search shape: the uniform widths anchor the front; mixed candidates come
from a deterministic *savings ladder* — blocks ranked by the measured
latency they give back when dropped from the widest to the narrowest
choice, then the top-k blocks are dropped for a schedule of k values
(plus a mid-width ladder when three choices are given). Deterministic,
budget-bounded, and every number in the artifact is either measured or
derived from measured entries.

Artifacts land as `experiments/precision/{model}_{backend}_pareto.json`
(BENCH_*.json-style, schema `precision-pareto-v1`) and selected
allocations export as ordinary `.qnet` files through `train.vision.export`
— which refuses to write unless all four serving routes prove bit-exact,
mixed bits included.

CLI: `python -m repro.tune --precision` (see also `launch/hillclimb.py
--precision`). Docs: docs/tuning.md, docs/quantization.md.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import compiler as CC
from repro.core import graph as G
from repro.energy import model as EM
from repro.energy.power import PowerModel, default_power_model
from repro.tune import cache as TC

PARETO_SCHEMA = "precision-pareto-v1"
PRECISION_DIR = os.path.join("experiments", "precision")


# ---------------------------------------------------------------------------
# latency table: tuned-cache entries -> per-net microseconds
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetCost:
    """One net's latency as the tuner tables price it."""

    us_per_image: float
    n_tuned: int
    n_ops: int
    missing: Tuple[str, ...]  # op_key strings with no cache entry

    @property
    def fps(self) -> float:
        return 1e6 / self.us_per_image if self.us_per_image > 0 else 0.0

    @property
    def tuned_fraction(self) -> float:
        return self.n_tuned / self.n_ops if self.n_ops else 0.0


class LatencyTable:
    """Latency lookup assembled from a `TunedPlan`'s measured entries.

    Every entry's `us` is the best measured wall time of the winning
    bit-exact route at `tuned_batch`; `op_us` normalizes to per-image.
    Blocks whose block-level entry selected the fused IRB kernel are
    priced by that block timing (that is the route serving would run);
    everything else sums per-op entries. Ops without an entry fall back
    to the analytic pJ/MAC estimate and are reported in `missing` so
    callers can tell measured points from modeled ones."""

    def __init__(self, tuned: TC.TunedPlan, power: PowerModel,
                 backend: Optional[str] = None):
        self.tuned = tuned
        self.power = power
        self.backend = backend or tuned.backend
        self.per_image = max(tuned.tuned_batch, 1)

    def op_us(self, op: G.OpSpec, in_hw: Optional[int],
              rank: int = 2) -> Optional[float]:
        entry = self.tuned.entries.get(
            TC.op_key(op, in_hw, self.backend, rank=rank))
        if entry is None or entry.us <= 0:
            return None
        return entry.us / self.per_image

    def _analytic_us(self, op: G.OpSpec, in_hw: Optional[int],
                     rank: int) -> float:
        compute_j = (EM.op_macs(op, in_hw, rank)
                     * EM.op_pj_per_mac(op) * 1e-12)
        return compute_j / self.power.busy_w * 1e6

    def net_cost(self, spec: G.NetSpec,
                 plan: Optional[CC.CUPlan] = None) -> NetCost:
        from repro.kernels.ops import fusable_irb

        plan = plan if plan is not None else CC.compile_net(spec)
        rank = spec.spatial_rank
        block_in_hw: Dict[str, Optional[int]] = {}
        for _, block, _, in_hw in plan.op_descriptors():
            block_in_hw.setdefault(block.name, in_hw)
        fused_us: Dict[str, float] = {}
        for block in spec.blocks:
            if not fusable_irb(block):
                continue
            entry = self.tuned.entries.get(TC.irb_key(
                block, block_in_hw.get(block.name), self.backend))
            if (entry is not None and entry.route == TC.FUSED_IRB
                    and entry.us > 0):
                fused_us[block.name] = entry.us / self.per_image
        total = 0.0
        n_tuned = n_ops = 0
        missing: List[str] = []
        priced_blocks = set()
        for _, block, op, in_hw in plan.op_descriptors():
            if block.name in fused_us:
                if block.name not in priced_blocks:
                    priced_blocks.add(block.name)
                    total += fused_us[block.name]
                n_ops += 1
                n_tuned += 1
                continue
            n_ops += 1
            us = self.op_us(op, in_hw, rank)
            if us is None:
                if op.act != G.HSIGMOID:  # gate ops are never tuned
                    missing.append(TC.op_key(op, in_hw, self.backend,
                                             rank=rank))
                total += self._analytic_us(op, in_hw, rank)
            else:
                n_tuned += 1
                total += us
        return NetCost(us_per_image=total, n_tuned=n_tuned, n_ops=n_ops,
                       missing=tuple(dict.fromkeys(missing)))


def ensure_coverage(
    table: LatencyTable,
    nets: Sequence[G.NetSpec],
    *,
    measure=None,
    batch: int = 8,
    repeats: int = 1,
    seed: int = 0,
    log: Optional[Callable[[str], None]] = None,
) -> LatencyTable:
    """Time the nets whose op keys the table is missing; return the
    merged table.

    The search's candidate space only ever needs the keys of the uniform
    width variants (a per-block allocation's ops each carry one of the
    searched widths at an unchanged shape), so warming those nets makes
    every mixed candidate fully measured. `measure` is the autotuner's
    injectable timer — CI smoke passes a deterministic fake."""
    from repro.models.layers import make_calibrated_qnet
    from repro.tune.autotune import tune_qnet

    say = log or (lambda s: None)
    tuned = table.tuned
    # fresh timings must normalize like the seed cache's entries, so the
    # tuner runs at the cache's own batch when it has one
    batch = tuned.tuned_batch or batch
    for net in nets:
        probe = LatencyTable(tuned, table.power, table.backend)
        cost = probe.net_cost(net)
        if not cost.missing:
            continue
        say(f"[precision] timing {len(cost.missing)} missing keys "
            f"for {net.name}")
        qnet = make_calibrated_qnet(net, bits=8)
        fresh = tune_qnet(qnet, batch=batch, repeats=repeats, seed=seed,
                          measure=measure, backend=table.backend,
                          include_pallas=table.backend == "tpu")
        tuned = tuned.merge(fresh) if len(tuned.entries) else fresh
    return LatencyTable(tuned, table.power, table.backend)


# ---------------------------------------------------------------------------
# allocations + Pareto machinery
# ---------------------------------------------------------------------------


def block_allocation(net: G.NetSpec,
                     block_bits: Dict[str, int]) -> Dict[str, int]:
    """Expand per-block widths into the per-op map `with_op_act_bits`
    takes (every plain op of a named block gets the block's width —
    keeping fused-IRB eligibility, which requires one width per block)."""
    by_name = {b.name: b for b in net.blocks}
    unknown = sorted(set(block_bits) - set(by_name))
    if unknown:
        raise KeyError(f"unknown block name(s) {unknown!r}")
    return {op.name: int(bits)
            for name, bits in block_bits.items()
            for op in by_name[name].ops}


@dataclasses.dataclass(frozen=True)
class PrecisionPoint:
    """One evaluated allocation: the candidate and all four objectives."""

    name: str
    block_bits: Dict[str, int]  # per-block widths (the search variable)
    alloc: Dict[str, int]  # per-op expansion (what artifacts carry)
    uniform: Optional[int]  # the width when uniform, else None
    accuracy: float
    us_per_image: float
    model_bytes: int
    j_per_image: float
    edp: float
    tuned_fraction: float

    @property
    def fps(self) -> float:
        return 1e6 / self.us_per_image if self.us_per_image > 0 else 0.0

    def as_dict(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "block_bits": dict(self.block_bits),
            "alloc": dict(self.alloc),
            "uniform": self.uniform,
            "accuracy": self.accuracy,
            "us_per_image": self.us_per_image,
            "fps": self.fps,
            "model_bytes": self.model_bytes,
            "j_per_image": self.j_per_image,
            "edp": self.edp,
            "tuned_fraction": self.tuned_fraction,
        }


def dominates(a: PrecisionPoint, b: PrecisionPoint) -> bool:
    """a dominates b: no worse on every objective, strictly better on one
    (accuracy and fps maximize; model bytes and J/image minimize)."""
    ge = (a.accuracy >= b.accuracy and a.fps >= b.fps
          and a.model_bytes <= b.model_bytes
          and a.j_per_image <= b.j_per_image)
    gt = (a.accuracy > b.accuracy or a.fps > b.fps
          or a.model_bytes < b.model_bytes
          or a.j_per_image < b.j_per_image)
    return ge and gt


def pareto_front(points: Sequence[PrecisionPoint]) -> List[PrecisionPoint]:
    return [p for p in points
            if not any(dominates(q, p) for q in points if q is not p)]


# ---------------------------------------------------------------------------
# accuracy term: short QAT fine-tune through train/vision
# ---------------------------------------------------------------------------


class QATFinetuneAccuracy:
    """Held-out accuracy after a short QAT fine-tune at the allocation.

    One shared base run (the config's float + QAT schedule at uniform
    `base_act_bits` activations — the anneal starting point) trains
    once; each candidate then fine-tunes `steps` QAT steps at its own
    (possibly heterogeneous) widths through the SAME
    `make_vision_train_step` machinery the phase schedule uses, and is
    scored by `train.vision.eval_accuracy` on the held-out eval stream.
    Results are memoized by allocation, so re-proposed candidates are
    free. `finetune` also returns the fine-tuned params — the export
    path picks them up so the artifact is the net the score was measured
    on."""

    def __init__(self, cfg, *, steps: int = 10, base_act_bits: int = 8,
                 eval_seed: int = 2, eval_batches: int = 4,
                 log: Optional[Callable[[str], None]] = None):
        self.cfg = dataclasses.replace(cfg, op_act_bits=None)
        self.steps = steps
        self.base_act_bits = base_act_bits
        self.eval_seed = eval_seed
        self.eval_batches = eval_batches
        self.say = log or (lambda s: None)
        self._base = None
        self._memo: Dict[Tuple[Tuple[str, int], ...], float] = {}

    def base_params(self):
        if self._base is None:
            from repro.train import vision as V
            base_cfg = dataclasses.replace(
                self.cfg, act_bits=self.base_act_bits, anneal_from=None,
                calibrate_every=0, ckpt_every=0)
            self.say(f"[precision] base QAT run "
                     f"({base_cfg.total_steps} steps, "
                     f"act{self.base_act_bits})")
            self._base = V.train(base_cfg)
        return self._base.params

    def finetune(self, cfg_variant, net: G.NetSpec):
        """(params, accuracy) after `steps` QAT steps at `net`'s widths."""
        import jax

        from repro.train import optimizer as O
        from repro.train import vision as V
        params = self.base_params()
        if self.steps > 0:
            opt_cfg = O.AdamWConfig(
                lr=cfg_variant.qat_lr, warmup_steps=1,
                total_steps=self.steps,
                weight_decay=cfg_variant.weight_decay)
            step_fn = jax.jit(V.make_vision_train_step(
                net, opt_cfg, qat=True,
                grad_accum=cfg_variant.grad_accum))
            opt_state = O.init_state(params)
            # the data stream continues past the base run's steps, so the
            # fine-tune never re-sees a base batch
            offset = self.cfg.total_steps
            for i in range(self.steps):
                batch = V.train_batch(self.cfg, offset + i)
                params, opt_state, _ = step_fn(params, opt_state, batch)
        acc = V.eval_accuracy(params, net, self.cfg, qat=True,
                              eval_seed=self.eval_seed,
                              eval_batches=self.eval_batches)
        return params, acc

    def __call__(self, cfg_variant, net: G.NetSpec) -> float:
        key = tuple(sorted(G.op_act_bits(net).items()))
        if key not in self._memo:
            _, acc = self.finetune(cfg_variant, net)
            self._memo[key] = acc
            self.say(f"[precision] accuracy({net.name}) = {acc:.3f}")
        return self._memo[key]


def fake_accuracy(cfg_variant, net: G.NetSpec) -> float:
    """Deterministic accuracy stand-in for CI smoke: monotone in the mean
    activation width with a small early-layer sensitivity bonus, so the
    fake front has the right qualitative shape without training."""
    widths = [op.act_bits for b in net.blocks for op in b.ops]
    mean_w = float(np.mean(widths)) if widths else 0.0
    early = float(np.mean(widths[: max(1, len(widths) // 4)]))
    return round(min(1.0, 0.55 + 0.04 * mean_w + 0.01 * early), 4)


def fake_measure(fn, x, candidate=None) -> float:
    """Deterministic timer stand-in for CI smoke: pseudo-seconds derived
    from the workload size and a fixed per-route factor (never runs the
    candidate — the tuner's exactness gate already did)."""
    factors = {TC.INT_REF: 3.0, TC.INT_F32: 2.0, TC.DW_SHIFTS: 2.5,
               TC.PALLAS_PW: 1.5, TC.PALLAS_DW: 1.6, TC.FUSED_IRB: 1.2,
               TC.PER_OP: 2.8}
    route = getattr(candidate, "route", None)
    size = float(np.prod(np.asarray(x).shape))
    return size * factors.get(route, 2.0) * 1e-9


# ---------------------------------------------------------------------------
# the search driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PrecisionResult:
    """Everything one search run produced, artifact-shaped."""

    model: str
    backend: str
    choices: Tuple[int, ...]
    build: Dict[str, object]  # the base config's build record (no alloc)
    points: Tuple[PrecisionPoint, ...]
    front: Tuple[str, ...]  # names of non-dominated points
    tuned_batch: int
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def point(self, name: str) -> PrecisionPoint:
        for p in self.points:
            if p.name == name:
                return p
        raise KeyError(name)

    def front_points(self) -> List[PrecisionPoint]:
        return [self.point(n) for n in self.front]

    def as_dict(self) -> Dict[str, object]:
        return {
            "schema": PARETO_SCHEMA,
            "model": self.model,
            "backend": self.backend,
            "choices": list(self.choices),
            "build": dict(self.build),
            "tuned_batch": self.tuned_batch,
            "meta": dict(self.meta),
            "points": [p.as_dict() for p in self.points],
            "pareto": list(self.front),
        }


def _evaluate(name: str, cfg, block_bits: Dict[str, int],
              uniform: Optional[int], table: LatencyTable,
              accuracy_fn, power: PowerModel) -> PrecisionPoint:
    from repro.train import vision as V
    base_net = V.build_net(dataclasses.replace(cfg, op_act_bits=None))
    alloc = block_allocation(base_net, block_bits)
    if uniform is not None:
        cfg_v = dataclasses.replace(cfg, act_bits=uniform, op_act_bits=None)
    else:
        cfg_v = dataclasses.replace(cfg,
                                    op_act_bits=tuple(sorted(alloc.items())))
    net = V.build_net(cfg_v)
    cost = table.net_cost(net)
    report = EM.estimate_energy(net, tuned=table.tuned, power=power,
                                backend=table.backend)
    j = report.j_per_image
    acc = float(accuracy_fn(cfg_v, net))
    return PrecisionPoint(
        name=name,
        block_bits=dict(block_bits),
        alloc=alloc,
        uniform=uniform,
        accuracy=acc,
        us_per_image=cost.us_per_image,
        model_bytes=(net.model_bits(with_bias=True) + 7) // 8,
        j_per_image=j,
        edp=EM.edp_score(cost.us_per_image * 1e-6,
                         sum(o.bytes_moved for o in report.ops), power),
        tuned_fraction=cost.tuned_fraction,
    )


def _block_savings(net: G.NetSpec, table: LatencyTable, lo: int,
                   hi: int) -> List[Tuple[str, float]]:
    """Per-block latency give-back when dropped hi -> lo, descending."""
    hi_net = G.with_act_bits(net, hi)
    lo_net = G.with_act_bits(net, lo)
    plan = CC.compile_net(hi_net)
    rank = hi_net.spatial_rank
    per_block: Dict[str, float] = {}
    by_name_lo = {b.name: b for b in lo_net.blocks}
    for _, block, op, in_hw in plan.op_descriptors():
        op_lo = next(o for o in by_name_lo[block.name].ops
                     if o.name == op.name)
        us_hi = table.op_us(op, in_hw, rank)
        us_lo = table.op_us(op_lo, in_hw, rank)
        if us_hi is None or us_lo is None:
            us_hi = table._analytic_us(op, in_hw, rank)
            us_lo = table._analytic_us(op_lo, in_hw, rank)
        per_block[block.name] = (per_block.get(block.name, 0.0)
                                 + (us_hi - us_lo))
    return sorted(per_block.items(), key=lambda kv: (-kv[1], kv[0]))


def _ladder_schedule(n: int, budget: int) -> List[int]:
    """k values for the savings ladder: geometric coverage of 1..n."""
    ks: List[int] = []
    k = 1
    while k < n and len(ks) < max(budget - 1, 1):
        ks.append(k)
        k *= 2
    if n > 0 and (not ks or ks[-1] != n):
        ks.append(n)
    return ks[:budget]


def search_precision(
    cfg,
    *,
    choices: Sequence[int] = (4, 6, 8),
    tuned: Optional[TC.TunedPlan] = None,
    power: Optional[PowerModel] = None,
    backend: Optional[str] = None,
    accuracy_fn=None,
    measure=None,
    ladder_budget: int = 5,
    tune_batch: int = 8,
    tune_repeats: int = 1,
    finetune_steps: int = 10,
    log: Optional[Callable[[str], None]] = None,
) -> PrecisionResult:
    """Search per-block act-bit allocations for `cfg`'s model.

    `tuned` seeds the latency table (committed caches); missing keys are
    timed through the autotuner with `measure` (wall clock by default,
    deterministic fake in CI). `accuracy_fn(cfg_variant, net) -> float`
    defaults to the QAT fine-tune scorer. Returns every evaluated point
    plus the non-dominated front."""
    import dataclasses as DC

    import jax

    from repro.train import vision as V

    say = log or (lambda s: None)
    choices = tuple(sorted(int(c) for c in choices))
    if len(choices) < 2:
        raise ValueError("need at least two width choices to search over")
    backend = backend or (tuned.backend if tuned is not None
                          else jax.default_backend())
    power = power if power is not None else default_power_model(backend)
    if tuned is None:
        tuned = TC.TunedPlan(backend=backend, nets=(), tuned_batch=tune_batch,
                             entries={})
    if accuracy_fn is None:
        accuracy_fn = QATFinetuneAccuracy(cfg, steps=finetune_steps,
                                          log=say)

    base_cfg = DC.replace(cfg, op_act_bits=None)
    base_net = V.build_net(base_cfg)
    uniform_nets = [G.with_act_bits(base_net, w) for w in choices]
    table = LatencyTable(tuned, power, backend)
    table = ensure_coverage(table, uniform_nets, measure=measure,
                            batch=tune_batch, repeats=tune_repeats, log=say)

    block_names = [b.name for b in base_net.blocks]
    lo, hi = choices[0], choices[-1]
    points: List[PrecisionPoint] = []

    for w in choices:
        bits = {name: w for name in block_names}
        points.append(_evaluate(f"uniform{w}", cfg, bits, w, table,
                                accuracy_fn, power))
        say(f"[precision] uniform{w}: {points[-1].us_per_image:.1f} us, "
            f"acc {points[-1].accuracy:.3f}")

    savings = _block_savings(base_net, table, lo, hi)
    order = [name for name, _ in savings]
    seen = {tuple(sorted(p.block_bits.items())) for p in points}

    def ladder(width_low: int, tag: str):
        for k in _ladder_schedule(len(order), ladder_budget):
            bits = {name: hi for name in block_names}
            for name in order[:k]:
                bits[name] = width_low
            sig = tuple(sorted(bits.items()))
            if sig in seen:
                continue
            seen.add(sig)
            points.append(_evaluate(f"{tag}_top{k}", cfg, bits, None,
                                    table, accuracy_fn, power))
            say(f"[precision] {tag}_top{k}: "
                f"{points[-1].us_per_image:.1f} us, "
                f"acc {points[-1].accuracy:.3f}")

    ladder(lo, f"mix{lo}of{hi}")
    for w in choices[1:-1]:
        ladder(w, f"mix{w}of{hi}")

    front = [p.name for p in pareto_front(points)]
    return PrecisionResult(
        model=cfg.model,
        backend=backend,
        choices=choices,
        build=V.build_record(base_cfg),
        points=tuple(points),
        front=tuple(front),
        tuned_batch=table.tuned.tuned_batch,
        meta={
            "n_blocks": len(block_names),
            "savings_order": order,
            "ladder_budget": ladder_budget,
            "tuned_entries": len(table.tuned.entries),
            "objectives": ["accuracy", "fps", "model_bytes", "j_per_image"],
        },
    )


# ---------------------------------------------------------------------------
# artifact I/O + schema gate
# ---------------------------------------------------------------------------


def pareto_path(model: str, backend: str,
                out_dir: str = PRECISION_DIR) -> str:
    return os.path.join(out_dir, f"{model}_{backend}_pareto.json")


def write_pareto(result: PrecisionResult, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(result.as_dict(), f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def check_pareto_artifact(path: str, *, min_points: int = 3,
                          require_domination: bool = False) -> Dict:
    """Schema-check a committed Pareto artifact; raises ValueError.

    Verifies the schema tag, the per-point field set, that every width
    drawn is one of the declared choices, that the recorded front is
    exactly the non-dominated set of the recorded points, and (when
    `require_domination`) that some mixed allocation strictly beats a
    uniform point on the latency axis at no worse model bytes and
    equal-or-better accuracy — the claim the artifact headline makes."""
    with open(path) as f:
        d = json.load(f)
    if d.get("schema") != PARETO_SCHEMA:
        raise ValueError(f"{path}: schema {d.get('schema')!r} != "
                         f"{PARETO_SCHEMA!r}")
    choices = set(d.get("choices", ()))
    if not choices:
        raise ValueError(f"{path}: empty choices")
    raw = d.get("points", [])
    need = {"name", "block_bits", "alloc", "uniform", "accuracy",
            "us_per_image", "fps", "model_bytes", "j_per_image", "edp",
            "tuned_fraction"}
    points: List[PrecisionPoint] = []
    for rp in raw:
        missing = need - set(rp)
        if missing:
            raise ValueError(
                f"{path}: point {rp.get('name')!r} missing {sorted(missing)}")
        bad = {b for b in rp["alloc"].values() if b not in choices}
        if bad:
            raise ValueError(f"{path}: point {rp['name']!r} uses widths "
                             f"{sorted(bad)} outside choices")
        points.append(PrecisionPoint(
            name=rp["name"], block_bits=rp["block_bits"], alloc=rp["alloc"],
            uniform=rp["uniform"], accuracy=float(rp["accuracy"]),
            us_per_image=float(rp["us_per_image"]),
            model_bytes=int(rp["model_bytes"]),
            j_per_image=float(rp["j_per_image"]), edp=float(rp["edp"]),
            tuned_fraction=float(rp["tuned_fraction"])))
    front = [p.name for p in pareto_front(points)]
    if sorted(front) != sorted(d.get("pareto", [])):
        raise ValueError(f"{path}: recorded front {sorted(d.get('pareto'))} "
                         f"!= recomputed {sorted(front)}")
    if len(front) < min_points:
        raise ValueError(f"{path}: front has {len(front)} points "
                         f"(need >= {min_points})")
    if require_domination and not find_domination(points):
        raise ValueError(f"{path}: no mixed point dominates a uniform one "
                         f"on (latency, model_bytes) at >= accuracy")
    return d


def find_domination(
    points: Sequence[PrecisionPoint],
) -> Optional[Tuple[str, str]]:
    """(mixed, uniform) names where the mixed allocation strictly beats
    the uniform one on latency at no worse model bytes and equal-or-
    better accuracy — the acceptance claim, checked, not asserted."""
    for m in points:
        if m.uniform is not None:
            continue
        for u in points:
            if u.uniform is None:
                continue
            if (m.us_per_image < u.us_per_image
                    and m.model_bytes <= u.model_bytes
                    and m.accuracy >= u.accuracy):
                return m.name, u.name
    return None


# ---------------------------------------------------------------------------
# export: one searched allocation -> a conformant .qnet
# ---------------------------------------------------------------------------


def export_point(
    cfg,
    point: PrecisionPoint,
    path: str,
    *,
    tuned: Optional[TC.TunedPlan] = None,
    accuracy_impl: Optional[QATFinetuneAccuracy] = None,
    finetune_steps: int = 10,
) -> Dict:
    """Export one searched allocation as a `.qnet` through the standard
    training export path — `train.vision.export` proves reference /
    prepared / stage-executor / engine routes bit-exact before writing,
    exactly as for uniform artifacts, and the build record carries the
    `op_act_bits` allocation so the file self-describes."""
    import dataclasses as DC

    from repro.train import vision as V

    if point.uniform is not None:
        cfg_v = DC.replace(cfg, act_bits=point.uniform, op_act_bits=None)
    else:
        cfg_v = DC.replace(cfg,
                           op_act_bits=tuple(sorted(point.alloc.items())))
    net = V.build_net(cfg_v)
    impl = accuracy_impl or QATFinetuneAccuracy(cfg, steps=finetune_steps)
    params, acc = impl.finetune(cfg_v, net)
    _, report = V.export(
        params, net, cfg_v, path=path, verify=True, tuned=tuned,
        provenance={"precision_point": point.name,
                    "precision_accuracy": acc})
    report["accuracy"] = acc
    return report


__all__ = [
    "PARETO_SCHEMA",
    "PRECISION_DIR",
    "LatencyTable",
    "NetCost",
    "PrecisionPoint",
    "PrecisionResult",
    "QATFinetuneAccuracy",
    "block_allocation",
    "check_pareto_artifact",
    "dominates",
    "ensure_coverage",
    "export_point",
    "fake_accuracy",
    "fake_measure",
    "find_domination",
    "pareto_front",
    "pareto_path",
    "search_precision",
    "write_pareto",
]
