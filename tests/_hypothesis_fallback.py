"""Deterministic stand-in for `hypothesis` on machines without it.

Implements only the surface the property tests here use — `given`,
`settings`, `st.integers`, `st.floats`, `st.sampled_from`. Each @given test
runs `max_examples` examples drawn from a fixed-seed PRNG: the properties
still execute (without shrinking or adversarial search), so the suite stays
meaningful in the dependency-free container. Install the real `hypothesis`
(see pyproject.toml [test] extra) to get full example search back.
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float, **_: object) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))


st = strategies


def settings(max_examples: int = 20, **_: object):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats: _Strategy):
    def deco(fn):
        # No functools.wraps: pytest must see a zero-arg function, not the
        # strategy parameters (it would treat them as fixtures).
        def run():
            # @settings sits above @given, so it annotates `run` — read the
            # attribute at call time, not decoration time.
            n = getattr(run, "_max_examples", 20)
            rng = random.Random(0)
            for _ in range(n):
                fn(**{k: s.draw(rng) for k, s in strats.items()})

        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        return run

    return deco


__all__ = ["given", "settings", "st", "strategies"]
