"""Regenerate the frozen golden conformance fixtures under tests/golden/.

    PYTHONPATH=src python -m tests.regen_golden            # rewrite fixtures
    PYTHONPATH=src python -m tests.regen_golden --check    # no-write drift CI

`--check` recomputes every fixture's golden vectors IN MEMORY — from the
frozen `.qnet` and the stored input batch, through the reference
interpreter — and diffs them against the committed `.npz`, writing
nothing. Any mismatch (integer-datapath drift) prints a per-stage delta
summary and exits non-zero; CI runs this as its own step. The `.qnet`
itself is the frozen input of the check, not recomputed: float calibration
legitimately varies across BLAS builds, while the integer datapath must be
bit-stable everywhere — which is exactly what this gate pins.

Each case freezes BOTH the quantized network and the golden vectors:

  * ``<model>_act<bits>.qnet`` — the serialized `QNet` (weights + requant
    constants). Freezing the deployment artifact itself means float
    calibration drift across machines/BLAS builds can never silently move
    the fixture; the conformance suite tests the *integer datapath*, which
    must be bit-exact everywhere.
  * ``<model>_act<bits>.npz`` — the input batch (float32), every CU-stage
    output activation (uint8 — the integer datapath never leaves
    [0, 2^act_bits - 1]), and the final dequantized float32 logits, all
    produced by the reference interpreter `cu.run_blocks`.

Cases: MobileNetV2 (alpha=0.35) and the compact EfficientNet at act_bits
{4, 8}, input 32x32, 10 classes, batch 2 — small enough to check in, deep
enough to cover every op kind (CONV/DW/PW/DENSE, residual, SE, avgpool).

The golden vectors come from `repro.train.vision.stage_vectors` — the
same derivation the QAT training pipeline's export step proves trained
artifacts against (this module is a thin wrapper, not a parallel
implementation; see tests/golden/README.md "Provenance").
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro.core import qnet as Q
from repro.models import dscnn1d, efficientnet as effn, mobilenet_v2 as mnv2
from repro.models.layers import make_calibrated_qnet
from repro.train.vision import stage_vectors

HW = 32
BATCH = 2
NUM_CLASSES = 10
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

# streaming fixture geometry (dscnn_kws): hop = window / 8, frozen windows
KWS_KW = dict(input_t=32, input_ch=6, channels=16, n_blocks=2, kernel=3)
STREAM_HOP = KWS_KW["input_t"] // 8
STREAM_WINDOWS = 5

CASES = tuple((model, bits)
              for model in ("mobilenet_v2", "efficientnet_compact")
              for bits in (4, 8)) + (("dscnn_kws", 8),)


def build_net(model: str, bits: int):
    if model == "mobilenet_v2":
        return mnv2.build(alpha=0.35, input_hw=HW, bits=bits,
                          num_classes=NUM_CLASSES)
    if model == "efficientnet_compact":
        return effn.build_compact(input_hw=HW, bits=bits,
                                  num_classes=NUM_CLASSES)
    if model == "dscnn_kws":
        return dscnn1d.build_kws(bits=bits, num_classes=NUM_CLASSES,
                                 **KWS_KW)
    raise ValueError(model)


def make_qnet(net, bits: int, seed: int = 0):
    return make_calibrated_qnet(net, bits=bits, seed=seed)


def golden_vectors(qnet, x: np.ndarray):
    """(stage_cus, per-stage int activations, float logits) — a thin
    wrapper over the training pipeline's export derivation
    (`repro.train.vision.stage_vectors`), so the frozen fixtures and every
    trained `.qnet` export are produced by ONE code path; a drift between
    'what training exports' and 'what the conformance suite pins' is
    structurally impossible."""
    return stage_vectors(qnet, x)


def build_record(model: str, bits: int):
    """Self-description stamped into regenerated `.qnet` fixtures (lets
    `Q.load_qnet(path)` rebuild the NetSpec without this module)."""
    if model == "dscnn_kws":
        return {"model": model, "bits": bits, "num_classes": NUM_CLASSES,
                **KWS_KW}
    rec = {"model": model, "input_hw": HW, "bits": bits,
           "num_classes": NUM_CLASSES}
    if model == "mobilenet_v2":
        rec["alpha"] = 0.35
    return rec


def stream_golden(qnet, frames: np.ndarray) -> np.ndarray:
    """Frozen per-window logits for the streaming fixture, derived by the
    full-window reference route (`stream.reference_windows` wraps
    `cu.run_qnet` per window) — the streaming engine is *checked against*
    this, never used to generate it."""
    from repro.serve import stream as ST

    return ST.reference_windows(qnet, frames, qnet.spec.input_hw,
                                STREAM_HOP)


def fixture_paths(model: str, bits: int):
    base = os.path.join(GOLDEN_DIR, f"{model}_act{bits}")
    return base + ".qnet", base + ".npz"


def check() -> int:
    """Recompute fixtures in memory and diff against tests/golden/.

    Returns the number of drifted/missing cases (0 == green)."""
    from repro.core import qnet as Q

    failures = 0
    for model, bits in CASES:
        qnet_path, npz_path = fixture_paths(model, bits)
        tag = f"{model} act{bits}"
        if not (os.path.exists(qnet_path) and os.path.exists(npz_path)):
            print(f"[golden-check] {tag}: MISSING fixture files")
            failures += 1
            continue
        qnet = Q.load_qnet(qnet_path, build_net(model, bits))
        fix = np.load(npz_path)
        cus, acts, logits = golden_vectors(qnet, fix["input"])
        bad = []
        n_stored = sum(1 for k in fix.files if k.startswith("stage"))
        if n_stored != len(cus):
            bad.append(f"stage count {len(cus)} != stored {n_stored}")
        for i, (cu_name, act) in enumerate(zip(cus, acts)):
            key = f"stage{i}_{cu_name}"
            if key not in fix.files:
                bad.append(f"{key}: absent from committed npz")
                continue
            stored = fix[key].astype(np.int32)
            if act.shape != stored.shape:
                bad.append(f"{key}: shape {act.shape} != stored "
                           f"{stored.shape}")
            elif not np.array_equal(act, stored):
                n = int(np.sum(act != stored))
                d = int(np.max(np.abs(act - stored)))
                bad.append(f"{key}: {n} elems differ (max |delta| {d} LSB)")
        if logits.shape != fix["logits"].shape:
            bad.append(f"logits: shape {logits.shape} != stored "
                       f"{fix['logits'].shape}")
        elif not np.array_equal(logits, fix["logits"]):
            n = int(np.sum(logits != fix["logits"]))
            d = float(np.max(np.abs(logits - fix["logits"])))
            bad.append(f"logits: {n} elems differ (max |delta| {d:.3g})")
        if "stream_frames" in fix.files:
            # streaming conformance: the frozen per-window logits must be
            # reproduced BOTH by the full-window derivation and by the
            # ring-buffer streaming engine itself
            from repro.serve import stream as ST

            want = fix["stream_logits"]
            ref = stream_golden(qnet, fix["stream_frames"])
            if not np.array_equal(ref, want):
                bad.append("stream_logits: full-window derivation drifted")
            eng = ST.StreamEngine(qnet, STREAM_HOP)
            res = eng.push(eng.open_session(), fix["stream_frames"])
            got = np.stack([r.logits for r in res])
            if got.shape != want.shape or not np.array_equal(got, want):
                bad.append("stream_logits: streaming engine drifted from "
                           "the frozen windows")
        if bad:
            failures += 1
            print(f"[golden-check] {tag}: DRIFT")
            for line in bad:
                print(f"  {line}")
        else:
            print(f"[golden-check] {tag}: ok ({len(cus)} stages + logits)")
    if failures:
        print(f"[golden-check] FAILED: {failures}/{len(CASES)} cases "
              f"drifted — if the semantics change is intentional, "
              f"regenerate with `python -m tests.regen_golden`")
    return failures


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    rng_img = jax.random.PRNGKey(7)
    for model, bits in CASES:
        net = build_net(model, bits)
        x = np.asarray(jax.random.uniform(
            rng_img, (BATCH, *net.input_shape()), minval=-1, maxval=1),
            np.float32)
        qnet = make_qnet(net, bits)
        cus, acts, logits = golden_vectors(qnet, x)
        qnet_path, npz_path = fixture_paths(model, bits)
        Q.save_qnet(qnet, qnet_path, build=build_record(model, bits),
                    provenance={"derivation": "make_calibrated_qnet",
                                "seed": 0, "n_cal": 2})
        arrays = {"input": x, "logits": logits}
        for i, (cu_name, act) in enumerate(zip(cus, acts)):
            assert act.min() >= 0 and act.max() <= 255, (model, bits, cu_name)
            arrays[f"stage{i}_{cu_name}"] = act.astype(np.uint8)
        if net.spatial_rank == 1:
            from repro.serve import stream as ST

            n = ST.frames_for_windows(STREAM_WINDOWS, net.input_hw,
                                      STREAM_HOP)
            frames = np.asarray(jax.random.uniform(
                jax.random.PRNGKey(8), (n, net.input_ch),
                minval=-1, maxval=1), np.float32)
            arrays["stream_frames"] = frames
            arrays["stream_logits"] = stream_golden(qnet, frames)
        np.savez_compressed(npz_path, **arrays)
        sizes = (os.path.getsize(qnet_path) + os.path.getsize(npz_path)) / 1024
        print(f"[golden] {model} act{bits}: {len(cus)} stages, "
              f"{sizes:.0f} KiB -> {os.path.relpath(npz_path)}")


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="no-write mode: recompute fixtures in memory and "
                         "diff against tests/golden/ (exit 1 on drift)")
    args = ap.parse_args()
    if args.check:
        sys.exit(1 if check() else 0)
    main()
