"""Regenerate the frozen golden conformance fixtures under tests/golden/.

    PYTHONPATH=src python -m tests.regen_golden

Each case freezes BOTH the quantized network and the golden vectors:

  * ``<model>_act<bits>.qnet`` — the serialized `QNet` (weights + requant
    constants). Freezing the deployment artifact itself means float
    calibration drift across machines/BLAS builds can never silently move
    the fixture; the conformance suite tests the *integer datapath*, which
    must be bit-exact everywhere.
  * ``<model>_act<bits>.npz`` — the input batch (float32), every CU-stage
    output activation (uint8 — the integer datapath never leaves
    [0, 2^act_bits - 1]), and the final dequantized float32 logits, all
    produced by the reference interpreter `cu.run_blocks`.

Cases: MobileNetV2 (alpha=0.35) and the compact EfficientNet at act_bits
{4, 8}, input 32x32, 10 classes, batch 2 — small enough to check in, deep
enough to cover every op kind (CONV/DW/PW/DENSE, residual, SE, avgpool).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler as CC, cu, qnet as Q
from repro.models import efficientnet as effn, mobilenet_v2 as mnv2
from repro.models.layers import make_calibrated_qnet

HW = 32
BATCH = 2
NUM_CLASSES = 10
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")

CASES = tuple((model, bits)
              for model in ("mobilenet_v2", "efficientnet_compact")
              for bits in (4, 8))


def build_net(model: str, bits: int):
    if model == "mobilenet_v2":
        return mnv2.build(alpha=0.35, input_hw=HW, bits=bits,
                          num_classes=NUM_CLASSES)
    if model == "efficientnet_compact":
        return effn.build_compact(input_hw=HW, bits=bits,
                                  num_classes=NUM_CLASSES)
    raise ValueError(model)


def make_qnet(net, bits: int, seed: int = 0):
    return make_calibrated_qnet(net, bits=bits, seed=seed)


def golden_vectors(qnet, x: np.ndarray):
    """(stage_cus, per-stage int activations, float logits) from the
    reference `cu.run_blocks` route — the semantic ground truth."""
    plan = CC.compile_net(qnet.spec)
    sigs = plan.stage_signatures()
    s, z = cu.input_qparams(qnet)
    y = cu.quantize_input(jnp.asarray(x), s, z, 8)
    acts, cus = [], []
    for sig in sigs:
        y, s, z = cu.run_blocks(y, sig.blocks, qnet, s, z)
        acts.append(np.asarray(y))
        cus.append(sig.cu)
    logits = (acts[-1].astype(np.float32) + np.float32(z)) * np.float32(s)
    return cus, acts, logits


def fixture_paths(model: str, bits: int):
    base = os.path.join(GOLDEN_DIR, f"{model}_act{bits}")
    return base + ".qnet", base + ".npz"


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    rng_img = jax.random.PRNGKey(7)
    x = np.asarray(jax.random.uniform(
        rng_img, (BATCH, HW, HW, 3), minval=-1, maxval=1), np.float32)
    for model, bits in CASES:
        net = build_net(model, bits)
        qnet = make_qnet(net, bits)
        cus, acts, logits = golden_vectors(qnet, x)
        qnet_path, npz_path = fixture_paths(model, bits)
        Q.save_qnet(qnet, qnet_path)
        arrays = {"input": x, "logits": logits}
        for i, (cu_name, act) in enumerate(zip(cus, acts)):
            assert act.min() >= 0 and act.max() <= 255, (model, bits, cu_name)
            arrays[f"stage{i}_{cu_name}"] = act.astype(np.uint8)
        np.savez_compressed(npz_path, **arrays)
        sizes = (os.path.getsize(qnet_path) + os.path.getsize(npz_path)) / 1024
        print(f"[golden] {model} act{bits}: {len(cus)} stages, "
              f"{sizes:.0f} KiB -> {os.path.relpath(npz_path)}")


if __name__ == "__main__":
    main()
