"""Route autotuner: cache round-trip, deterministic selection, exactness
disqualification, and tuned-vs-default stage-executor parity on the frozen
golden fixtures.

The timing side is injectable (`measure(fn, x, candidate)`), so selection
logic is tested deterministically with a fake timer; the committed caches
under `experiments/tuned/` are exercised against the golden vectors so CI
runs the tuned serving path without re-measuring anything.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compiler as CC, cu, graph as G, qnet as Q
from repro.models.layers import make_calibrated_qnet
from repro.serve.vision import VisionEngine, compile_stages
from repro.tune import (
    Candidate,
    RouteChoice,
    TunedPlan,
    load_tuned,
    op_candidates,
    op_key,
    save_tuned,
    tune_qnet,
)
from tests.regen_golden import CASES, build_net, fixture_paths

TUNED_DIR = os.path.join(os.path.dirname(__file__), "..",
                         "experiments", "tuned")


def _tiny_net() -> G.NetSpec:
    """Stem conv + one residual IRB + tail + classifier: every op kind and
    a fusable Body block, small enough to tune in seconds."""
    blocks = (
        G.BlockSpec("stem", (
            G.OpSpec("stem/conv", G.CONV, 3, 8, 3, 2, G.RELU6, 8, 4),)),
        G.BlockSpec("b1", (
            G.OpSpec("b1/expand", G.PW, 8, 16, 1, 1, G.RELU6, 4, 4),
            G.OpSpec("b1/dw", G.DW, 16, 16, 3, 1, G.RELU6, 4, 4),
            G.OpSpec("b1/project", G.PW, 16, 8, 1, 1, G.NONE, 4, 4),
        ), residual=True),
        G.BlockSpec("tail", (
            G.OpSpec("tail/pw", G.PW, 8, 16, 1, 1, G.RELU6, 4, 4),),
            avgpool=True),
        G.BlockSpec("classifier", (
            G.OpSpec("classifier/fc", G.DENSE, 16, 7, 1, 1, G.NONE, 4, 4),)),
    )
    return G.NetSpec(name="tiny", blocks=blocks, input_hw=16, input_ch=3,
                     num_classes=7)


@pytest.fixture(scope="module")
def tiny_qnet():
    return make_calibrated_qnet(_tiny_net())


def _fake_measure(times):
    """Deterministic timer: seconds per route name (default 1.0)."""

    def measure(fn, x, candidate=None):
        route = candidate.route if candidate is not None else None
        return times.get(route, 1.0)

    return measure


# ---------------------------------------------------------------------------
# cache round-trip
# ---------------------------------------------------------------------------


def test_cache_roundtrip(tmp_path):
    plan = TunedPlan(
        backend="cpu", nets=("tiny",), tuned_batch=4,
        entries={
            "dw:hw8:cin16:cout16:k3:s1:a4:cpu": RouteChoice.make(
                "dw_shifts", us=12.5, us_ref=600.0, n_candidates=5),
            "pw:hw8:cin8:cout16:k1:s1:a4:cpu": RouteChoice.make(
                "pallas_pw", {"block_m": 64, "block_n": 128, "block_k": 128},
                us=20.0, n_candidates=5, disqualified=("evil",)),
        },
        meta={"jax": jax.__version__})
    path = tmp_path / "cache.json"
    save_tuned(plan, str(path))
    loaded = load_tuned(str(path))
    assert loaded == plan
    assert loaded.entries[
        "pw:hw8:cin8:cout16:k1:s1:a4:cpu"].params_dict == {
            "block_m": 64, "block_n": 128, "block_k": 128}


def test_cache_version_mismatch_raises(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text('{"version": 999, "backend": "cpu"}')
    with pytest.raises(ValueError, match="version"):
        load_tuned(str(path))


def test_merge_prefers_faster_entry():
    key = "dw:hw8:cin16:cout16:k3:s1:a4:cpu"
    a = TunedPlan("cpu", ("a",), 4,
                  {key: RouteChoice.make("int_ref", us=100.0)})
    b = TunedPlan("cpu", ("b",), 4,
                  {key: RouteChoice.make("dw_shifts", us=10.0)})
    merged = a.merge(b)
    assert merged.entries[key].route == "dw_shifts"
    assert merged.nets == ("a", "b")
    with pytest.raises(ValueError):
        a.merge(TunedPlan("tpu", ("c",), 4, {}))


# ---------------------------------------------------------------------------
# deterministic selection under a fake timer
# ---------------------------------------------------------------------------


def test_selection_deterministic_under_fake_timer(tiny_qnet):
    times = {"int_ref": 5.0, "dw_shifts": 0.5, "int_f32": 0.25,
             "pallas_pw": 9.0, "pallas_dw": 9.0,
             "per_op": 1.0, "fused_irb": 2.0}
    plans = [tune_qnet(tiny_qnet, batch=2, measure=_fake_measure(times))
             for _ in range(2)]
    assert plans[0] == plans[1]
    routes = {k: v.route for k, v in plans[0].entries.items()}
    # the fake timer fully determines the winners
    for key, route in routes.items():
        if key.startswith("dw:"):
            assert route == "dw_shifts"
        elif key.startswith("irb:"):
            assert route == "per_op"  # per_op (1.0) beats fused_irb (2.0)
        elif key.startswith(("pw:", "dense:")):
            assert route in ("int_f32", "int_ref")  # f32 only when exact


def test_fused_irb_selected_when_fastest(tiny_qnet):
    times = {"per_op": 5.0, "fused_irb": 0.5}
    plan = tune_qnet(tiny_qnet, batch=2, measure=_fake_measure(times))
    irb_entries = {k: v for k, v in plan.entries.items()
                   if k.startswith("irb:")}
    assert irb_entries and all(
        v.route == "fused_irb" for v in irb_entries.values())
    # the stage executors honor the block-level choice and stay bit-exact
    x = jnp.asarray(np.random.default_rng(0).uniform(
        -1, 1, (2, 16, 16, 3)).astype(np.float32))
    ref = np.asarray(cu.run_qnet(tiny_qnet, x))
    y = x
    for stage in compile_stages(tiny_qnet, tuned=plan):
        y = stage(y)
    np.testing.assert_array_equal(np.asarray(y), ref)


# ---------------------------------------------------------------------------
# exactness disqualification
# ---------------------------------------------------------------------------


def test_wrong_candidate_never_selected(tiny_qnet):
    """A deliberately-drifting route that 'times' fastest must be
    disqualified at verification, never selected."""

    def evil_candidates(pop):
        cands = op_candidates(pop)
        if cands:
            base = cands[0].fn
            cands.append(Candidate(
                "evil", {}, lambda x, f=base: f(x) + jnp.int32(1)))
        return cands

    times = {"evil": 0.0}  # fastest by far, if it were ever timed
    plan = tune_qnet(tiny_qnet, batch=2, measure=_fake_measure(times),
                     candidates_fn=evil_candidates)
    assert plan.entries
    for key, choice in plan.entries.items():
        assert choice.route != "evil", key
        if not key.startswith("irb:"):
            assert "evil" in choice.disqualified, key


def test_unrunnable_candidate_is_disqualified(tiny_qnet):
    def broken_candidates(pop):
        cands = op_candidates(pop)
        if cands:
            def boom(x):
                raise RuntimeError("broken route")
            cands.append(Candidate("broken", {}, boom))
        return cands

    plan = tune_qnet(tiny_qnet, batch=2,
                     measure=_fake_measure({"broken": 0.0}),
                     candidates_fn=broken_candidates)
    for key, choice in plan.entries.items():
        assert choice.route != "broken", key


# ---------------------------------------------------------------------------
# resolve / fallback semantics
# ---------------------------------------------------------------------------


def test_empty_cache_resolves_nothing_and_serves_default(tiny_qnet):
    empty = TunedPlan("cpu", ("tiny",), 2, {})
    op_routes, fused = empty.resolve(tiny_qnet)
    assert op_routes == {} and fused == set()
    x = jnp.asarray(np.random.default_rng(1).uniform(
        -1, 1, (2, 16, 16, 3)).astype(np.float32))
    ref = np.asarray(cu.run_qnet(tiny_qnet, x))
    y = x
    for stage in compile_stages(tiny_qnet, tuned=empty):
        y = stage(y)
    np.testing.assert_array_equal(np.asarray(y), ref)


def test_resolve_with_defaults_fills_misses_with_heuristics(tiny_qnet):
    """A partial cache must never degrade a route below the non-tuned
    heuristics: on TPU (op_kernels/body_fast_path on) uncovered ops keep
    the default-tile Pallas routes and uncovered fusable blocks keep the
    fused kernel; the one covered op keeps its measured route."""
    plan = CC.compile_net(tiny_qnet.spec)
    descs = plan.op_descriptors()
    _, _, dw_op, dw_hw = next(d for d in descs if d[2].kind == G.DW)
    cache = TunedPlan("tpu", ("tiny",), 2, {
        op_key(dw_op, dw_hw, "tpu"): RouteChoice.make("dw_shifts", us=1.0)})
    op_routes, fused = cache.resolve_with_defaults(
        tiny_qnet, plan, backend="tpu", op_kernels=True,
        body_fast_path=True)
    assert op_routes[dw_op.name] == ("dw_shifts", {})
    for _, _, op, _ in descs:
        if op.name != dw_op.name and op.kind in (G.PW, G.DENSE):
            assert op_routes[op.name] == ("pallas_pw", {})
    assert "b1" in fused  # fusable, no block entry -> heuristic fused
    # off-TPU (heuristics off) nothing is filled: cu defaults apply
    op_routes_cpu, fused_cpu = cache.resolve_with_defaults(
        tiny_qnet, plan, backend="cpu")
    assert op_routes_cpu == {} and fused_cpu == set()


def test_foreign_backend_cache_resolves_nothing(tiny_qnet):
    plan = CC.compile_net(tiny_qnet.spec)
    descs = plan.op_descriptors()
    _, _, op, in_hw = next(d for d in descs if d[2].kind == G.DW)
    foreign = TunedPlan("tpu", ("tiny",), 2, {
        op_key(op, in_hw, "tpu"): RouteChoice.make("dw_shifts", us=1.0)})
    op_routes, _ = foreign.resolve(tiny_qnet, plan, backend="cpu")
    assert op_routes == {}


def test_tuned_refuses_fixed_point_and_unprepared(tiny_qnet):
    plan = TunedPlan("cpu", ("tiny",), 2, {})
    with pytest.raises(ValueError, match="fixed_point"):
        compile_stages(tiny_qnet, tuned=plan, fixed_point=True)
    with pytest.raises(ValueError, match="prepare"):
        compile_stages(tiny_qnet, tuned=plan, prepare=False)


def test_plan_carries_tuned_to_stage_compiler(tiny_qnet):
    """compile_net(tuned=...) rides the plan into compile_stages."""
    tuned = tune_qnet(tiny_qnet, batch=2,
                      measure=_fake_measure({"dw_shifts": 0.1}))
    plan = CC.compile_net(tiny_qnet.spec, tuned=tuned)
    stages = compile_stages(tiny_qnet, plan)
    assert all(s._tuned for s in stages)


# ---------------------------------------------------------------------------
# EDP objective: same gate, different ranking
# ---------------------------------------------------------------------------


def test_edp_flips_traffic_dominated_block_latency_does_not(tiny_qnet):
    """The whole point of objective='edp': a fused IRB that times slightly
    SLOWER still wins on EDP because per_op spills its intermediates to
    DRAM (~3.6x the traffic on this block). Per-op candidates share bytes,
    so everywhere else EDP degenerates to latency selection."""
    from repro.energy import PowerModel

    # fused 10% slower than per_op: latency selection (with its 10%
    # hysteresis) must keep per_op; EDP must flip to fused on traffic
    times = {"per_op": 1.0, "fused_irb": 1.1,
             "int_ref": 1.0, "int_f32": 0.5, "dw_shifts": 0.5}
    power = PowerModel(busy_w=1e-9, source="test")  # traffic-dominated
    lat = tune_qnet(tiny_qnet, batch=2, measure=_fake_measure(times))
    edp = tune_qnet(tiny_qnet, batch=2, measure=_fake_measure(times),
                    objective="edp", power=power)
    lat_irb = {v.route for k, v in lat.entries.items()
               if k.startswith("irb:")}
    edp_irb = {v.route for k, v in edp.entries.items()
               if k.startswith("irb:")}
    assert lat_irb == {"per_op"}
    assert edp_irb == {"fused_irb"}
    # per-op winners are identical under both objectives (equal bytes)
    lat_ops = {k: v.route for k, v in lat.entries.items()
               if not k.startswith("irb:")}
    edp_ops = {k: v.route for k, v in edp.entries.items()
               if not k.startswith("irb:")}
    assert lat_ops == edp_ops
    # provenance: the cache says how it was ranked, and RouteChoice.us
    # stays TIME-valued under both (the energy model divides it by batch)
    assert lat.meta["objective"] == "latency"
    assert edp.meta["objective"] == "edp"
    assert edp.meta["power"]["busy_w"] == 1e-9
    irb_choice = next(v for k, v in edp.entries.items()
                      if k.startswith("irb:"))
    assert irb_choice.us == pytest.approx(1.1e6)  # measured seconds, in us


def test_edp_selection_still_bit_exact(tiny_qnet):
    """An EDP winner is exactness-gated like any other: the tuned stage
    executors must reproduce the reference logits bit-for-bit."""
    from repro.energy import PowerModel

    times = {"per_op": 1.0, "fused_irb": 1.1}
    plan = tune_qnet(tiny_qnet, batch=2, measure=_fake_measure(times),
                     objective="edp",
                     power=PowerModel(busy_w=1e-9, source="test"))
    x = jnp.asarray(np.random.default_rng(0).uniform(
        -1, 1, (2, 16, 16, 3)).astype(np.float32))
    ref = np.asarray(cu.run_qnet(tiny_qnet, x))
    y = x
    for stage in compile_stages(tiny_qnet, tuned=plan):
        y = stage(y)
    np.testing.assert_array_equal(np.asarray(y), ref)


def test_edp_requires_known_objective(tiny_qnet):
    with pytest.raises(ValueError):
        tune_qnet(tiny_qnet, batch=2, measure=_fake_measure({}),
                  objective="joules")


# ---------------------------------------------------------------------------
# committed caches: tuned-vs-default parity on the frozen goldens
# ---------------------------------------------------------------------------


def _golden_cache_path(model: str, bits: int, suffix: str = "") -> str:
    return os.path.join(TUNED_DIR, f"{model}_act{bits}_cpu{suffix}.json")


# both committed cache families ride the same conformance tier: the
# latency-tuned caches and the EDP-tuned ones (`*_edp.json`) must each
# cover their golden net and serve bit-exactly — an EDP winner is still
# exactness-gated before it may enter a cache
_GOLDEN_PARAMS = [(m, b, sfx) for sfx in ("", "_edp") for m, b in CASES]


@pytest.fixture(scope="module", params=_GOLDEN_PARAMS,
                ids=lambda c: f"{c[0]}_act{c[1]}{c[2]}")
def golden_case(request):
    model, bits, suffix = request.param
    cache_path = _golden_cache_path(model, bits, suffix)
    if jax.default_backend() != "cpu":
        pytest.skip("committed caches are CPU-tuned")
    if not os.path.exists(cache_path):
        pytest.skip(f"no committed cache {cache_path}")
    qnet_path, npz_path = fixture_paths(model, bits)
    qnet = Q.load_qnet(qnet_path, build_net(model, bits))
    fix = np.load(npz_path)
    return qnet, fix, load_tuned(cache_path)


def test_committed_cache_covers_golden_net(golden_case):
    qnet, fix, tuned = golden_case
    assert tuned.coverage(qnet) == 1.0  # tuned on exactly this net


def test_tuned_prepared_run_qnet_matches_golden(golden_case):
    qnet, fix, tuned = golden_case
    pq = cu.prepare_qnet(qnet, tuned=tuned)
    assert pq.routes  # the tuned routes actually resolved
    got = np.asarray(cu.run_qnet(pq, jnp.asarray(fix["input"])))
    np.testing.assert_array_equal(got, fix["logits"])


def test_tuned_stage_executors_match_golden_per_stage(golden_case):
    qnet, fix, tuned = golden_case
    stages = compile_stages(qnet, tuned=tuned)
    acts = [fix[k] for k in sorted(f for f in fix.files
                                   if f.startswith("stage"))]
    y = jnp.asarray(fix["input"])
    for i, stage in enumerate(stages):
        y = stage(y)
        if i < len(stages) - 1:
            np.testing.assert_array_equal(
                np.asarray(y), acts[i].astype(np.int32),
                err_msg=stage.spec.cu)
    np.testing.assert_array_equal(np.asarray(y), fix["logits"])


def test_tuned_engine_parity_with_default_engine(golden_case):
    """Stage-executor parity tuned-vs-default: identical logits for the
    same requests through both engines."""
    qnet, fix, tuned = golden_case
    x = fix["input"]
    default_eng = VisionEngine(qnet, buckets=(x.shape[0],))
    tuned_eng = VisionEngine(qnet, buckets=(x.shape[0],), tuned=tuned)
    rids_d = [default_eng.submit(img) for img in x]
    rids_t = [tuned_eng.submit(img) for img in x]
    res_d, res_t = default_eng.run(), tuned_eng.run()
    got_d = np.stack([res_d[r].logits for r in rids_d])
    got_t = np.stack([res_t[r].logits for r in rids_t])
    np.testing.assert_array_equal(got_t, got_d)
    np.testing.assert_array_equal(got_t, fix["logits"])
