"""BN fusing (Eqs. 3-6): exactness + the ~4% op-reduction claim."""
import jax
import numpy as np
import pytest

from repro.core.bn_fuse import BNParams, bn_apply, fuse_bn
from repro.models import layers, mobilenet_v2 as mnv2


def _rand_bn(key, c):
    ks = jax.random.split(key, 4)
    return BNParams(
        gamma=jax.random.uniform(ks[0], (c,), minval=0.5, maxval=2.0),
        beta=jax.random.normal(ks[1], (c,)),
        mean=jax.random.normal(ks[2], (c,)),
        var=jax.random.uniform(ks[3], (c,), minval=0.1, maxval=2.0),
    )


@pytest.mark.parametrize("kind", ["conv", "dw", "pw", "dense"])
def test_bn_fuse_exact(kind):
    key = jax.random.PRNGKey(0)
    if kind == "conv":
        w = jax.random.normal(key, (3, 3, 8, 16))
        def apply(x, w, b):
            return layers.conv2d(x, w) + b
        x = jax.random.normal(key, (2, 6, 6, 8))
        c = 16
    elif kind == "dw":
        w = jax.random.normal(key, (3, 3, 1, 8))
        def apply(x, w, b):
            return layers.depthwise_conv2d(x, w) + b
        x = jax.random.normal(key, (2, 6, 6, 8))
        c = 8
    elif kind == "pw":
        w = jax.random.normal(key, (8, 16))
        def apply(x, w, b):
            return layers.pointwise_conv2d(x, w) + b
        x = jax.random.normal(key, (2, 6, 6, 8))
        c = 16
    else:
        w = jax.random.normal(key, (8, 16))
        def apply(x, w, b):
            return x @ w + b
        x = jax.random.normal(key, (4, 8))
        c = 16
    b = jax.random.normal(key, (c,))
    bn = _rand_bn(key, c)
    y_ref = bn_apply(apply(x, w, b), bn)
    w_hat, b_hat = fuse_bn(w, b, bn)
    y_fused = apply(x, w_hat, b_hat)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_fused),
                               rtol=2e-5, atol=2e-5)


def test_bn_op_reduction_about_4_percent():
    """Paper Sec. 1: BN fusing reduces computation by ~4% on MobileNet-V2."""
    net = mnv2.build(alpha=1.0, input_hw=224)
    macs = net.count_macs()
    bn_ops = net.count_bn_ops()
    frac = bn_ops / (macs + bn_ops)
    assert 0.03 <= frac <= 0.05, f"BN fraction {frac:.4f} not ~4%"


def test_paper_table2_ops_includes_bn():
    """Paper #Ops(M) at alpha=1, H=224 is 313.6M == our MACs+BN to <1%."""
    net = mnv2.build(alpha=1.0, input_hw=224)
    total = (net.count_macs() + net.count_bn_ops()) / 1e6
    assert abs(total - 313.6) / 313.6 < 0.01, total
