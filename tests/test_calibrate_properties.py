"""Property tests for `core/calibrate` (ActObserver + ReLU6-fused qparams).

Runs under real `hypothesis` when installed, else the deterministic
`tests/_hypothesis_fallback` harness (same properties, fixed-seed draws).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.calibrate import ActObserver, calibrate, relu6_fused_qparams
from repro.core.quant import QuantConfig

ACFG = QuantConfig(4, symmetric=False, channel_axis=None)


def _batches(seed: int, n: int, lo: float, hi: float):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.uniform(lo, hi, size=(4, 3)).astype(np.float32))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# ActObserver: true min/max mode
# ---------------------------------------------------------------------------


@settings(max_examples=20)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 6),
       lo=st.floats(-8.0, 0.0), hi=st.floats(0.5, 8.0))
def test_true_minmax_observer_is_monotone_and_tight(seed, n, lo, hi):
    """Without momentum the observer is the exact running extremum:
    min_val never increases, max_val never decreases, and after the stream
    both equal the global extrema."""
    batches = _batches(seed, n, lo, hi)
    obs = ActObserver.init()
    prev_mn, prev_mx = float("inf"), float("-inf")
    for b in batches:
        obs = obs.update(b, ACFG)
        mn, mx = float(obs.min_val), float(obs.max_val)
        assert mn <= prev_mn or prev_mn == float("inf")
        assert mx >= prev_mx or prev_mx == float("-inf")
        prev_mn, prev_mx = mn, mx
    all_x = np.concatenate([np.asarray(b).ravel() for b in batches])
    assert float(obs.min_val) == all_x.min()
    assert float(obs.max_val) == all_x.max()


@settings(max_examples=20)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 6),
       m=st.floats(0.1, 0.95))
def test_ema_observer_bounded_by_true_extrema(seed, n, m):
    """The EMA observer is a convex combination of per-batch extrema, so it
    can never leave the envelope the true-min/max observer pins — and it is
    never *tighter at the first batch* (both start at batch-1's range)."""
    batches = _batches(seed, n, -3.0, 3.0)
    ema = ActObserver.init(momentum=m)
    true = ActObserver.init()
    for b in batches:
        ema = ema.update(b, ACFG)
        true = true.update(b, ACFG)
        assert float(ema.min_val) >= float(true.min_val) - 1e-6
        assert float(ema.max_val) <= float(true.max_val) + 1e-6
    # constant stream: the EMA fixes on the constant range exactly
    const = [jnp.ones((2, 2)) * 1.5 for _ in range(4)]
    fixed = ActObserver.init(momentum=m)
    for b in const:
        fixed = fixed.update(b, ACFG)
    assert float(fixed.min_val) == pytest.approx(1.5)
    assert float(fixed.max_val) == pytest.approx(1.5)


@settings(max_examples=10)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 5),
       k=st.integers(1, 4))
def test_calibrate_resume_equals_single_pass(seed, n, k):
    """`calibrate(observers=...)` continuation (the online-quantization
    API) is associative: two passes over a split stream equal one pass over
    the whole stream in true-min/max mode."""
    k = min(k, n - 1)
    batches = _batches(seed, n, -2.0, 2.0)

    def apply_fn(params, b):
        return {"act": b * 2.0, "head": b - 1.0}

    whole = calibrate(apply_fn, None, batches, ACFG)
    first = calibrate(apply_fn, None, batches[:k], ACFG)
    resumed = calibrate(apply_fn, None, batches[k:], ACFG, observers=first)
    assert set(whole) == set(resumed)
    for name in whole:
        np.testing.assert_allclose(np.asarray(resumed[name].min_val),
                                   np.asarray(whole[name].min_val))
        np.testing.assert_allclose(np.asarray(resumed[name].max_val),
                                   np.asarray(whole[name].max_val))


# ---------------------------------------------------------------------------
# relu6_fused_qparams: the h^pq quantizer invariants
# ---------------------------------------------------------------------------


@settings(max_examples=8)
@given(bits=st.sampled_from([4, 8]))
def test_relu6_fused_qparams_invariants(bits):
    """h^pq : [0, 6] -> [0, 2^BW - 1] exactly: zp = 0, S * qmax = 6, the
    endpoints land on the integer rails, and the integer clip IS ReLU6."""
    cfg = QuantConfig(bits, symmetric=False, channel_axis=None)
    s, z = relu6_fused_qparams(cfg)
    s, z = float(s), float(z)
    assert z == 0.0
    # scale is carried in float32: S * qmax reproduces 6.0 to f32 precision
    assert s * cfg.qmax == pytest.approx(6.0, rel=1e-6)
    # endpoint mapping: q(0) = 0, q(6) = qmax; anything beyond clips
    q = lambda x: int(np.clip(np.round(x / s - z), 0, cfg.qmax))  # noqa: E731
    assert q(0.0) == 0
    assert q(6.0) == cfg.qmax
    assert q(7.3) == cfg.qmax  # clip == activation
    assert q(-1.0) == 0
    # 4-bit scale is coarser than 8-bit (fewer levels over the same range)
    if bits == 4:
        s8, _ = relu6_fused_qparams(QuantConfig(8, False, None))
        assert s > float(s8)


def test_relu6_fusion_requires_asymmetric():
    with pytest.raises(ValueError):
        relu6_fused_qparams(QuantConfig(4, symmetric=True, channel_axis=None))
