"""The paper's own DSCNNs on the production mesh: batch-parallel integer
inference lowers + compiles across 256 chips (subprocess: needs 512 fake
devices without leaking XLA_FLAGS into the main test process)."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=SRC)
    prelude = ("import os\n"
               "os.environ['XLA_FLAGS']="
               "'--xla_force_host_platform_device_count=512'\n")
    out = subprocess.run([sys.executable, "-c", prelude + code],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mobilenet_qnet_inference_compiles_on_mesh():
    out = _run("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import cu, qnet as Q
from repro.core.calibrate import calibrate
from repro.core.quant import QuantConfig
from repro.launch.mesh import make_production_mesh
from repro.models import layers, mobilenet_v2 as mnv2

# build + quantize a small-but-real MobileNet-V2 design point
net = mnv2.build(alpha=0.35, input_hw=96, num_classes=1000)
params = layers.init_params(jax.random.PRNGKey(0), net)
def apply_fn(p, b):
    return layers.forward(p, b, net, capture=True)[1]
cal = [jax.random.uniform(jax.random.PRNGKey(i), (1, 96, 96, 3),
                          minval=-1, maxval=1) for i in range(2)]
obs = calibrate(apply_fn, params, cal, QuantConfig(4, False, None))
qn = Q.quantize_net(params, net, obs)

# batch-parallel integer inference across the single-pod mesh
mesh = make_production_mesh()
x_spec = jax.ShapeDtypeStruct((1024, 96, 96, 3), jnp.float32)
in_sh = NamedSharding(mesh, P(("data",), None, None, None))
out_sh = NamedSharding(mesh, P(("data",), None))
fn = jax.jit(lambda x: cu.run_qnet(qn, x), in_shardings=in_sh,
             out_shardings=out_sh)
compiled = fn.lower(x_spec).compile()
mem = compiled.memory_analysis()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):  # older jax returns [dict]
    ca = ca[0] if ca else {}
assert mem.temp_size_in_bytes < 2e9  # tiny per-chip working set
print("OK flops/dev=%.2e temp=%.1fMB" % (
    float(ca.get("flops", 0)), mem.temp_size_in_bytes / 1e6))
""")
    assert "OK" in out
