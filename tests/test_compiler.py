"""Network SoC Compiler: CU partition, invocation counts, knobs (Sec. 4.2)."""
import pytest

from repro.core import compiler as CC
from repro.models import efficientnet as effnet, mobilenet_v2 as mnv2


def test_mobilenet_v2_cu_mapping_matches_paper_fig15():
    """Head, Tail, Classifier once; Body scheduled 16 times."""
    plan = CC.compile_net(mnv2.build(alpha=0.75, input_hw=224))
    roles = [a.cu for a in plan.schedule]
    assert roles.count(CC.HEAD) == 2  # stem conv + first (t=1) IRB
    assert plan.body_invocations == 16
    assert roles.count(CC.TAIL) == 1
    assert roles.count(CC.CLASSIFIER) == 1


def test_efficientnet_compact_body_invoked_9_times():
    """Paper Sec. 5.2: 'invoking the Body CU only nine times'."""
    plan = CC.compile_net(effnet.build_compact(input_hw=128))
    assert plan.body_invocations == 9


def test_body_invocation_ratio():
    """Paper Table 6 note: MobileNet-V2 body count is 1.78x EfficientNet's."""
    m = CC.compile_net(mnv2.build(alpha=0.75, input_hw=224)).body_invocations
    e = CC.compile_net(effnet.build_compact(128)).body_invocations
    assert m / e == pytest.approx(16 / 9, rel=1e-6)


def test_parallel_ops_eq8_eq9_eq10():
    net = mnv2.build(alpha=1.0, input_hw=224)
    po = CC.compile_net(net).parallel_ops()
    # Eq. 8: K_max^2 * N_max over depthwise convs (3x3, widest dw = 960)
    assert po["dw"] == 9 * 960
    # Eq. 9: first conv is the only normal conv: 3x3 x 3 input channels
    assert po["conv"] == 9 * 3
    # Eq. 10: per pointwise type
    assert po["pw_expansion"] == 320  # widest expand input
    assert po["pw_projection"] == 960  # widest project input


def test_buffer_sizing_scales_with_alpha():
    big = CC.compile_net(mnv2.build(alpha=1.0, input_hw=224)).buffer_bytes()
    small = CC.compile_net(mnv2.build(alpha=0.35, input_hw=224)).buffer_bytes()
    assert big["body"] > small["body"]
