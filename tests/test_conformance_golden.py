"""Cross-route integer conformance against frozen golden fixtures.

Pairwise equality tests (route A == route B) cannot catch *common-mode*
requant drift — a change that moves every route the same way. These tests
pin each route to golden vectors checked into `tests/golden/` (input batch,
per-CU-stage integer activations, float logits), regenerated only by an
explicit `python -m tests.regen_golden` run:

  * the reference interpreter (`cu.run_qnet` / `cu.run_blocks`),
  * the `PreparedQNet` device-resident fast path,
  * the jitted stage-executor chain (the serving configuration),
  * the per-op Pallas kernel route (interpret mode off-TPU), and
  * the sharded multi-replica route (`mesh=data_mesh(...)`).

The quantized net itself is frozen in the fixture (`.qnet`), so float
calibration differences across machines cannot move the goalposts — any
mismatch here is integer-datapath drift.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import cu, qnet as Q
from repro.dist.sharding import data_mesh
from repro.serve.vision import VisionEngine, compile_stages
from tests.regen_golden import CASES, build_net, fixture_paths

jnp  # imported for parity with sibling suites; silences linters


def _load_case(model: str, bits: int):
    qnet_path, npz_path = fixture_paths(model, bits)
    qnet = Q.load_qnet(qnet_path, build_net(model, bits))
    fix = np.load(npz_path)
    stages = sorted(k for k in fix.files if k.startswith("stage"))
    return qnet, fix["input"], [fix[k] for k in stages], fix["logits"]


@pytest.fixture(scope="module", params=CASES, ids=lambda c: f"{c[0]}_act{c[1]}")
def case(request):
    model, bits = request.param
    return (model, bits, *_load_case(model, bits))


def test_reference_route_matches_golden(case):
    """`run_qnet` on the frozen QNet reproduces the frozen logits, and the
    per-stage `run_blocks` walk reproduces every stage activation."""
    model, bits, qnet, x, acts, logits = case
    np.testing.assert_array_equal(
        np.asarray(cu.run_qnet(qnet, jnp.asarray(x))), logits)
    from repro.core import compiler as CC
    sigs = CC.compile_net(qnet.spec).stage_signatures()
    s, z = cu.input_qparams(qnet)
    y = cu.quantize_input(jnp.asarray(x), s, z, 8)
    for sig, golden in zip(sigs, acts):
        y, s, z = cu.run_blocks(y, sig.blocks, qnet, s, z)
        np.testing.assert_array_equal(
            np.asarray(y), golden.astype(np.int32), err_msg=sig.cu)


def test_prepared_fast_path_matches_golden(case):
    model, bits, qnet, x, acts, logits = case
    pq = cu.prepare_qnet(qnet)
    np.testing.assert_array_equal(
        np.asarray(cu.run_qnet(pq, jnp.asarray(x))), logits)


def test_stage_executors_match_golden_per_stage(case):
    """The serving configuration (prepared fast path, jitted per-CU stage
    executors) reproduces every frozen stage activation and the logits."""
    model, bits, qnet, x, acts, logits = case
    stages = compile_stages(qnet)
    assert len(stages) == len(acts)
    y = jnp.asarray(x)
    for i, st in enumerate(stages):
        y = st(y)
        if i < len(stages) - 1:
            np.testing.assert_array_equal(
                np.asarray(y), acts[i].astype(np.int32), err_msg=st.spec.cu)
    np.testing.assert_array_equal(np.asarray(y), logits)


def test_sharded_route_matches_golden(case):
    """Data-parallel sharded serving over however many devices are visible
    (the CI matrix forces 4 CPU devices) stays bit-exact with the fixture."""
    model, bits, qnet, x, acts, logits = case
    n_dev = len(jax.devices())
    # largest replica count that divides the fixture batch
    replicas = max(r for r in range(1, n_dev + 1) if x.shape[0] % r == 0)
    mesh = data_mesh(replicas)
    eng = VisionEngine(qnet, buckets=(x.shape[0],), mesh=mesh)
    rids = [eng.submit(img) for img in x]
    res = eng.run()
    got = np.stack([res[r].logits for r in rids])
    np.testing.assert_array_equal(got, logits)


@pytest.mark.slow
def test_kernel_route_matches_golden(case):
    """Per-op Pallas kernel route (DW/PW/DENSE kernels; interpret mode on
    CPU) against the same frozen vectors."""
    model, bits, qnet, x, acts, logits = case
    if bits != 4:
        pytest.skip("kernel route conformance pinned at act4 (interpret "
                    "mode is slow; act8 is covered by the XLA routes)")
    eng = VisionEngine(qnet, buckets=(x.shape[0],), op_kernels="on",
                       interpret=not jax.default_backend() == "tpu")
    rids = [eng.submit(img) for img in x]
    res = eng.run()
    got = np.stack([res[r].logits for r in rids])
    np.testing.assert_array_equal(got, logits)
