"""Docs stay truthful: tier-1 runs the same gate as the CI ``docs`` job.

Every relative markdown link in README.md + docs/*.md must resolve (files
and heading anchors), and docs/architecture.md must reference every
package under src/repro/ — so adding a package without documenting it,
or moving a file out from under a doc link, fails the suite locally
before it fails CI.
"""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
CHECKER = REPO / "tools" / "check_docs.py"

sys.path.insert(0, str(REPO / "tools"))
import check_docs  # noqa: E402


def test_docs_tree_exists():
    for name in ("architecture", "serving", "streaming", "quantization",
                 "tuning", "energy", "benchmarks"):
        assert (REPO / "docs" / f"{name}.md").exists(), f"docs/{name}.md missing"


def test_links_and_coverage_clean():
    assert check_docs.collect_errors(REPO) == []


def test_checker_catches_broken_link(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "README.md").write_text("[gone](docs/nope.md)\n")
    (tmp_path / "docs" / "architecture.md").write_text("# arch\n")
    errs = check_docs.collect_errors(tmp_path)
    assert any("broken link" in e and "nope.md" in e for e in errs)


def test_checker_catches_dangling_anchor(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "README.md").write_text("x\n")
    (tmp_path / "docs" / "architecture.md").write_text(
        "# Arch\n[self](architecture.md#no-such-heading)\n")
    errs = check_docs.collect_errors(tmp_path)
    assert any("dangling anchor" in e for e in errs)


def test_checker_catches_undocumented_package(tmp_path):
    (tmp_path / "docs").mkdir()
    pkg = tmp_path / "src" / "repro" / "newpkg"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (tmp_path / "README.md").write_text("x\n")
    (tmp_path / "docs" / "architecture.md").write_text("# Arch\n")
    errs = check_docs.collect_errors(tmp_path)
    assert any("newpkg" in e for e in errs)


def test_checker_cli_exit_status():
    proc = subprocess.run([sys.executable, str(CHECKER)],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout
