"""Dry-run machinery unit tests.

Run in a SUBPROCESS because importing repro.launch.dryrun sets
XLA_FLAGS=--xla_force_host_platform_device_count=512, which must never leak
into the main test process (smoke tests expect 1 device).
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_depth_points_per_family():
    out = _run("""
from repro.launch.dryrun import build_cfg, depth_points, shape_by_name
from repro.launch.plans import plan_for
shape = shape_by_name("train_4k")
for arch, expect in [
    ("llama3.2-1b", (1, 2, 16)),
    ("qwen3-32b", (1, 2, 64)),
    ("mamba2-1.3b", (1, 2, 48)),
    ("recurrentgemma-2b", (5, 8, 8)),   # pattern 3 + tail 2
    ("seamless-m4t-large-v2", (1, 2, 24)),
]:
    cfg = build_cfg(arch, shape, plan_for(arch), scan_unroll=False)
    got = depth_points(cfg)
    assert got == expect, (arch, got, expect)
print("OK")
""")
    assert "OK" in out


def test_extrapolation_linear():
    out = _run("""
from repro.launch.dryrun import _extrapolate
from repro.launch.roofline import Roofline
r1 = Roofline(10.0, 100.0, 5.0, {"all-reduce": 4}, 256)
r2 = Roofline(14.0, 130.0, 7.0, {"all-reduce": 6}, 256)
full = _extrapolate(r1, r2, 16)
assert full.flops == 10 + 15 * 4
assert full.hbm_bytes == 100 + 15 * 30
assert full.coll_bytes == 5 + 15 * 2
assert full.coll_detail["all-reduce"] == 4 + 15 * 2
print("OK")
""")
    assert "OK" in out


def test_production_mesh_shapes():
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.axis_names == ("data", "model") and m1.devices.size == 256
m2 = make_production_mesh(multi_pod=True)
assert m2.axis_names == ("pod", "data", "model") and m2.devices.size == 512
print("OK")
""")
    assert "OK" in out


def test_single_cell_lower_compile_multipod():
    """End-to-end: one multi-pod cell lowers AND compiles in-process."""
    out = _run("""
import json, tempfile
from repro.launch.dryrun import run_cell, shape_by_name
rep = run_cell("llama3.2-1b", shape_by_name("decode_32k"), multi_pod=True,
               out_dir=tempfile.mkdtemp())
assert rep["status"] == "ok", rep
assert rep["mesh"] == "2x16x16"
assert rep["roofline"]["flops_per_device"] > 0
print("OK")
""")
    assert "OK" in out
