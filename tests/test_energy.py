"""Calibrated energy model + RAPL power plumbing + power governor.

Everything here is deterministic: RAPL is exercised against a fixture
powercap tree under tmp_path (the real `/sys/class/powercap` is never
touched), clocks are injected counters, and permission faults are driven
through the `power._read_uj` seam rather than chmod (the suite runs as
root in CI, where mode bits don't deny anything).
"""
import os

import pytest

from repro.core import graph as G
from repro.energy import (
    BACKEND_WATTS,
    EnergyReport,
    PJ_PER_BYTE,
    PJ_PER_MAC,
    PowerGovernor,
    PowerModel,
    RaplEnergyReader,
    RaplUnavailable,
    analytic_energy_j,
    calibrate_power,
    default_power_model,
    edp_score,
    estimate_energy,
    measure_power,
    op_bytes_moved,
    op_macs,
    op_pj_per_mac,
    reset_default_power_model,
)
from repro.energy import power as EP
from repro.models import mobilenet_v2 as mnv2
from repro.models.layers import make_calibrated_qnet


class Ticker:
    """Fake clock: every read advances by `step` seconds."""

    def __init__(self, step: float = 1.0):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


def _write_domain(root, name, uj, range_uj=2 ** 32 - 1):
    d = root / name
    d.mkdir(parents=True, exist_ok=True)
    (d / "energy_uj").write_text(f"{uj}\n")
    (d / "max_energy_range_uj").write_text(f"{range_uj}\n")
    return d


@pytest.fixture(autouse=True)
def _fresh_memo():
    reset_default_power_model()
    yield
    reset_default_power_model()


# ---------------------------------------------------------------------------
# PowerModel
# ---------------------------------------------------------------------------


def test_power_model_validates():
    m = PowerModel(busy_w=10.0, idle_w=2.0, source="test")
    assert m.as_dict() == {"busy_w": 10.0, "idle_w": 2.0, "source": "test"}
    with pytest.raises(ValueError):
        PowerModel(busy_w=0.0)
    with pytest.raises(ValueError):
        PowerModel(busy_w=5.0, idle_w=-1.0)
    with pytest.raises(ValueError):
        PowerModel(busy_w=5.0, idle_w=6.0)  # idle above busy


# ---------------------------------------------------------------------------
# RAPL reader against a fixture powercap tree
# ---------------------------------------------------------------------------


def test_rapl_missing_tree_raises(tmp_path):
    with pytest.raises(RaplUnavailable):
        RaplEnergyReader(str(tmp_path / "nope"))


def test_rapl_tree_without_counters_raises(tmp_path):
    (tmp_path / "intel-rapl:0").mkdir()  # directory but no energy_uj
    with pytest.raises(RaplUnavailable):
        RaplEnergyReader(str(tmp_path))


def test_rapl_reads_package_domains_and_skips_subdomains(tmp_path):
    _write_domain(tmp_path, "intel-rapl:0", 1_000_000)
    _write_domain(tmp_path, "intel-rapl:1", 500_000)
    # core/dram subdomains are INSIDE the package counters: counting them
    # would double-bill every joule
    _write_domain(tmp_path, "intel-rapl:0:0", 900_000)
    r = RaplEnergyReader(str(tmp_path))
    assert r.n_domains == 2
    assert r.read_j() == 0.0  # no counter movement yet
    _write_domain(tmp_path, "intel-rapl:0", 1_250_000)
    _write_domain(tmp_path, "intel-rapl:1", 750_000)
    _write_domain(tmp_path, "intel-rapl:0:0", 9_900_000)  # must be ignored
    assert r.read_j() == pytest.approx(0.5)  # 2 x 250_000 uJ


def test_rapl_counter_wraparound(tmp_path):
    range_uj = 1_000_000
    _write_domain(tmp_path, "intel-rapl:0", 999_900, range_uj=range_uj)
    r = RaplEnergyReader(str(tmp_path))
    r.read_j()
    # counter wrapped: raw < last means range - last + raw, not a negative
    _write_domain(tmp_path, "intel-rapl:0", 400, range_uj=range_uj)
    assert r.read_j() == pytest.approx((range_uj - 999_900 + 400) * 1e-6)


def test_rapl_unreadable_domain_skipped_then_unavailable(tmp_path,
                                                        monkeypatch):
    """Permission-denied counters (non-root readers) are skipped at scan
    time; a tree where every domain is denied raises RaplUnavailable."""
    _write_domain(tmp_path, "intel-rapl:0", 1_000)

    def deny(path):
        raise PermissionError(13, "Permission denied", path)

    monkeypatch.setattr(EP, "_read_uj", deny)
    with pytest.raises(RaplUnavailable):
        RaplEnergyReader(str(tmp_path))


def test_rapl_counter_vanishing_mid_run_raises(tmp_path, monkeypatch):
    _write_domain(tmp_path, "intel-rapl:0", 1_000)
    r = RaplEnergyReader(str(tmp_path))

    def gone(path):
        raise FileNotFoundError(2, "No such file", path)

    monkeypatch.setattr(EP, "_read_uj", gone)
    with pytest.raises(RaplUnavailable):
        r.read_j()


def test_measure_and_calibrate_power_fixture_tree(tmp_path):
    _write_domain(tmp_path, "intel-rapl:0", 0)
    reader = RaplEnergyReader(str(tmp_path))
    clock = Ticker(step=1.0)  # measure_power reads it twice -> dt == 1s

    def burn(uj):
        def fn():
            cur = int((tmp_path / "intel-rapl:0" / "energy_uj")
                      .read_text())
            _write_domain(tmp_path, "intel-rapl:0", cur + uj)
        return fn

    assert measure_power(burn(3_000_000), reader, clock) \
        == pytest.approx(3.0)
    model = calibrate_power(reader=reader, clock=clock,
                            idle_fn=burn(2_000_000),
                            busy_fn=burn(12_000_000))
    assert model.idle_w == pytest.approx(2.0)
    assert model.busy_w == pytest.approx(12.0)
    assert model.source == f"rapl:{tmp_path}"


def test_calibrate_clamps_noisy_busy_below_idle(tmp_path):
    """A busy window that measured below idle is scheduler noise; the
    model must still satisfy busy >= idle > 0 (PowerModel validates)."""
    _write_domain(tmp_path, "intel-rapl:0", 0)
    reader = RaplEnergyReader(str(tmp_path))
    clock = Ticker(step=1.0)

    def burn(uj):
        def fn():
            cur = int((tmp_path / "intel-rapl:0" / "energy_uj")
                      .read_text())
            _write_domain(tmp_path, "intel-rapl:0", cur + uj)
        return fn

    model = calibrate_power(reader=reader, clock=clock,
                            idle_fn=burn(5_000_000),
                            busy_fn=burn(1_000_000))
    assert model.busy_w >= model.idle_w > 0


def test_default_power_model_falls_back_to_constants(tmp_path):
    """No powercap tree (this container, macOS, accelerators): the
    per-backend constants with a provenance string that says so."""
    m = default_power_model("cpu", root=str(tmp_path / "absent"))
    assert (m.busy_w, m.idle_w) == BACKEND_WATTS["cpu"]
    assert m.source == "constant:cpu"
    # memoized per (backend, root): same object until reset
    assert default_power_model("cpu", root=str(tmp_path / "absent")) is m
    assert default_power_model("tpu").busy_w == BACKEND_WATTS["tpu"][0]


def test_default_power_model_calibrates_from_fixture_tree(tmp_path,
                                                          monkeypatch):
    # live counters that advance on every read -> calibration succeeds
    state = {"uj": 0}

    def advancing(path):
        if path.endswith("max_energy_range_uj"):
            return 2 ** 32 - 1
        state["uj"] += 50_000
        return state["uj"]

    _write_domain(tmp_path, "intel-rapl:0", 0)
    monkeypatch.setattr(EP, "_read_uj", advancing)
    m = default_power_model("cpu", root=str(tmp_path), calibrate_s=0.001)
    assert m.source == f"rapl:{tmp_path}"
    assert m.busy_w >= m.idle_w > 0


# ---------------------------------------------------------------------------
# the energy model: bytes matter (the deleted MAC-proxy's blind spot)
# ---------------------------------------------------------------------------


def test_dw_and_pw_equal_macs_different_bytes():
    """Regression for the old `_energy_j_per_image` MAC-only proxy: a DW
    and a PW op with IDENTICAL MAC counts move ~5x different DDR bytes,
    so their modeled energy must differ. The proxy scored them equal."""
    dw = G.OpSpec("dw", G.DW, in_ch=256, out_ch=256, kernel=3, bits=8,
                  act_bits=8)
    pw = G.OpSpec("pw", G.PW, in_ch=48, out_ch=48, bits=8, act_bits=8)
    hw = 16
    assert op_macs(dw, hw) == op_macs(pw, hw)  # the proxy's whole input
    b_dw, b_pw = op_bytes_moved(dw, hw), op_bytes_moved(pw, hw)
    assert b_dw > 4 * b_pw  # DW streams 256ch activations, PW only 48ch
    e_dw = op_macs(dw, hw) * PJ_PER_MAC[8] * 1e-12 + b_dw * PJ_PER_BYTE * 1e-12
    e_pw = op_macs(pw, hw) * PJ_PER_MAC[8] * 1e-12 + b_pw * PJ_PER_BYTE * 1e-12
    assert e_dw > e_pw


def test_mixed_act_bits_change_byte_and_mac_pricing():
    """Regression for the uniform-width blind spots: `op_bytes_moved` used
    to charge 1 B/element regardless of act width, and the analytic MAC
    price keyed on weight bits alone. A DW op at act4 must move ~half the
    activation bytes of the same op at act8, and a w4/a8 op must be priced
    at the 8-bit MAC energy (the datapath runs at the wider operand)."""
    hw = 16
    dw8 = G.OpSpec("dw", G.DW, in_ch=256, out_ch=256, kernel=3, bits=8,
                   act_bits=8)
    dw4 = G.OpSpec("dw", G.DW, in_ch=256, out_ch=256, kernel=3, bits=8,
                   act_bits=4)
    b8, b4 = op_bytes_moved(dw8, hw), op_bytes_moved(dw4, hw)
    assert b4 < b8
    # exactly the activation-stream halving: weights are unchanged
    n_el = hw * hw * 256 + hw * hw * 256  # input + output feature maps
    assert b8 - b4 == n_el // 2
    # upstream width matters too: an act8 op fed by an act4 producer reads
    # narrower input traffic than the same op fed at 8 bits
    assert op_bytes_moved(dw8, hw, in_bits=4) < op_bytes_moved(dw8, hw)
    # MAC pricing follows the wider of weight/act operand
    w4a8 = G.OpSpec("pw", G.PW, in_ch=48, out_ch=48, bits=4, act_bits=8)
    w4a4 = G.OpSpec("pw", G.PW, in_ch=48, out_ch=48, bits=4, act_bits=4)
    assert op_pj_per_mac(w4a8) == PJ_PER_MAC[8]
    assert op_pj_per_mac(w4a4) == PJ_PER_MAC[4]
    assert op_pj_per_mac(w4a8) > op_pj_per_mac(w4a4)


def test_mixed_allocation_lowers_modeled_energy():
    """End to end through `estimate_energy`: dropping part of a net to
    act4 must strictly lower the modeled J/image vs the uniform-8 net
    (byte traffic shrinks, nothing else changes)."""
    net8 = G.with_act_bits(
        mnv2.build(alpha=0.35, input_hw=32, num_classes=10), 8)
    alloc = G.op_act_bits(net8)
    mixed = dict(alloc)
    for name in list(mixed)[len(mixed) // 2:]:
        mixed[name] = 4
    net_mix = G.with_op_act_bits(net8, mixed)
    power = PowerModel(busy_w=10.0, idle_w=2.0, source="test")
    j8 = estimate_energy(make_calibrated_qnet(net8, bits=8),
                         power=power, backend="cpu").j_per_image
    jm = estimate_energy(make_calibrated_qnet(net_mix, bits=8),
                         power=power, backend="cpu").j_per_image
    assert jm < j8


def test_analytic_energy_includes_byte_term():
    spec = mnv2.build(alpha=0.35, input_hw=32, num_classes=10)
    j = analytic_energy_j(spec)
    mac_only = sum(
        op_macs(op, in_hw, spec.spatial_rank)
        * PJ_PER_MAC.get(op.bits, 0.2) * 1e-12
        for _, _, op, in_hw in __import__(
            "repro.core.compiler", fromlist=["compile_net"]
        ).compile_net(spec).op_descriptors())
    assert j > mac_only  # the byte term is live, not vestigial


def test_estimate_energy_analytic_when_untuned():
    qnet = make_calibrated_qnet(
        mnv2.build(alpha=0.35, input_hw=32, num_classes=10))
    power = PowerModel(busy_w=10.0, idle_w=2.0, source="test")
    rep = estimate_energy(qnet, power=power, backend="cpu")
    assert isinstance(rep, EnergyReport)
    assert rep.tuned_fraction == 0.0
    assert rep.j_per_image > 0 and rep.us_per_image > 0
    assert set(rep.per_cu()) == {"head", "body", "tail", "classifier"}
    # rate-dependent watts: idle floor at 0 fps, linear in fps above it
    assert rep.watts(0.0) == pytest.approx(2.0)
    assert rep.watts(100.0) == pytest.approx(2.0 + 100 * rep.j_per_image)
    assert rep.fps_per_watt(100.0) == pytest.approx(
        100.0 / rep.watts(100.0))
    d = rep.as_dict()
    assert d["tuned_fraction"] == 0.0 and d["n_ops"] == len(rep.ops)


def test_estimate_energy_tuned_routes_from_committed_cache():
    cache = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "tuned", "mobilenet_v2_act8_cpu.json")
    if not os.path.exists(cache):
        pytest.skip("no committed cache")
    from repro.tune import load_tuned
    from tests.regen_golden import build_net, fixture_paths
    from repro.core import qnet as Q

    qnet_path, _ = fixture_paths("mobilenet_v2", 8)
    qnet = Q.load_qnet(qnet_path, build_net("mobilenet_v2", 8))
    tuned = load_tuned(cache)
    power = PowerModel(busy_w=10.0, source="test")
    rep = estimate_energy(qnet, tuned=tuned, power=power)
    tuned_ops = [o for o in rep.ops if o.source == "tuned"]
    assert tuned_ops, "committed cache resolved no routes"
    # every autotuned op is measurement-priced; only SE side ops fall back
    assert all(o.source == "tuned" for o in rep.ops if o.key)
    assert rep.tuned_fraction > 0.5
    # measured timings dominate the pJ/MAC guess by orders of magnitude on
    # this host; the report must reflect the measurement, not the guess
    analytic = estimate_energy(qnet, power=power, backend="cpu")
    assert rep.j_per_image != analytic.j_per_image


# ---------------------------------------------------------------------------
# EDP score
# ---------------------------------------------------------------------------


def test_edp_score_properties():
    p = PowerModel(busy_w=10.0, source="test")
    assert edp_score(0.0, 100, p) == float("inf")
    assert edp_score(-1.0, 100, p) == float("inf")
    assert edp_score(float("nan"), 100, p) == float("inf")
    # equal bytes -> monotone in t (per-op EDP degenerates to latency)
    assert edp_score(1e-3, 1000, p) < edp_score(2e-3, 1000, p)
    # traffic can flip a winner: slightly slower but much lighter wins
    heavy = edp_score(1.00e-6, 10_000_000, p)
    light = edp_score(1.05e-6, 1_000, p)
    assert light < heavy


# ---------------------------------------------------------------------------
# PowerGovernor
# ---------------------------------------------------------------------------


def test_governor_validates():
    with pytest.raises(ValueError):
        PowerGovernor(1.0, idle_w=2.0)  # budget below idle floor
    with pytest.raises(ValueError):
        PowerGovernor(10.0, window_s=0.0)
    g = PowerGovernor(10.0, idle_w=2.0)
    with pytest.raises(ValueError):
        g.record(-1.0, now=0.0)


def test_governor_rolling_window_accounting():
    g = PowerGovernor(10.0, window_s=1.0, idle_w=2.0)
    assert g.watts(0.0) == pytest.approx(2.0)  # idle floor
    assert g.headroom_j(0.0) == pytest.approx(8.0)
    g.record(3.0, now=0.0)
    g.record(4.0, now=0.5)
    assert g.window_j(0.5) == pytest.approx(7.0)
    assert g.watts(0.5) == pytest.approx(9.0)
    assert not g.would_exceed(1.0, now=0.5)
    assert g.would_exceed(1.1, now=0.5)
    # the t=0 event ages out of the window; headroom comes back
    assert g.window_j(1.25) == pytest.approx(4.0)
    assert not g.would_exceed(4.0, now=1.25)
    assert g.total_j == pytest.approx(7.0)  # lifetime total never pruned


def test_governor_never_crosses_budget_when_policed():
    """The engine's contract: check would_exceed BEFORE record. Under
    that discipline the windowed estimate never exceeds the budget."""
    g = PowerGovernor(5.0, window_s=1.0, idle_w=1.0)
    t = 0.0
    dispatched = 0
    for _ in range(50):
        j = 1.5
        if not g.would_exceed(j, now=t):
            g.record(j, now=t)
            dispatched += 1
        assert g.watts(t) <= g.budget_w + 1e-9
        t += 0.2
    assert dispatched > 10  # headroom keeps returning as events age out
