"""Checkpoint/restart fault tolerance: atomicity, rotation, bitwise resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data.pipeline import DataConfig, lm_batch
from repro.models.lm import model as M
from repro.train import checkpoint as CKPT, optimizer as O
from repro.train.train_loop import make_train_step


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4), "b": {"c": jnp.ones(5)}}
    CKPT.save(str(tmp_path), 7, tree)
    restored, step = CKPT.restore(str(tmp_path), tree)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_rotation_keeps_last_k(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in range(6):
        CKPT.save(str(tmp_path), s, tree, keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]
    assert CKPT.latest_step(str(tmp_path)) == 5


def test_async_save_then_restore(tmp_path):
    tree = {"x": jnp.arange(4.0)}
    t = CKPT.save(str(tmp_path), 3, tree, async_=True)
    t.join()
    restored, step = CKPT.restore(str(tmp_path), tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(tree["x"]))


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        CKPT.restore(str(tmp_path), {"x": jnp.zeros(1)})


def test_bitwise_restart_continuation(tmp_path):
    """Train 6 steps straight vs train 3 + checkpoint + restore + 3: params
    must match bitwise (deterministic data + donated-step determinism)."""
    cfg = reduced_config("llama3.2-1b")
    data = DataConfig(seed=11, vocab=cfg.vocab, seq_len=16, global_batch=4)
    ocfg = O.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=6)
    step_fn = jax.jit(make_train_step(cfg, ocfg))

    def run(params, opt, s0, s1):
        for s in range(s0, s1):
            batch = {k: jnp.asarray(v) for k, v in lm_batch(data, s).items()}
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    p0, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    o0 = O.init_state(p0)

    # straight 6
    p_a, o_a = run(p0, o0, 0, 6)
    # 3 + ckpt + restore + 3  (data stream resumes at the saved step)
    p_b, o_b = run(p0, o0, 0, 3)
    CKPT.save(str(tmp_path), 3, (p_b, o_b))
    (p_c, o_c), start = CKPT.restore(str(tmp_path), (p_b, o_b))
    assert start == 3
    p_d, _ = run(p_c, o_c, start, 6)

    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_d)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_on_restore(tmp_path):
    """Restore with explicit (degenerate) shardings — the elastic-resize path."""
    from repro.launch.mesh import make_host_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_host_mesh()
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    CKPT.save(str(tmp_path), 1, tree)
    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored, _ = CKPT.restore(str(tmp_path), tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
