"""Flash-decode attention Pallas kernel vs oracle (GQA grouping + int8 KV)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.ref import decode_attention_ref
from repro.models.lm.common import full_attention, kv_quant


def _mk(b, kv, rep, dh, s, quant, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, kv, rep, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    ks = vs = None
    if quant:
        kc, ks = kv_quant(kc)
        vc, vs = kv_quant(vc)
    return q, kc, vc, ks, vs


@pytest.mark.parametrize("b,kv,rep,dh,s,bs,quant,vlen", [
    (2, 2, 4, 16, 64, 16, False, 64),
    (2, 2, 4, 16, 64, 16, False, 37),    # partially filled cache
    (1, 4, 1, 32, 128, 32, False, 100),  # MHA (rep=1)
    (2, 2, 4, 16, 100, 32, False, 70),   # ragged S vs block
    (2, 2, 4, 16, 64, 16, True, 50),     # int8 cache (fused dequant)
    (2, 1, 8, 32, 96, 32, True, 96),     # MQA + int8
    (1, 8, 8, 64, 256, 128, False, 256), # qwen3-like geometry
])
def test_decode_attention_matches_ref(b, kv, rep, dh, s, bs, quant, vlen):
    q, kc, vc, ks, vs = _mk(b, kv, rep, dh, s, quant)
    out = decode_attention(q, kc, vc, jnp.int32(vlen), ks, vs,
                           block_s=bs, interpret=True)
    ref = decode_attention_ref(q, kc, vc, jnp.int32(vlen), ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_matches_model_attention_path():
    """Kernel == the model's jnp decode attention (full_attention w/ kv_len)."""
    b, kv, rep, dh, s = 2, 2, 4, 16, 64
    q, kc, vc, _, _ = _mk(b, kv, rep, dh, s, quant=False, seed=3)
    vlen = 41
    out = decode_attention(q, kc, vc, jnp.int32(vlen), block_s=16,
                           interpret=True)
    # model path: q as [B, 1, H, dh]
    qm = q.transpose(0, 2, 1, 3).reshape(b, 1, kv * rep, dh)
    qm = q.reshape(b, kv, rep, dh).reshape(b, kv * rep, dh)[:, None]
    ref = full_attention(qm, kc, vc, causal=False,
                         kv_offset=vlen - 1,
                         kv_len=jnp.full((b,), vlen, jnp.int32))
    ref_g = ref[:, 0].reshape(b, kv, rep, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_g),
                               rtol=1e-4, atol=1e-4)


def test_int8_cache_error_within_quantization_noise():
    b, kv, rep, dh, s = 1, 2, 2, 32, 64
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(b, kv, rep, dh)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, s, kv, dh)), jnp.float32)
    exact = decode_attention_ref(q, kc, vc, jnp.int32(s))
    kq, ks = kv_quant(kc)
    vq, vs = kv_quant(vc)
    quant = decode_attention(q, kq, vq, jnp.int32(s), ks, vs, block_s=16,
                             interpret=True)
    rel = float(jnp.abs(quant - exact).max() / jnp.abs(exact).max())
    assert rel < 0.05, rel
