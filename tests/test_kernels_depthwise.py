"""Pallas depthwise kernel vs pure-jnp oracle: shape/dtype/stride sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.depthwise_conv import depthwise_conv_q


def _mk(h, w, c, K, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 16, (2, h, w, c)), jnp.int32)
    wq = jnp.asarray(rng.integers(-7, 8, (K, K, c)), jnp.int32)
    mult = jnp.asarray(rng.uniform(0.001, 0.01, c), jnp.float32)
    zc = jnp.asarray(rng.uniform(0, 0.5, c), jnp.float32)
    b = jnp.asarray(rng.integers(-3, 3, c), jnp.int32)
    return x, wq, mult, zc, b


@pytest.mark.parametrize("h,w,c,K,s,bc", [
    (8, 8, 16, 3, 1, 8),
    (8, 8, 16, 3, 2, 16),
    (9, 9, 8, 3, 1, 8),       # odd spatial
    (11, 13, 8, 3, 2, 8),     # odd + rectangular + stride 2
    (12, 12, 32, 5, 1, 8),    # 5x5 kernel (EfficientNet)
    (10, 10, 24, 5, 2, 8),
    (16, 16, 128, 3, 1, 128), # full-channel block
])
def test_depthwise_matches_ref(h, w, c, K, s, bc):
    x, wq, mult, zc, b = _mk(h, w, c, K)
    y = depthwise_conv_q(x, wq, mult, zc, b, kernel=K, stride=s,
                         block_c=bc, interpret=True)
    yr = ref.depthwise_conv_q_ref(x, wq, mult, zc, b, kernel=K, stride=s)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


@pytest.mark.parametrize("qmax", [7, 15, 63, 255])
def test_depthwise_bitwidth_sweep(qmax):
    """BW in {3,4,6,8}: clip bound == fused ReLU6 at that BW."""
    x, wq, mult, zc, b = _mk(8, 8, 16, 3)
    y = depthwise_conv_q(x, wq, mult, zc, b, qmax=qmax, block_c=8,
                         interpret=True)
    yr = ref.depthwise_conv_q_ref(x, wq, mult, zc, b, qmax=qmax)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    assert int(y.max()) <= qmax and int(y.min()) >= 0


def test_depthwise_no_clip_linear_output():
    x, wq, mult, zc, b = _mk(8, 8, 8, 3)
    y = depthwise_conv_q(x, wq, mult, zc, b, clip=False, block_c=8,
                         interpret=True)
    yr = ref.depthwise_conv_q_ref(x, wq, mult, zc, b, clip=False)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    assert int(y.min()) < 0  # linear path keeps negatives


try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st


@pytest.mark.parametrize("h,w,c,K,s,bh", [
    (16, 16, 8, 3, 1, 4),     # 4 row tiles
    (16, 16, 8, 3, 2, 2),     # stride 2: strip start walks 2x per tile
    (14, 14, 8, 5, 1, 3),     # 5x5 halo spans two neighbouring tiles
    (13, 11, 8, 5, 2, 2),     # 5x5 stride 2, odd rectangular
    (12, 12, 8, 3, 1, 1),     # one output row per tile (max grid)
    (9, 9, 8, 3, 2, 8),       # block_h > H_out: single tile fallback
    (10, 10, 16, 5, 2, 7),    # block_h not dividing H_out: shrinks to 5
])
def test_depthwise_row_tiling(h, w, c, K, s, bh):
    """Row-tiled grid (batch, row_tiles, channel_tiles): every tiling of the
    output rows — including strips whose K-1 halo crosses the in-kernel
    zero padding — agrees with the oracle bit-for-bit."""
    x, wq, mult, zc, b = _mk(h, w, c, K, seed=1)
    y = depthwise_conv_q(x, wq, mult, zc, b, kernel=K, stride=s,
                         block_c=8, block_h=bh, interpret=True)
    yr = ref.depthwise_conv_q_ref(x, wq, mult, zc, b, kernel=K, stride=s)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(6, 14), w=st.integers(6, 14),
    c=st.sampled_from([8, 16]), k=st.sampled_from([3, 5]),
    s=st.sampled_from([1, 2]), bh=st.sampled_from([1, 2, 3, 8]),
    seed=st.integers(0, 10_000),
)
def test_property_depthwise_random_geometry(h, w, c, k, s, bh, seed):
    """Hypothesis sweep: any (H, W, C, K, stride, row tile) agrees with the
    oracle — covers stride-2 and 5x5 (EfficientNet) geometries."""
    x, wq, mult, zc, b = _mk(h, w, c, k, seed=seed)
    y = depthwise_conv_q(x, wq, mult, zc, b, kernel=k, stride=s,
                         block_c=8, block_h=bh, interpret=True)
    yr = ref.depthwise_conv_q_ref(x, wq, mult, zc, b, kernel=k, stride=s)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
