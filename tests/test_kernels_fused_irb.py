"""Fused IRB (Body CU) Pallas kernel vs oracle + vs the unfused CU runner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fused_irb import fused_irb_q


def _mk(c, e, co, seed=0):
    rng = np.random.default_rng(seed)
    w1 = jnp.asarray(rng.integers(-7, 8, (c, e)), jnp.int32)
    w2 = jnp.asarray(rng.integers(-7, 8, (3, 3, e)), jnp.int32)
    w3 = jnp.asarray(rng.integers(-7, 8, (e, co)), jnp.int32)
    def mk(n, z=False):
        return (
            jnp.asarray(rng.uniform(0.001, 0.01, n), jnp.float32),
            jnp.zeros(n, jnp.float32) if z
            else jnp.asarray(rng.uniform(0, 1, n), jnp.float32),
            jnp.asarray(rng.integers(-2, 3, n), jnp.int32),
        )
    return w1, w2, w3, mk(e), mk(e, True), mk(co, True)


@pytest.mark.parametrize("h,w,c,e,co,s,res,bh", [
    (8, 8, 8, 32, 16, 1, False, 4),
    (8, 8, 16, 64, 16, 1, True, 8),
    (9, 9, 8, 24, 16, 2, False, 4),
    (12, 16, 16, 96, 24, 2, False, 3),
    (8, 8, 8, 48, 8, 1, True, 2),    # residual, small strips
    (16, 16, 24, 144, 32, 1, False, 16),  # MobileNet-ish geometry
])
def test_fused_irb_matches_ref(h, w, c, e, co, s, res, bh):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 16, (2, h, w, c)), jnp.int32)
    w1, w2, w3, (m1, c1, b1), (m2, c2, b2), (m3, c3, b3) = _mk(c, e, co)
    rc = (0.5, 1.0, 0.9, -0.5) if res else None
    y = fused_irb_q(x, w1, m1, c1, b1, w2, m2, c2, b2, w3, m3, c3, b3,
                    stride=s, residual=res, res_consts=rc, block_h=bh,
                    interpret=True)
    yr = ref.fused_irb_q_ref(x, w1, m1, c1, b1, w2, m2, c2, b2,
                             w3, m3, c3, b3, stride=s, residual=res,
                             res_scale=rc)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_fused_irb_equals_unfused_cu_execution():
    """The fused kernel must reproduce the unfused integer CU path exactly
    on a real quantized MobileNet-V2 block (paper's fusion-is-lossless claim)."""
    from repro.core import cu, qnet as Q
    from repro.core.calibrate import calibrate
    from repro.core.quant import QuantConfig
    from repro.kernels.ops import run_irb_block
    from repro.models import layers, mobilenet_v2 as mnv2

    net = mnv2.build(alpha=0.35, input_hw=32, num_classes=10)
    params = layers.init_params(jax.random.PRNGKey(0), net)

    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]

    batches = [jax.random.uniform(jax.random.PRNGKey(i), (2, 32, 32, 3),
                                  minval=-1, maxval=1) for i in range(2)]
    obs = calibrate(apply_fn, params, batches, QuantConfig(4, False, None))
    qn = Q.quantize_net(params, net, obs)

    # walk to the first 3-op IRB and compare fused kernel vs unfused run_block
    x = batches[0]
    first = qn.ops[net.blocks[0].ops[0].name]
    y = cu.quantize_input(x, first.in_scale, first.in_zp, 8)
    s, z = first.in_scale, first.in_zp
    checked = 0
    for block in net.blocks:
        if len(block.ops) == 3 and block.se is None:
            y_fused, fs, fz = run_irb_block(y, block, qn, s, z, interpret=True)
            y_ref, rs, rz = cu.run_block(y, block, qn, s, z)
            np.testing.assert_array_equal(np.asarray(y_fused), np.asarray(y_ref))
            assert (fs, fz) == (rs, rz)
            y, s, z = y_ref, rs, rz
            checked += 1
            if checked >= 3:
                break
        else:
            y, s, z = cu.run_block(y, block, qn, s, z)
    assert checked >= 3
