"""Pallas pointwise-CU kernel vs the `int_pointwise` + epilogue reference.

Bit-exactness (array_equal, not allclose) is the bar: the kernel must be a
drop-in for the reference integer datapath on every PW/DENSE op.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.integer_ops import int_pointwise, quantized_op_epilogue
from repro.kernels.pointwise_conv import pointwise_conv_q

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st


def _mk(shape, cin, cout, *, in_qmax=15, wmax=7, zx=0.0, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, in_qmax + 1, (*shape, cin)), jnp.int32)
    w = jnp.asarray(rng.integers(-wmax, wmax + 1, (cin, cout)), jnp.int32)
    mult = jnp.asarray(rng.uniform(0.001, 0.01, cout), jnp.float32)
    bias = jnp.asarray(rng.integers(-3, 4, cout), jnp.int32)
    wsum = w.sum(0).astype(jnp.int32)
    zpc = (jnp.int32(zx) * wsum).astype(jnp.int32)
    return x, w, mult, zpc, bias, wsum, jnp.int32(zx)


def _ref(x, w, mult, bias, wsum, zx, qmax):
    return quantized_op_epilogue(
        int_pointwise(x, w), z_x=zx, wsum=wsum, bias_q=bias, mult=mult,
        qmax=qmax)


@pytest.mark.parametrize("shape,cin,cout,bm,bn,bk", [
    ((2, 8, 8), 16, 32, 32, 32, 16),     # PW op on NHWC activations
    ((2, 7, 7), 24, 56, 16, 128, 128),   # odd spatial -> M padding
    ((4,), 48, 10, 128, 128, 128),       # DENSE op on [B, C] (classifier)
    ((1, 3, 5), 100, 36, 8, 32, 64),     # C_in/C_out with no 2^7 divisor
    ((2, 6, 6), 8, 1280, 64, 128, 8),    # wide tail pw
])
def test_pointwise_matches_int_pointwise(shape, cin, cout, bm, bn, bk):
    x, w, mult, zpc, bias, wsum, zx = _mk(shape, cin, cout)
    y = pointwise_conv_q(x, w, mult, zpc, bias, qmax=15, block_m=bm,
                         block_n=bn, block_k=bk, interpret=True)
    yr = _ref(x, w, mult, bias, wsum, zx, 15)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


@pytest.mark.parametrize("zx", [-128, -7, 3, 117])
def test_pointwise_nonzero_input_zero_point(zx):
    """Post-residual PW inputs carry a nonzero zero point: the integer
    zpc = z_x * wsum correction must match the reference bit-for-bit."""
    x, w, mult, zpc, bias, wsum, jzx = _mk((2, 5, 5), 32, 24, zx=zx, seed=3)
    y = pointwise_conv_q(x, w, mult, zpc, bias, qmax=15, block_m=16,
                         block_n=8, block_k=16, interpret=True)
    yr = _ref(x, w, mult, bias, wsum, jzx, 15)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


@pytest.mark.parametrize("act_bits", [4, 8])
def test_pointwise_bitwidth_sweep(act_bits):
    qmax = 2**act_bits - 1
    x, w, mult, zpc, bias, wsum, zx = _mk(
        (2, 6, 6), 16, 16, in_qmax=qmax, seed=1)
    y = pointwise_conv_q(x, w, mult, zpc, bias, qmax=qmax, block_m=32,
                         block_n=16, block_k=16, interpret=True)
    yr = _ref(x, w, mult, bias, wsum, zx, qmax)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    assert 0 <= int(y.min()) and int(y.max()) <= qmax


def test_pointwise_no_clip_linear_output():
    x, w, mult, zpc, bias, wsum, zx = _mk((2, 4, 4), 16, 8, seed=2)
    bias = bias - 10  # force negatives through
    y = pointwise_conv_q(x, w, mult, zpc, bias, qmax=15, clip=False,
                         block_m=16, block_n=8, block_k=16, interpret=True)
    acc = int_pointwise(x, w)
    yr = jnp.round(acc.astype(jnp.float32) * mult).astype(jnp.int32) + bias
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    assert int(y.min()) < 0


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(3, 9), b=st.integers(1, 3),
    cin=st.sampled_from([8, 24, 33]), cout=st.sampled_from([8, 17, 40]),
    act_bits=st.sampled_from([4, 8]), seed=st.integers(0, 10_000),
)
def test_property_pointwise_vs_int_pointwise(h, b, cin, cout, act_bits, seed):
    """Any geometry/bit-width: the Pallas kernel == int_pointwise + epilogue."""
    qmax = 2**act_bits - 1
    x, w, mult, zpc, bias, wsum, zx = _mk(
        (b, h, h), cin, cout, in_qmax=qmax, seed=seed)
    y = pointwise_conv_q(x, w, mult, zpc, bias, qmax=qmax, block_m=32,
                         block_n=32, block_k=32, interpret=True)
    yr = _ref(x, w, mult, bias, wsum, zx, qmax)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
