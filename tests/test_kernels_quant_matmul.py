"""Quantized matmul Pallas kernel vs oracle: bits/group/shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ref
from repro.kernels.ops import quantize_weight_for_matmul, quantized_linear
from repro.kernels.quant_matmul import quant_matmul, unpack_int4


@pytest.mark.parametrize("m,k,n,bits,gs,bm,bn,bk", [
    (64, 256, 128, 8, None, 32, 64, 128),
    (64, 256, 128, 4, None, 32, 64, 128),
    (32, 512, 256, 4, 128, 32, 128, 128),
    (128, 384, 128, 8, 128, 64, 128, 128),
    (16, 128, 64, 8, 64, 16, 64, 64),
    (256, 1024, 512, 4, 256, 128, 128, 256),
])
def test_quant_matmul_matches_ref(m, k, n, bits, gs, bm, bn, bk):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    wfp = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    wq, sc = quantize_weight_for_matmul(wfp, bits=bits, group_size=gs)
    y = quant_matmul(x, wq, sc, bits=bits, block_m=bm, block_n=bn,
                     block_k=bk, interpret=True)
    wq_un = unpack_int4(wq, signed=True) if bits == 4 else wq
    yr = ref.quant_matmul_ref(x, wq_un, sc, group_size=gs if gs else k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantized_linear_dtypes(dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(3, 5, 128)), dtype)
    wfp = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    wq, sc = quantize_weight_for_matmul(wfp, bits=8)
    y = quantized_linear(x, wq, sc, bits=8)
    assert y.shape == (3, 5, 64) and y.dtype == dtype
    yr = ref.quant_matmul_ref(x.reshape(-1, 128).astype(jnp.float32), wq, sc,
                              group_size=128)
    np.testing.assert_allclose(
        np.asarray(y.reshape(-1, 64), np.float32), np.asarray(yr),
        rtol=2e-2, atol=2e-1)


def test_quantization_error_scales_with_bits():
    """4-bit weight error > 8-bit weight error (sanity on the BW knob)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(32, 256)), jnp.float32)
    wfp = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    exact = x @ wfp
    errs = {}
    for bits in (4, 8):
        wq, sc = quantize_weight_for_matmul(wfp, bits=bits, group_size=64)
        y = quant_matmul(x, wq, sc, bits=bits, block_m=32, block_n=64,
                         block_k=64, interpret=True)
        errs[bits] = float(jnp.abs(y - exact).mean())
    assert errs[4] > errs[8] > 0


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([16, 64]),
    k=st.sampled_from([128, 256]),
    n=st.sampled_from([64, 128]),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 1000),
)
def test_property_quant_matmul(m, k, n, bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    wfp = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    wq, sc = quantize_weight_for_matmul(wfp, bits=bits)
    y = quant_matmul(x, wq, sc, bits=bits, block_m=16, block_n=64,
                     block_k=128, interpret=True)
    wq_un = unpack_int4(wq, signed=True) if bits == 4 else wq
    yr = ref.quant_matmul_ref(x, wq_un, sc, group_size=k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-3)
