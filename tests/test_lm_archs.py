"""Per-architecture smoke tests (deliverable f): reduced configs on CPU.

One forward + one train step asserting output shapes and no NaNs, plus the
prefill/decode == teacher-forced-forward equivalence for every family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.models.lm import model as M
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(
            key, (b, cfg.frontend_len, cfg.d_model))
    if cfg.family in ("encdec", "audio"):
        batch["enc_inputs"] = jax.random.normal(
            key, (b, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact published hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "seamless-m4t-large-v2": (48, 1024, 16, 16, 8192, 256206),
        "mamba2-1.3b": (48, 2048, 32, 32, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff if cfg.family != "moe" or arch == "arctic-480b" else cfg.moe_d_ff,
           cfg.vocab)
    if arch == "qwen2-moe-a2.7b":
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.moe_d_ff, cfg.vocab)
    assert got == expected
    if cfg.family == "moe":
        n_e = {"arctic-480b": (128, 2), "qwen2-moe-a2.7b": (60, 4)}[arch]
        assert (cfg.n_experts, cfg.top_k) == n_e
    if arch == "mamba2-1.3b":
        assert cfg.ssm_state == 128
    if arch == "recurrentgemma-2b":
        assert cfg.block_pattern == ("rec", "rec", "attn")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params, _ = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = M.forward_train(
        params, cfg, batch["tokens"], embeds=batch.get("embeds"),
        enc_inputs=batch.get("enc_inputs"))
    s_expect = batch["tokens"].shape[1] + (
        cfg.frontend_len if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_expect, M.padded_vocab(cfg))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    step = make_train_step(cfg, O.AdamWConfig(lr=1e-3, total_steps=10))
    opt = O.init_state(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2))
    assert moved


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params, _ = M.init_params(cfg, key)
    b, s, t0 = 2, 16, 8
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    kw = {}
    offset = 0
    if cfg.family == "vlm":
        kw["embeds"] = jax.random.normal(key, (b, cfg.frontend_len, cfg.d_model))
        offset = cfg.frontend_len
    if cfg.family in ("encdec", "audio"):
        kw["enc_inputs"] = jax.random.normal(
            key, (b, cfg.frontend_len, cfg.d_model))
    full, _ = M.forward_train(params, cfg, tokens, embeds=kw.get("embeds"),
                              enc_inputs=kw.get("enc_inputs"))
    logits, cache = M.prefill(params, cfg, tokens[:, :t0],
                              max_len=offset + s, embeds=kw.get("embeds"),
                              enc_inputs=kw.get("enc_inputs"))
    errs = [float(jnp.abs(logits[:, 0] - full[:, offset + t0 - 1]).max())]
    for t in range(t0, s):
        logits, cache = M.decode_step(
            params, cfg, tokens[:, t:t + 1], cache, jnp.int32(offset + t))
        errs.append(float(jnp.abs(logits[:, 0] - full[:, offset + t]).max()))
    assert max(errs) < 2e-2, f"decode drift {max(errs)}"


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b"])
def test_quantized_serving_params(arch):
    """quant_bits=8: int8 weights load and decode produces finite logits."""
    cfg = dataclasses.replace(reduced_config(arch), quant_bits=8)
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    int8_leaves = [x for x in jax.tree.leaves(params) if x.dtype == jnp.int8]
    assert int8_leaves, "no quantized weights found"
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, cache = M.prefill(params, cfg, tokens, max_len=16)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_long_context_skip_rule():
    """long_500k runs only for sub-quadratic families (DESIGN.md §4)."""
    sub = {a for a in ALL_ARCHS if get_config(a).subquadratic}
    assert sub == {"recurrentgemma-2b", "mamba2-1.3b"}
