"""MoE dispatch: lossless-capacity exactness, dropping, EP-shardable shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models.lm import moe as MOE


def _dense_reference(p, x, cfg):
    """Compute ALL experts on ALL tokens and combine with the gates."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["wg"]))
    h = h * jnp.einsum("td,edf->tef", xt, p["wi"])
    out_all = jnp.einsum("tef,efd->ted", h, p["wo"])  # [T, E, D]
    y = jnp.zeros_like(xt)
    for k in range(cfg.top_k):
        sel = jnp.take_along_axis(out_all, idx[:, k][:, None, None], 1)[:, 0]
        y = y + gate[:, k][:, None] * sel
    from repro.models.lm.common import mlp
    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], xt)
    if cfg.dense_residual:
        y = y + mlp(p["dense"], xt)
    return y.reshape(b, s, d)


@pytest.mark.parametrize("arch", ["arctic-480b", "qwen2-moe-a2.7b"])
def test_moe_lossless_capacity_equals_dense_reference(arch):
    cfg = reduced_config(arch)  # capacity_factor == n_experts -> no drops
    p, _ = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = MOE.moe_ffn(p, x, cfg)
    y_ref = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound is ~1


def test_moe_capacity_dropping_bounds_buffer():
    cfg = dataclasses.replace(reduced_config("qwen2-moe-a2.7b"),
                              capacity_factor=0.5)
    p, _ = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = MOE.moe_ffn(p, x, cfg)  # must not error; some tokens dropped
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_moe_aux_loss_balanced_router_is_minimal():
    """Uniform routing should give aux ~= 1 (the theoretical minimum)."""
    cfg = reduced_config("qwen2-moe-a2.7b")
    p, _ = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    # zero router weights -> uniform probs -> perfectly balanced expectation
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    _, aux = MOE.moe_ffn(p, x, cfg)
    assert 0.9 < float(aux) < 1.3


def test_moe_grad_flows_to_router_and_experts():
    cfg = reduced_config("qwen2-moe-a2.7b")
    p, _ = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = MOE.moe_ffn(p, x, cfg)
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0
    assert float(jnp.abs(g["wi"]).sum()) > 0
    assert float(jnp.abs(g["wo"]).sum()) > 0
