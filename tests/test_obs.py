"""Observability layer unit tests: tracer determinism under a fake clock,
Chrome trace-event schema validation, metrics registry semantics
(get-or-create, type/bucket conflicts, Prometheus exposition), NaN-free
snapshots at zero completions, and histogram property tests (bucket-count
conservation, quantile bounds, merge associativity) under hypothesis — or
the `tests/_hypothesis_fallback` harness on machines without it."""
import json

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.obs import (
    LATENCY_BUCKETS_S,
    MetricsRegistry,
    Tracer,
    render_report,
    summarize_trace,
    validate_chrome_trace,
)
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.obs.summary import async_durations, span_groups


class FakeClock:
    def __init__(self, t0: float = 0.0, step: float = 0.0):
        self.t = t0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def _record_session(tracer):
    tracer.name_track(OT.TID_ENGINE, "engine")
    tracer.complete("form_batch", 1.0, 1.5, cat="pipeline",
                    tid=OT.TID_SCHED, args={"bucket": 4})
    tracer.instant("retrace:Body", 2.0, cat="retrace")
    tracer.counter("queue_depth", {"pending": 3}, 2.5)
    tracer.async_begin("request", 7, 3.0, cat="request:m")
    tracer.async_end("request", 7, 4.0, cat="request:m",
                     args={"status": "ok"})
    with tracer.span("tune:dw", cat="tune", tid=OT.TID_TUNE):
        pass


def test_tracer_deterministic_under_fake_clock():
    """Two identically-driven fake-clock tracers export byte-identical
    JSON — the trace of a deterministic run is itself deterministic."""
    docs = []
    for _ in range(2):
        tracer = Tracer(FakeClock(step=0.125), origin_s=0.0)
        _record_session(tracer)
        docs.append(json.dumps(tracer.to_chrome(), sort_keys=True))
    assert docs[0] == docs[1]


def test_tracer_timebase_microseconds_from_origin():
    tracer = Tracer(FakeClock(), origin_s=10.0)
    tracer.complete("work", 10.5, 10.75)
    (ev,) = tracer.events
    assert ev["ts"] == pytest.approx(0.5e6)
    assert ev["dur"] == pytest.approx(0.25e6)
    # inverted span (clock skew between explicit stamps) clamps, not negates
    tracer.complete("skew", 11.0, 10.0)
    assert tracer.events[-1]["dur"] == 0.0


def test_tracer_export_and_validate():
    tracer = Tracer(FakeClock(step=0.1), origin_s=0.0,
                    process_name="test-proc")
    _record_session(tracer)
    doc = tracer.to_chrome()
    assert validate_chrome_trace(doc) == []
    # metadata first: process name + every named track precede the events
    metas = [ev for ev in doc["traceEvents"] if ev["ph"] == "M"]
    assert metas and doc["traceEvents"][:len(metas)] == metas
    names = {ev["args"]["name"] for ev in metas}
    assert {"test-proc", "engine"} <= names


def test_tracer_name_track_dedupes():
    tracer = Tracer(FakeClock(), origin_s=0.0)
    tracer.name_track(5, "stage:Body")
    tracer.name_track(5, "stage:Body")
    thread_metas = [ev for ev in tracer.to_chrome()["traceEvents"]
                    if ev["ph"] == "M" and ev["name"] == "thread_name"]
    assert len(thread_metas) == 1


def test_tracer_save_roundtrip(tmp_path):
    tracer = Tracer(FakeClock(step=0.1), origin_s=0.0)
    _record_session(tracer)
    path = tracer.save(str(tmp_path / "trace.json"))
    with open(path) as f:
        loaded = json.load(f)
    assert validate_chrome_trace(loaded) == []
    assert loaded == json.loads(json.dumps(tracer.to_chrome()))


@pytest.mark.parametrize("doc, fragment", [
    ([], "traceEvents"),
    ({"traceEvents": 5}, "not an array"),
    ({"traceEvents": [{"ph": "Z", "name": "x", "pid": 0, "tid": 0}]},
     "unknown phase"),
    ({"traceEvents": [{"ph": "i", "pid": 0, "tid": 0, "ts": 1, "s": "t"}]},
     "missing name"),
    ({"traceEvents": [{"ph": "i", "name": "x", "ts": 1}]}, "integer"),
    ({"traceEvents": [{"ph": "X", "name": "x", "pid": 0, "tid": 0,
                       "ts": 1, "dur": -2.0}]}, "dur"),
    ({"traceEvents": [{"ph": "C", "name": "x", "pid": 0, "tid": 0,
                       "ts": 1}]}, "args"),
    ({"traceEvents": [{"ph": "e", "name": "r", "cat": "request", "id": 1,
                       "pid": 0, "tid": 0, "ts": 1}]}, "without begin"),
    ({"traceEvents": [{"ph": "b", "name": "r", "cat": "request", "id": 1,
                       "pid": 0, "tid": 0, "ts": 1}]}, "without end"),
    ({"traceEvents": [{"ph": "b", "name": "r", "pid": 0, "tid": 0,
                       "ts": 1, "id": 1}]}, "id and cat"),
])
def test_validate_catches_schema_violations(doc, fragment):
    errors = validate_chrome_trace(doc)
    assert errors and any(fragment in e for e in errors), errors


def test_null_tracer_is_falsy_noop():
    assert not OT.NULL
    OT.NULL.complete("x", 0.0, 1.0)
    OT.NULL.instant("x")
    with OT.NULL.span("x"):
        pass
    assert OT.NULL.to_chrome() == {"traceEvents": []}
    with pytest.raises(ValueError):
        OT.NULL.save("/tmp/never.json")


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_monotone():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests", labels={"model": "m"})
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)


def test_registry_get_or_create_and_conflicts():
    reg = MetricsRegistry()
    a = reg.counter("x_total", labels={"model": "a"})
    assert reg.counter("x_total", labels={"model": "a"}) is a
    # same name, different labels: a sibling, not the same handle
    assert reg.counter("x_total", labels={"model": "b"}) is not a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    with pytest.raises(ValueError, match="buckets"):
        reg.histogram("lat_seconds", buckets=(0.5, 1.0))


def test_histogram_rejects_bad_bounds():
    with pytest.raises(ValueError, match="at least one"):
        OM.Histogram("h", ())
    with pytest.raises(ValueError, match="strictly"):
        OM.Histogram("h", (1.0, 1.0, 2.0))
    with pytest.raises(ValueError, match="strictly"):
        OM.Histogram("h", (2.0, 1.0))
    with pytest.raises(ValueError, match="finite"):
        OM.Histogram("h", (1.0, float("inf")))


def test_snapshot_safe_at_zero_completions():
    """A snapshot before any traffic has no NaN anywhere — every value is
    finite-or-None, so strict JSON encoding succeeds."""
    reg = MetricsRegistry()
    reg.counter("reqs_total")
    g = reg.gauge("fps")
    reg.histogram("lat_seconds")
    g.set(float("nan"))  # a gauge fed garbage must not poison the export
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)
    assert snap["gauges"]["fps"] is None
    h = snap["histograms"]["lat_seconds"]
    assert h["count"] == 0
    assert h["p50"] is None and h["p95"] is None and h["p99"] is None


def test_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "requests served",
                labels={"model": "m"}).inc(3)
    reg.gauge("fps").set(42.0)
    h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = reg.to_prometheus()
    assert "# TYPE reqs_total counter" in text
    assert 'reqs_total{model="m"} 3.0' in text
    assert "# HELP reqs_total requests served" in text
    assert "# TYPE lat_seconds histogram" in text
    # cumulative le rows; the +Inf bucket equals the total count
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1.0"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text


def test_registry_save_formats(tmp_path):
    reg = MetricsRegistry()
    reg.counter("x_total").inc()
    prom = tmp_path / "m.prom"
    reg.save(str(prom))
    assert "# TYPE x_total counter" in prom.read_text()
    js = tmp_path / "m.json"
    reg.save(str(js))
    assert json.loads(js.read_text())["counters"]["x_total"] == 1.0


def test_null_registry_is_falsy_and_absorbs():
    assert not OM.NULL_REGISTRY
    c = OM.NULL_REGISTRY.counter("anything")
    assert c is OM.NULL_INSTRUMENT
    c.inc()
    c.observe(1.0)
    c.set(2.0)
    c.dec()


# ---------------------------------------------------------------------------
# histogram properties
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(n=st.integers(min_value=0, max_value=64),
       seed=st.integers(min_value=0, max_value=10_000))
def test_histogram_count_conservation(n, seed):
    """Every observation lands in exactly one bucket: sum(counts) == count
    and sum == the running total, for arbitrary value streams."""
    import random
    rng = random.Random(seed)
    h = OM.Histogram("h", LATENCY_BUCKETS_S)
    total = 0.0
    for _ in range(n):
        v = rng.uniform(0.0, 20.0)
        h.observe(v)
        total += v
    assert sum(h.counts) == h.count == n
    assert h.sum == pytest.approx(total)


@settings(max_examples=30)
@given(n=st.integers(min_value=1, max_value=64),
       q=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=10_000))
def test_histogram_quantile_bounded_by_bucket_range(n, q, seed):
    import random
    rng = random.Random(seed)
    h = OM.Histogram("h", LATENCY_BUCKETS_S)
    for _ in range(n):
        h.observe(rng.uniform(0.0, 20.0))
    est = h.quantile(q)
    assert est is not None
    assert 0.0 <= est <= LATENCY_BUCKETS_S[-1]


@settings(max_examples=20)
@given(na=st.integers(min_value=0, max_value=32),
       nb=st.integers(min_value=0, max_value=32),
       nc=st.integers(min_value=0, max_value=32),
       seed=st.integers(min_value=0, max_value=10_000))
def test_histogram_merge_associative_commutative(na, nb, nc, seed):
    """merge is a pointwise sum under identical bounds: (a+b)+c == a+(b+c)
    and a+b == b+a — shard-local histograms compose into the fleet view
    in any order."""
    import random
    rng = random.Random(seed)

    def make(n):
        h = OM.Histogram("h", LATENCY_BUCKETS_S)
        for _ in range(n):
            h.observe(rng.uniform(0.0, 20.0))
        return h

    a, b, c = make(na), make(nb), make(nc)

    def state(h):
        return (h.counts, h.count, pytest.approx(h.sum))

    assert state(a.merge(b).merge(c)) == state(a.merge(b.merge(c)))
    assert state(a.merge(b)) == state(b.merge(a))


def test_histogram_merge_requires_identical_buckets():
    a = OM.Histogram("h", (0.1, 1.0))
    b = OM.Histogram("h", (0.2, 1.0))
    with pytest.raises(ValueError, match="different buckets"):
        a.merge(b)


# ---------------------------------------------------------------------------
# summary
# ---------------------------------------------------------------------------


def _evt(ph, name, ts, **kw):
    return dict({"ph": ph, "name": name, "pid": 0, "tid": 0, "ts": ts}, **kw)


def test_async_durations_matches_cat_prefix_and_keys_by_cat_id():
    """The engine qualifies the request category per model; rids are only
    unique per model, so pairing must key on (cat, id) — two models' rid=0
    on one shared tracer must not collide."""
    events = [
        _evt("b", "request", 0.0, cat="request:a", id=0),
        _evt("b", "request", 0.0, cat="request:b", id=0),
        _evt("e", "request", 2e6, cat="request:a", id=0),
        _evt("e", "request", 5e6, cat="request:b", id=0),
        # unrelated category: ignored despite the name
        _evt("b", "request", 0.0, cat="other", id=0),
        _evt("e", "request", 9e6, cat="other", id=0),
    ]
    durs = async_durations(events, "request")
    assert durs == {("request:a", 0): pytest.approx(2.0),
                    ("request:b", 0): pytest.approx(5.0)}
    # exact (unqualified) category still matches
    exact = async_durations(
        [_evt("b", "request", 0.0, cat="request", id=3),
         _evt("e", "request", 1e6, cat="request", id=3)], "request")
    assert exact == {("request", 3): pytest.approx(1.0)}


def test_span_groups_sorted_by_total():
    events = [
        _evt("X", "small", 0.0, dur=10.0),
        _evt("X", "big", 0.0, dur=100.0),
        _evt("X", "small", 0.0, dur=20.0),
        _evt("i", "not_a_span", 0.0, s="t"),
    ]
    groups = span_groups(events)
    assert [g["name"] for g in groups] == ["big", "small"]
    small = groups[1]
    assert small["count"] == 2
    assert small["mean_us"] == pytest.approx(15.0)
    assert small["max_us"] == pytest.approx(20.0)


def test_summarize_and_render_zero_completions():
    """An empty trace + a zero-traffic snapshot render without NaN or
    division by zero — the mid-drain / nothing-served report is
    well-defined."""
    summary = summarize_trace({"traceEvents": []})
    assert summary["requests"]["completed"] == 0
    assert summary["requests"]["latency_p50_s"] is None
    assert summary["queue_wait"]["n"] == 0
    reg = MetricsRegistry()
    reg.histogram("lat_seconds")
    text = render_report(summary, reg.snapshot())
    assert "0 completed" in text
    assert "nan" not in text.lower()


def test_summarize_trace_counts_statuses():
    tracer = Tracer(FakeClock(step=0.5), origin_s=0.0)
    tracer.async_begin("request", 0, 1.0, cat="request:m")
    tracer.async_end("request", 0, 2.0, cat="request:m",
                     args={"status": "ok"})
    tracer.async_begin("request", 1, 1.0, cat="request:m")
    tracer.async_end("request", 1, 1.5, cat="request:m",
                     args={"status": "expired"})
    summary = summarize_trace(tracer.to_chrome())
    assert summary["requests"]["completed"] == 2
    assert summary["requests"]["by_status"] == {"ok": 1, "expired": 1}
    assert summary["requests"]["latency_p50_s"] == pytest.approx(0.5)
    assert summary["requests"]["latency_p99_s"] == pytest.approx(1.0)
