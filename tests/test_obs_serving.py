"""Observability wired through the serving, tuning, and training layers:
deterministic fake-clock traces of a pipelined drain, full request-lifecycle
coverage in the exported Chrome trace, obs-on bit-exactness, retrace-leak
detection (warning + metric + stats), NaN-free snapshots at zero
completions / all-expired drains, multi-model tracing on one shared
timeline, autotune provenance spans, and trainer metrics."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

import jax

from repro.models import efficientnet as effn, mobilenet_v2 as mnv2
from repro.models.layers import make_calibrated_qnet
from repro.obs import (
    MetricsRegistry,
    Tracer,
    summarize_trace,
    validate_chrome_trace,
)
from repro.serve.vision import MultiModelEngine, VisionEngine
from repro.train import vision as V
from repro.tune import tune_qnet

HW = 32


class FakeClock:
    def __init__(self, t0: float = 0.0, step: float = 0.0):
        self.t = t0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def mnv2_qnet():
    return make_calibrated_qnet(
        mnv2.build(alpha=0.35, input_hw=HW, num_classes=10))


@pytest.fixture(scope="module")
def effnet_qnet():
    return make_calibrated_qnet(
        effn.build_compact(input_hw=HW, num_classes=10))


def _images(n, seed=7):
    return np.asarray(jax.random.uniform(
        jax.random.PRNGKey(seed), (n, HW, HW, 3), minval=-1, maxval=1))


def _traced_drain(qnet, n=4):
    """One full obs-enabled drain under a fake clock; returns
    (trace document, metrics registry, results)."""
    clock = FakeClock(step=1e-3)
    tracer = Tracer(clock, origin_s=0.0)
    reg = MetricsRegistry()
    eng = VisionEngine(qnet, buckets=(2,), clock=clock, tracer=tracer,
                       metrics=reg, name="m")
    rids = [eng.submit(img) for img in _images(n)]
    results = eng.run()
    assert sorted(results) == rids
    return tracer.to_chrome(), reg, results


# ---------------------------------------------------------------------------
# deterministic, schema-valid, lifecycle-complete traces
# ---------------------------------------------------------------------------


def test_trace_deterministic_across_runs(mnv2_qnet):
    """Fresh fake clock + fresh tracer, same inputs -> byte-identical
    exported trace: the obs layer adds no hidden nondeterminism."""
    doc1, _, _ = _traced_drain(mnv2_qnet)
    doc2, _, _ = _traced_drain(mnv2_qnet)
    assert json.dumps(doc1, sort_keys=True) == json.dumps(doc2,
                                                          sort_keys=True)


def test_trace_covers_every_request_lifecycle(mnv2_qnet):
    doc, reg, results = _traced_drain(mnv2_qnet, n=4)
    assert validate_chrome_trace(doc) == []
    events = doc["traceEvents"]

    def named(ph, name):
        return [ev for ev in events
                if ev["ph"] == ph and ev["name"] == name]

    # one open + one ok-close per admitted request, in the model's category
    begins = named("b", "request")
    ends = named("e", "request")
    assert {ev["id"] for ev in begins} == set(results)
    assert {ev["id"] for ev in ends} == set(results)
    assert all(ev["cat"] == "request:m" for ev in begins + ends)
    assert all(ev["args"]["status"] == "ok" for ev in ends)
    # queue-wait pairs for every request that rode a micro-batch
    assert len(named("b", "queue_wait")) == len(results)
    # 4 requests at bucket 2 -> 2 form_batch spans, each stage dispatched
    # once per micro-batch, one drain span over the whole run()
    form = named("X", "form_batch")
    assert len(form) == 2
    assert all(ev["args"]["bucket"] == 2 for ev in form)
    n_stages = len({ev["name"] for ev in events
                    if ev["ph"] == "X" and ev["name"].startswith("dispatch:")})
    dispatches = [ev for ev in events
                  if ev["ph"] == "X" and ev["name"].startswith("dispatch:")]
    assert len(dispatches) == 2 * n_stages and n_stages >= 2
    assert len(named("X", "drain")) == 1
    # the summary reconstructs the same lifecycle from the document alone
    summary = summarize_trace(doc)
    assert summary["requests"]["completed"] == len(results)
    assert summary["requests"]["by_status"] == {"ok": len(results)}
    assert summary["queue_wait"]["n"] == len(results)
    # metrics agree with the trace
    snap = reg.snapshot()
    assert snap["counters"]['serve_requests_completed_total{model="m"}'] == 4
    assert snap["counters"]['serve_micro_batches_total{model="m"}'] == 2
    assert snap["histograms"][
        'serve_request_latency_seconds{model="m"}']["count"] == 4
    json.dumps(snap, allow_nan=False)


def test_obs_on_is_bit_exact(mnv2_qnet):
    imgs = _images(4)
    plain = VisionEngine(mnv2_qnet, buckets=(2,))
    rids = [plain.submit(img) for img in imgs]
    want = plain.run()
    _, _, got = _traced_drain(mnv2_qnet, n=4)
    for rid in rids:
        np.testing.assert_array_equal(got[rid].logits, want[rid].logits)


# ---------------------------------------------------------------------------
# retrace-leak detection
# ---------------------------------------------------------------------------


def test_retrace_leak_warns_and_counts(mnv2_qnet):
    """A caller bypassing the batch former (novel batch shape straight
    into a stage executor) is a silent recompile-per-shape stall: the
    stage must warn, bump the metric, and surface in stats()."""
    clock = FakeClock(step=1e-3)
    reg = MetricsRegistry()
    eng = VisionEngine(mnv2_qnet, buckets=(2,), clock=clock,
                       tracer=Tracer(clock, origin_s=0.0), metrics=reg,
                       name="m")
    head = eng.stages[0]
    cu_name = head.spec.cu
    assert eng.stats().stage_retraces == {
        s.spec.cu: 0 for s in eng.stages}
    with pytest.warns(RuntimeWarning, match="retrace at non-bucketed"):
        head(jnp.asarray(_images(3), jnp.float32))  # 3 is not a bucket
    assert eng.stats().stage_retraces[cu_name] == 1
    key = f'serve_stage_retraces_total{{cu="{cu_name}",model="m"}}'
    assert reg.snapshot()["counters"][key] == 1
    # bucketed shapes stay silent
    head(jnp.asarray(_images(2), jnp.float32))
    assert eng.stats().stage_retraces[cu_name] == 1


# ---------------------------------------------------------------------------
# zero-completion / expiry snapshot safety
# ---------------------------------------------------------------------------


def test_stats_and_snapshot_defined_with_no_traffic(mnv2_qnet):
    clock = FakeClock(step=1e-3)
    reg = MetricsRegistry()
    eng = VisionEngine(mnv2_qnet, buckets=(2,), clock=clock,
                       tracer=Tracer(clock, origin_s=0.0), metrics=reg,
                       name="m")
    assert eng.run() == {}  # draining an empty queue is a no-op
    st = eng.stats()
    assert st.n_ok == 0 and st.pad_fraction == 0.0
    json.dumps(reg.snapshot(), allow_nan=False)


def test_all_expired_drain_closes_spans_and_counts(mnv2_qnet):
    clock = FakeClock(t0=100.0, step=1e-3)
    tracer = Tracer(clock, origin_s=100.0)
    reg = MetricsRegistry()
    eng = VisionEngine(mnv2_qnet, buckets=(2,), clock=clock, tracer=tracer,
                       metrics=reg, name="m")
    rid = eng.submit(_images(1)[0], deadline_s=1.0)  # long past
    results = eng.run()
    assert results[rid].status == "expired"
    st = eng.stats()
    assert st.n_ok == 0 and st.n_expired == 1
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)
    assert snap["counters"]['serve_requests_expired_total{model="m"}'] == 1
    assert snap["histograms"][
        'serve_request_latency_seconds{model="m"}']["p50"] is None
    doc = tracer.to_chrome()
    assert validate_chrome_trace(doc) == []  # expiry closed the async span
    summary = summarize_trace(doc)
    assert summary["requests"]["by_status"] == {"expired": 1}


# ---------------------------------------------------------------------------
# multi-model: one shared timeline
# ---------------------------------------------------------------------------


def test_multimodel_shared_tracer_one_timeline(mnv2_qnet, effnet_qnet):
    clock = FakeClock(step=1e-3)
    tracer = Tracer(clock, origin_s=0.0)
    reg = MetricsRegistry()
    mm = MultiModelEngine({
        "mnv2": VisionEngine(mnv2_qnet, buckets=(2,), clock=clock,
                             tracer=tracer, metrics=reg, name="mnv2"),
        "effnet": VisionEngine(effnet_qnet, buckets=(2,), clock=clock,
                               tracer=tracer, metrics=reg, name="effnet"),
    }, clock=clock)
    for img in _images(2):
        mm.submit("mnv2", img)
        mm.submit("effnet", img)
    results = mm.run()
    assert len(results) == 4
    doc = tracer.to_chrome()
    assert validate_chrome_trace(doc) == []
    events = doc["traceEvents"]
    # per-model request categories keep rid 0/1 of each model distinct
    cats = {ev["cat"] for ev in events
            if ev.get("ph") == "b" and ev["name"] == "request"}
    assert cats == {"request:mnv2", "request:effnet"}
    summary = summarize_trace(doc)
    assert summary["requests"]["completed"] == 4
    # one router_dispatch instant per dispatch_log entry, counters agree
    instants = [ev for ev in events
                if ev["ph"] == "i" and ev["name"] == "router_dispatch"]
    assert len(instants) == len(mm.dispatch_log)
    per_model = {m: sum(1 for n, _ in mm.dispatch_log if n == m)
                 for m in ("mnv2", "effnet")}
    snap = reg.snapshot()
    for m, n in per_model.items():
        assert snap["counters"][f'router_dispatch_total{{model="{m}"}}'] == n


# ---------------------------------------------------------------------------
# autotune provenance spans
# ---------------------------------------------------------------------------


def _tiny_net():
    from repro.core import graph as G
    blocks = (
        G.BlockSpec("stem", (
            G.OpSpec("stem/conv", G.CONV, 3, 8, 3, 2, G.RELU6, 8, 4),)),
        G.BlockSpec("b1", (
            G.OpSpec("b1/expand", G.PW, 8, 16, 1, 1, G.RELU6, 4, 4),
            G.OpSpec("b1/dw", G.DW, 16, 16, 3, 1, G.RELU6, 4, 4),
            G.OpSpec("b1/project", G.PW, 16, 8, 1, 1, G.NONE, 4, 4),
        ), residual=True),
        G.BlockSpec("tail", (
            G.OpSpec("tail/pw", G.PW, 8, 16, 1, 1, G.RELU6, 4, 4),),
            avgpool=True),
        G.BlockSpec("classifier", (
            G.OpSpec("classifier/fc", G.DENSE, 16, 7, 1, 1, G.NONE, 4, 4),)),
    )
    return G.NetSpec(name="tiny", blocks=blocks, input_hw=16, input_ch=3,
                     num_classes=7)


def test_autotune_emits_provenance_spans():
    qnet = make_calibrated_qnet(_tiny_net())
    clock = FakeClock(step=1e-4)
    tracer = Tracer(clock, origin_s=0.0)

    def measure(fn, x, candidate=None):
        return 1.0

    plan = tune_qnet(qnet, batch=2, measure=measure, tracer=tracer)
    doc = tracer.to_chrome()
    assert validate_chrome_trace(doc) == []
    events = doc["traceEvents"]
    spans = [ev for ev in events
             if ev["ph"] == "X" and ev["name"].startswith("tune:")]
    # one candidate-timing span per (key, candidate), each carrying the
    # measured-or-disqualified provenance
    assert len(spans) >= len(plan.entries)
    assert all("candidate" in ev["args"] and "disqualified" in ev["args"]
               for ev in spans)
    winners = [ev for ev in events
               if ev["ph"] == "i" and ev["name"] == "tune_winner"]
    assert len(winners) == len(plan.entries)  # one fresh selection per key
    assert ({ev["args"]["key"] for ev in winners}
            == set(plan.entries))
    # the autotune track is metadata-named
    assert any(ev["ph"] == "M" and ev["name"] == "thread_name"
               and ev["args"]["name"] == "autotune" for ev in events)


# ---------------------------------------------------------------------------
# trainer metrics + spans
# ---------------------------------------------------------------------------


def test_train_emits_metrics_and_phase_spans(tmp_path):
    # same net/batch geometry as tests/test_train_vision.CFG so the jitted
    # train step is already compiled when that module ran first
    cfg = V.VisionTrainConfig(
        model="mobilenet_v2", alpha=0.35, input_hw=16, num_classes=4,
        float_steps=2, qat_steps=4, batch=8, anneal_from=8,
        calibrate_every=2, ckpt_every=2)
    clock = FakeClock(step=1e-3)
    tracer = Tracer(clock, origin_s=0.0)
    reg = MetricsRegistry()
    result = V.train(cfg, ckpt_dir=str(tmp_path), tracer=tracer,
                     metrics=reg)
    assert result.done
    snap = reg.snapshot()
    json.dumps(snap, allow_nan=False)
    assert snap["counters"]["train_steps_total"] == result.step
    assert snap["gauges"]["train_act_bits"] == 4.0  # final anneal stage
    assert snap["counters"]["train_calibration_rounds_total"] == len(
        result.history["calibration"])
    assert snap["histograms"]["train_checkpoint_seconds"]["count"] >= 1
    assert snap["gauges"]["train_loss"] is not None
    doc = tracer.to_chrome()
    assert validate_chrome_trace(doc) == []
    names = {ev["name"] for ev in doc["traceEvents"] if ev["ph"] == "X"}
    for ph in V.phase_schedule(cfg):
        assert f"phase:{ph.name}" in names
    assert "calibration_round" in names
    assert "checkpoint" in names
