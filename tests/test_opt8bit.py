"""8-bit AdamW states (the paper's quantization applied to the optimizer)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train import optimizer as O


def test_state_bytes_4x_smaller():
    params = {"w": jnp.zeros((256, 128), jnp.bfloat16)}
    full = O.init_state(params)
    q8 = O.init_state(params, state_bits=8)
    b_full = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(full.m))
    b_q8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(q8.m))
    assert b_full / b_q8 > 3.4  # int8 + per-row f32 scale/zero ~= 3.5-4x


def test_8bit_adamw_converges_like_fp32():
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    results = {}
    for bits in (None, 8):
        params = {"w": jnp.zeros((64, 16), jnp.float32)}
        ocfg = O.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                             total_steps=300, schedule="constant",
                             state_bits=bits)
        st = O.init_state(params, state_bits=bits)
        step = jax.jit(lambda p, s: O.apply_updates(
            p, jax.grad(loss)(p), s, ocfg)[:2])
        for _ in range(300):
            params, st = step(params, st)
        results[bits] = float(loss(params))
    assert results[8] < 1e-2, results
    assert results[8] < results[None] * 50  # same ballpark as fp32 states


def test_8bit_state_roundtrip_error():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(32, 64)), jnp.float32)
    q = O._quantize_state_leaf(x)
    xr = O._dq8(q)
    rel = float(jnp.abs(xr - x).max() / jnp.abs(x).max())
    assert rel < 0.02


def test_8bit_v_log_quantization_handles_dynamic_range():
    """v spans many decades within a row; log-domain keeps relative error."""
    v = jnp.asarray([[1e-12, 1e-6, 1e-2, 10.0]] * 4, jnp.float32)
    q = O._quantize_v_leaf(v)
    vr = O._dq8_v(q)
    rel = jnp.abs(vr - v) / v
    assert float(rel.max()) < 0.15  # every decade preserved to ~±15%
