"""§Perf hillclimb levers must preserve semantics (see EXPERIMENTS.md §Perf)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models.lm import model as M
from repro.models.lm import rglru as RG


def _decode_drift(cfg, key, s=16, t0=8):
    params, _ = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, s), 0, cfg.vocab)
    full, _ = M.forward_train(params, cfg, tokens)
    logits, cache = M.prefill(params, cfg, tokens[:, :t0], max_len=s)
    errs = [float(jnp.abs(logits[:, 0] - full[:, t0 - 1]).max())]
    for t in range(t0, s):
        logits, cache = M.decode_step(
            params, cfg, tokens[:, t:t + 1], cache, jnp.int32(t))
        errs.append(float(jnp.abs(logits[:, 0] - full[:, t]).max()))
    return max(errs)


def test_kv_cache_int8_decode_within_quant_tolerance():
    cfg = dataclasses.replace(reduced_config("llama3.2-1b"), kv_bits=8)
    assert _decode_drift(cfg, jax.random.PRNGKey(0)) < 0.35


def test_rglru_diagonal_gates_exact_decode():
    cfg = dataclasses.replace(reduced_config("recurrentgemma-2b"),
                              rglru_diagonal_gates=True)
    assert _decode_drift(cfg, jax.random.PRNGKey(0)) < 2e-2


def test_rglru_chunked_scan_matches_full_scan():
    """chunk > 0 must be numerically equivalent to the full associative scan."""
    cfg = reduced_config("recurrentgemma-2b")
    p, _ = RG.init_rglru_block(jax.random.PRNGKey(0), cfg)
    xc = jax.random.normal(jax.random.PRNGKey(1), (2, 19, cfg.lru_width))
    h_full, last_full = RG.rglru_scan(p, xc, chunk=0)
    for chunk in (4, 8, 16):
        h_c, last_c = RG.rglru_scan(p, xc, chunk=chunk)
        np.testing.assert_allclose(np.asarray(h_full, np.float32),
                                   np.asarray(h_c, np.float32),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(last_full), np.asarray(last_c),
                                   rtol=1e-4, atol=1e-4)


def test_kv_quant_roundtrip_error_bound():
    from repro.models.lm.common import kv_dequant, kv_quant
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16)) * 3
    q, s = kv_quant(x)
    xr = kv_dequant(q, s, jnp.float32)
    rel = float(jnp.abs(xr - x).max() / jnp.abs(x).max())
    assert rel < 1e-2
    assert q.dtype == jnp.int8


def test_grouped_gqa_matches_repeat_reference():
    """Grouped GQA (no materialized K/V repeat — §Perf cell A iter 1) must be
    numerically identical to the explicit-repeat formulation."""
    from repro.models.lm import common as C
    key = jax.random.PRNGKey(0)
    for (h, kv, sq, sk) in [(8, 2, 6, 6), (4, 1, 3, 9), (8, 4, 5, 5)]:
        q = jax.random.normal(key, (2, sq, h, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (2, sk, kv, 16))
        v = jax.random.normal(jax.random.PRNGKey(2), (2, sk, kv, 16))
        out = C.full_attention(q, k, v, causal=True)
        kr, vr = C._repeat_kv(k, h), C._repeat_kv(v, h)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * 16**-0.5
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        sc = jnp.where(mask[None, None], sc, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), vr)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=1e-4, atol=1e-5)
