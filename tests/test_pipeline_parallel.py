"""Pipeline parallelism (dist/pp.py): numeric equivalence + multi-pod compile.

Run in subprocesses — the multi-device cases need their own
XLA_FLAGS=--xla_force_host_platform_device_count, which must never leak into
the main test process.
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int) -> str:
    env = dict(os.environ, PYTHONPATH=SRC)
    prelude = (f"import os\n"
               f"os.environ['XLA_FLAGS']="
               f"'--xla_force_host_platform_device_count={devices}'\n")
    out = subprocess.run([sys.executable, "-c", prelude + code],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_two_stage_pipeline_matches_plain_forward():
    """2 stages x 2 microbatches on 8 fake devices == non-pipelined loss."""
    out = _run("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.configs import reduced_config
from repro.dist import pp
from repro.launch.mesh import make_mesh
from repro.models.lm import model as M

cfg = dataclasses.replace(reduced_config("llama3.2-1b"), n_layers=4)
params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)

# reference: plain (non-pipelined) loss
ref = M.loss_fn(params, cfg, {"tokens": tokens})
# remove the aux term for comparison (pp loss has no aux)
logits, _ = M.forward_train(params, cfg, tokens)
lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
oh = jax.nn.one_hot(tokens[:, 1:], lp.shape[-1], dtype=lp.dtype)
ref_loss = float(-(lp * oh).sum(-1).mean())

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
sp = dict(params)
sp["layers"] = pp.split_stage_params(params["layers"], 2)
loss_fn = pp.make_pp_loss(cfg, n_stages=2, n_micro=2)

specs_p = jax.tree.map(lambda _: P(), params)
specs_p["layers"] = jax.tree.map(lambda _: P("pod"), sp["layers"])
f = shard_map(loss_fn, mesh=mesh, in_specs=(specs_p, P()), out_specs=P(),
              check_rep=False)
pp_loss = float(jax.jit(f)(sp, tokens))
print("ref", ref_loss, "pp", pp_loss)
assert abs(pp_loss - ref_loss) < 5e-2 * max(1.0, abs(ref_loss)), (ref_loss, pp_loss)
print("OK")
""", devices=8)
    assert "OK" in out


def test_pipeline_grads_flow_to_all_stages():
    out = _run("""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.configs import reduced_config
from repro.dist import pp
from repro.launch.mesh import make_mesh
from repro.models.lm import model as M

cfg = dataclasses.replace(reduced_config("llama3.2-1b"), n_layers=4)
params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
sp = dict(params)
sp["layers"] = pp.split_stage_params(params["layers"], 2)
loss_fn = pp.make_pp_loss(cfg, n_stages=2, n_micro=2)
specs_p = jax.tree.map(lambda _: P(), params)
specs_p["layers"] = jax.tree.map(lambda _: P("pod"), sp["layers"])
f = shard_map(loss_fn, mesh=mesh, in_specs=(specs_p, P()), out_specs=P(),
              check_rep=False)
g = jax.jit(jax.grad(f))(sp, tokens)
# gradient energy must reach BOTH stages' layer blocks
gl = g["layers"]["mix"]["wq"]["w"]  # [S=2, L/2, D, H]
import numpy as np
e = np.asarray(jnp.sum(jnp.abs(gl.astype(jnp.float32)), axis=(1, 2, 3)))
assert (e > 0).all(), e
print("OK")
""", devices=8)
    assert "OK" in out


def test_pipeline_compiles_on_production_multipod_mesh():
    """2 pipeline stages == the 2 pods of the 2x16x16 production mesh."""
    out = _run("""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.configs import get_config
from repro.dist import pp
from repro.launch.mesh import make_production_mesh
from repro.models.lm import model as M

cfg = dataclasses.replace(get_config("llama3.2-1b"), scan_unroll=False)
mesh = make_production_mesh(multi_pod=True)
key = jax.random.PRNGKey(0)
shapes = jax.eval_shape(lambda k: M.init_params(cfg, k)[0], key)
sp_shapes = dict(shapes)
sp_shapes["layers"] = jax.tree.map(
    lambda x: jax.ShapeDtypeStruct((2, x.shape[0] // 2, *x.shape[1:]), x.dtype),
    shapes["layers"])
tokens = jax.ShapeDtypeStruct((32, 4096), jnp.int32)
loss_fn = pp.make_pp_loss(cfg, n_stages=2, n_micro=4)
specs_p = jax.tree.map(lambda _: P(), shapes)
specs_p["layers"] = jax.tree.map(lambda _: P("pod"), sp_shapes["layers"])
f = shard_map(loss_fn, mesh=mesh, in_specs=(specs_p, P()), out_specs=P(),
              check_rep=False)
lowered = jax.jit(jax.grad(f)).lower(sp_shapes, tokens)
compiled = lowered.compile()
txt = compiled.as_text()
assert "collective-permute" in txt  # the stage-to-stage activation transfer
print("OK compile, permutes present")
""", devices=512)
    assert "OK" in out
