"""Mixed-precision search (`repro.tune.precision`) + the PR-10 precision
plumbing regressions: heterogeneous cache keys, quantization-name
idempotence, per-op allocation maps through graph/build records, and the
fake-driven (deterministic, train-free) search/artifact pipeline CI's
smoke job runs."""
import dataclasses
import json

import pytest

from repro.core import graph as G, qnet as Q
from repro.models import mobilenet_v2 as mnv2
from repro.train.vision import VisionTrainConfig
from repro.tune import cache as TC
from repro.tune import precision as P


def _tiny_cfg(**over):
    base = dict(model="mobilenet_v2", input_hw=8, num_classes=4, bits=4,
                act_bits=4, float_steps=6, qat_steps=4, calibrate_every=0,
                ckpt_every=0, batch=8)
    base.update(over)
    return VisionTrainConfig(**base)


def _tiny_net(act_bits=8):
    net = mnv2.build(alpha=0.35, input_hw=8, bits=4, num_classes=4)
    return G.with_act_bits(net, act_bits)


# ---------------------------------------------------------------------------
# bugfix regressions
# ---------------------------------------------------------------------------


def test_irb_key_distinguishes_heterogeneous_blocks():
    """Regression: `irb_key` used to key a fused IRB on the PROJECT op's
    act width alone, so a mixed block (expand/dw at 4, project at 8)
    aliased the uniform-8 block's cache entry and could resolve a route
    timed (and verified) on a different numerical workload."""
    def irb(e_bits, d_bits, p_bits):
        return G.BlockSpec("irb0", (
            G.OpSpec("irb0/expand", G.PW, 8, 16, 1, 1, G.RELU6, 4, e_bits),
            G.OpSpec("irb0/dw", G.DW, 16, 16, 3, 1, G.RELU6, 4, d_bits),
            G.OpSpec("irb0/project", G.PW, 16, 8, 1, 1, G.NONE, 4, p_bits),
        ), residual=True)

    uniform8 = TC.irb_key(irb(8, 8, 8), 16, "cpu")
    mixed = TC.irb_key(irb(4, 4, 8), 16, "cpu")
    assert mixed != uniform8  # the aliasing bug
    # every stage width is load-bearing, not just a combined hash
    assert TC.irb_key(irb(4, 8, 8), 16, "cpu") != mixed
    assert TC.irb_key(irb(4, 4, 8), 16, "cpu") == mixed  # deterministic
    assert "a4x4x8" in mixed and "a8x8x8" in uniform8


def test_cache_version_bumped_for_irb_key_change():
    """v1 caches hold irb entries under the aliasing key — they must be
    rejected, not silently resolved."""
    assert TC.CACHE_VERSION == 2
    with pytest.raises(ValueError, match="version"):
        TC.TunedPlan.from_json({"version": 1, "backend": "cpu",
                                "entries": {}})


def test_with_act_bits_name_idempotent():
    """Regression: `with_act_bits` used to append `_act{n}` on every
    application, so re-quantizing an already-quantized spec produced
    `..._act8_act8` names (and unbounded growth under a search loop)."""
    net = mnv2.build(alpha=0.35, input_hw=8, bits=4, num_classes=4)
    once = G.with_act_bits(net, 6)
    twice = G.with_act_bits(once, 6)
    assert once.name == twice.name == f"{net.name}_act6"
    # re-widening replaces the suffix instead of stacking a second one
    assert G.with_act_bits(once, 8).name == f"{net.name}_act8"
    # and the mixed-allocation suffix is stripped the same way
    alloc = {op.name: (4 if i % 2 else 8)
             for i, (_, op) in enumerate(net.all_ops())}
    mixed = G.with_op_act_bits(net, alloc)
    assert mixed.name.startswith(f"{net.name}_actmix")
    assert G.with_act_bits(mixed, 8).name == f"{net.name}_act8"


def test_with_op_act_bits_roundtrip_and_validation():
    net = _tiny_net(8)
    alloc = G.op_act_bits(net)
    assert set(alloc.values()) == {8}
    mixed = dict(alloc)
    for name in list(mixed)[::3]:
        mixed[name] = 4
    net_mix = G.with_op_act_bits(net, mixed)
    assert G.op_act_bits(net_mix) == mixed
    # collapsing back to one width restores the uniform name
    assert G.with_op_act_bits(
        net_mix, {k: 8 for k in mixed}).name == net.name
    with pytest.raises(KeyError, match="nonexistent"):
        G.with_op_act_bits(net, {"nonexistent/op": 8})


def test_build_netspec_applies_op_act_bits():
    """A heterogeneous `.qnet` self-describes: the build record's
    allocation map must reconstruct the exact per-op widths."""
    net = _tiny_net(8)
    alloc = G.op_act_bits(net)
    for name in list(alloc)[: len(alloc) // 2]:
        alloc[name] = 6
    build = {"model": "mobilenet_v2", "alpha": 0.35, "input_hw": 8,
             "bits": 4, "num_classes": 4, "act_bits": 8,
             "op_act_bits": alloc}
    rebuilt = Q.build_netspec(build)
    assert G.op_act_bits(rebuilt) == alloc
    assert rebuilt.name == G.with_op_act_bits(net, alloc).name


def test_train_config_carries_allocation_into_build_record():
    from repro.train import vision as V

    net = _tiny_net(8)
    alloc = G.op_act_bits(net)
    for name in list(alloc)[:5]:
        alloc[name] = 4
    cfg = _tiny_cfg(act_bits=8, op_act_bits=tuple(sorted(alloc.items())))
    assert cfg.alloc == alloc
    rec = V.build_record(cfg)
    assert rec["op_act_bits"] == alloc
    assert G.op_act_bits(V.build_net(cfg)) == alloc
    # anneal phases train at a uniform override width: allocation dropped
    uniform = V.build_net(cfg, act_bits=6)
    assert set(G.op_act_bits(uniform).values()) == {6}


# ---------------------------------------------------------------------------
# latency table + search (fake measure/accuracy: deterministic, train-free)
# ---------------------------------------------------------------------------


def test_latency_table_analytic_fallback_then_coverage():
    from repro.energy import PowerModel

    net = _tiny_net(8)
    empty = TC.TunedPlan(backend="cpu", nets=(), tuned_batch=2, entries={})
    table = P.LatencyTable(
        empty, PowerModel(busy_w=10.0, idle_w=2.0, source="test"), "cpu")
    cost = table.net_cost(net)
    assert cost.n_tuned == 0 and cost.missing  # all analytic
    assert cost.us_per_image > 0
    table = P.ensure_coverage(table, [net], measure=P.fake_measure,
                              batch=2)
    cost2 = table.net_cost(net)
    assert not cost2.missing and cost2.tuned_fraction == 1.0


def test_block_allocation_expands_and_validates():
    net = _tiny_net(8)
    alloc = P.block_allocation(net, {"irb3": 4})
    assert set(alloc) == {op.name for op in
                          next(b for b in net.blocks
                               if b.name == "irb3").ops}
    assert set(alloc.values()) == {4}
    with pytest.raises(KeyError, match="irb99"):
        P.block_allocation(net, {"irb99": 4})


def _fake_search(**over):
    kw = dict(choices=(4, 6, 8), backend="cpu",
              accuracy_fn=P.fake_accuracy, measure=P.fake_measure,
              ladder_budget=3, tune_batch=2)
    kw.update(over)
    return P.search_precision(_tiny_cfg(), **kw)


@pytest.fixture(scope="module")
def fake_result():
    return P.search_precision(
        _tiny_cfg(), choices=(4, 6, 8), backend="cpu",
        accuracy_fn=P.fake_accuracy, measure=P.fake_measure,
        ladder_budget=3, tune_batch=2)


def test_search_produces_uniform_anchors_and_mixed_points(fake_result):
    names = [p.name for p in fake_result.points]
    assert {"uniform4", "uniform6", "uniform8"} <= set(names)
    assert any(n.startswith("mix") for n in names)
    # uniform anchors carry their width; mixed points don't
    by_name = {p.name: p for p in fake_result.points}
    assert by_name["uniform8"].uniform == 8
    mixed = next(p for p in fake_result.points if p.uniform is None)
    assert len(set(mixed.alloc.values())) > 1
    # per-block granularity: every block is internally uniform
    net = Q.build_netspec(
        {**fake_result.build, "op_act_bits": mixed.alloc})
    for block in net.blocks:
        assert len({op.act_bits for op in block.ops}) == 1, block.name


def test_search_is_deterministic(fake_result):
    again = _fake_search()
    assert [p.as_dict() for p in again.points] == \
        [p.as_dict() for p in fake_result.points]
    assert again.front == fake_result.front


def test_artifact_roundtrip_and_schema_gate(fake_result, tmp_path):
    path = str(tmp_path / "pareto.json")
    P.write_pareto(fake_result, path)
    P.check_pareto_artifact(path)  # passes
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == P.PARETO_SCHEMA
    assert len(doc["pareto"]) >= 3
    # tampering with the recorded front must be caught
    doc["pareto"] = doc["pareto"][:1]
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(ValueError, match="front"):
        P.check_pareto_artifact(path)


def test_pareto_front_drops_dominated_points(fake_result):
    pts = list(fake_result.points)
    worst = dataclasses.replace(
        pts[0], name="strictly_worse", accuracy=0.0,
        us_per_image=pts[0].us_per_image * 10,
        j_per_image=pts[0].j_per_image * 10,
        model_bytes=pts[0].model_bytes + 1)
    front = P.pareto_front(pts + [worst])
    assert all(p.name != "strictly_worse" for p in front)
    assert P.dominates(pts[0], worst)
    assert not P.dominates(worst, pts[0])
    assert not P.dominates(pts[0], pts[0])


def test_export_searched_allocation_passes_conformance(fake_result,
                                                       tmp_path):
    """The CI smoke contract: a searched mixed allocation exports through
    the REAL QAT fine-tune + 4-route `verify_export` gate and the artifact
    reloads with the exact searched widths."""
    cfg = _tiny_cfg()
    point = next(p for p in fake_result.points if p.uniform is None)
    path = str(tmp_path / "mixed.qnet")
    impl = P.QATFinetuneAccuracy(cfg, steps=0)
    report = P.export_point(cfg, point, path, accuracy_impl=impl)
    assert {"reference", "prepared", "stage-executors"} <= \
        set(report["routes"])
    meta = Q.read_qnet_meta(path)
    assert meta["build"]["op_act_bits"] == {
        k: v for k, v in point.alloc.items()}
    qnet = Q.load_qnet(path)
    assert G.op_act_bits(qnet.spec) == point.alloc


def test_find_domination_semantics():
    def pt(name, uniform, acc, us, nbytes):
        return P.PrecisionPoint(
            name=name, block_bits={}, alloc={}, uniform=uniform,
            accuracy=acc, us_per_image=us, model_bytes=nbytes,
            j_per_image=1.0, edp=1.0, tuned_fraction=1.0)

    u8 = pt("uniform8", 8, 0.90, 100.0, 500)
    faster = pt("mix_a", None, 0.90, 80.0, 500)
    slower = pt("mix_b", None, 0.95, 120.0, 500)
    assert P.find_domination([u8, slower, faster]) == ("mix_a", "uniform8")
    assert P.find_domination([u8, slower]) is None


def test_committed_pareto_artifact_is_valid():
    """The committed MobileNetV2/cpu artifact satisfies the acceptance
    bar: schema-clean, >= 3 non-dominated points, and at least one mixed
    allocation strictly dominates a uniform one on (latency, model bytes)
    at equal-or-better accuracy."""
    import os
    path = P.pareto_path("mobilenet_v2", "cpu")
    if not os.path.exists(path):
        pytest.skip("committed artifact absent (pre-generation tree)")
    P.check_pareto_artifact(path, min_points=3, require_domination=True)
