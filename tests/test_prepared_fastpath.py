"""PreparedQNet integer fast path: bit-exactness, zero per-call host
uploads, trace-count stability, integer residual, differential property
fuzz over random NetSpecs, and the quantized_linear block-size
regressions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import cu, graph as G
from repro.core.integer_ops import (
    f32_accum_exact,
    int_conv2d,
    int_depthwise_shifts,
    int_residual_add,
    residual_fixed_consts,
)
from repro.models import efficientnet as effn, layers, mobilenet_v2 as mnv2
from repro.serve.vision import VisionEngine

HW = 32


def _make_qnet(net, seed=0):
    return layers.make_calibrated_qnet(net, seed=seed)


@pytest.fixture(scope="module")
def mnv2_qnet():
    return _make_qnet(mnv2.build(alpha=0.35, input_hw=HW, num_classes=10))


@pytest.fixture(scope="module")
def effnet_qnet():
    return _make_qnet(effn.build_compact(input_hw=HW, num_classes=10))


def _images(n, seed=7):
    return np.asarray(jax.random.uniform(
        jax.random.PRNGKey(seed), (n, HW, HW, 3), minval=-1, maxval=1))


# ---------------------------------------------------------------------------
# integer fast-path formulations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,s", [(3, 1), (3, 2), (5, 1), (5, 2)])
def test_depthwise_shifts_matches_int_conv(k, s):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 256, (2, 11, 13, 16)), jnp.int32)
    w = jnp.asarray(rng.integers(-127, 128, (k, k, 16)), jnp.int32)
    got = int_depthwise_shifts(x, w, stride=s)
    ref = int_conv2d(x, w.reshape(k, k, 1, 16), stride=s, groups=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_f32_accum_exact_bound():
    # 4-bit weights, tiny reduction: trivially exact
    assert f32_accum_exact(np.full((16, 8), 7, np.int8), 15)
    # adversarial: bound 255 * 127 * 600 > 2^24 must be rejected
    assert not f32_accum_exact(np.full((600, 4), 127, np.int8), 255)


def test_integer_residual_add_close_to_float():
    """14-bit mantissa skip-add tracks the float rescale within 1 LSB."""
    rng = np.random.default_rng(1)
    a_q = jnp.asarray(rng.integers(0, 16, (256,)), jnp.int32)
    b_q = jnp.asarray(rng.integers(0, 16, (256,)), jnp.int32)
    a_s, a_z, b_s, b_z, y_s, y_z = 0.11, -1.7, 0.27, 0.9, 0.31, -0.4
    consts = residual_fixed_consts(a_s, a_z, b_s, b_z, y_s, y_z)
    got = int_residual_add(a_q, b_q, consts, qmax=15)
    a = (a_q.astype(jnp.float32) + a_z) * (a_s / y_s)
    b = (b_q.astype(jnp.float32) + b_z) * (b_s / y_s)
    ref = jnp.clip(jnp.round(a + b) - round(y_z), 0, 15).astype(jnp.int32)
    assert int(jnp.abs(got - ref).max()) <= 1
    assert 0 <= int(got.min()) and int(got.max()) <= 15


# ---------------------------------------------------------------------------
# PreparedQNet: bit-exactness + device residency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qnet_fixture", ["mnv2_qnet", "effnet_qnet"])
def test_prepared_run_qnet_bit_exact(qnet_fixture, request):
    qnet = request.getfixturevalue(qnet_fixture)
    pq = cu.prepare_qnet(qnet)
    x = jnp.asarray(_images(3))
    ref = np.asarray(cu.run_qnet(qnet, x))
    fast = np.asarray(cu.run_qnet(pq, x))
    np.testing.assert_array_equal(ref, fast)


def test_prepared_fixed_point_consistent(mnv2_qnet):
    pq = cu.prepare_qnet(mnv2_qnet)
    x = jnp.asarray(_images(2))
    ref = np.asarray(cu.run_qnet(mnv2_qnet, x, fixed_point=True))
    fast = np.asarray(cu.run_qnet(pq, x, fixed_point=True))
    np.testing.assert_array_equal(ref, fast)


def test_prepare_is_idempotent(mnv2_qnet):
    pq = cu.prepare_qnet(mnv2_qnet)
    assert cu.prepare_qnet(pq) is pq


def test_prepared_constants_are_device_arrays(mnv2_qnet):
    """Every constant a stage trace closes over is already a jax.Array —
    nothing left for jit to upload from host numpy at trace time."""
    pq = cu.prepare_qnet(mnv2_qnet)
    for pop in pq.ops.values():
        for field in ("w_q", "w_kern", "wsum", "bias_q", "mult", "zcorr",
                      "zpc", "z_x", "mantissa", "shift", "w_scale"):
            assert isinstance(getattr(pop, field), jax.Array), field
    for consts in pq.res_fixed.values():
        assert all(isinstance(c, int) for c in consts)


def test_stage_hot_loop_has_no_host_uploads(mnv2_qnet):
    """After warmup, serving micro-batches must not convert host numpy
    arrays (weights / requant constants) — only the input image enters via
    the engine. Monkeypatch-counts np.ndarray -> jnp conversions."""
    eng = VisionEngine(mnv2_qnet, buckets=(2,))
    eng.warmup()
    real_asarray = jnp.asarray
    uploads = []

    def counting_asarray(x, *a, **k):
        if isinstance(x, np.ndarray) and x.ndim > 0:
            uploads.append(x.shape)
        return real_asarray(x, *a, **k)

    jnp.asarray = counting_asarray
    try:
        for img in _images(4):
            eng.submit(img)
        eng.run()
    finally:
        jnp.asarray = real_asarray
    # the only host->device transfers are the micro-batch images themselves
    assert uploads == [(2, HW, HW, 3), (2, HW, HW, 3)], uploads


def test_stage_trace_count_stays_one_per_bucket(mnv2_qnet):
    eng = VisionEngine(mnv2_qnet, buckets=(2,))
    eng.warmup()
    for img in _images(8):
        eng.submit(img)
    eng.run()
    for img in _images(4, seed=9):
        eng.submit(img)
    eng.run()
    assert all(s.traces == 1 for s in eng.stages)  # one bucket -> one trace


def test_prepared_stages_bit_exact_with_reference_stages(mnv2_qnet):
    imgs = _images(5)
    fast = VisionEngine(mnv2_qnet, buckets=(1, 2, 4))
    slow = VisionEngine(mnv2_qnet, buckets=(1, 2, 4), prepare=False,
                        op_kernels="off", body_fast_path="off")
    out = {}
    for name, eng in (("fast", fast), ("slow", slow)):
        rids = [eng.submit(img) for img in imgs]
        res = eng.run()
        out[name] = np.stack([res[r].logits for r in rids])
    np.testing.assert_array_equal(out["fast"], out["slow"])


def test_op_kernels_flag_validation(mnv2_qnet):
    with pytest.raises(ValueError, match="op_kernels"):
        VisionEngine(mnv2_qnet, buckets=(1,), op_kernels="maybe")
    with pytest.raises(ValueError, match="fixed_point"):
        VisionEngine(mnv2_qnet, buckets=(1,), op_kernels="on",
                     fixed_point=True)


# ---------------------------------------------------------------------------
# differential property fuzz: random NetSpecs, fast path vs reference
# ---------------------------------------------------------------------------


ACT_CHOICES = (4, 6, 8)  # widths the mixed-precision search draws from


def _mixed_act_bits(net: G.NetSpec, plan: int) -> G.NetSpec:
    """Deterministically scatter per-op act bits from ACT_CHOICES over the
    net (plan is a base-3 digit stream), keeping the stem at 8 like the
    model builders do. plan=0 leaves the net uniform."""
    if plan == 0:
        return net
    alloc = {}
    for i, (_, op) in enumerate(net.all_ops()):
        alloc[op.name] = ACT_CHOICES[(plan >> (2 * i)) % len(ACT_CHOICES)]
    return G.with_op_act_bits(net, alloc)


def _rand_netspec(stem_ch: int, n_body: int, expand: int, kernel: int,
                  stride: int, bits: int, body_ch: int) -> G.NetSpec:
    """A small compile_net-compatible net: CONV stem -> IRB-ish body blocks
    (mixed DW kernel/stride, optional expansion, residual where shapes
    allow) -> PW+avgpool tail -> DENSE classifier."""
    blocks = [G.BlockSpec("stem", (
        G.OpSpec("stem/conv", G.CONV, 3, stem_ch, 3, 2, G.RELU6, 8, bits),))]
    in_ch = stem_ch
    for i in range(n_body + 1):  # +1: first IRB completes the Head
        name = f"irb{i}"
        ops = []
        hidden = in_ch * expand
        if expand != 1:
            ops.append(G.OpSpec(f"{name}/expand", G.PW, in_ch, hidden, 1, 1,
                                G.RELU6, bits, bits))
        # stride/kernel only vary on the first body block so later blocks
        # keep stride 1 and can exercise the residual skip-line
        s = stride if i == 0 else 1
        ops.append(G.OpSpec(f"{name}/dw", G.DW, hidden, hidden, kernel, s,
                            G.RELU6, bits, bits))
        ops.append(G.OpSpec(f"{name}/project", G.PW, hidden, body_ch, 1, 1,
                            G.NONE, bits, bits))
        residual = s == 1 and in_ch == body_ch
        blocks.append(G.BlockSpec(name, tuple(ops), residual=residual))
        in_ch = body_ch
    blocks.append(G.BlockSpec("tail", (
        G.OpSpec("tail/pw", G.PW, in_ch, 2 * body_ch, 1, 1, G.RELU6, bits,
                 bits),), avgpool=True))
    blocks.append(G.BlockSpec("classifier", (
        G.OpSpec("classifier/fc", G.DENSE, 2 * body_ch, 7, 1, 1, G.NONE,
                 bits, bits),)))
    return G.NetSpec(name="fuzz", blocks=tuple(blocks), input_hw=16,
                     num_classes=7)


@settings(max_examples=6, deadline=None)
@given(
    stem_ch=st.sampled_from([8, 16]),
    n_body=st.integers(1, 2),
    expand=st.sampled_from([1, 2]),
    kernel=st.sampled_from([3, 5]),
    stride=st.sampled_from([1, 2]),
    bits=st.sampled_from([4, 8]),
    body_ch=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
    act_plan=st.integers(0, 2**20),
)
def test_fuzz_fast_path_matches_reference(stem_ch, n_body, expand, kernel,
                                          stride, bits, body_ch, seed,
                                          act_plan):
    """Differential property: for random small NetSpecs (mixed DW kernel /
    stride / 5x5 / residual / PER-OP heterogeneous act bits from {4,6,8}),
    the PreparedQNet fast path — eager AND jitted, float AND fixed-point
    requant — is bit-exact with the reference interpreter, and the full
    `verify_export` route chain (reference / prepared / stage executors /
    engine kernels) agrees bitwise. Catches per-op formulation drift (e.g.
    f32 reassociation under jit, requant chained at the wrong input width)
    that the two fixed model topologies miss."""
    from repro.train.vision import verify_export

    net = _mixed_act_bits(
        _rand_netspec(stem_ch, n_body, expand, kernel, stride, bits,
                      body_ch), act_plan)
    qnet = _make_qnet(net, seed=seed % 7)
    pq = cu.prepare_qnet(qnet)
    x = jnp.asarray(np.asarray(jax.random.uniform(
        jax.random.PRNGKey(seed), (2, 16, 16, 3), minval=-1, maxval=1)))
    ref = np.asarray(cu.run_qnet(qnet, x))
    np.testing.assert_array_equal(ref, np.asarray(cu.run_qnet(pq, x)))
    np.testing.assert_array_equal(
        ref, np.asarray(jax.jit(lambda t: cu.run_qnet(pq, t))(x)))
    ref_fx = np.asarray(cu.run_qnet(qnet, x, fixed_point=True))
    np.testing.assert_array_equal(
        ref_fx, np.asarray(cu.run_qnet(pq, x, fixed_point=True)))
    np.testing.assert_array_equal(
        ref_fx,
        np.asarray(jax.jit(
            lambda t: cu.run_qnet(pq, t, fixed_point=True))(x)))
    # 4-route conformance chain on the heterogeneous net (raises on drift)
    report = verify_export(qnet, np.asarray(x))
    assert {"reference", "prepared", "stage-executors",
            "engine"} <= set(report["routes"])


# ---------------------------------------------------------------------------
# quantized_linear block-size regressions
# ---------------------------------------------------------------------------


def test_quantized_linear_blockn_not_whole_n():
    """N=192 (not a multiple of 128) used to become ONE 192-wide block;
    now it tiles with the largest divisor <= 128 (96) and stays correct."""
    from repro.kernels import ref as kref
    from repro.kernels.ops import quantize_weight_for_matmul, quantized_linear

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    wfp = jnp.asarray(rng.normal(size=(64, 192)), jnp.float32)
    wq, sc = quantize_weight_for_matmul(wfp, bits=8)
    y = quantized_linear(x, wq, sc, bits=8)
    yr = kref.quant_matmul_ref(x, wq, sc, group_size=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-3)


def test_quantized_linear_non_pow2_group():
    """K and group without a power-of-two relationship to 512 still pick a
    valid block_k (the search may no longer crash or emit block 0)."""
    from repro.kernels import ref as kref
    from repro.kernels.ops import quantized_linear

    rng = np.random.default_rng(1)
    k, n, g = 96, 32, 6  # group = 16
    x = jnp.asarray(rng.normal(size=(8, k)), jnp.float32)
    wq = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    sc = jnp.asarray(rng.uniform(0.005, 0.02, (g, n)), jnp.float32)
    y = quantized_linear(x, wq, sc, bits=8)
    yr = kref.quant_matmul_ref(x, wq, sc, group_size=k // g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-3)


def test_quantized_linear_degenerate_groups_raise_cleanly():
    """G > K means group == 0: previously a ZeroDivisionError from `k % 0`,
    now the shape error surfaces as quant_matmul's ValueError."""
    from repro.kernels.ops import quantized_linear

    x = jnp.ones((4, 2), jnp.float32)
    wq = jnp.ones((2, 8), jnp.int8)
    sc = jnp.ones((4, 8), jnp.float32)  # 4 scale groups for K=2
    with pytest.raises(ValueError):
        quantized_linear(x, wq, sc, bits=8)
