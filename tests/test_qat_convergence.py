"""E2E front-end flow (paper Fig. 1/4): QAT -> calibrate -> QNet -> integer
inference preserves accuracy (Fig. 13a: UInt4 ~= FP32 after QAT)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cu, qnet as Q
from repro.core.calibrate import calibrate
from repro.core.quant import QuantConfig
from repro.data.pipeline import image_batch
from repro.models import layers, mobilenet_v2 as mnv2
from repro.train import optimizer as O

HW, CLASSES = 16, 4


def _net():
    return mnv2.build(alpha=0.35, input_hw=HW, num_classes=CLASSES)


def _train(net, params, steps, qat, lr=2e-3, seed=0):
    ocfg = O.AdamWConfig(lr=lr, warmup_steps=5, total_steps=steps,
                         weight_decay=0.0)
    opt = O.init_state(params)

    @jax.jit
    def step(params, opt, images, labels):
        def loss_fn(p):
            logits, _ = layers.forward(p, images, net, qat=qat)
            lp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(lp, labels[:, None], 1).mean()

        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = O.apply_updates(params, g, opt, ocfg)
        return params, opt, loss

    for s in range(steps):
        b = image_batch(seed, s, 32, HW, CLASSES)
        params, opt, loss = step(params, opt, jnp.asarray(b["images"]),
                                 jnp.asarray(b["labels"]))
    return params


def _accuracy(fn, seed=99, n=4):
    correct = total = 0
    for s in range(n):
        b = image_batch(seed, s, 32, HW, CLASSES)
        pred = fn(jnp.asarray(b["images"]))
        correct += int((np.asarray(pred) == b["labels"]).sum())
        total += len(b["labels"])
    return correct / total


def test_qat_pipeline_fast_deterministic():
    """Tier-1 stand-in for the slow convergence run below: a fixed-seed
    micro-schedule through the training subsystem (float+BN -> fuse -> QAT)
    must reduce loss AND reproduce bitwise run-to-run — the determinism the
    nightly convergence run (and the checkpoint-restart contract) rests on."""
    from repro.train import vision as V

    cfg = V.VisionTrainConfig(
        model="mobilenet_v2", alpha=0.35, input_hw=HW, num_classes=CLASSES,
        float_steps=3, qat_steps=2, batch=8)
    a = V.train(cfg)
    b = V.train(cfg)
    assert a.history["loss"] == b.history["loss"]
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert a.history["loss"][-1] < a.history["loss"][0]
    assert np.isfinite(a.history["loss"]).all()


@pytest.mark.slow
def test_qat_to_integer_qnet_preserves_accuracy():
    net = _net()
    params = layers.init_params(jax.random.PRNGKey(0), net)
    # stage 1: float pre-training, stage 2: online quantization (QAT)
    params = _train(net, params, steps=120, qat=False)
    params = _train(net, params, steps=60, qat=True, lr=5e-4)

    acc_float = _accuracy(
        lambda x: jnp.argmax(layers.forward(params, x, net)[0], -1))
    assert acc_float > 0.6, f"float model failed to learn: {acc_float}"

    # calibration + post-training quantization -> QNet
    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]

    batches = [jnp.asarray(image_batch(1, s, 32, HW, CLASSES)["images"])
               for s in range(4)]
    obs = calibrate(apply_fn, params, batches, QuantConfig(4, False, None))
    qn = Q.quantize_net(params, net, obs)

    acc_int = _accuracy(lambda x: jnp.argmax(cu.run_qnet(qn, x), -1))
    # Fig. 13a: 4-bit QAT tracks float accuracy closely
    assert acc_int >= acc_float - 0.15, (acc_float, acc_int)

    # Fig. 13b: and the deployed model is ~8x smaller
    fp32_bytes = net.n_params(with_bias=False) * 4
    assert fp32_bytes / qn.model_bytes() > 4.0
