"""Integer QNet execution: per-op exactness, fixed-point requant, save/load."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cu, graph as G, qnet as Q
from repro.core.calibrate import ActObserver, relu6_fused_qparams
from repro.core.integer_ops import (
    quantize_multiplier, requantize_fixedpoint, requantize_float)
from repro.core.quant import QuantConfig, compute_scale_zp, dequantize
from repro.models import layers


def _quantize_one_op(op, p, x, bits_in=8):
    in_cfg = QuantConfig(bits_in, symmetric=False)
    s_in, z_in = compute_scale_zp(jnp.asarray(0.0), jnp.asarray(6.0), in_cfg)
    x_q = cu.quantize_input(x, float(s_in), float(z_in), bits_in)
    x_deq = (x_q.astype(jnp.float32) + float(z_in)) * float(s_in)
    y_fp = layers._apply_op(x_deq, op, p, qat=False)
    obs = {op.name: ActObserver.init(()).update(y_fp, QuantConfig(op.act_bits, False, None))}
    qops = {}
    Q._quantize_op(qops, {op.name: p}, op, float(s_in), float(z_in), obs)
    return qops[op.name], x_q, x_deq


@pytest.mark.parametrize("kind,act", [
    (G.CONV, G.RELU6), (G.DW, G.RELU6), (G.PW, G.RELU6),
    (G.PW, G.NONE), (G.DENSE, G.NONE),
])
def test_integer_op_matches_float_within_one_lsb(kind, act):
    key = jax.random.PRNGKey(0)
    if kind == G.DW:
        op = G.OpSpec("op", kind, 16, 16, 3, 1, act, 4, 4)
    elif kind in (G.CONV,):
        op = G.OpSpec("op", kind, 16, 32, 3, 1, act, 4, 4)
    else:
        op = G.OpSpec("op", kind, 16, 32, 1, 1, act, 4, 4)
    p = layers.init_op_params(key, op)
    x = jax.random.uniform(
        key, (4, op.in_ch) if kind == G.DENSE else (2, 8, 8, op.in_ch),
        minval=0, maxval=6)
    qop, x_q, x_deq = _quantize_one_op(op, p, x)
    y_int = cu._run_qop(x_q, qop, fixed_point=False)
    y_int_deq = (y_int.astype(jnp.float32) + round(qop.out_zp)) * qop.out_scale
    wcfg = QuantConfig(4, True, -1)
    w_deq = dequantize(jnp.asarray(qop.w_q, jnp.int32), jnp.asarray(qop.w_scale),
                       jnp.zeros_like(jnp.asarray(qop.w_scale)), wcfg)
    y_ref = layers._apply_op(x_deq, op, {"w": w_deq, "b": p["b"]}, qat=False)
    # two independent roundings (requant multiplier + folded bias) -> <= 1 LSB
    assert float(jnp.abs(y_int_deq - y_ref).max()) <= qop.out_scale * 1.01


def test_fixed_point_requant_matches_float():
    """The FPGA 'Approximator' (int mantissa + shift) == float multiplier."""
    rng = np.random.default_rng(0)
    acc = jnp.asarray(rng.integers(-(2**20), 2**20, (256,)), jnp.int32)
    mult = rng.uniform(1e-5, 0.5, (256,))
    mant, shift = quantize_multiplier(mult)
    y_float = requantize_float(acc, jnp.asarray(mult, jnp.float32))
    with jax.experimental.enable_x64():
        y_fxp = requantize_fixedpoint(
            acc.astype(jnp.int64), jnp.asarray(mant), jnp.asarray(shift))
    # mantissa has 31 bits: agree within 1 ULP of the requantized grid
    assert int(jnp.abs(y_float - y_fxp.astype(jnp.int32)).max()) <= 1


def test_relu6_fusion_is_integer_clip():
    """h^pq: [0,6] -> [0, 2^BW-1]; integer clip == ReLU6 after dequant."""
    cfg = QuantConfig(4, symmetric=False)
    s, z = relu6_fused_qparams(cfg)
    xs = jnp.linspace(-2, 8, 101)
    q = jnp.clip(jnp.round(xs / s - z), 0, cfg.qmax)
    deq = (q + z) * s
    relu6 = jnp.clip(xs, 0, 6)
    assert float(jnp.abs(deq - relu6).max()) <= float(s) * 0.5 + 1e-6


def test_qnet_save_load_roundtrip(tmp_path):
    from repro.models import mobilenet_v2 as mnv2
    from repro.core.calibrate import calibrate

    net = mnv2.build(alpha=0.35, input_hw=32, num_classes=10)
    params = layers.init_params(jax.random.PRNGKey(0), net)

    def apply_fn(p, b):
        return layers.forward(p, b, net, capture=True)[1]

    batches = [jax.random.uniform(jax.random.PRNGKey(i), (2, 32, 32, 3),
                                  minval=-1, maxval=1) for i in range(2)]
    obs = calibrate(apply_fn, params, batches, QuantConfig(4, False, None))
    qn = Q.quantize_net(params, net, obs)
    path = str(tmp_path / "qnet.bin")
    Q.save_qnet(qn, path)
    qn2 = Q.load_qnet(path, net)
    x = batches[0]
    y1 = cu.run_qnet(qn, x)
    y2 = cu.run_qnet(qn2, x)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert qn2.res_q == qn.res_q


def test_qnet_model_size_compression():
    """Fig 13b: BW=4 model ~8x smaller than FP32 weights."""
    from repro.models import mobilenet_v2 as mnv2
    net = mnv2.build(alpha=0.35, input_hw=32, num_classes=10)
    fp32_bytes = net.n_params(with_bias=False) * 4
    q_bytes = net.model_bits(with_bias=False) / 8
    assert 7.0 < fp32_bytes / q_bytes <= 8.01
