"""Unit + property tests for core/quant.py (range-based linear quantization)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.quant import (
    QuantConfig, compute_scale_zp, dequantize, fake_quant, fake_quant_minmax,
    observe_range, pack_int4, packed_nbytes, quantize, unpack_int4,
)


@pytest.mark.parametrize("bits", [3, 4, 5, 6, 8])
@pytest.mark.parametrize("symmetric", [False, True])
def test_roundtrip_error_bound(bits, symmetric):
    """|dequant(quant(x)) - x| <= S/2 for x inside the observed range."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-3, 5, (64, 32)), jnp.float32)
    cfg = QuantConfig(bits, symmetric=symmetric, channel_axis=None)
    mn, mx = observe_range(x, cfg)
    s, z = compute_scale_zp(mn, mx, cfg)
    q = quantize(x, s, z, cfg)
    xr = dequantize(q, s, z, cfg)
    assert float(jnp.abs(xr - x).max()) <= float(s) * 0.5 + 1e-6


def test_asymmetric_maps_min_to_zero_max_to_qmax():
    """The paper's asymmetric mode: min -> 0, max -> 2^BW - 1."""
    cfg = QuantConfig(4, symmetric=False)
    x = jnp.asarray([0.0, 1.5, 6.0])
    mn, mx = observe_range(x, cfg)
    s, z = compute_scale_zp(mn, mx, cfg)
    q = quantize(x, s, z, cfg)
    assert int(q[0]) == 0 and int(q[-1]) == cfg.qmax == 15


def test_zero_is_exact():
    """x == 0.0 must be exactly representable (zero-point requirement)."""
    cfg = QuantConfig(4, symmetric=False)
    x = jnp.asarray([-0.7, 0.0, 2.3])
    mn, mx = observe_range(x, cfg)
    s, z = compute_scale_zp(mn, mx, cfg)
    q = quantize(x, s, z, cfg)
    xr = dequantize(q, s, z, cfg)
    assert float(jnp.abs(xr[1])) == 0.0


def test_per_channel_independent_scales():
    cfg = QuantConfig(4, symmetric=True, channel_axis=-1)
    x = jnp.stack([jnp.linspace(-1, 1, 32), jnp.linspace(-100, 100, 32)], -1)
    mn, mx = observe_range(x, cfg)
    s, _ = compute_scale_zp(mn, mx, cfg)
    assert s.shape == (2,)
    assert float(s[1]) > 50 * float(s[0])


def test_ste_gradient_clips_out_of_range():
    cfg = QuantConfig(4, symmetric=False)
    s, z = jnp.asarray(0.1), jnp.asarray(0.0)

    def f(x):
        return fake_quant(x, s, z, cfg).sum()

    g = jax.grad(f)(jnp.asarray([0.5, 100.0, -5.0]))
    assert float(g[0]) == 1.0  # in range: pass-through
    assert float(g[1]) == 0.0  # above range: clipped
    assert float(g[2]) == 0.0  # below range: clipped


def test_pack_unpack_int4_roundtrip():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.integers(0, 16, (8, 6)), jnp.int32)
    assert (unpack_int4(pack_int4(q)) == q).all()
    qs = jnp.asarray(rng.integers(-8, 8, (4, 10)), jnp.int32)
    packed = pack_int4(jnp.where(qs < 0, qs + 16, qs))
    assert (unpack_int4(packed, signed=True) == qs).all()


def test_packed_nbytes_model_size():
    """Fig 13b: BW=4 -> 8x smaller than FP32."""
    shape = (1000, 32)
    assert packed_nbytes(shape, 4) * 8 == packed_nbytes(shape, 32)


@settings(max_examples=50, deadline=None)
@given(
    bits=st.sampled_from([3, 4, 5, 6, 8]),
    lo=st.floats(-100, 0, allow_nan=False),
    span=st.floats(0.01, 200, allow_nan=False),
)
def test_property_quantized_values_in_range(bits, lo, span):
    cfg = QuantConfig(bits, symmetric=False)
    x = jnp.linspace(lo, lo + span, 128, dtype=jnp.float32)
    mn, mx = observe_range(x, cfg)
    s, z = compute_scale_zp(mn, mx, cfg)
    q = quantize(x, s, z, cfg)
    assert int(q.min()) >= cfg.qmin and int(q.max()) <= cfg.qmax


@settings(max_examples=30, deadline=None)
@given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
def test_property_fake_quant_idempotent(bits, seed):
    """fake_quant(fake_quant(x)) == fake_quant(x)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    cfg = QuantConfig(bits, symmetric=True)
    y1 = fake_quant_minmax(x, cfg)
    y2 = fake_quant_minmax(y1, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=0, atol=1e-6)
