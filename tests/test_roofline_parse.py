"""Roofline HLO parsing: shape bytes, collective operand accounting."""
import pytest

from repro.launch import roofline as RL


def test_shape_bytes():
    assert RL.shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert RL.shape_bytes("bf16[8]") == 16
    assert RL.shape_bytes("(f32[2,2], s8[4])") == 16 + 4
    assert RL.shape_bytes("pred[]") == 1
    assert RL.shape_bytes("token[]") == 0


def test_collective_bytes_sums_operands():
    hlo = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = bf16[64]{0} parameter(1)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = bf16[128]{0} all-gather(%p1), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
  ROOT %t = (f32[128,256]{1,0}) tuple(%cp)
}
"""
    out = RL.collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 64 * 2
    assert out["collective-permute"] == 128 * 256 * 4
    assert out["n_ops"] == 3


def test_roofline_terms_and_bottleneck():
    r = RL.Roofline(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=50e9 * 0.5,
                    coll_detail={}, n_devices=256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.t_bound == pytest.approx(2.0)


def test_model_flops_modes():
    from repro.configs import get_config
    from repro.models.lm.config import SHAPES

    cfg = get_config("llama3.2-1b")
    n = cfg.param_count()
    train = next(s for s in SHAPES if s.name == "train_4k")
    dec = next(s for s in SHAPES if s.name == "decode_32k")
    assert RL.model_flops(cfg, train, n) == 6.0 * n * 256 * 4096
    assert RL.model_flops(cfg, dec, n) == 2.0 * n * 128


def test_moe_active_params_much_smaller_than_total():
    from repro.configs import get_config
    cfg = get_config("arctic-480b")
    total, active = cfg.param_count(), cfg.active_param_count()
    assert total > 400e9, total  # it really is a ~480B config
    assert active < 30e9, active  # top-2 of 128 experts + dense residual
