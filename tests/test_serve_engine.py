"""Serving engine: batched greedy decode == manual decode loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models.lm import model as M
from repro.serve.engine import Engine, Request


def _manual_greedy(params, cfg, prompt, max_new, max_len):
    tokens = jnp.asarray(prompt)[None, :]
    logits, cache = M.prefill(params, cfg, tokens, max_len=max_len)
    out = []
    cur = int(jnp.argmax(logits[0, 0]))
    out.append(cur)
    pos = tokens.shape[1]
    for _ in range(max_new - 1):
        logits, cache = M.decode_step(
            params, cfg, jnp.asarray([[cur]], jnp.int32), cache,
            jnp.int32(pos))
        pos += 1
        cur = int(jnp.argmax(logits[0, 0]))
        out.append(cur)
    return out


def test_engine_matches_manual_greedy():
    cfg = reduced_config("llama3.2-1b")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    eng = Engine(cfg, params, batch_slots=1, max_len=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new=5, temperature=0.0))
    done = eng.run()
    manual = _manual_greedy(params, cfg, prompt, 5, 32)
    assert done[0] == manual


def test_engine_batches_multiple_requests():
    cfg = reduced_config("llama3.2-1b")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    eng = Engine(cfg, params, batch_slots=4, max_len=32)
    for i in range(6):  # > slots: two batches
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                           max_new=4))
    done = eng.run()
    assert sorted(done) == list(range(6))
    assert all(len(v) == 4 for v in done.values())


def test_engine_same_prompt_same_output_across_batches():
    """Batched decoding must not cross-contaminate slots."""
    cfg = reduced_config("llama3.2-1b")
    params, _ = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab, 5).astype(np.int32)
    other = rng.integers(0, cfg.vocab, 5).astype(np.int32)
    eng = Engine(cfg, params, batch_slots=2, max_len=32)
    eng.submit(Request(rid=0, prompt=p, max_new=6))
    eng.submit(Request(rid=1, prompt=other, max_new=6))
    done_a = eng.run()
    eng.submit(Request(rid=2, prompt=p, max_new=6))
    eng.submit(Request(rid=3, prompt=np.flip(other).copy(), max_new=6))
    done_b = eng.run()
    assert done_a[0] == done_b[2]
